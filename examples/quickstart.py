"""Quickstart: build an Ada-ef index, then search it declaratively.

The whole public knob surface is one immutable ``SearchSpec`` — say *what*
you need (k results at a target recall) and the planner lowers it into a
cached ``ExecutionPlan`` that picks the loop strategy, kernel dispatch,
estimation budget, tier ladder and batching policy for you.
``plan.explain()`` prints every derived decision, DB-EXPLAIN style.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.api import SearchSpec
from repro.index import (
    brute_force_topk,
    build_ada_index,
    prepare_database,
    prepare_queries,
    recall_at_k,
)


def main():
    # --- data: 8k vectors in 10 Zipf-skewed clusters (paper §7.1 style) -----
    rng = np.random.default_rng(0)
    n, d, nq, k = 8000, 64, 256, 10
    nc = 50
    w = 1.0 / np.arange(1, nc + 1)
    w /= w.sum()
    centers = rng.normal(0, 1, (nc, d))
    data = (centers[rng.choice(nc, n, p=w)] + 0.25 * rng.normal(0, 1, (n, d))).astype(np.float32)
    queries = (centers[rng.choice(nc, nq, p=w)] + 0.25 * rng.normal(0, 1, (nq, d))).astype(np.float32)

    # --- offline: HNSW build + Ada-ef statistics / ef-table (Figure 2) ------
    print("building index + Ada-ef offline artifacts ...")
    index = build_ada_index(data, k=k, target_recall=0.95, m=8,
                            ef_construction=100, ef_cap=400, num_samples=128)
    t = index.timings
    print(f"offline: stats={t.stats_s:.2f}s sample={t.sample_s:.2f}s table={t.ef_table_s:.2f}s"
          f"  (WAE={float(index.table.wae):.0f})")

    # --- ground truth for evaluation ----------------------------------------
    gt = brute_force_topk(prepare_queries(jnp.asarray(queries), "cos_dist"),
                          prepare_database(jnp.asarray(data), "cos_dist"), k=k)[1]

    # --- declarative search: state the target, the planner picks the how ----
    spec = SearchSpec(k=k, target_recall=0.95)
    plan = index.plan(spec)                          # cached on the index
    print("\n" + plan.explain(fmt="text") + "\n")
    res = plan.search(queries)                       # <- no ef parameter!
    rec = np.asarray(recall_at_k(res.ids, gt))
    efs = np.asarray(res.ef_used)
    print(f"Ada-ef @ target 0.95: avg recall={rec.mean():.3f} "
          f"P5={np.percentile(rec, 5):.2f} work={np.asarray(res.ndist).mean():.0f} dists/query")
    print(f"adaptive ef range: min={efs.min()} median={int(np.median(efs))} max={efs.max()}")

    # --- same spec, serving execution: the ef-tier routed dispatch ----------
    routed = index.plan(SearchSpec(k=k, target_recall=0.95, mode="routed"))
    res_r, stats = routed.search(queries, with_stats=True)
    rr = np.asarray(recall_at_k(jnp.asarray(res_r.ids), gt))
    tiers = " ".join(f"ef{t.ef}:{t.count}" for t in stats.tiers)
    print(f"routed (same spec):   avg recall={rr.mean():.3f} tiers[{tiers}]")

    # --- versus static ef (what HNSWlib/FAISS users do today) ----------------
    for ef in (k, 4 * k):
        r = index.query_static(queries, ef)
        rr = np.asarray(recall_at_k(r.ids, gt))
        print(f"static ef={ef:3d}:       avg recall={rr.mean():.3f} "
              f"P5={np.percentile(rr, 5):.2f} work={np.asarray(r.ndist).mean():.0f} dists/query")


if __name__ == "__main__":
    main()
