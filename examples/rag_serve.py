"""End-to-end serving driver (the paper's deployment context): a batched LM
serving loop where every request runs Ada-ef retrieval at a declarative
target recall before decoding.

    PYTHONPATH=src python examples/rag_serve.py --requests 4 --new-tokens 12

``--stream`` demos the request-lifecycle serving API instead: requests
arrive one by one (Poisson), are submitted to a streaming-mode
``ExecutionPlan`` (the declarative facade over the continuous-batching
scheduler), and responses are polled as their ef tier drains — no batch
barrier, per-request latency telemetry.

``--filtered`` demos metadata-filtered retrieval: the corpus carries
per-document attributes (tenant namespace + ingest date), the request's
``SearchSpec.filter`` declares the predicate (this tenant's documents from
the last ~90 days), and the planner compiles it to a validity mask and
picks pre-filter vs post-filter-with-overquery from the estimated
selectivity — see ``plan.explain()["filter"]``.
"""
import argparse
import time
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SearchSpec
from repro.configs import ARCHS
from repro.index import build_ada_index
from repro.models import build_model
from repro.serve import Engine, SearchRequest
from repro.serve.scheduler import replay_trace


def stream_demo(engine, index, batch, *, rate_rps=64.0, deadline_ms=50.0):
    """The request lifecycle: submit -> step -> poll, one request at a time
    (``replay_trace`` is the canonical loop; see its source for the shape)."""
    plan = index.plan(
        SearchSpec(target_recall=0.95, deadline_ms=deadline_ms, mode="streaming")
    )
    print(plan.explain(fmt="text"))
    emb = np.asarray(engine._request_embedding(batch))
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, len(emb)))
    requests = [SearchRequest(query=e) for e in emb]  # deadline from the spec
    responses, lats = replay_trace(plan, requests, arrivals)
    for resp, wait in list(zip(responses, lats))[:4]:
        s = resp.stats
        print(f"  request {resp.ticket.uid}: tier ef={s.tier_ef} "
              f"(est ef={s.ef_est}, drained by {s.trigger}) "
              f"latency={wait * 1e3:.1f}ms ids={resp.ids[:4]}...")
    by_status = Counter(r.status for r in responses)
    print(f"streamed {len(responses)} requests: p50={np.percentile(lats, 50) * 1e3:.1f}ms "
          f"p99={np.percentile(lats, 99) * 1e3:.1f}ms "
          f"(first run includes jit compiles)")
    print("  statuses: " + ", ".join(
        f"{s}={n}" for s, n in sorted(by_status.items())))


def filtered_demo(engine, index, batch, rng):
    """Metadata-filtered retrieval: tenant + date-window predicate."""
    from repro.filter import FilterSpec

    n = len(index.graph.alive)
    # per-document metadata: owning tenant + ingest date (epoch days)
    index.attach_attributes(
        tenant=rng.choice(["acme", "globex", "initech"], n).tolist(),
        numeric={"ingest_day": 19000.0 + rng.uniform(0, 365, n)},
    )
    filt = FilterSpec(
        tenant="acme", ranges={"ingest_day": (19275.0, 19365.0)}
    )
    plan = index.plan(SearchSpec(target_recall=0.95, filter=filt))
    print(plan.explain(fmt="text"))
    fd = plan.explain()["filter"]
    print(f"planner: {fd['mode']}-filter at estimated selectivity "
          f"{fd['selectivity_estimate']:.3f} "
          f"(ef x{fd['ef_inflation']:.2f} overquery)")
    emb = np.asarray(engine._request_embedding(batch))
    res = plan.search(emb)
    store = index.attributes
    for i, row in enumerate(np.asarray(res.ids)[:4]):
        kept = row[row >= 0]
        days = store._nums["ingest_day"][kept]
        print(f"  request {i}: ids={kept[:5]}... tenants="
              f"{sorted(set(store._cats['tenant'][kept]))} "
              f"ingest_day=[{days.min():.0f}, {days.max():.0f}]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--corpus", type=int, default=3000)
    ap.add_argument("--routed", action="store_true",
                    help="continuous-batching scheduler dispatch for the "
                         "retrieval stage (overlaps the decode loop)")
    ap.add_argument("--stream", action="store_true",
                    help="streaming-arrival demo of the request-lifecycle "
                         "serving API (submit/step/poll)")
    ap.add_argument("--filtered", action="store_true",
                    help="metadata-filtered retrieval demo (tenant + date "
                         "predicate compiled to a validity mask)")
    args = ap.parse_args()

    cfg = ARCHS["qwen2-0.5b"].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    centers = rng.normal(0, 1, (32, cfg.d_model))
    corpus = (centers[rng.integers(0, 32, args.corpus)]
              + 0.3 * rng.normal(0, 1, (args.corpus, cfg.d_model))).astype(np.float32)
    print("building retrieval corpus index ...")
    index = build_ada_index(corpus, k=10, target_recall=0.95, m=8,
                            ef_construction=60, ef_cap=200, num_samples=64)

    engine = Engine(model, params, index=index,
                    max_new_tokens=args.new_tokens, target_recall=0.95,
                    routed=args.routed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len)), jnp.int32)}
    if args.stream:
        stream_demo(engine, index, batch)
        return
    if args.filtered:
        filtered_demo(engine, index, batch, rng)
        return
    t0 = time.perf_counter()
    res = engine.serve(batch)
    print(f"\nserved {args.requests} requests x {args.new_tokens} tokens "
          f"in {time.perf_counter() - t0:.1f}s")
    print("generated:", res.tokens[:, :8], "...")
    print("retrieved neighbor ids (req 0):", res.retrieved_ids[0])
    print("per-request adaptive ef:", res.ef_used)
    if res.router_stats is not None:
        print("router tiers:", [
            (t["ef"], t["count"]) for t in res.router_stats["tiers"]
        ])


if __name__ == "__main__":
    main()
