"""Production-update simulation (paper §7.5): a live index receiving batch
inserts and deletes, with Ada-ef's statistics maintained incrementally
(§6.3 merge/unmerge) — compare stale / incremental / recomputed variants.

    PYTHONPATH=src python examples/update_workload.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.index import (
    brute_force_topk_chunked,
    build_ada_index,
    prepare_queries,
    recall_at_k,
)


def evaluate(idx, queries, data, k=10, ids=None):
    """Recall vs exact GT over ``data``; ``ids`` maps GT row positions back
    to index ids when ``data`` is a survivor subset (post-delete)."""
    qp = prepare_queries(jnp.asarray(queries), "cos_dist")
    _, gt = brute_force_topk_chunked(qp, data, k=k)
    gt = np.asarray(gt) if ids is None else np.asarray(ids)[np.asarray(gt)]
    res = idx.query(queries)
    rec = np.asarray(recall_at_k(res.ids, jnp.asarray(gt)))
    return rec.mean(), np.percentile(rec, 5), float(np.asarray(res.ndist).mean())


def main():
    rng = np.random.default_rng(0)
    n, d, k = 6000, 64, 10
    nc = 40
    w = 1.0 / np.arange(1, nc + 1); w /= w.sum()
    centers = rng.normal(0, 1, (nc, d))
    full = (centers[rng.choice(nc, n, p=w)] + 0.3 * rng.normal(0, 1, (n, d))).astype(np.float32)
    queries = (centers[rng.choice(nc, 128, p=w)] + 0.3 * rng.normal(0, 1, (128, d))).astype(np.float32)

    base, batch1 = full[:4500], full[4500:]
    print("initial build on 4500 vectors ...")
    idx = build_ada_index(base, k=k, target_recall=0.95, m=8,
                          ef_construction=80, ef_cap=400, num_samples=96)
    avg, p5, nd = evaluate(idx, queries, base)
    print(f"  t0: recall={avg:.3f} p5={p5:.2f} work={nd:.0f}")

    print("\ninserting 1500 vectors (incremental §6.3) ...")
    t = idx.insert(batch1)
    print(f"  ada-ef update: stats={t['stats_s']:.2f}s gt={t['sample_s']:.2f}s "
          f"table={t['ef_table_s']:.2f}s   (index add: {t['index_s']:.1f}s)")
    avg, p5, nd = evaluate(idx, queries, full)
    print(f"  after insert: recall={avg:.3f} p5={p5:.2f} work={nd:.0f}")

    print("\ndeleting 1000 vectors ...")
    dead = np.arange(1000)
    t = idx.delete(dead)
    print(f"  ada-ef update: stats={t['stats_s']:.2f}s gt={t['sample_s']:.2f}s "
          f"table={t['ef_table_s']:.2f}s")
    avg, p5, nd = evaluate(idx, queries, full[1000:], ids=np.arange(1000, n))
    print(f"  after delete: recall={avg:.3f} p5={p5:.2f} work={nd:.0f}")

    # ---- serving through churn: mutate with tickets in flight (PR 8) ------
    # A held streaming plan and its scheduler survive mutations: pending
    # tickets are fenced and complete on the epoch they were admitted under
    # (stamped in response stats); new submissions bind the new epoch.
    from repro.api import SearchSpec
    from repro.serve import SearchRequest

    print("\nserving through churn (epoch-versioned mutation) ...")
    more = (centers[rng.choice(nc, 200, p=w)]
            + 0.3 * rng.normal(0, 1, (200, d))).astype(np.float32)
    plan = idx.plan(SearchSpec(target_recall=0.95, mode="streaming"))
    sched = plan.new_scheduler()
    pre = [sched.submit(SearchRequest(query=q)) for q in queries[:4]]
    idx.insert(more)               # absorbed mid-flight, not refused
    idx.delete(np.asarray([1500]))
    post = [sched.submit(SearchRequest(query=q)) for q in queries[4:8]]
    by = {r.ticket.uid: r for r in sched.drain()}
    e_pre = sorted({by[t.uid].stats.epoch for t in pre})
    e_post = sorted({by[t.uid].stats.epoch for t in post})
    print(f"  {len(by)}/8 tickets terminal across 2 mutations "
          f"(0 stale-plan errors)")
    print(f"  in-flight epochs={e_pre} post-mutation epochs={e_post} "
          f"fenced={sched.stats.fenced_requests}")
    print(f"  epoch ledger: {idx.epochs.as_dict()}")


if __name__ == "__main__":
    main()
