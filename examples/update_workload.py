"""Production-update simulation (paper §7.5): a live index receiving batch
inserts and deletes, with Ada-ef's statistics maintained incrementally
(§6.3 merge/unmerge) — compare stale / incremental / recomputed variants.

    PYTHONPATH=src python examples/update_workload.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.index import (
    brute_force_topk_chunked,
    build_ada_index,
    prepare_queries,
    recall_at_k,
)


def evaluate(idx, queries, data, k=10):
    qp = prepare_queries(jnp.asarray(queries), "cos_dist")
    _, gt = brute_force_topk_chunked(qp, data, k=k)
    res = idx.query(queries)
    rec = np.asarray(recall_at_k(res.ids, jnp.asarray(gt)))
    return rec.mean(), np.percentile(rec, 5), float(np.asarray(res.ndist).mean())


def main():
    rng = np.random.default_rng(0)
    n, d, k = 6000, 64, 10
    nc = 40
    w = 1.0 / np.arange(1, nc + 1); w /= w.sum()
    centers = rng.normal(0, 1, (nc, d))
    full = (centers[rng.choice(nc, n, p=w)] + 0.3 * rng.normal(0, 1, (n, d))).astype(np.float32)
    queries = (centers[rng.choice(nc, 128, p=w)] + 0.3 * rng.normal(0, 1, (128, d))).astype(np.float32)

    base, batch1 = full[:4500], full[4500:]
    print("initial build on 4500 vectors ...")
    idx = build_ada_index(base, k=k, target_recall=0.95, m=8,
                          ef_construction=80, ef_cap=400, num_samples=96)
    avg, p5, nd = evaluate(idx, queries, base)
    print(f"  t0: recall={avg:.3f} p5={p5:.2f} work={nd:.0f}")

    print("\ninserting 1500 vectors (incremental §6.3) ...")
    t = idx.insert(batch1)
    print(f"  ada-ef update: stats={t['stats_s']:.2f}s gt={t['sample_s']:.2f}s "
          f"table={t['ef_table_s']:.2f}s   (index add: {t['index_s']:.1f}s)")
    avg, p5, nd = evaluate(idx, queries, full)
    print(f"  after insert: recall={avg:.3f} p5={p5:.2f} work={nd:.0f}")

    print("\ndeleting 1000 vectors ...")
    dead = np.arange(1000)
    t = idx.delete(dead)
    print(f"  ada-ef update: stats={t['stats_s']:.2f}s gt={t['sample_s']:.2f}s "
          f"table={t['ef_table_s']:.2f}s")
    avg, p5, nd = evaluate(idx, queries, full[1000:])
    print(f"  after delete: recall={avg:.3f} p5={p5:.2f} work={nd:.0f}")


if __name__ == "__main__":
    main()
