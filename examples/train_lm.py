"""Train a ~100M-parameter LM with the full substrate (optimizer, remat,
deterministic data, async checkpointing, resume).

Default is a CPU-sized smoke (~15M params, 60 steps); pass --big for the
~100M/300-step configuration the framework targets on real hardware.

    PYTHONPATH=src python examples/train_lm.py [--big] [--steps N]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import ARCHS
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true", help="~100M params, slower on CPU")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    steps = args.steps or (300 if args.big else 60)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="trainlm_")

    if args.big:
        # ~100M params: 12L x d768 x ff3072, 32k vocab
        base = ARCHS["qwen2-0.5b"]
        cfg = dataclasses.replace(
            base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=3072, vocab_size=32064, remat=False,
        )
        from repro.configs.base import ShapeConfig
        from repro.models import build_model
        import jax
        from repro.train import (DataConfig, OptimizerConfig, TrainConfig,
                                 init_optimizer, make_batch, make_train_step)

        model = build_model(cfg, impl="jnp_flash")
        params = model.init(jax.random.PRNGKey(0))
        n = sum(x.size for x in jax.tree_util.tree_leaves(params))
        print(f"params: {n/1e6:.0f}M")
        step_fn = jax.jit(make_train_step(model, TrainConfig(
            opt=OptimizerConfig(lr=3e-4, warmup_steps=20, total_steps=steps))),
            donate_argnums=(0, 1))
        opt = init_optimizer(params)
        shape = ShapeConfig("ex", 256, 4, "train")
        for step in range(steps):
            params, opt, m = step_fn(params, opt, make_batch(cfg, shape, step))
            if step % 10 == 0:
                print(f"step {step:4d} loss {float(m['loss']):.4f}")
        return

    _, _, losses = train_loop(
        "qwen2-0.5b", reduced=True, steps=steps, batch=8, seq=128,
        ckpt_dir=ckpt, ckpt_every=max(steps // 3, 10), log_every=5, impl="naive",
    )
    print(f"\nloss {losses[0][1]:.3f} -> {losses[-1][1]:.3f}   (checkpoints in {ckpt})")


if __name__ == "__main__":
    main()
