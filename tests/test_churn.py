"""Churn robustness (PR 8): epoch-versioned mutation, delete edge cases,
plan revalidation equivalence, and the scheduler mutation seam.

The contract under test: an index mutating under live consumers never
loses work and never serves incoherent results — in-flight requests
complete on the epoch they were dispatched on, held plans rebind, and a
revalidated plan is *bit-identical* to one freshly lowered against the
post-mutation index.
"""
import numpy as np
import pytest

from repro.api import SearchSpec
from repro.index import IndexMutationError, build_ada_index
from repro.plan import plan_spec
from repro.serve.api import SearchRequest


def _queries(small_db, nq=8, seed=2):
    data, centers, w = small_db
    rng = np.random.default_rng(seed)
    qc = rng.choice(len(centers), size=nq, p=w)
    return (centers[qc] + 0.3 * rng.normal(0, 1, (nq, centers.shape[1]))).astype(
        np.float32
    )


def _toy(small_db, n=1200, k=5, num_samples=32):
    data, _, _ = small_db
    return build_ada_index(
        data[:n], k=k, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=num_samples,
    )


# --------------------------------------------------------------------------
# delete / insert edge cases (typed, atomic)
# --------------------------------------------------------------------------


def test_empty_mutations_are_version_preserving_noops(small_db):
    idx = _toy(small_db)
    v0 = idx._graph_version
    p0 = idx.plan(SearchSpec())
    out = idx.insert(np.zeros((0, idx.raw_data.shape[1]), np.float32))
    assert out.get("noop") is True
    out = idx.delete(np.asarray([], dtype=np.int64))
    assert out.get("noop") is True
    assert idx._graph_version == v0  # no version bump
    assert idx.epochs.version == v0  # no epoch published
    assert idx.plan(SearchSpec()) is p0 and not p0.stale  # cache untouched


def test_delete_out_of_range_raises(small_db):
    idx = _toy(small_db)
    v0 = idx._graph_version
    with pytest.raises(IndexMutationError, match="out of range"):
        idx.delete(np.asarray([0, idx.host_index.n + 5]))
    with pytest.raises(IndexMutationError, match="out of range"):
        idx.delete(np.asarray([-1]))
    assert idx._graph_version == v0  # atomic: nothing was tombstoned


def test_delete_already_tombstoned_raises(small_db):
    idx = _toy(small_db)
    idx.delete(np.asarray([3]))
    v1 = idx._graph_version
    with pytest.raises(IndexMutationError, match="tombstoned"):
        idx.delete(np.asarray([3]))
    # mixed batches fail atomically: the still-alive id survives
    with pytest.raises(IndexMutationError, match="tombstoned"):
        idx.delete(np.asarray([3, 4]))
    assert idx._graph_version == v1
    assert bool(idx.host_index.alive[4])


def test_delete_below_k_raises(small_db):
    idx = _toy(small_db, n=40, num_samples=8)
    v0 = idx._graph_version
    with pytest.raises(IndexMutationError, match="k="):
        idx.delete(np.arange(36))  # would leave 4 alive rows < k=5
    assert idx._graph_version == v0
    q = _queries(small_db, nq=2, seed=3)
    assert idx.query(q).ids.shape == (2, 5)  # index still serviceable


def test_insert_shape_and_finite_validation(small_db):
    idx = _toy(small_db)
    v0 = idx._graph_version
    with pytest.raises(IndexMutationError, match="expected"):
        idx.insert(np.zeros((3, idx.raw_data.shape[1] + 1), np.float32))
    bad = np.zeros((2, idx.raw_data.shape[1]), np.float32)
    bad[1, 0] = np.nan
    with pytest.raises(IndexMutationError, match="NaN"):
        idx.insert(bad)
    assert idx._graph_version == v0


def test_delete_entry_point_is_legal(small_db):
    idx = _toy(small_db)
    ep = int(idx.host_index.entry)
    idx.delete(np.asarray([ep]))
    assert not bool(idx.host_index.alive[ep])
    q = _queries(small_db, nq=8, seed=4)
    res = idx.query(q)
    assert res.ids.shape == (8, 5)
    ids = np.asarray(res.ids)
    assert (ids >= 0).all()          # searches still complete...
    assert not (ids == ep).any()     # ...and never surface the dead entry


def test_proxy_resample_when_all_samples_deleted(small_db):
    idx = _toy(small_db, num_samples=8)
    doomed = np.asarray(idx.sample_ids).copy()
    idx.delete(doomed)
    # the proxy set regenerated from survivors instead of going empty
    assert len(idx.sample_ids) > 0
    alive = idx.host_index.alive[: idx.host_index.n]
    assert alive[np.asarray(idx.sample_ids)].all()
    assert not np.isin(np.asarray(idx.sample_ids), doomed).any()
    # the regenerated ground-truth table still drives calibrated planning
    q = _queries(small_db, nq=4, seed=5)
    assert idx.plan(SearchSpec()).search(q).ids.shape == (4, 5)


# --------------------------------------------------------------------------
# epoch manager contract
# --------------------------------------------------------------------------


def test_epoch_manager_publishes_and_retires(small_db):
    idx = _toy(small_db)
    data, _, _ = small_db
    epochs = idx.epochs
    v0 = epochs.version
    assert v0 == idx._graph_version
    pinned = epochs.pin()  # a consumer holds the pre-mutation snapshot
    idx.insert(data[1200:1205])
    idx.delete(np.asarray([7]))
    assert epochs.version == idx._graph_version == v0 + 2
    assert epochs.retired_versions == [v0, v0 + 1]
    # the pinned epoch's arrays are untouched by the mutations
    assert pinned.version == v0
    assert pinned.alive_rows == 1200 and pinned.n == 1200
    assert epochs.current.n == 1205 and epochs.current.alive_rows == 1204
    d = epochs.as_dict()
    assert d["version"] == v0 + 2 and d["published"] == 2
    # publishing is strictly monotone
    with pytest.raises(ValueError, match="monotone"):
        epochs.publish(
            version=v0,
            graph=pinned.graph,
            stats=pinned.stats,
            table=pinned.table,
            n=pinned.n,
            alive_rows=pinned.alive_rows,
        )


# --------------------------------------------------------------------------
# revalidated plan == freshly lowered plan (the acceptance property)
# --------------------------------------------------------------------------


def _run_plan(plan, q):
    """Execute a plan over a batch through its mode's native surface."""
    if plan.spec.mode == "streaming":
        tickets = [plan.submit(row) for row in q]
        by = {r.ticket.uid: r for r in plan.drain()}
        assert sorted(by) == sorted(t.uid for t in tickets)
        ids = np.stack([np.asarray(by[t.uid].ids) for t in tickets])
        dists = np.stack([np.asarray(by[t.uid].dists) for t in tickets])
        return ids, dists
    res = plan.search(q)
    return np.asarray(res.ids), np.asarray(res.dists)


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("mode", ["oneshot", "routed", "streaming"])
def test_revalidated_plan_matches_fresh_plan(small_db, seed, mode):
    """3-seed property: after insert+delete churn, a held (revalidated)
    plan returns bit-identical ids *and* distances to a plan freshly
    lowered against the post-mutation index — revalidation is invisible."""
    idx = _toy(small_db)
    data, _, _ = small_db
    q = _queries(small_db, nq=6, seed=100 + seed)
    spec = SearchSpec(mode=mode)
    held = idx.plan(spec)
    _run_plan(held, q)  # prove pre-mutation liveness, warm the executors

    rng = np.random.default_rng(seed)
    idx.insert(data[1200 : 1205 + seed])
    idx.delete(np.sort(rng.choice(1200, size=4, replace=False)))

    fresh = plan_spec(idx, spec)  # bypass the cache: lowered from scratch
    a_ids, a_dists = _run_plan(held, q)
    b_ids, b_dists = _run_plan(fresh, q)
    np.testing.assert_array_equal(a_ids, b_ids)
    np.testing.assert_array_equal(a_dists, b_dists)


@pytest.mark.parametrize("seed", range(3))
def test_streaming_mutation_between_submit_and_poll(small_db, seed):
    """Mutating between ``submit()`` and ``poll()`` loses nothing: fenced
    tickets complete on the pre-mutation epoch, later submissions bind the
    new one, and every ticket reaches exactly one terminal status."""
    idx = _toy(small_db)
    data, _, _ = small_db
    q = _queries(small_db, nq=4, seed=200 + seed)
    plan = idx.plan(SearchSpec(mode="streaming"))
    pre = [plan.submit(row) for row in q[:2]]
    idx.delete(np.asarray([5 + seed]))  # mutation with tickets pending
    post = [plan.submit(row) for row in q[2:]]
    by = {r.ticket.uid: r for r in plan.drain()}
    assert sorted(by) == sorted(t.uid for t in pre + post)
    assert all(r.status in ("ok", "partial") for r in by.values())
    (v_pre,) = {by[t.uid].stats.epoch for t in pre}
    (v_post,) = {by[t.uid].stats.epoch for t in post}
    assert v_post == v_pre + 1  # fenced on the old epoch, rebound for new
    # nothing the fence dispatched can surface the deleted row
    for t in post:
        assert not (np.asarray(by[t.uid].ids) == 5 + seed).any()


# --------------------------------------------------------------------------
# the manual mutation seam
# --------------------------------------------------------------------------


def test_apply_mutation_seam_is_idempotent_for_registered(small_db):
    idx = _toy(small_db)
    data, _, _ = small_db
    sched = idx.scheduler()
    q = _queries(small_db, nq=1, seed=9)
    out = sched.apply_mutation(lambda: idx.insert(data[1200:1203]))
    assert isinstance(out, dict) and not out.get("noop")
    # the index already absorbed its registered scheduler; the second
    # absorb inside apply_mutation was a version-match no-op
    assert sched.stats.mutations == 1
    t = sched.submit(SearchRequest(query=q[0]))
    (r,) = sched.drain()
    assert r.ticket.uid == t.uid
    assert r.stats.epoch == idx._graph_version
