"""HLO analysis + sharding rules (pure host logic; no 512-device init)."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import param_spec
from repro.utils.hlo import analyze_hlo


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_param_rules():
    m = _FakeMesh()
    assert param_spec("embed", 2, m, fsdp=True) == P("model", "data")
    # head-major 3D attention layouts: (L, D, H, hd) / (L, H, hd, D)
    assert param_spec("layers.attn.wq", 4, m, fsdp=False) == P(None, None, "model", None)
    assert param_spec("layers.attn.wo", 4, m, fsdp=True) == P(None, "model", None, "data")
    assert param_spec("layers.moe.w_gate", 4, m, fsdp=False) == P(None, "model", None, None)
    assert param_spec("layers.mlp.w_gate", 3, m, fsdp=True) == P(None, "data", "model")
    assert param_spec("layers.ln1.scale", 2, m, fsdp=True) == P()


HLO = """
HloModule test

%cond (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%cond
  %i = s32[] get-tuple-element(%arg), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %ar)
}

ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %p)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_trip_count_weighting():
    cost = analyze_hlo(HLO)
    # dot: 2 * 64 * 8 flops, executed 12 times
    assert cost.flops == 12 * 2 * 64 * 8
    # all-reduce: 256 bytes x 12
    assert cost["all-reduce"] == 12 * 256


def test_trip_count_from_condition_constant():
    hlo = HLO.replace(', backend_config={"known_trip_count":{"n":"12"}}', "")
    cost = analyze_hlo(hlo)
    assert cost.flops == 12 * 2 * 64 * 8  # bound constant(12) in %cond


def test_collective_kinds_and_tuples():
    hlo = """
HloModule m

ENTRY %e (p: bf16[4,4]) -> bf16[4,4] {
  %p = bf16[4,4]{1,0} parameter(0)
  %ag = bf16[16,4]{1,0} all-gather(%p), dimensions={0}
  %rs = bf16[1,4]{1,0} reduce-scatter(%p), dimensions={0}, to_apply=%e
  %a2a = bf16[4,4]{1,0} all-to-all(%p), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
  ROOT %o = bf16[4,4]{1,0} add(%p, %cp)
}
"""
    cost = analyze_hlo(hlo)
    assert cost["all-gather"] == 128
    assert cost["reduce-scatter"] == 8
    assert cost["all-to-all"] == 32
    assert cost["collective-permute"] == 32


def test_cache_and_batch_shardings_single_device():
    """Rules must degrade gracefully on a 1-device mesh (tests/CI)."""
    from repro.configs import ARCHS
    from repro.launch.sharding import batch_shardings, cache_shardings

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = ARCHS["qwen3-14b"]
    import jax.numpy as jnp

    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    bs = batch_shardings(batch, mesh)
    assert bs["tokens"].spec == P("data", None)
    cache = {
        "k": jax.ShapeDtypeStruct((4, 8, 64, 8, 128), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((4, 8, 64, 8, 128), jnp.bfloat16),
    }
    cs = cache_shardings(cache, cfg, mesh)
    assert cs["k"].spec[1] is not None  # batch axis sharded (trivially, 1 way)
