"""Continuous-batching scheduler: arrival-order invariance vs the synchronous
plan-search barrier, ticket bookkeeping, drain triggers (fill vs deadline vs
flush), estimation-pass padding cost, and cache invalidation."""
import numpy as np
import pytest

from repro.api import RouterConfig, SchedulerConfig, SearchSpec, SpecOverrides
from repro.serve import (
    AdaServeScheduler,
    SearchRequest,
)


class FakeClock:
    """Deterministic scheduler clock for deadline tests."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _queries(small_db, nq=64, seed=1):
    data, centers, w = small_db
    rng = np.random.default_rng(seed)
    qc = rng.choice(len(centers), size=nq, p=w)
    return (centers[qc] + 0.3 * rng.normal(0, 1, (nq, centers.shape[1]))).astype(
        np.float32
    )


def _barrier_ref(index, q, target, rcfg=None):
    """Synchronous routed reference through the declarative facade (the
    submit-all/drain-all barrier ExecutionPlan.search runs in routed mode)."""
    plan = index.plan(SearchSpec(
        target_recall=float(target),
        mode="routed",
        overrides=SpecOverrides(router=rcfg or RouterConfig(beam_mode="fixed")),
    ))
    return plan.search(q, with_stats=True)


# --------------------------------------------------------------------------
# config validation
# --------------------------------------------------------------------------


def test_scheduler_config_validation():
    SchedulerConfig(fill=1)
    SchedulerConfig(fill=16)
    with pytest.raises(ValueError):
        SchedulerConfig(fill=0)
    with pytest.raises(ValueError):
        SchedulerConfig(fill=6)  # not a power of two
    with pytest.raises(ValueError):
        SchedulerConfig(flush_margin_s=-1.0)


# --------------------------------------------------------------------------
# ticket bookkeeping
# --------------------------------------------------------------------------


def test_ticket_bookkeeping(small_db, small_index):
    q = _queries(small_db, nq=5, seed=2)
    clock = FakeClock(10.0)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        SchedulerConfig(fill=64),
        default_target_recall=small_index.target_recall,
        clock=clock,
    )
    assert sched.pending == 0
    assert sched.poll() == []

    t0 = sched.submit(SearchRequest(query=q[0]))
    clock.advance(0.5)
    t1 = sched.submit(SearchRequest(query=q[1], deadline_s=2.0))
    assert t1.uid > t0.uid  # unique, monotone
    assert t0.submit_t == 10.0 and t1.submit_t == 10.5
    assert t0.deadline_t is None
    assert t1.deadline_t == pytest.approx(12.5)
    assert sched.pending == 2
    assert sched.stats.submitted == 2

    # nothing runs before a tick; drain returns exactly the submitted set
    assert sched.poll() == []
    responses = sched.drain()
    assert sched.pending == 0
    assert {r.ticket.uid for r in responses} == {t0.uid, t1.uid}
    assert sched.stats.completed == 2
    for r in responses:
        assert r.ids.shape == (small_index.k,)
        assert r.stats.trigger == "flush"
        assert r.stats.latency_s >= 0.0
        assert r.stats.ndist == r.ndist > 0


def test_submit_validation(small_db, small_index):
    q = _queries(small_db, nq=2, seed=3)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
    )
    with pytest.raises(ValueError):
        sched.submit(SearchRequest(query=q))  # a batch, not one query
    with pytest.raises(ValueError):
        sched.submit(SearchRequest(query=q[0], k=small_index.k + 1))
    no_default = AdaServeScheduler(small_index.router(RouterConfig()))
    with pytest.raises(ValueError):
        no_default.submit(SearchRequest(query=q[0]))
    # (1, d) single-row batches are accepted as one query
    t = sched.submit(SearchRequest(query=q[:1], target_recall=0.9))
    assert t.uid >= 0
    sched.drain()


def test_per_request_k_override(small_db, small_index):
    q = _queries(small_db, nq=2, seed=4)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
    )
    sched.submit(SearchRequest(query=q[0], k=3))
    sched.submit(SearchRequest(query=q[1]))
    r3, rk = sorted(sched.drain(), key=lambda r: r.ticket.uid)
    assert r3.ids.shape == (3,) and r3.dists.shape == (3,)
    assert rk.ids.shape == (small_index.k,)


def test_poll_uid_filter(small_db, small_index):
    q = _queries(small_db, nq=4, seed=5)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
    )
    tickets = [sched.submit(SearchRequest(query=row)) for row in q]
    sched.flush()
    mine = sched.poll(block=True, uids=[tickets[0].uid, tickets[2].uid])
    assert {r.ticket.uid for r in mine} == {tickets[0].uid, tickets[2].uid}
    assert sched.pending == 2  # the other two stay queued
    rest = sched.poll(block=True)
    assert {r.ticket.uid for r in rest} == {tickets[1].uid, tickets[3].uid}
    assert sched.pending == 0


# --------------------------------------------------------------------------
# drain triggers
# --------------------------------------------------------------------------


def test_deadline_draining(small_db, small_index):
    q = _queries(small_db, nq=3, seed=6)
    clock = FakeClock()
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        # fill never reached; strict policy (no work-conserving idle drains)
        SchedulerConfig(fill=64, work_conserving=False),
        default_target_recall=small_index.target_recall,
        clock=clock,
    )
    for row in q:
        sched.submit(SearchRequest(query=row, deadline_s=1.0))
    # before the deadline: estimated + tier-queued, but not dispatched
    assert sched.step() == 0
    assert sum(sched.queue_depths()) == 3
    assert sched.poll() == []
    clock.advance(0.5)
    assert sched.step() == 0  # still inside the budget
    clock.advance(0.75)
    assert sched.step() == 3  # deadline due -> bucket drains
    responses = sched.poll(block=True)
    assert len(responses) == 3
    assert sched.stats.deadline_drains >= 1
    assert all(r.stats.trigger == "deadline" for r in responses)


def test_fill_draining_across_estimation_passes(small_db, small_index):
    """A bucket accumulates across step()s (separate estimation passes) and
    drains exactly when it reaches the pow2 fill — no deadline involved."""
    q0 = _queries(small_db, nq=1, seed=7)[0]
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        SchedulerConfig(fill=4, work_conserving=False),
        default_target_recall=small_index.target_recall,
    )
    for _ in range(3):  # identical queries -> identical ef -> one tier
        sched.submit(SearchRequest(query=q0))
    assert sched.step() == 0
    assert sum(sched.queue_depths()) == 3
    assert sched.stats.est_passes == 1
    sched.submit(SearchRequest(query=q0))
    assert sched.step() == 4  # second pass tops the bucket up to fill
    assert sched.stats.est_passes == 2
    responses = sched.poll(block=True)
    assert len(responses) == 4
    assert sched.stats.fill_drains == 1
    assert all(r.stats.trigger == "fill" for r in responses)
    # the 4 requests resumed bit-identically despite 2 estimation passes
    ids = np.stack([r.ids for r in responses])
    assert (ids == ids[0]).all()


# --------------------------------------------------------------------------
# arrival-order invariance (the tentpole acceptance property)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(5))
def test_arrival_order_invariance_vs_plan_barrier(small_db, small_index, seed):
    """Property: for a random interleaving of submit()/step()/poll() with
    random per-request deadlines (mixing fill, deadline and flush drains),
    the scheduler returns ids/dists/ndist/ef bit-identical to the synchronous
    plan-search barrier under a lossless config."""
    rng = np.random.default_rng(1000 + seed)
    nq = int(rng.integers(8, 48))
    q = _queries(small_db, nq=nq, seed=seed)
    ref, _ = _barrier_ref(small_index, q, small_index.target_recall)

    clock = FakeClock()
    fill = int(rng.choice([2, 8, 16]))
    # scheduler over the *same* lowered router the barrier plan used, so the
    # equivalence is between executions of one plan's policy
    plan = small_index.plan(SearchSpec(
        target_recall=float(small_index.target_recall), mode="routed",
        overrides=SpecOverrides(router=RouterConfig(beam_mode="fixed")),
    ))
    sched = AdaServeScheduler(
        plan.router,
        SchedulerConfig(fill=fill),
        default_target_recall=small_index.target_recall,
        clock=clock,
    )
    tickets = []
    responses = []
    i = 0
    while i < nq:
        for _ in range(int(rng.integers(1, 6))):
            if i >= nq:
                break
            deadline = None if rng.random() < 0.5 else float(rng.uniform(0.01, 0.3))
            tickets.append(
                sched.submit(SearchRequest(query=q[i], deadline_s=deadline))
            )
            i += 1
        clock.advance(float(rng.uniform(0.0, 0.2)))
        sched.step()
        if rng.random() < 0.5:
            responses.extend(sched.poll())
    responses.extend(sched.drain())

    assert len(responses) == nq and sched.pending == 0
    by_uid = {r.ticket.uid: r for r in responses}
    ids = np.stack([by_uid[t.uid].ids for t in tickets])
    dists = np.stack([by_uid[t.uid].dists for t in tickets])
    ndist = np.asarray([by_uid[t.uid].ndist for t in tickets])
    ef = np.asarray([by_uid[t.uid].ef_used for t in tickets])
    np.testing.assert_array_equal(ids, ref.ids)
    np.testing.assert_array_equal(dists, ref.dists)
    np.testing.assert_array_equal(ndist, ref.ndist)
    np.testing.assert_array_equal(ef, ref.ef_used)
    st = sched.stats
    drains = (
        st.fill_drains + st.deadline_drains + st.flush_drains + st.idle_drains
    )
    assert drains == len(st.tiers)
    assert sum(t.count for t in st.tiers) == nq


def test_mixed_target_recalls_in_one_pass(small_db, small_index):
    """Requests with different declarative targets share one estimation pass
    and still match their per-target synchronous reference."""
    q = _queries(small_db, nq=8, seed=11)
    lo, hi = 0.8, small_index.target_recall
    ref_lo, _ = _barrier_ref(small_index, q[:4], lo)
    ref_hi, _ = _barrier_ref(small_index, q[4:], hi)
    plan = small_index.plan(SearchSpec(
        target_recall=float(hi), mode="routed",
        overrides=SpecOverrides(router=RouterConfig(beam_mode="fixed")),
    ))
    sched = AdaServeScheduler(plan.router, default_target_recall=hi)
    tickets = [
        sched.submit(SearchRequest(query=q[i], target_recall=lo if i < 4 else hi))
        for i in range(8)
    ]
    by_uid = {r.ticket.uid: r for r in sched.drain()}
    ids = np.stack([by_uid[t.uid].ids for t in tickets])
    np.testing.assert_array_equal(ids[:4], ref_lo.ids)
    np.testing.assert_array_equal(ids[4:], ref_hi.ids)


# --------------------------------------------------------------------------
# estimation-pass padding + telemetry
# --------------------------------------------------------------------------


def test_estimation_padding_converges_immediately(small_db, small_index):
    """Satellite fix: estimation-pass padding rows skip phase A — each pad
    row costs exactly the entry-point distance, reported in est_pad_ndist."""
    q = _queries(small_db, nq=13, seed=12)  # pads 13 -> 16
    _, stats = _barrier_ref(
        small_index, q, small_index.target_recall, rcfg=RouterConfig()
    )
    assert stats.est_shape == 16
    assert stats.est_pad_ndist == stats.est_shape - stats.batch == 3
    assert stats.as_dict()["est_pad_ndist"] == 3
    # real rows pay full phase A, so the pad total is far below the real total
    assert stats.est_ndist_total > 13 * stats.est_pad_ndist


def test_router_stats_compat_from_scheduler(small_db, small_index):
    q = _queries(small_db, nq=9, seed=13)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
    )
    mark = sched.stats.snapshot()
    for row in q:
        sched.submit(SearchRequest(query=row))
    sched.drain()
    rs = sched.router_stats(mark)
    assert rs.batch == 9
    assert sum(t.count for t in rs.tiers) == 9
    assert rs.ndist_total > 0 and 0.0 <= rs.padding_waste < 1.0
    d = rs.as_dict()
    assert d["batch"] == 9 and len(d["tiers"]) == len(rs.tiers)
    # a second serving slice measures only its own traffic
    mark2 = sched.stats.snapshot()
    sched.submit(SearchRequest(query=q[0]))
    sched.drain()
    rs2 = sched.router_stats(mark2)
    assert rs2.batch == 1 and sum(t.count for t in rs2.tiers) == 1


# --------------------------------------------------------------------------
# deleted shims + cache invalidation
# --------------------------------------------------------------------------


def test_legacy_shims_deleted():
    """route()/query_routed are gone for good — the facade (ExecutionPlan
    search/submit/poll) is the only public execution surface, and the
    suite-wide ``error::DeprecationWarning`` filter keeps dead API from
    creeping back behind a warning."""
    from repro.index.pipeline import AdaEfIndex
    from repro.serve.router import QueryRouter

    assert not hasattr(QueryRouter, "route")
    assert not hasattr(AdaEfIndex, "query_routed")


def test_scheduler_invalidated_on_update(small_db):
    from repro.index import build_ada_index

    data, _, _ = small_db
    idx = build_ada_index(
        data[:1200], k=5, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=32,
    )
    s0 = idx.scheduler()
    assert idx.scheduler() is s0  # cached
    assert s0.router is idx.router()
    idx.insert(data[1200:1210])
    s1 = idx.scheduler()
    assert s1 is not s0  # graph changed -> scheduler rebuilt
    assert s1.router is idx.router()
    idx.delete(np.asarray([0, 1]))
    s2 = idx.scheduler()
    assert s2 is not s1
    # the rebuilt scheduler serves against the updated graph
    q = _queries(small_db, nq=4, seed=15)
    tickets = [s2.submit(SearchRequest(query=row)) for row in q]
    responses = s2.drain()
    assert len(responses) == len(tickets)
    assert all(r.ids.shape == (5,) for r in responses)
    # installed configs survive invalidation-triggered rebuilds
    idx.scheduler(SchedulerConfig(fill=16))
    idx.insert(data[1210:1215])
    assert idx.scheduler().cfg.fill == 16
