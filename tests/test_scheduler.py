"""Continuous-batching scheduler: arrival-order invariance vs the synchronous
plan-search barrier, ticket bookkeeping, drain triggers (fill vs deadline vs
flush), estimation-pass padding cost, and cache invalidation."""
import numpy as np
import pytest

from repro.api import RouterConfig, SchedulerConfig, SearchSpec, SpecOverrides
from repro.serve import (
    AdaServeScheduler,
    SearchRequest,
)


class FakeClock:
    """Deterministic scheduler clock for deadline tests."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _queries(small_db, nq=64, seed=1):
    data, centers, w = small_db
    rng = np.random.default_rng(seed)
    qc = rng.choice(len(centers), size=nq, p=w)
    return (centers[qc] + 0.3 * rng.normal(0, 1, (nq, centers.shape[1]))).astype(
        np.float32
    )


def _barrier_ref(index, q, target, rcfg=None):
    """Synchronous routed reference through the declarative facade (the
    submit-all/drain-all barrier ExecutionPlan.search runs in routed mode)."""
    plan = index.plan(SearchSpec(
        target_recall=float(target),
        mode="routed",
        overrides=SpecOverrides(router=rcfg or RouterConfig(beam_mode="fixed")),
    ))
    return plan.search(q, with_stats=True)


# --------------------------------------------------------------------------
# config validation
# --------------------------------------------------------------------------


def test_scheduler_config_validation():
    SchedulerConfig(fill=1)
    SchedulerConfig(fill=16)
    with pytest.raises(ValueError):
        SchedulerConfig(fill=0)
    with pytest.raises(ValueError):
        SchedulerConfig(fill=6)  # not a power of two
    with pytest.raises(ValueError):
        SchedulerConfig(flush_margin_s=-1.0)


# --------------------------------------------------------------------------
# ticket bookkeeping
# --------------------------------------------------------------------------


def test_ticket_bookkeeping(small_db, small_index):
    q = _queries(small_db, nq=5, seed=2)
    clock = FakeClock(10.0)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        SchedulerConfig(fill=64),
        default_target_recall=small_index.target_recall,
        clock=clock,
    )
    assert sched.pending == 0
    assert sched.poll() == []

    t0 = sched.submit(SearchRequest(query=q[0]))
    clock.advance(0.5)
    t1 = sched.submit(SearchRequest(query=q[1], deadline_s=2.0))
    assert t1.uid > t0.uid  # unique, monotone
    assert t0.submit_t == 10.0 and t1.submit_t == 10.5
    assert t0.deadline_t is None
    assert t1.deadline_t == pytest.approx(12.5)
    assert sched.pending == 2
    assert sched.stats.submitted == 2

    # nothing runs before a tick; drain returns exactly the submitted set
    assert sched.poll() == []
    responses = sched.drain()
    assert sched.pending == 0
    assert {r.ticket.uid for r in responses} == {t0.uid, t1.uid}
    assert sched.stats.completed == 2
    for r in responses:
        assert r.ids.shape == (small_index.k,)
        assert r.stats.trigger == "flush"
        assert r.stats.latency_s >= 0.0
        assert r.stats.ndist == r.ndist > 0


def test_submit_validation(small_db, small_index):
    q = _queries(small_db, nq=2, seed=3)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
    )
    with pytest.raises(ValueError):
        sched.submit(SearchRequest(query=q))  # a batch, not one query
    with pytest.raises(ValueError):
        sched.submit(SearchRequest(query=q[0], k=small_index.k + 1))
    no_default = AdaServeScheduler(small_index.router(RouterConfig()))
    with pytest.raises(ValueError):
        no_default.submit(SearchRequest(query=q[0]))
    # (1, d) single-row batches are accepted as one query
    t = sched.submit(SearchRequest(query=q[:1], target_recall=0.9))
    assert t.uid >= 0
    sched.drain()


def test_per_request_k_override(small_db, small_index):
    q = _queries(small_db, nq=2, seed=4)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
    )
    sched.submit(SearchRequest(query=q[0], k=3))
    sched.submit(SearchRequest(query=q[1]))
    r3, rk = sorted(sched.drain(), key=lambda r: r.ticket.uid)
    assert r3.ids.shape == (3,) and r3.dists.shape == (3,)
    assert rk.ids.shape == (small_index.k,)


def test_poll_uid_filter(small_db, small_index):
    q = _queries(small_db, nq=4, seed=5)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
    )
    tickets = [sched.submit(SearchRequest(query=row)) for row in q]
    sched.flush()
    mine = sched.poll(block=True, uids=[tickets[0].uid, tickets[2].uid])
    assert {r.ticket.uid for r in mine} == {tickets[0].uid, tickets[2].uid}
    assert sched.pending == 2  # the other two stay queued
    rest = sched.poll(block=True)
    assert {r.ticket.uid for r in rest} == {tickets[1].uid, tickets[3].uid}
    assert sched.pending == 0


# --------------------------------------------------------------------------
# drain triggers
# --------------------------------------------------------------------------


def test_deadline_draining(small_db, small_index):
    q = _queries(small_db, nq=3, seed=6)
    clock = FakeClock()
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        # fill never reached; strict policy (no work-conserving idle drains)
        SchedulerConfig(fill=64, work_conserving=False),
        default_target_recall=small_index.target_recall,
        clock=clock,
    )
    for row in q:
        sched.submit(SearchRequest(query=row, deadline_s=1.0))
    # before the deadline: estimated + tier-queued, but not dispatched
    assert sched.step() == 0
    assert sum(sched.queue_depths()) == 3
    assert sched.poll() == []
    clock.advance(0.5)
    assert sched.step() == 0  # still inside the budget
    clock.advance(0.75)
    assert sched.step() == 3  # deadline due -> bucket drains
    responses = sched.poll(block=True)
    assert len(responses) == 3
    assert sched.stats.deadline_drains >= 1
    assert all(r.stats.trigger == "deadline" for r in responses)


def test_fill_draining_across_estimation_passes(small_db, small_index):
    """A bucket accumulates across step()s (separate estimation passes) and
    drains exactly when it reaches the pow2 fill — no deadline involved."""
    q0 = _queries(small_db, nq=1, seed=7)[0]
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        SchedulerConfig(fill=4, work_conserving=False),
        default_target_recall=small_index.target_recall,
    )
    for _ in range(3):  # identical queries -> identical ef -> one tier
        sched.submit(SearchRequest(query=q0))
    assert sched.step() == 0
    assert sum(sched.queue_depths()) == 3
    assert sched.stats.est_passes == 1
    sched.submit(SearchRequest(query=q0))
    assert sched.step() == 4  # second pass tops the bucket up to fill
    assert sched.stats.est_passes == 2
    responses = sched.poll(block=True)
    assert len(responses) == 4
    assert sched.stats.fill_drains == 1
    assert all(r.stats.trigger == "fill" for r in responses)
    # the 4 requests resumed bit-identically despite 2 estimation passes
    ids = np.stack([r.ids for r in responses])
    assert (ids == ids[0]).all()


# --------------------------------------------------------------------------
# arrival-order invariance (the tentpole acceptance property)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(5))
def test_arrival_order_invariance_vs_plan_barrier(small_db, small_index, seed):
    """Property: for a random interleaving of submit()/step()/poll() with
    random per-request deadlines (mixing fill, deadline and flush drains),
    the scheduler returns ids/dists/ndist/ef bit-identical to the synchronous
    plan-search barrier under a lossless config."""
    rng = np.random.default_rng(1000 + seed)
    nq = int(rng.integers(8, 48))
    q = _queries(small_db, nq=nq, seed=seed)
    ref, _ = _barrier_ref(small_index, q, small_index.target_recall)

    clock = FakeClock()
    fill = int(rng.choice([2, 8, 16]))
    # scheduler over the *same* lowered router the barrier plan used, so the
    # equivalence is between executions of one plan's policy
    plan = small_index.plan(SearchSpec(
        target_recall=float(small_index.target_recall), mode="routed",
        overrides=SpecOverrides(router=RouterConfig(beam_mode="fixed")),
    ))
    sched = AdaServeScheduler(
        plan.router,
        SchedulerConfig(fill=fill),
        default_target_recall=small_index.target_recall,
        clock=clock,
    )
    tickets = []
    responses = []
    i = 0
    while i < nq:
        for _ in range(int(rng.integers(1, 6))):
            if i >= nq:
                break
            deadline = None if rng.random() < 0.5 else float(rng.uniform(0.01, 0.3))
            tickets.append(
                sched.submit(SearchRequest(query=q[i], deadline_s=deadline))
            )
            i += 1
        clock.advance(float(rng.uniform(0.0, 0.2)))
        sched.step()
        if rng.random() < 0.5:
            responses.extend(sched.poll())
    responses.extend(sched.drain())

    assert len(responses) == nq and sched.pending == 0
    by_uid = {r.ticket.uid: r for r in responses}
    ids = np.stack([by_uid[t.uid].ids for t in tickets])
    dists = np.stack([by_uid[t.uid].dists for t in tickets])
    ndist = np.asarray([by_uid[t.uid].ndist for t in tickets])
    ef = np.asarray([by_uid[t.uid].ef_used for t in tickets])
    np.testing.assert_array_equal(ids, ref.ids)
    np.testing.assert_array_equal(dists, ref.dists)
    np.testing.assert_array_equal(ndist, ref.ndist)
    np.testing.assert_array_equal(ef, ref.ef_used)
    st = sched.stats
    drains = (
        st.fill_drains + st.deadline_drains + st.flush_drains + st.idle_drains
    )
    assert drains == len(st.tiers)
    assert sum(t.count for t in st.tiers) == nq


def test_mixed_target_recalls_in_one_pass(small_db, small_index):
    """Requests with different declarative targets share one estimation pass
    and still match their per-target synchronous reference."""
    q = _queries(small_db, nq=8, seed=11)
    lo, hi = 0.8, small_index.target_recall
    ref_lo, _ = _barrier_ref(small_index, q[:4], lo)
    ref_hi, _ = _barrier_ref(small_index, q[4:], hi)
    plan = small_index.plan(SearchSpec(
        target_recall=float(hi), mode="routed",
        overrides=SpecOverrides(router=RouterConfig(beam_mode="fixed")),
    ))
    sched = AdaServeScheduler(plan.router, default_target_recall=hi)
    tickets = [
        sched.submit(SearchRequest(query=q[i], target_recall=lo if i < 4 else hi))
        for i in range(8)
    ]
    by_uid = {r.ticket.uid: r for r in sched.drain()}
    ids = np.stack([by_uid[t.uid].ids for t in tickets])
    np.testing.assert_array_equal(ids[:4], ref_lo.ids)
    np.testing.assert_array_equal(ids[4:], ref_hi.ids)


# --------------------------------------------------------------------------
# estimation-pass padding + telemetry
# --------------------------------------------------------------------------


def test_estimation_padding_converges_immediately(small_db, small_index):
    """Satellite fix: estimation-pass padding rows skip phase A — each pad
    row costs exactly the entry-point distance, reported in est_pad_ndist."""
    q = _queries(small_db, nq=13, seed=12)  # pads 13 -> 16
    _, stats = _barrier_ref(
        small_index, q, small_index.target_recall, rcfg=RouterConfig()
    )
    assert stats.est_shape == 16
    assert stats.est_pad_ndist == stats.est_shape - stats.batch == 3
    assert stats.as_dict()["est_pad_ndist"] == 3
    # real rows pay full phase A, so the pad total is far below the real total
    assert stats.est_ndist_total > 13 * stats.est_pad_ndist


def test_router_stats_compat_from_scheduler(small_db, small_index):
    q = _queries(small_db, nq=9, seed=13)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
    )
    mark = sched.stats.snapshot()
    for row in q:
        sched.submit(SearchRequest(query=row))
    sched.drain()
    rs = sched.router_stats(mark)
    assert rs.batch == 9
    assert sum(t.count for t in rs.tiers) == 9
    assert rs.ndist_total > 0 and 0.0 <= rs.padding_waste < 1.0
    d = rs.as_dict()
    assert d["batch"] == 9 and len(d["tiers"]) == len(rs.tiers)
    # a second serving slice measures only its own traffic
    mark2 = sched.stats.snapshot()
    sched.submit(SearchRequest(query=q[0]))
    sched.drain()
    rs2 = sched.router_stats(mark2)
    assert rs2.batch == 1 and sum(t.count for t in rs2.tiers) == 1


# --------------------------------------------------------------------------
# deleted shims + cache invalidation
# --------------------------------------------------------------------------


def test_legacy_shims_deleted():
    """route()/query_routed are gone for good — the facade (ExecutionPlan
    search/submit/poll) is the only public execution surface, and the
    suite-wide ``error::DeprecationWarning`` filter keeps dead API from
    creeping back behind a warning."""
    from repro.index.pipeline import AdaEfIndex
    from repro.serve.router import QueryRouter

    assert not hasattr(QueryRouter, "route")
    assert not hasattr(AdaEfIndex, "query_routed")


def test_scheduler_rebinds_on_update(small_db):
    from repro.index import build_ada_index

    data, _, _ = small_db
    idx = build_ada_index(
        data[:1200], k=5, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=32,
    )
    s0 = idx.scheduler()
    assert idx.scheduler() is s0  # cached
    assert s0.router is idx.router()
    idx.insert(data[1200:1210])
    # mutation absorbs the registered scheduler in place: same object,
    # rebound to the post-mutation router (cost models/stats survive)
    assert idx.scheduler() is s0
    assert s0.router is idx.router()
    assert s0.stats.mutations == 1
    idx.delete(np.asarray([0, 1]))
    s2 = idx.scheduler()
    assert s2 is s0 and s2.stats.mutations == 2
    # the absorbed scheduler serves against the updated graph
    q = _queries(small_db, nq=4, seed=15)
    tickets = [s2.submit(SearchRequest(query=row)) for row in q]
    responses = s2.drain()
    assert len(responses) == len(tickets)
    assert all(r.ids.shape == (5,) for r in responses)
    assert all(r.stats.epoch == idx._graph_version for r in responses)
    # installing a config swaps the instance; the new one absorbs onward
    s3 = idx.scheduler(SchedulerConfig(fill=16))
    assert s3 is not s0
    idx.insert(data[1210:1215])
    assert idx.scheduler() is s3
    assert idx.scheduler().cfg.fill == 16


# --------------------------------------------------------------------------
# admission control + typed outcomes (overload-resilient serving)
# --------------------------------------------------------------------------


def _make_sched(small_index, cfg=None, **kw):
    kw.setdefault("default_target_recall", small_index.target_recall)
    return AdaServeScheduler(small_index.router(RouterConfig()), cfg, **kw)


def test_admission_control_raise_mode(small_db, small_index):
    from repro.serve import STATUS_OK, OverloadedError

    q = _queries(small_db, nq=6, seed=21)
    sched = _make_sched(small_index, SchedulerConfig(max_inflight=4))
    for row in q[:4]:
        sched.submit(SearchRequest(query=row))
    with pytest.raises(OverloadedError):
        sched.submit(SearchRequest(query=q[4]))
    assert sched.stats.rejected == 1
    assert sched.stats.submitted == 4  # the refused request never entered
    responses = sched.drain()  # freeing capacity re-opens admission
    assert len(responses) == 4
    assert all(r.status == STATUS_OK for r in responses)
    t5 = sched.submit(SearchRequest(query=q[4]))
    res2 = sched.drain()
    assert [r.ticket.uid for r in res2] == [t5.uid]


def test_admission_control_ticket_mode(small_db, small_index):
    from repro.serve import (
        STATUS_OK, STATUS_REJECTED, TERMINAL_STATUSES,
    )

    q = _queries(small_db, nq=4, seed=22)
    sched = _make_sched(
        small_index, SchedulerConfig(max_inflight=2, overload="ticket")
    )
    tickets = [sched.submit(SearchRequest(query=row)) for row in q]
    assert len(tickets) == 4  # never raises: 1:1 submit/poll pairing holds
    responses = sched.drain()
    assert len(responses) == 4
    by_uid = {r.ticket.uid: r for r in responses}
    statuses = [by_uid[t.uid].status for t in tickets]
    assert statuses == [
        STATUS_OK, STATUS_OK, STATUS_REJECTED, STATUS_REJECTED,
    ]
    for t in tickets[2:]:
        r = by_uid[t.uid]
        assert r.stats.reject_reason == "overloaded"
        assert (r.ids == -1).all() and r.ndist == 0
    assert all(r.status in TERMINAL_STATUSES for r in responses)
    assert sched.stats.rejected == 2 and sched.stats.submitted == 4


def test_submit_with_backoff_fills_bounded_scheduler(small_db, small_index):
    from repro.serve import STATUS_OK, submit_with_backoff

    q = _queries(small_db, nq=8, seed=23)
    sched = _make_sched(small_index, SchedulerConfig(max_inflight=2))
    got = []
    tickets = [
        submit_with_backoff(
            sched, SearchRequest(query=row), harvest=got.extend
        )
        for row in q
    ]
    got.extend(sched.drain())
    assert {r.ticket.uid for r in got} == {t.uid for t in tickets}
    assert all(r.status == STATUS_OK for r in got)
    assert sched.pending == 0


def test_tier_queue_bound_sheds_overflow(small_db, small_index):
    from repro.serve import STATUS_REJECTED

    q0 = _queries(small_db, nq=1, seed=24)[0]
    sched = _make_sched(
        small_index,
        SchedulerConfig(max_tier_queue=1, work_conserving=False, fill=8),
    )
    for _ in range(4):  # identical queries -> identical ef -> one tier
        sched.submit(SearchRequest(query=q0))
    sched.step()
    assert sched.stats.rejected == 3  # bound 1: the other three shed
    responses = sched.drain()
    rejected = [r for r in responses if r.status == STATUS_REJECTED]
    assert len(responses) == 4 and len(rejected) == 3
    assert all(
        r.stats.reject_reason.startswith("tier queue full") for r in rejected
    )


# --------------------------------------------------------------------------
# input hardening (typed InvalidQueryError before the shared estimation pass)
# --------------------------------------------------------------------------


def test_submit_rejects_nan_query(small_db, small_index):
    from repro.serve import InvalidQueryError

    q = _queries(small_db, nq=1, seed=25)[0]
    sched = _make_sched(small_index)
    bad = q.copy()
    bad[3] = np.nan
    with pytest.raises(InvalidQueryError, match="NaN/Inf"):
        sched.submit(SearchRequest(query=bad))
    assert sched.pending == 0


def test_submit_rejects_inf_query(small_db, small_index):
    from repro.serve import InvalidQueryError

    q = _queries(small_db, nq=1, seed=26)[0]
    sched = _make_sched(small_index)
    bad = q.copy()
    bad[0] = np.inf
    with pytest.raises(InvalidQueryError, match="NaN/Inf"):
        sched.submit(SearchRequest(query=bad))


def test_submit_rejects_non_numeric_dtype(small_index):
    from repro.serve import InvalidQueryError

    sched = _make_sched(small_index)
    dim = int(small_index.graph.vectors.shape[1])
    with pytest.raises(InvalidQueryError, match="dtype"):
        sched.submit(SearchRequest(query=np.array(["x"] * dim)))


def test_submit_rejects_wrong_dimensionality(small_index):
    from repro.serve import InvalidQueryError

    sched = _make_sched(small_index)
    with pytest.raises(InvalidQueryError, match="dimensionality"):
        sched.submit(SearchRequest(query=np.zeros(7, np.float32)))


def test_invalid_query_error_is_a_value_error(small_db, small_index):
    """Back-compat: callers catching ValueError keep working (the batch-query
    case in test_submit_validation relies on this too)."""
    from repro.serve import InvalidQueryError, ServeError

    assert issubclass(InvalidQueryError, ValueError)
    assert issubclass(InvalidQueryError, ServeError)
    q = _queries(small_db, nq=2, seed=27)
    sched = _make_sched(small_index)
    with pytest.raises(ValueError):
        sched.submit(SearchRequest(query=q))  # a batch, not one query


def test_plan_search_rejects_bad_queries(small_db, small_index):
    from repro.serve import InvalidQueryError

    q = _queries(small_db, nq=4, seed=28)
    plan = small_index.plan(SearchSpec(target_recall=0.9))
    bad = q.copy()
    bad[2, 5] = np.nan
    with pytest.raises(InvalidQueryError, match=r"rows \[2\]"):
        plan.search(bad)
    with pytest.raises(InvalidQueryError, match="dimensionality"):
        plan.search(np.zeros((3, 7), np.float32))
    with pytest.raises(InvalidQueryError, match="dtype"):
        plan.search(np.array([["y"] * q.shape[1]]))
    res = plan.search(q)  # the clean batch still serves
    assert res.ids.shape == (4, small_index.k)


# --------------------------------------------------------------------------
# deadline-aware degradation ladder (fake-clock driven)
# --------------------------------------------------------------------------


def _seed_costs(sched, costs):
    for t, w in enumerate(costs):
        if w is not None:
            sched.cost_model.observe(t, w)


def test_degradation_demotes_at_risk_request(small_db, small_index):
    from repro.serve import STATUS_DEGRADED

    q = _queries(small_db, nq=1, seed=31)[0]
    clock = FakeClock()
    # ef_margin inflates every estimate to the ef cap -> top tier,
    # deterministically, so the ladder has rungs to walk down
    sched = AdaServeScheduler(
        small_index.router(RouterConfig(ef_margin=50.0)),
        SchedulerConfig(fill=64, work_conserving=False, degrade=True),
        default_target_recall=small_index.target_recall,
        clock=clock,
    )
    ntiers = len(sched.router.tiers)
    assert ntiers >= 2
    # seed the cost model: every rung above 0 far too slow for the deadline
    _seed_costs(sched, [0.02] + [0.5] * (ntiers - 1))
    sched.submit(SearchRequest(query=q, deadline_s=0.1))
    sched.step()
    # demoted all the way to rung 0 (0.5s predicted vs 0.1s budget), which
    # fits (0.02s) -- and the deadline lookahead dispatches it in time
    clock.advance(0.085)
    assert sched.step() == 1
    (r,) = sched.poll(block=True)
    assert r.status == STATUS_DEGRADED
    assert r.stats.demotions == ntiers - 1
    assert r.ef_used <= sched.router.tiers[0].ef < r.stats.ef_est
    assert r.stats.ef_achieved == r.ef_used
    assert r.stats.status == STATUS_DEGRADED
    assert sched.stats.degraded == 1
    assert sched.stats.demotions == ntiers - 1
    assert r.ids.shape == (small_index.k,) and (r.ids >= 0).any()


def test_partial_answer_on_blown_deadline(small_db, small_index):
    from repro.serve import STATUS_PARTIAL

    q = _queries(small_db, nq=1, seed=32)[0]
    clock = FakeClock()
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        SchedulerConfig(fill=64, work_conserving=False, degrade=True),
        default_target_recall=small_index.target_recall,
        clock=clock,
    )
    sched.submit(SearchRequest(query=q, deadline_s=0.05))
    clock.advance(0.2)  # scheduler was busy; the deadline is already blown
    assert sched.step() == 0  # no tier dispatch is spent on it
    (r,) = sched.poll()
    assert r.status == STATUS_PARTIAL
    assert r.stats.trigger == "partial"
    assert r.ids.shape == (small_index.k,)
    assert (r.ids >= 0).any()  # phase A found *something* to answer with
    assert np.isfinite(r.dists[r.ids >= 0]).all()
    assert r.ndist == r.stats.est_ndist > 0
    assert sched.stats.partials == 1
    assert sched.pending == 0


def test_timed_out_is_explicit_without_degrade(small_db, small_index):
    """degrade=False keeps the lossless barrier semantics, but a missed
    deadline is still *declared* (TIMED_OUT), never silent."""
    from repro.serve import STATUS_TIMED_OUT

    q = _queries(small_db, nq=1, seed=33)[0]
    clock = FakeClock()
    sched = _make_sched(
        small_index,
        SchedulerConfig(fill=64, work_conserving=False),
        clock=clock,
    )
    sched.submit(SearchRequest(query=q, deadline_s=0.05))
    clock.advance(0.2)
    assert sched.step() == 1  # deadline trigger still drains the full search
    (r,) = sched.poll(block=True)
    assert r.status == STATUS_TIMED_OUT
    assert (r.ids >= 0).any()  # the full answer rides along
    assert sched.stats.timed_out == 1


@pytest.mark.parametrize("seed", range(3))
def test_terminal_status_property_random_interleavings(
    small_db, small_index, seed
):
    """Property (the overload contract): over random submit/step/poll
    interleavings with random deadlines, admission bounds and the
    degradation ladder armed, every ticket resolves to exactly one response
    with a terminal status, and every OK response met its deadline."""
    from repro.serve import STATUS_OK, TERMINAL_STATUSES

    rng = np.random.default_rng(2000 + seed)
    nq = int(rng.integers(12, 32))
    q = _queries(small_db, nq=nq, seed=40 + seed)
    clock = FakeClock()
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        SchedulerConfig(
            fill=int(rng.choice([2, 8])),
            degrade=True,
            max_inflight=int(rng.integers(4, 12)),
            overload="ticket",
        ),
        default_target_recall=small_index.target_recall,
        clock=clock,
    )
    for t in range(len(sched.router.tiers)):
        sched.cost_model.observe(t, float(rng.uniform(0.001, 0.1)))
    tickets = []
    responses = []
    i = 0
    while i < nq:
        for _ in range(int(rng.integers(1, 5))):
            if i >= nq:
                break
            deadline = (
                None if rng.random() < 0.3 else float(rng.uniform(0.001, 0.3))
            )
            tickets.append(
                sched.submit(SearchRequest(query=q[i], deadline_s=deadline))
            )
            i += 1
        clock.advance(float(rng.uniform(0.0, 0.2)))
        sched.step()
        if rng.random() < 0.5:
            responses.extend(sched.poll())
    responses.extend(sched.drain())

    assert len(responses) == nq and sched.pending == 0
    by_uid = {r.ticket.uid: r for r in responses}
    assert set(by_uid) == {t.uid for t in tickets}  # exactly one each
    for t in tickets:
        r = by_uid[t.uid]
        assert r.status in TERMINAL_STATUSES
        assert r.stats.status == r.status
        if r.status == STATUS_OK and t.deadline_t is not None:
            assert r.stats.done_t <= t.deadline_t  # OK means the deadline held
    st = sched.stats
    assert (
        st.rejected + st.partials
        + sum(tr.count for tr in st.tiers)
        == nq
    )


# --------------------------------------------------------------------------
# mutation seam: index-registered schedulers absorb, orphans raise
# --------------------------------------------------------------------------


def test_mutation_under_live_scheduler_absorbed(small_db):
    from repro.index import build_ada_index

    data, _, _ = small_db
    idx = build_ada_index(
        data[:1200], k=5, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=32,
    )
    sched = idx.scheduler()
    q = _queries(small_db, nq=3, seed=51)
    t0 = sched.submit(SearchRequest(query=q[0]))
    sched.flush()
    t1 = sched.submit(SearchRequest(query=q[1]))  # one in flight, one queued
    idx.insert(data[1200:1205])  # mutation under a live scheduler: absorbed
    t2 = sched.submit(SearchRequest(query=q[2]))  # new work binds new epoch
    sched.flush()
    rs = sched.poll(block=True)
    # every ticket reaches exactly one terminal status — nothing is lost
    assert sorted(r.ticket.uid for r in rs) == sorted(
        [t0.uid, t1.uid, t2.uid]
    )
    assert all(r.status in ("ok", "partial") for r in rs)
    by = {r.ticket.uid: r for r in rs}
    # the queued request was fenced: it completes on the snapshot it was
    # admitted against, not the post-mutation one
    assert by[t1.uid].stats.epoch == by[t0.uid].stats.epoch
    assert by[t2.uid].stats.epoch == by[t0.uid].stats.epoch + 1
    assert sched.stats.mutations == 1
    assert sched.stats.fenced_requests >= 1
    assert idx.scheduler() is sched  # absorb rebinds in place, no rebuild


def test_orphaned_scheduler_raises_instead_of_losing_tickets(small_db):
    from repro.index import build_ada_index
    from repro.serve import AdaServeScheduler, StalePlanError

    data, _, _ = small_db
    idx = build_ada_index(
        data[:1200], k=5, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=32,
    )
    # hand-constructed around a version probe but with no router_probe and
    # unknown to the index: there is no seam to rebind it through
    sched = AdaServeScheduler(
        idx.router(), default_target_recall=idx.target_recall,
        version_probe=lambda: idx._graph_version,
    )
    q = _queries(small_db, nq=2, seed=51)
    sched.submit(SearchRequest(query=q[0]))
    sched.flush()
    sched.submit(SearchRequest(query=q[1]))  # one in flight, one queued
    idx.insert(data[1200:1205])  # mutation under an orphaned scheduler
    with pytest.raises(StalePlanError, match="graph version"):
        sched.poll(block=True)
    with pytest.raises(StalePlanError, match="graph version"):
        sched.submit(SearchRequest(query=q[0]))
    with pytest.raises(StalePlanError, match="graph version"):
        sched.step()
    assert issubclass(StalePlanError, RuntimeError)
    # the manual seam recovers it: absorb against the fresh router, then
    # the pinned in-flight/queued work drains and new submits succeed
    sched.absorb_mutation(router=idx.router())
    rs = sched.poll(block=True)
    assert len(rs) == 2 and all(r.status in ("ok", "partial") for r in rs)
    # a *drained* registered scheduler stays harmless after mutation:
    # nothing to fence, poll just returns empty
    fresh = idx.scheduler()
    fresh.submit(SearchRequest(query=q[0]))
    fresh.drain()
    idx.insert(data[1205:1210])
    assert fresh.poll() == []


# --------------------------------------------------------------------------
# TierCostModel: the degradation ladder's deadline oracle
# --------------------------------------------------------------------------


def test_tier_cost_model_cold_predicts_zero():
    from repro.serve import TierCostModel

    m = TierCostModel()
    # no evidence at all -> 0.0 for every tier: degradation never fires on
    # priors (a cold model must not shed work before one drain is measured)
    assert m.predict(32) == 0.0
    assert m.predict(240) == 0.0


def test_tier_cost_model_borrows_costliest_lower_rung():
    from repro.serve import TierCostModel

    m = TierCostModel()
    m.observe(32, 0.004)
    m.observe(64, 0.010)
    # unseen higher tier borrows the costliest measured *lower* rung (a
    # lower bound: higher ef never drains faster)
    assert m.predict(128) == pytest.approx(0.010)
    assert m.predict(240) == pytest.approx(0.010)
    # unseen tier *below* every measurement still has no lower evidence
    assert m.predict(16) == 0.0
    # a measured tier answers its own EWMA, not a borrowed one
    assert m.predict(64) == pytest.approx(0.010)


def test_tier_cost_model_ewma_converges_alternating():
    from repro.serve import TierCostModel

    m = TierCostModel(alpha=0.25)
    m.observe(64, 0.008)  # first sample seeds the EWMA directly
    assert m.predict(64) == pytest.approx(0.008)
    m.observe(64, 0.016)
    assert m.predict(64) == pytest.approx(0.008 + 0.25 * 0.008)
    # alternating 8ms/16ms walls: the EWMA settles strictly inside the band
    for _ in range(200):
        m.observe(64, 0.008)
        m.observe(64, 0.016)
    assert 0.008 < m.predict(64) < 0.016
    assert m.as_dict() == {"64": m.predict(64)}


# --------------------------------------------------------------------------
# RequestStats derived intervals (queue_wait_s / service_s / e2e_s)
# --------------------------------------------------------------------------


def test_request_stats_derived_intervals():
    from repro.serve import RequestStats

    st = RequestStats(submit_t=10.0, est_t=10.5, dispatch_t=11.0,
                      done_t=11.25)
    assert st.queue_wait_s == pytest.approx(0.5)
    assert st.service_s == pytest.approx(0.25)
    assert st.e2e_s == pytest.approx(1.25)
    assert st.latency_s == st.e2e_s
    d = st.as_dict()
    for key in ("latency_s", "queue_wait_s", "service_s", "e2e_s"):
        assert d[key] == getattr(st, key)


def test_request_stats_intervals_guard_missing_stamps():
    from repro.serve import RequestStats

    # rejected: sheds at submit -- no estimate, no dispatch, no negatives
    rej = RequestStats(submit_t=5.0, done_t=5.001)
    assert rej.queue_wait_s == 0.0
    assert rej.service_s == 0.0
    assert rej.e2e_s == pytest.approx(0.001)
    # partial: estimated + queued but never dispatched a tier drain
    part = RequestStats(submit_t=5.0, est_t=5.1, done_t=5.4)
    assert part.queue_wait_s == 0.0
    assert part.service_s == 0.0
    assert part.e2e_s == pytest.approx(0.4)
    # in flight: nothing terminal yet
    live = RequestStats(submit_t=5.0, est_t=5.1, dispatch_t=5.2)
    assert live.e2e_s == 0.0
    assert live.service_s == 0.0
    assert live.queue_wait_s == pytest.approx(0.1)


def test_request_stats_wired_through_response(small_db, small_index):
    q = _queries(small_db, nq=4, seed=61)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
    )
    for x in q:
        sched.submit(SearchRequest(query=x))
    for r in sched.drain():
        st = r.stats
        assert st.e2e_s > 0.0
        assert st.queue_wait_s >= 0.0 and st.service_s > 0.0
        assert st.e2e_s >= st.queue_wait_s + st.service_s - 1e-9
        assert r.status == st.status
