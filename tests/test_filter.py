"""Filtered & multi-tenant search: FilterSpec canonicalization/round-trip,
AttributeStore mask compilation + histogram selectivity, the planner's
pre-filter vs post-filter-with-overquery lowering, the selectivity-sweep
recall property under *both* lowerings, bit-identity of masked kernels vs
the masked oracle, and per-tenant SLO resolution / admission quotas /
bounded metric labels in the scheduler."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RouterConfig, SchedulerConfig, SearchSpec, SpecOverrides
from repro.filter import (
    AttributeStore,
    FilterCompileError,
    FilterSpec,
    attach_mask,
)
from repro.index import build_ada_index
from repro.obs.audit import oracle_topk
from repro.serve import (
    AdaServeScheduler,
    OverloadedError,
    SearchRequest,
    TenantSLO,
)

NC = 40  # clusters in the filtered-search fixture (each ~2.5% of rows)


@pytest.fixture(scope="module")
def fdb():
    """Clustered vectors with known cluster assignment + attribute columns.

    Built separately from ``small_db`` because the filter tests need the
    per-row cluster id to construct masks of controlled selectivity that
    stay *correlated with query locality* (a tenant querying its own data —
    the regime where post-filter-with-overquery is actually sound)."""
    rng = np.random.default_rng(11)
    n, d = 3000, 32
    centers = rng.normal(0, 1, (NC, d))
    assign = rng.integers(0, NC, n)
    data = (centers[assign] + 0.25 * rng.normal(0, 1, (n, d))).astype(np.float32)
    rvals = rng.uniform(0, 1, n)
    return data, centers, assign, rvals


@pytest.fixture(scope="module")
def fidx(fdb):
    data, centers, assign, rvals = fdb
    idx = build_ada_index(
        data, k=5, target_recall=0.9, m=8, ef_construction=60, ef_cap=160,
        num_samples=32,
    )
    idx.attach_attributes(
        tenant=[f"t{a % 4}" for a in assign],
        categorical={"cluster": [str(a) for a in assign]},
        numeric={"r": rvals, "date": 19000.0 + 365.0 * rvals},
    )
    return idx


def _fqueries(centers, nq=16, seed=0):
    """Queries near cluster 0's center (the always-valid cluster below)."""
    rng = np.random.default_rng(100 + seed)
    return (
        centers[0][None] + 0.25 * rng.normal(0, 1, (nq, centers.shape[1]))
    ).astype(np.float32)


def _queries(small_db, nq=8, seed=1):
    data, centers, w = small_db
    rng = np.random.default_rng(seed)
    qc = rng.choice(len(centers), size=nq, p=w)
    return (centers[qc] + 0.3 * rng.normal(0, 1, (nq, centers.shape[1]))).astype(
        np.float32
    )


def _recall(ids, gt) -> float:
    out = []
    for row, g in zip(np.asarray(ids), np.asarray(gt)):
        g = g[g >= 0]
        out.append(len(set(row.tolist()) & set(g.tolist())) / max(len(g), 1))
    return float(np.mean(out))


# --------------------------------------------------------------------------
# FilterSpec: canonicalization, hashability, round-trip, trivial collapse
# --------------------------------------------------------------------------


def test_filterspec_canonicalization_and_hash():
    a = FilterSpec(
        tenant="acme",
        attrs={"cat": ("b", "a"), "kind": "x"},  # scalar + unordered values
        ranges={"date": (19000, 19365)},
    )
    b = FilterSpec(
        tenant="acme",
        attrs=(("kind", ("x",)), ("cat", ("a", "b"))),  # tuple form, reordered
        ranges=(("date", 19000.0, 19365.0),),
    )
    assert a == b and hash(a) == hash(b)
    assert a.attrs == (("cat", ("a", "b")), ("kind", ("x",)))
    assert a.needs_store() and not a.trivial
    assert FilterSpec.from_dict(a.as_dict()) == a
    only_ids = FilterSpec(id_range=(10, 90))
    assert not only_ids.needs_store() and not only_ids.trivial
    with pytest.raises(ValueError):
        FilterSpec(id_range=(-1, 5))
    with pytest.raises(ValueError):
        FilterSpec(ranges={"x": (2.0, 1.0)})
    with pytest.raises(ValueError):
        FilterSpec(tenant="")
    with pytest.raises(ValueError):
        FilterSpec(attrs={"cat": ()})


def test_searchspec_collapses_trivial_filter_and_roundtrips():
    assert SearchSpec(filter=FilterSpec()).filter is None  # trivial -> None
    spec = SearchSpec(
        k=5,
        mode="streaming",
        filter=FilterSpec(tenant="a", ranges={"date": (1.0, 2.0)}),
        overrides=SpecOverrides(
            scheduler=SchedulerConfig(
                fill=16,
                tenants={"a": TenantSLO(target_recall=0.95, max_inflight=4)},
            )
        ),
    )
    # dict round-trip reconstructs FilterSpec and the TenantSLO tuple alike
    assert SearchSpec.from_dict(spec.as_dict()) == spec
    twin = SearchSpec.from_dict(spec.as_dict())
    assert hash(twin) == hash(spec)


def test_scheduler_config_tenant_validation():
    cfg = SchedulerConfig(tenants={"b": TenantSLO(), "a": TenantSLO()})
    assert [name for name, _ in cfg.tenants] == ["a", "b"]  # canonical order
    with pytest.raises(ValueError):
        SchedulerConfig(tenants=(("a", TenantSLO()), ("a", TenantSLO())))
    with pytest.raises(ValueError):
        SchedulerConfig(tenants=(("", TenantSLO()),))
    with pytest.raises(ValueError):
        SchedulerConfig(tenants=(("a", {"max_inflight": 1}),))
    with pytest.raises(ValueError):
        TenantSLO(target_recall=1.5)
    with pytest.raises(ValueError):
        TenantSLO(deadline_s=0.0)
    with pytest.raises(ValueError):
        TenantSLO(max_inflight=-1)


# --------------------------------------------------------------------------
# AttributeStore: exact masks, histogram estimates, append semantics
# --------------------------------------------------------------------------


def test_attribute_store_mask_matches_brute_force():
    n = 1000
    rng = np.random.default_rng(3)
    tenant = rng.choice(["a", "b", "c"], n)
    cat = rng.choice(["u", "v", "w", "x"], n)
    x = rng.uniform(0, 1, n)
    x[::17] = np.nan  # unattributed rows must fail range clauses
    store = AttributeStore(
        n, tenant=tenant, categorical={"cat": cat}, numeric={"x": x}
    )
    spec = FilterSpec(
        tenant="a", attrs={"cat": ("u", "v")}, ranges={"x": (0.2, 0.7)},
        id_range=(100, 900),
    )
    mask = store.compile_mask(spec)
    ref = (
        (tenant == "a")
        & np.isin(cat, ["u", "v"])
        & (x >= 0.2) & (x <= 0.7)
        & (np.arange(n) >= 100) & (np.arange(n) < 900)
    )
    np.testing.assert_array_equal(mask, ref)
    # histogram estimate: clauses here really are independent draws, so the
    # independence-product estimate lands near the exact pass fraction
    est = store.estimate_selectivity(spec)
    assert abs(est - ref.mean()) < 0.05
    with pytest.raises(FilterCompileError):
        store.compile_mask(FilterSpec(attrs={"nope": ("a",)}))
    with pytest.raises(FilterCompileError):
        store.estimate_selectivity(FilterSpec(ranges={"nope": (0, 1)}))
    with pytest.raises(ValueError):
        store.compile_mask(spec, n + 5)  # store/index row-count drift


def test_attribute_store_append_fills_never_match():
    store = AttributeStore(
        4, tenant=["a", "a", "b", "b"], numeric={"d": [1.0, 2.0, 3.0, 4.0]}
    )
    store.append(2, tenant=["a", "b"], numeric={"d": [5.0, 6.0]})
    store.append(2)  # unattributed rows: "" tenant, NaN numeric
    assert store.n == 8
    np.testing.assert_array_equal(
        store.compile_mask(FilterSpec(tenant="a")),
        [True, True, False, False, True, False, False, False],
    )
    np.testing.assert_array_equal(
        store.compile_mask(FilterSpec(ranges={"d": (1.0, 99.0)}))[-2:],
        [False, False],
    )
    with pytest.raises(ValueError):
        store.append(1, categorical={"unknown": ["x"]})
    with pytest.raises(ValueError):
        store.append(1, numeric={"d": [1.0, 2.0]})  # wrong length


# --------------------------------------------------------------------------
# masked kernels vs masked oracle: bit-identity
# --------------------------------------------------------------------------


def test_masked_frontier_kernels_bit_identical_to_masked_oracle(fidx):
    from repro.kernels import ops, ref

    g = fidx.graph
    n = int(g.alive.shape[0])
    rng = np.random.default_rng(5)
    valid = jnp.asarray(rng.random(n) < 0.3)
    ids = jnp.asarray(rng.integers(0, n, (4, 64)), jnp.int32)
    ids = ids.at[0, :5].set(-1)  # pre-existing pad/visited masking survives
    qn = g.vectors[:4]  # prepared rows double as prepared queries

    masked_ids = jnp.where(valid[jnp.maximum(ids, 0)], ids, -1)
    want = np.asarray(ref.frontier_ref(masked_ids, qn, g.vectors))
    fin = np.isfinite(want)
    # the per-query jnp-oracle rung IS frontier_ref: bit-identical
    got_oracle = ops.frontier_keys(ids, qn, g.vectors, valid=valid)
    np.testing.assert_array_equal(np.asarray(got_oracle), want)

    # every rung (per-query/batch x oracle/Pallas): scoring through valid=
    # is bit-identical to hand-masking the ids on the same path, masked
    # slots are exactly +inf where the masked oracle says so, and finite
    # keys match the oracle at the kernel suite's tolerance
    for fn in (ops.frontier_keys, ops.frontier_keys_batch):
        for use_kernel in (False, True):
            got = np.asarray(
                fn(ids, qn, g.vectors, use_kernel=use_kernel, valid=valid)
            )
            pre = np.asarray(
                fn(masked_ids, qn, g.vectors, use_kernel=use_kernel)
            )
            np.testing.assert_array_equal(got, pre)
            np.testing.assert_array_equal(np.isinf(got), ~fin)
            np.testing.assert_allclose(
                got[fin], want[fin], rtol=3e-4, atol=3e-4
            )


def test_premode_search_bit_identical_to_masked_oracle(fdb, fidx):
    """filter_mode="pre" + g.fmask is the same search as folding the mask
    into ``alive`` (tombstone semantics) — ids AND distances, bit-exact."""
    from repro.index.search import search

    data, centers, assign, rvals = fdb
    filt = FilterSpec(attrs={"cluster": tuple(str(c) for c in range(8))})
    mask = fidx.attributes.compile_mask(filt)
    q = _fqueries(centers, nq=6, seed=3)
    gt = oracle_topk(fidx.graph, q, fidx.search_cfg, valid=jnp.asarray(mask))

    cfg = dataclasses.replace(
        fidx.search_cfg,
        filter_mode="pre", patience=0, precision="fp32",
        use_distance_kernel=False,
    )
    g = attach_mask(fidx.graph, jnp.asarray(mask))
    ef = jnp.full((q.shape[0],), cfg.ef_cap, jnp.int32)
    res = search(g, jnp.asarray(q), ef, cfg)
    np.testing.assert_array_equal(np.asarray(res.ids), gt)


def test_oracle_topk_valid_mask_changes_ground_truth(fdb, fidx):
    """Satellite fix: GT builders must grade filtered queries against
    *filtered* ground truth — the valid= mask is honored, and a graph
    already carrying fmask folds it automatically."""
    data, centers, assign, rvals = fdb
    q = _fqueries(centers, nq=4, seed=7)
    mask = np.asarray(assign != 0)  # exclude the query cluster entirely
    plain = oracle_topk(fidx.graph, q, fidx.search_cfg)
    masked = oracle_topk(fidx.graph, q, fidx.search_cfg, valid=jnp.asarray(mask))
    assert not np.array_equal(plain, masked)
    assert mask[masked[masked >= 0]].all()  # every graded id passes the mask
    # fmask-carrying graph == explicit valid=, with no extra plumbing
    via_fmask = oracle_topk(
        attach_mask(fidx.graph, jnp.asarray(mask)), q, fidx.search_cfg
    )
    np.testing.assert_array_equal(masked, via_fmask)


# --------------------------------------------------------------------------
# planner lowering: selectivity estimate -> pre vs post, explain record
# --------------------------------------------------------------------------


def test_planner_picks_pre_for_selective_filters(fidx):
    plan = fidx.plan(SearchSpec(filter=FilterSpec(attrs={"cluster": ("0",)})))
    d = plan.explain()["filter"]
    assert d["mode"] == "pre" and not d["pinned"]
    assert d["selectivity_estimate"] < 0.5
    assert plan.search_cfg.filter_mode == "pre"
    assert FilterSpec.from_dict(d["spec"]) == FilterSpec(
        attrs={"cluster": ("0",)}
    )
    assert plan.explain()["search"]["filter_mode"] == "pre"


def test_planner_picks_post_overquery_for_broad_filters(fidx):
    filt = FilterSpec(ranges={"r": (0.0, 0.95)})
    plan = fidx.plan(SearchSpec(filter=filt, mode="routed"))
    d = plan.explain()["filter"]
    assert d["mode"] == "post"
    assert d["selectivity_estimate"] > 0.5
    # overquery: ef_margin inflated toward 1/selectivity
    assert d["ef_inflation"] == pytest.approx(
        1.0 / d["selectivity_estimate"], rel=1e-6
    )
    assert plan.router_cfg.ef_margin >= d["ef_inflation"]
    assert plan.search_cfg.filter_mode == "post"
    # ...but the fused oneshot path has no overquery seam: forced to pre
    one = fidx.plan(SearchSpec(filter=filt))
    assert one.explain()["filter"]["mode"] == "pre"
    assert any("oneshot" in n for n in one.explain()["notes"])


def test_filter_without_store(fdb):
    data, centers, assign, rvals = fdb
    idx = build_ada_index(
        data[:400], k=5, target_recall=0.9, m=6, ef_construction=40,
        ef_cap=64, num_samples=16,
    )
    # attribute predicates need a store
    with pytest.raises(FilterCompileError, match="attach_attributes"):
        idx.plan(SearchSpec(filter=FilterSpec(tenant="a")))
    # ...but positional id_range works storeless (exact selectivity)
    plan = idx.plan(SearchSpec(filter=FilterSpec(id_range=(0, 40))))
    d = plan.explain()["filter"]
    assert d["mode"] == "pre"
    assert d["selectivity_estimate"] == pytest.approx(0.1)
    res = plan.search(_fqueries(centers, nq=4, seed=1))
    ids = np.asarray(res.ids)
    assert (ids[ids >= 0] < 40).all()


def test_filtered_plans_cache_by_spec(fidx):
    a = fidx.plan(SearchSpec(filter=FilterSpec(tenant="t0")))
    b = fidx.plan(SearchSpec(filter=FilterSpec(tenant="t0")))
    c = fidx.plan(SearchSpec(filter=FilterSpec(tenant="t1")))
    assert a is b and c is not a


# --------------------------------------------------------------------------
# the acceptance property: selectivity sweep x seeds, both lowerings
# --------------------------------------------------------------------------


def _sweep_filter(sel, seed):
    """A mask of ~``sel`` pass fraction that keeps cluster 0 (the query
    cluster) well-populated with valid rows — predicate correlated with
    query locality, the regime both lowerings must serve at target."""
    if sel == 0.01:
        off = 0.3 * seed
        return FilterSpec(
            attrs={"cluster": ("0",)}, ranges={"r": (off, off + 0.4)}
        )
    if sel == 0.1:
        keep = ("0",) + tuple(str(c) for c in range(3 * seed + 1, 3 * seed + 4))
        return FilterSpec(attrs={"cluster": keep})
    off = 0.25 * seed if sel == 0.5 else 0.05 * seed
    return FilterSpec(ranges={"r": (off, off + sel)})


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("sel", [0.01, 0.1, 0.5, 0.9])
def test_filtered_recall_sweep_both_lowerings(fdb, fidx, sel, seed):
    data, centers, assign, rvals = fdb
    filt = _sweep_filter(sel, seed)
    mask = fidx.attributes.compile_mask(filt)
    assert abs(mask.mean() - sel) < max(0.6 * sel, 0.01)  # construction sanity
    q = _fqueries(centers, nq=16, seed=seed)
    gt = oracle_topk(fidx.graph, q, fidx.search_cfg, valid=jnp.asarray(mask))
    target = fidx.target_recall

    pre = fidx.plan(SearchSpec(
        filter=filt,
        overrides=SpecOverrides(
            search=dataclasses.replace(fidx.search_cfg, filter_mode="pre")
        ),
    ))
    assert pre.search_cfg.filter_mode == "pre"
    ids_pre = np.asarray(pre.search(q).ids)
    assert mask[ids_pre[ids_pre >= 0]].all()  # never an invalid result
    assert _recall(ids_pre, gt) >= target

    post = fidx.plan(SearchSpec(
        filter=filt, mode="routed",
        overrides=SpecOverrides(
            search=dataclasses.replace(fidx.search_cfg, filter_mode="post")
        ),
    ))
    assert post.search_cfg.filter_mode == "post"
    assert post.explain()["filter"]["pinned"]
    ids_post = np.asarray(post.search(q).ids)
    assert mask[ids_post[ids_post >= 0]].all()
    assert _recall(ids_post, gt) >= target


# --------------------------------------------------------------------------
# mutation: attribute append rides insert; filtered plans revalidate
# --------------------------------------------------------------------------


def test_insert_with_attributes_revalidates_filtered_plan(fdb):
    data, centers, assign, rvals = fdb
    idx = build_ada_index(
        data[:600], k=5, target_recall=0.9, m=6, ef_construction=40,
        ef_cap=64, num_samples=16,
    )
    idx.attach_attributes(tenant=["a" if i % 2 else "b" for i in range(600)])
    q = _fqueries(centers, nq=4, seed=2)
    plan = idx.plan(SearchSpec(filter=FilterSpec(tenant="a")))
    ids0 = np.asarray(plan.search(q).ids)
    assert (ids0[ids0 >= 0] % 2 == 1).all()  # odd rows are tenant "a"

    idx.insert(data[600:610], attributes={"tenant": ["a"] * 10})
    res = plan.search(q)  # default on_mutation: revalidated in place
    assert np.asarray(res.ids).shape == (4, 5)
    assert idx.attributes.n == 610
    # the recompiled mask covers the inserted rows and admits them
    assert np.asarray(plan._filter_mask()).shape == (610,)
    assert np.asarray(plan._filter_mask())[600:610].all()

    idx.insert(data[610:615])  # no attributes: never-matching fills
    assert not np.asarray(plan._filter_mask())[610:615].any()
    ids2 = np.asarray(plan.search(q).ids)
    assert not np.isin(ids2, np.arange(610, 615)).any()


def test_insert_attributes_without_store_raises(fdb):
    from repro.index import IndexMutationError

    data, centers, assign, rvals = fdb
    idx = build_ada_index(
        data[:300], k=5, target_recall=0.9, m=6, ef_construction=40,
        ef_cap=64, num_samples=16,
    )
    with pytest.raises(IndexMutationError):
        idx.insert(data[300:305], attributes={"tenant": ["a"] * 5})


# --------------------------------------------------------------------------
# multi-tenancy: SLO resolution, admission quotas, bounded metric labels
# --------------------------------------------------------------------------


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def test_tenant_slo_resolution(small_db, small_index):
    clock = FakeClock(5.0)
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        SchedulerConfig(
            fill=64,
            tenants={"gold": TenantSLO(target_recall=0.95, deadline_s=2.0)},
        ),
        default_target_recall=0.9,
        clock=clock,
    )
    q = _queries(small_db, nq=3, seed=41)
    t_slo = sched.submit(SearchRequest(query=q[0], tenant="gold"))
    assert t_slo.deadline_t == pytest.approx(7.0)  # SLO deadline applied
    t_req = sched.submit(
        SearchRequest(query=q[1], tenant="gold", deadline_s=0.5)
    )
    assert t_req.deadline_t == pytest.approx(5.5)  # request wins over SLO
    t_def = sched.submit(SearchRequest(query=q[2]))
    assert t_def.deadline_t is None  # default namespace: no SLO deadline
    by_uid = {r.ticket.uid: r for r in sched.drain()}
    assert by_uid[t_slo.uid].stats.tenant == "gold"
    assert by_uid[t_def.uid].stats.tenant == ""


def test_tenant_quota_prevents_cross_tenant_starvation(small_db, small_index):
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        SchedulerConfig(
            fill=64, tenants={"noisy": TenantSLO(max_inflight=2)}
        ),
        default_target_recall=0.9,
    )
    q = _queries(small_db, nq=8, seed=43)
    sched.submit(SearchRequest(query=q[0], tenant="noisy"))
    sched.submit(SearchRequest(query=q[1], tenant="noisy"))
    with pytest.raises(OverloadedError, match="tenant"):
        sched.submit(SearchRequest(query=q[2], tenant="noisy"))
    # the saturating tenant does not consume the other tenants' headroom
    sched.submit(SearchRequest(query=q[3], tenant="quiet"))
    sched.submit(SearchRequest(query=q[4]))
    responses = sched.drain()
    assert len(responses) == 4
    assert all(r.status != "rejected" for r in responses)
    # quota frees when the tenant's requests reach a terminal state
    sched.submit(SearchRequest(query=q[5], tenant="noisy"))
    assert len(sched.drain()) == 1


def test_tenant_quota_ticket_mode_and_metric_labels(small_db, small_index):
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        SchedulerConfig(
            fill=64, overload="ticket",
            tenants={"gold": TenantSLO(max_inflight=1)},
        ),
        default_target_recall=0.9,
    )
    q = _queries(small_db, nq=4, seed=47)
    t0 = sched.submit(SearchRequest(query=q[0], tenant="gold"))
    t_shed = sched.submit(SearchRequest(query=q[1], tenant="gold"))
    sched.submit(SearchRequest(query=q[2]))              # default namespace
    sched.submit(SearchRequest(query=q[3], tenant="rando"))  # unconfigured
    by_uid = {r.ticket.uid: r for r in sched.drain()}
    shed = by_uid[t_shed.uid]
    assert shed.status == "rejected" and shed.stats.tenant == "gold"
    assert by_uid[t0.uid].status == "ok"

    # bounded label cardinality: configured names + "default" + "other"
    req = sched.metrics.as_dict()["requests"]
    assert req['{tenant="gold"}'] == 2
    assert req['{tenant="default"}'] == 1
    assert req['{tenant="other"}'] == 1
    e2e = sched.metrics.as_dict()["request_e2e_s"]
    assert any('tenant="gold"' in k for k in e2e)
    text = sched.metrics.render_prometheus()
    assert 'requests{tenant="gold"} 2' in text
    assert 'tenant="rando"' not in text  # unconfigured never mints a label


def test_plan_submits_carry_filter_tenant(fdb, fidx):
    data, centers, assign, rvals = fdb
    plan = fidx.plan(
        SearchSpec(filter=FilterSpec(tenant="t0"), mode="streaming")
    )
    q = _fqueries(centers, nq=2, seed=6)
    tickets = [plan.submit(row) for row in q]
    plan.flush()
    by_uid = {r.ticket.uid: r for r in plan.poll(block=True)}
    mask = fidx.attributes.compile_mask(FilterSpec(tenant="t0"))
    for t in tickets:
        r = by_uid[t.uid]
        assert r.stats.tenant == "t0"  # the spec's tenant rides the request
        ids = np.asarray(r.ids)
        assert mask[ids[ids >= 0]].all()


def test_explain_lists_configured_tenants(fidx):
    plan = fidx.plan(SearchSpec(
        mode="streaming",
        overrides=SpecOverrides(
            scheduler=SchedulerConfig(
                tenants={"b": TenantSLO(), "a": TenantSLO(max_inflight=2)}
            )
        ),
    ))
    assert plan.explain()["scheduler"]["tenants"] == ["a", "b"]
