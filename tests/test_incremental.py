"""§6.3 incremental updates: insert/delete keep stats exact, GT fresh, recall up."""
import jax.numpy as jnp
import numpy as np

from repro.core import compute_stats
from repro.index import brute_force_topk, build_ada_index, prepare_database, prepare_queries, recall_at_k


def test_insert_updates_stats_and_gt(small_db):
    data, centers, w = small_db
    base, extra = data[:2000], data[2000:2500]
    idx = build_ada_index(
        base, k=10, target_recall=0.9, m=8, ef_construction=60, ef_cap=200, num_samples=50
    )
    t = idx.insert(extra)
    assert t["stats_s"] >= 0
    # stats must equal recompute on the union
    ref = compute_stats(jnp.asarray(np.concatenate([base, extra])), mode="full", normalize=True)
    np.testing.assert_allclose(np.asarray(idx.stats.mean), np.asarray(ref.mean), rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(idx.stats.cov), np.asarray(ref.cov), rtol=5e-2, atol=1e-4)
    assert int(idx.stats.n) == 2500
    # GT of proxies must include new rows when they are nearer
    qp = prepare_queries(jnp.asarray(idx.raw_data[idx.sample_ids]), "cos_dist")
    vp = prepare_database(jnp.asarray(idx.raw_data), "cos_dist")
    _, true_gt = brute_force_topk(qp, vp, k=10)
    overlap = recall_at_k(jnp.asarray(idx.sample_gt), true_gt)
    assert float(overlap.mean()) > 0.98
    # searching still works after the insert
    res = idx.query(idx.raw_data[:32])
    assert np.asarray(res.ids).max() >= 2000  # new rows retrievable


def test_delete_updates_stats_and_search(small_db):
    data, _, _ = small_db
    base = data[:2000]
    idx = build_ada_index(
        base, k=10, target_recall=0.9, m=8, ef_construction=60, ef_cap=200, num_samples=50
    )
    dead = np.arange(0, 300)
    idx.delete(dead)
    assert int(idx.stats.n) == 1700
    ref = compute_stats(jnp.asarray(base[300:]), mode="full", normalize=True)
    np.testing.assert_allclose(np.asarray(idx.stats.mean), np.asarray(ref.mean), rtol=1e-2, atol=1e-4)
    res = idx.query(base[1000:1032])
    ids = np.asarray(res.ids)
    assert not np.isin(ids[ids >= 0], dead).any()
