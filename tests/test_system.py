"""End-to-end behaviour tests for the paper's system (Ada-ef, Figure 2 flow).

These assert the paper's *claims* on a scaled-down workload:
(i)  Ada-ef approximately meets the declarative target recall,
(ii) it avoids over-searching (less work than a recall-matched static ef),
(iii) it improves tail recall vs an average-matched static ef,
(iv) higher targets cost more work (sensitivity, Fig. 7 direction),
(v)  the offline stage is cheap and its artifacts tiny (Tables 2-3).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.index import (
    brute_force_topk,
    build_ada_index,
    prepare_database,
    prepare_queries,
    recall_at_k,
)


@pytest.fixture(scope="module")
def workload():
    rng = np.random.default_rng(42)
    n, d, nc, nq = 4000, 64, 40, 192
    sizes = 1.0 / np.arange(1, nc + 1)
    sizes /= sizes.sum()
    centers = rng.normal(0, 1, (nc, d))
    assign = rng.choice(nc, size=n, p=sizes)
    data = (centers[assign] + 0.25 * rng.normal(0, 1, (n, d))).astype(np.float32)
    qa = rng.choice(nc, size=nq, p=sizes)
    queries = (centers[qa] + 0.25 * rng.normal(0, 1, (nq, d))).astype(np.float32)
    vp = prepare_database(jnp.asarray(data), "cos_dist")
    qp = prepare_queries(jnp.asarray(queries), "cos_dist")
    gt = np.asarray(brute_force_topk(qp, vp, k=10)[1])
    return data, queries, gt


@pytest.fixture(scope="module")
def ada(workload):
    data, _, _ = workload
    return build_ada_index(
        data, k=10, target_recall=0.95, m=8, ef_construction=100, ef_cap=400, num_samples=100
    )


def test_meets_target_recall(workload, ada):
    _, queries, gt = workload
    res = ada.query(queries)
    rec = np.asarray(recall_at_k(res.ids, jnp.asarray(gt)))
    assert rec.mean() >= 0.92, f"avg recall {rec.mean():.3f} below target band"


def test_avoids_over_searching(workload, ada):
    """Work (distance comps) must be below the max-ef baseline at ~same recall."""
    _, queries, gt = workload
    res_ada = ada.query(queries)
    res_max = ada.query_static(queries, ada.search_cfg.ef_cap)
    rec_ada = float(recall_at_k(res_ada.ids, jnp.asarray(gt)).mean())
    rec_max = float(recall_at_k(res_max.ids, jnp.asarray(gt)).mean())
    nd_ada = float(np.mean(np.asarray(res_ada.ndist)))
    nd_max = float(np.mean(np.asarray(res_max.ndist)))
    assert nd_ada < 0.8 * nd_max
    assert rec_ada >= rec_max - 0.05


def test_improves_tail_recall_vs_matched_static(workload, ada):
    """Paper claim: at comparable average work, Ada-ef lifts P5 recall."""
    _, queries, gt = workload
    res_ada = ada.query(queries)
    nd_ada = float(np.mean(np.asarray(res_ada.ndist)))
    best = None
    for ef in (10, 15, 20, 30, 45, 65, 100):
        r = ada.query_static(queries, ef)
        nd = float(np.mean(np.asarray(r.ndist)))
        if best is None or abs(nd - nd_ada) < abs(best[1] - nd_ada):
            best = (ef, nd, r)
    _, _, res_static = best
    gt_j = jnp.asarray(gt)
    p5_ada = np.percentile(np.asarray(recall_at_k(res_ada.ids, gt_j)), 5)
    p5_static = np.percentile(np.asarray(recall_at_k(res_static.ids, gt_j)), 5)
    assert p5_ada >= p5_static - 1e-9


def test_sensitivity_higher_target_costs_more(workload, ada):
    _, queries, _ = workload
    nd = []
    for target in (0.85, 0.99):
        res = ada.query(queries, target_recall=target)
        nd.append(float(np.mean(np.asarray(res.ndist))))
    assert nd[1] >= nd[0]


def test_offline_artifacts_tiny_vs_index(ada):
    """Tables 2-3 claim: offline stage cheap; artifacts << index size."""
    from repro.core import stats_nbytes

    assert ada.timings.stats_s < 5.0
    footprint = stats_nbytes(ada.stats) + ada.table.nbytes()
    index_bytes = ada.host_index.freeze().nbytes()
    assert footprint < 0.1 * index_bytes
