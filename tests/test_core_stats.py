"""Core Ada-ef math: dataset stats, FDL Gaussianity, incremental updates.

Includes hypothesis property tests of the system invariants:
- merge is exact (merge(split(V)) == stats(V))
- unmerge inverts merge
- FDL moments match the empirical full distance list
- quantiles are monotone in p
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    METRIC_COSINE_DIST,
    METRIC_IP,
    compute_stats,
    estimate_fdl,
    fdl_quantile,
    merge_stats,
    quadratic_form,
    unmerge_stats,
)


def _db(seed, n=2000, d=32, skew=True):
    rng = np.random.default_rng(seed)
    v = rng.normal(0.05, 1.0, (n, d)).astype(np.float32)
    if skew:
        v *= 1.0 + rng.gamma(2.0, 0.4, (1, d)).astype(np.float32)
    return v


def test_stats_match_numpy():
    v = _db(0)
    st_ = compute_stats(jnp.asarray(v), mode="full")
    np.testing.assert_allclose(np.asarray(st_.mean), v.mean(0), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_.cov), np.cov(v.T), rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_.var), v.var(0, ddof=1), rtol=1e-3, atol=1e-5)


def test_normalized_stats():
    v = _db(1)
    st_ = compute_stats(jnp.asarray(v), mode="full", normalize=True)
    vn = v / np.linalg.norm(v, axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(st_.mean), vn.mean(0), rtol=1e-4, atol=1e-6)


def test_quadratic_form_modes():
    v = _db(2, d=24)
    q = np.random.default_rng(3).normal(0, 1, (5, 24)).astype(np.float32)
    full = compute_stats(jnp.asarray(v), mode="full")
    diag = compute_stats(jnp.asarray(v), mode="diag")
    lr = compute_stats(jnp.asarray(v), mode="lowrank", rank=24)
    qf_full = np.asarray(quadratic_form(full, jnp.asarray(q)))
    qf_lr = np.asarray(quadratic_form(lr, jnp.asarray(q)))
    qf_diag = np.asarray(quadratic_form(diag, jnp.asarray(q)))
    # full-rank "lowrank" should match the exact quadratic form
    np.testing.assert_allclose(qf_lr, qf_full, rtol=5e-2)
    assert qf_diag.shape == qf_full.shape
    assert (qf_full > 0).all()


@settings(max_examples=20, deadline=None)
@given(split=st.integers(min_value=100, max_value=1900), seed=st.integers(0, 50))
def test_merge_exact(split, seed):
    v = _db(seed, n=2000, d=16)
    a = compute_stats(jnp.asarray(v[:split]), mode="full")
    b = compute_stats(jnp.asarray(v[split:]), mode="full")
    ab = merge_stats(a, b)
    ref = compute_stats(jnp.asarray(v), mode="full")
    np.testing.assert_allclose(np.asarray(ab.mean), np.asarray(ref.mean), rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ab.cov), np.asarray(ref.cov), rtol=1e-2, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(split=st.integers(min_value=200, max_value=1800), seed=st.integers(0, 50))
def test_unmerge_inverts_merge(split, seed):
    v = _db(seed, n=2000, d=16)
    a = compute_stats(jnp.asarray(v[:split]), mode="full")
    b = compute_stats(jnp.asarray(v[split:]), mode="full")
    ab = merge_stats(a, b)
    back = unmerge_stats(ab, b)
    np.testing.assert_allclose(np.asarray(back.mean), np.asarray(a.mean), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(back.cov), np.asarray(a.cov), rtol=5e-2, atol=1e-3)


@pytest.mark.parametrize("metric", [METRIC_IP, METRIC_COSINE_DIST])
def test_fdl_moments_match_empirical(metric):
    """Theorem 5.2 / Eq. (1)-(3): estimated mu/sigma vs the actual FDL."""
    v = _db(4, n=4000, d=64)
    normalize = metric == METRIC_COSINE_DIST
    stats = compute_stats(jnp.asarray(v), mode="full", normalize=normalize)
    rng = np.random.default_rng(5)
    q = rng.normal(0, 1, (8, 64)).astype(np.float32)
    params = estimate_fdl(stats, jnp.asarray(q), metric=metric)
    if metric == METRIC_COSINE_DIST:
        vn = v / np.linalg.norm(v, axis=1, keepdims=True)
        qn = q / np.linalg.norm(q, axis=1, keepdims=True)
        fdl = 1.0 - qn @ vn.T
    else:
        fdl = q @ v.T
    np.testing.assert_allclose(np.asarray(params.mu), fdl.mean(1), rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(np.asarray(params.sigma), fdl.std(1), rtol=8e-2, atol=2e-3)


def test_fdl_gaussianity_ks():
    """The FDL of high-d embeddings is approximately Gaussian (paper §5)."""
    from scipy import stats as sps

    v = _db(6, n=5000, d=256)
    vn = v / np.linalg.norm(v, axis=1, keepdims=True)
    q = np.random.default_rng(7).normal(0, 1, 256)
    qn = q / np.linalg.norm(q)
    fdl = 1.0 - vn @ qn
    z = (fdl - fdl.mean()) / fdl.std()
    ks = sps.kstest(z, "norm").statistic
    assert ks < 0.05, f"FDL far from Gaussian: KS={ks:.3f}"


@settings(max_examples=20, deadline=None)
@given(
    p1=st.floats(min_value=1e-4, max_value=0.49),
    p2=st.floats(min_value=0.5, max_value=0.999),
)
def test_quantiles_monotone(p1, p2):
    v = _db(8)
    stats = compute_stats(jnp.asarray(v), mode="full", normalize=True)
    q = jnp.asarray(np.random.default_rng(9).normal(0, 1, (32,)).astype(np.float32))
    params = estimate_fdl(stats, q)
    assert float(fdl_quantile(params, jnp.asarray(p1))) < float(
        fdl_quantile(params, jnp.asarray(p2))
    )
