"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions, decode-vs-prefill consistency (assignment
deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.train import OptimizerConfig, TrainConfig, init_optimizer, make_train_step

RNG = np.random.default_rng(0)
ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b=2, s=48):
    tok = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(RNG.normal(0, 1, (b, s, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "vlm":
        npatch = cfg.num_frontend_tokens
        batch["tokens"] = tok[:, : s - npatch]
        batch["labels"] = tok[:, : s - npatch]
        batch["patches"] = jnp.asarray(
            RNG.normal(0, 1, (b, npatch, cfg.frontend_dim)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = model.loss_fn(params, batch)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0

    tcfg = TrainConfig(opt=OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    step = jax.jit(make_train_step(model, tcfg))
    opt = init_optimizer(params)
    new_params, _, m = step(params, opt, batch)
    assert jnp.isfinite(m["loss"])
    assert jnp.isfinite(m["grad_norm"]) and float(m["grad_norm"]) > 0
    # params actually moved
    d0 = jax.tree_util.tree_leaves(params)[3]
    d1 = jax.tree_util.tree_leaves(new_params)[3]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_shapes_no_nans(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    batch.pop("labels")
    logits, cache = model.prefill(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    assert cache is not None


@pytest.mark.parametrize(
    "arch",
    ["qwen2-0.5b", "qwen3-moe-30b-a3b", "zamba2-2.7b", "xlstm-350m",
     "seamless-m4t-large-v2", "phi-3-vision-4.2b"],
)
def test_decode_consistent_with_prefill(arch):
    """Greedy decode step t must match the full-forward logits at t."""
    from repro.serve import grow_cache

    cfg = ARCHS[arch].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(2))
    b, s = 2, 32
    tok = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s + 1)), jnp.int32)
    full = {"tokens": tok}
    pre = {"tokens": tok[:, :s]}
    if cfg.family == "audio":
        fr = jnp.asarray(RNG.normal(0, 1, (b, 16, cfg.frontend_dim)), jnp.float32)
        full["frames"] = fr
        pre["frames"] = fr
    if cfg.family == "vlm":
        pa = jnp.asarray(
            RNG.normal(0, 1, (b, cfg.num_frontend_tokens, cfg.frontend_dim)), jnp.float32
        )
        full["patches"] = pa
        pre["patches"] = pa
    logits_full, _ = model.prefill(params, full)
    _, cache = model.prefill(params, pre)
    cache = grow_cache(cfg, cache, 8)
    off = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
    pos = jnp.full((b,), s + off, jnp.int32)
    logits_dec, _ = model.decode(params, tok[:, s : s + 1], cache, pos)
    a = np.asarray(logits_full, np.float32)
    c = np.asarray(logits_dec, np.float32)
    rel = np.abs(a - c).max() / max(np.abs(a).max(), 1e-6)
    assert rel < 0.05, f"{arch}: decode/prefill mismatch rel={rel:.4f}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_input_specs_cover_all_shapes(arch):
    from repro.configs import SHAPES, cell_applicable

    cfg = ARCHS[arch]
    model = build_model(cfg)
    for shape in SHAPES:
        ok, _ = cell_applicable(cfg, shape)
        if not ok:
            continue
        specs = model.input_specs(shape)
        assert "tokens" in specs
        if shape.kind == "decode":
            assert "cache" in specs and "pos" in specs


def test_moe_expert_padding_masked():
    """Padded experts (60 -> 64) must receive zero routing mass."""
    import jax

    from repro.models.moe import moe_apply, moe_params, padded_experts

    cfg = dataclasses.replace(
        ARCHS["qwen2-moe-a2.7b"].reduced(), num_experts=6, num_experts_per_tok=2
    )
    e_pad = padded_experts(cfg, 4)  # pad 6 -> 8
    assert e_pad == 8
    p = moe_params(jax.random.PRNGKey(0), cfg, model_axis=4)
    x = jnp.asarray(RNG.normal(0, 1, (2, 16, cfg.d_model)), jnp.bfloat16)
    out, aux = moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert jnp.isfinite(aux)
    # router never routes to dead experts: max prob over padded slots == 0
    logits = (x.reshape(-1, cfg.d_model) @ p["router"].astype(jnp.bfloat16)).astype(jnp.float32)
    logits = jnp.where(jnp.arange(e_pad)[None, :] < 6, logits, -1e30)
    probs = jax.nn.softmax(logits, -1)
    assert float(probs[:, 6:].max()) < 1e-9


def test_mamba2_chunked_matches_stepwise():
    """SSD chunked parallel scan == sequential recurrence."""
    from repro.models.mamba2 import init_mamba_cache, mamba2_full, mamba2_params, mamba2_step

    cfg = ARCHS["zamba2-2.7b"].reduced()
    p = mamba2_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 24
    u = jnp.asarray(RNG.normal(0, 0.5, (b, s, cfg.d_model)), jnp.float32)
    full_out, full_cache = mamba2_full(p, cfg, u)
    cache = init_mamba_cache(cfg, b, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = mamba2_step(p, cfg, u[:, t : t + 1], cache)
        outs.append(o)
    step_out = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_out, np.float32), np.asarray(step_out, np.float32), rtol=5e-2, atol=5e-2
    )
    np.testing.assert_allclose(
        np.asarray(full_cache.ssm), np.asarray(cache.ssm), rtol=5e-2, atol=5e-2
    )


def test_xlstm_chunked_matches_stepwise():
    from repro.models.xlstm import (
        init_mlstm_cache,
        mlstm_full,
        mlstm_params,
        mlstm_step,
    )

    cfg = ARCHS["xlstm-350m"].reduced()
    p = mlstm_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    x = jnp.asarray(RNG.normal(0, 0.5, (b, s, cfg.d_model)), jnp.float32)
    full_out, _ = mlstm_full(p, cfg, x)
    cache = init_mlstm_cache(cfg, b)
    outs = []
    for t in range(s):
        o, cache = mlstm_step(p, cfg, x[:, t : t + 1], cache)
        outs.append(o)
    step_out = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_out, np.float32), np.asarray(step_out, np.float32), rtol=5e-2, atol=5e-2
    )
