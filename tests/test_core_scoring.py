"""Query scoring model (Eqs. 4-6) + ef table + estimator."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    DECAY_EXP,
    DECAY_LINEAR,
    DECAY_NONE,
    EfTable,
    FDLParams,
    bin_thresholds,
    bin_weights,
    build_ef_table,
    default_ef_ladder,
    lookup_ef,
    score_query,
)


def _params(b=1, mu=0.9, sigma=0.08):
    return FDLParams(
        mu=jnp.full((b,), mu, jnp.float32), sigma=jnp.full((b,), sigma, jnp.float32)
    )


def test_paper_appendix_c_example():
    """Reproduce the worked example from Appendix C."""
    p = FDLParams(mu=jnp.asarray([0.936]), sigma=jnp.asarray([0.0739]))
    th = np.asarray(bin_thresholds(p, m=5, delta=0.001))
    np.testing.assert_allclose(th[0, 0], 0.7076, atol=2e-3)
    np.testing.assert_allclose(th[0, 1], 0.7233, atol=2e-3)
    # counts c1=90, c2=5, c3=5 of |D|=100 -> score ~ 92.516
    d = np.concatenate([
        np.full(90, 0.70), np.full(5, 0.715), np.full(5, 0.728)
    ]).astype(np.float32)
    s = float(score_query(p, jnp.asarray(d[None, :]), m=5, delta=0.001)[0])
    np.testing.assert_allclose(s, 92.516, atol=0.5)


def test_weights_decay_variants():
    for decay, first_over_second in ((DECAY_EXP, np.e), (DECAY_LINEAR, 10 / 9)):
        w = np.asarray(bin_weights(10, decay))
        assert w[0] > w[1] > 0
        np.testing.assert_allclose(w[0] / w[1], first_over_second, rtol=1e-5)
    w = np.asarray(bin_weights(10, DECAY_NONE))
    assert np.allclose(w, w[0])


@settings(max_examples=30, deadline=None)
@given(
    nd=st.integers(min_value=1, max_value=200),
    mu=st.floats(min_value=0.5, max_value=1.5),
    sigma=st.floats(min_value=0.01, max_value=0.3),
)
def test_score_bounds(nd, mu, sigma):
    """0 <= s(q) <= 100 always (w1 = 100, sum c_i <= |D|)."""
    rng = np.random.default_rng(nd)
    d = rng.normal(mu, sigma, (1, nd)).astype(np.float32)
    s = float(score_query(_params(mu=mu, sigma=sigma), jnp.asarray(d))[0])
    assert 0.0 <= s <= 100.0 + 1e-4


def test_score_orders_difficulty():
    """All-near-quantile-0 distances must outscore spread distances."""
    p = _params()
    easy = jnp.full((1, 100), 0.9 + 0.08 * -3.3)  # ~ below the 0.001 quantile
    hard = jnp.asarray(np.random.default_rng(0).normal(0.9, 0.08, (1, 100)), jnp.float32)
    assert float(score_query(p, easy)[0]) > float(score_query(p, hard)[0])


def test_ef_ladder_and_table():
    ladder = default_ef_ladder(100, ef_max=2000)
    assert ladder[0] >= 25 and ladder[-1] == 2000
    assert (np.diff(ladder) > 0).all()

    scores = np.asarray([3.0, 3.2, 50.0, 50.5, 97.0, 97.5])

    def recall_at_ef(ef, idx):
        # hard (low score) queries need ef >= 400; easy ones ef >= 50
        need = np.where(scores[idx] < 10, 400, np.where(scores[idx] < 90, 100, 50))
        return (ef >= need).astype(np.float32)

    tbl = build_ef_table(scores, recall_at_ef, target_recall=0.95, ef_ladder=ladder)
    ef_hard = int(lookup_ef(tbl, jnp.asarray([3.0]), jnp.asarray(0.95))[0])
    ef_mid = int(lookup_ef(tbl, jnp.asarray([50.0]), jnp.asarray(0.95))[0])
    ef_easy = int(lookup_ef(tbl, jnp.asarray([97.0]), jnp.asarray(0.95))[0])
    assert ef_hard >= 400
    assert ef_hard > ef_mid >= ef_easy
    # WAE floor (Alg 1 line 10): easy group cannot fall below the WAE
    assert ef_easy >= int(tbl.wae)


def test_lookup_fallback_largest():
    """Score groups that never reach target return the row's largest ef."""
    ladder = np.asarray([10, 20, 40], np.int64)
    recall = np.zeros((101, 3), np.float32)  # never meets target
    tbl = EfTable(
        ef_ladder=jnp.asarray(ladder, jnp.int32),
        recall=jnp.asarray(recall),
        counts=jnp.ones((101,), jnp.int32),
        wae=jnp.asarray(10.0),
    )
    assert int(lookup_ef(tbl, jnp.asarray([55.0]), jnp.asarray(0.95))[0]) == 40
