"""Quantized estimation tier: calibration bounds, kernel parity, re-rank
recall, and epoch-snapshot invariance under mutation (PR 9)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SearchConfig, SearchSpec
from repro.index import build_ada_index, recall_at_k, search
from repro.kernels import ops, ref
from repro.quant import (
    QuantizedPanel,
    append_rows,
    attach_panel,
    bytes_per_distance,
    calibrate_panel,
    dequantize_panel,
    graph_resident_bytes,
    panel_bytes,
    panel_of,
    quantize_queries,
    roundtrip_bound,
    supported_precisions,
)

RNG = np.random.default_rng(0)


def _vectors(n=400, d=48, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return (scale * rng.normal(0, 1, (n, d))).astype(np.float32)


# ------------------------------------------------------------- calibration

@pytest.mark.parametrize("precision", [p for p in ("int8", "fp8")
                                       if p in supported_precisions()])
@pytest.mark.parametrize("scale", [1.0, 0.01, 50.0])
def test_roundtrip_within_bound(precision, scale):
    """Dequantized rows stay within the panel's analytic round-trip bound."""
    x = _vectors(scale=scale)
    panel = calibrate_panel(jnp.asarray(x), precision=precision)
    back = np.asarray(dequantize_panel(panel))
    err = np.abs(back - x)
    bound = np.asarray(roundtrip_bound(panel))
    if precision == "int8":
        # per-element bound is exact for affine int8 (round-to-nearest)
        assert (err <= bound + 1e-6).all()
    else:
        # fp8 rounding is relative, not absolute: the half-ULP analytic
        # bound holds in aggregate, with per-element slack for the mantissa
        assert np.mean(err <= bound + 1e-6) > 0.95
        assert (err <= 4 * bound + 1e-6).all()


def test_roundtrip_bound_shrinks_with_spread():
    """Tighter per-dim spread -> tighter bound (calibration is per-dim)."""
    x = _vectors()
    x[:, :8] *= 0.05  # eight low-spread dims
    panel = calibrate_panel(jnp.asarray(x))
    bound = np.asarray(roundtrip_bound(panel))
    assert bound[:, :8].mean() < 0.2 * bound[:, 8:].mean()


def test_constant_dim_is_exact():
    """A constant dimension has zero spread: absorbed by the zero-point."""
    x = _vectors()
    x[:, 0] = 3.25
    panel = calibrate_panel(jnp.asarray(x))
    back = np.asarray(dequantize_panel(panel))
    np.testing.assert_allclose(back[:, 0], 3.25, atol=1e-5)


def test_append_rows_prefix_frozen():
    """Appending re-quantizes only the new rows: prefix codes, dim scales
    and zero-points are bit-identical (epoch snapshots stay valid)."""
    x = _vectors(n=300)
    extra = _vectors(n=50, seed=1)
    panel = calibrate_panel(jnp.asarray(x))
    grown = append_rows(panel, jnp.asarray(extra))
    assert grown.codes.shape[0] == 350
    np.testing.assert_array_equal(np.asarray(grown.codes[:300]),
                                  np.asarray(panel.codes))
    np.testing.assert_array_equal(np.asarray(grown.row_scale[:300]),
                                  np.asarray(panel.row_scale))
    np.testing.assert_array_equal(np.asarray(grown.dim_scale),
                                  np.asarray(panel.dim_scale))
    np.testing.assert_array_equal(np.asarray(grown.zero),
                                  np.asarray(panel.zero))
    # appended rows still round-trip within the (frozen-grid) bound
    back = np.asarray(dequantize_panel(grown))[300:]
    bound = np.asarray(roundtrip_bound(grown))[300:]
    # rows outside the calibrated range clip — the frozen grid bounds only
    # in-range values, so allow the clipped tail a loose multiple
    assert np.mean(np.abs(back - extra) <= bound + 1e-6) > 0.9


def test_panel_byte_accounting():
    x = _vectors(n=256, d=32)
    panel = calibrate_panel(jnp.asarray(x))
    # codes n*d bytes + row_scale 4n + dim_scale/zero 4d each
    assert panel_bytes(panel) == 256 * 32 + 4 * 256 + 4 * 32 + 4 * 32
    assert bytes_per_distance(32, "int8") == 32
    assert bytes_per_distance(32, "fp32") == 128


# ---------------------------------------------------------- kernel parity

@pytest.mark.parametrize("b,f,d", [(8, 64, 32), (13, 48, 100), (3, 200, 64)])
@pytest.mark.parametrize("metric", ["cos_dist", "ip"])
def test_quant_kernel_matches_oracle(b, f, d, metric):
    """int8 Pallas kernel (interpret) vs the quantized jnp oracle: both sum
    the same exact small integers in fp32, so parity is bitwise."""
    n = 777
    vec = jnp.asarray(RNG.normal(0, 1, (n, d)).astype(np.float32))
    q = jnp.asarray(RNG.normal(0, 1, (b, d)).astype(np.float32))
    panel = calibrate_panel(vec)
    qpanel = (panel.codes, panel.row_scale, panel.dim_scale, panel.zero)
    ids = RNG.integers(0, n, (b, f)).astype(np.int32)
    ids[:, ::5] = -1
    ids[:, 3::7] = -1
    ids[0] = -1  # a converged query: whole row masked
    ids = jnp.asarray(ids)
    got = ops.frontier_keys_batch(
        ids, q, vec, metric=metric, use_kernel=True, interpret=True,
        qpanel=qpanel,
    )
    want = ops.frontier_keys_batch(
        ids, q, vec, metric=metric, use_kernel=False, qpanel=qpanel,
    )
    masked = np.asarray(ids) < 0
    assert np.isposinf(np.asarray(got)[masked]).all()
    np.testing.assert_array_equal(
        np.asarray(got)[~masked], np.asarray(want)[~masked]
    )


def test_quant_kernel_all_masked():
    vec = jnp.asarray(RNG.normal(0, 1, (50, 32)).astype(np.float32))
    panel = calibrate_panel(vec)
    q = jnp.asarray(RNG.normal(0, 1, (2, 32)).astype(np.float32))
    ids = jnp.full((2, 64), -1, jnp.int32)
    got = ops.frontier_keys_batch(
        ids, q, vec, use_kernel=True, interpret=True,
        qpanel=(panel.codes, panel.row_scale, panel.dim_scale, panel.zero),
    )
    assert np.isposinf(np.asarray(got)).all()


def test_quant_keys_approximate_fp32_keys():
    """Quantized frontier keys track the fp32 keys within the score-space
    error implied by the round-trip bound (the traversal sees a slightly
    perturbed metric, not a different one)."""
    n, d, b, f = 500, 48, 8, 64
    vec = RNG.normal(0, 1, (n, d)).astype(np.float32)
    vec /= np.linalg.norm(vec, axis=1, keepdims=True)
    q = RNG.normal(0, 1, (b, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    vec, q = jnp.asarray(vec), jnp.asarray(q)
    panel = calibrate_panel(vec)
    ids = jnp.asarray(RNG.integers(0, n, (b, f)).astype(np.int32))
    fp32 = ops.frontier_keys_batch(ids, q, vec)
    quant = ops.frontier_keys_batch(
        ids, q, vec,
        qpanel=(panel.codes, panel.row_scale, panel.dim_scale, panel.zero),
    )
    assert float(jnp.max(jnp.abs(quant - fp32))) < 0.05


# ------------------------------------------------- re-rank recall property

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rerank_recovers_fp32_recall(seed):
    """Quantized traversal + fp32 re-rank of the final ef candidates lands
    within 1 recall point of the all-fp32 search (3 seeds)."""
    rng = np.random.default_rng(seed)
    data = rng.normal(0, 1, (800, 32)).astype(np.float32)
    idx = build_ada_index(
        data, k=10, m=8, ef_construction=60, ef_cap=96, num_samples=16,
        seed=seed,
    )
    idx.ensure_panel("int8")
    qs = jnp.asarray(rng.normal(0, 1, (32, 32)).astype(np.float32))
    ef = jnp.full((32,), 96, jnp.int32)
    cfg_f = idx.search_cfg
    cfg_q = dataclasses.replace(cfg_f, precision="int8")
    res_f = search(idx.graph, qs, ef, cfg_f)
    res_q = search(idx.graph, qs, ef, cfg_q)
    from repro.index import brute_force_topk_chunked, prepare_queries

    _, gt = brute_force_topk_chunked(
        prepare_queries(qs, cfg_f.metric), data, k=10
    )
    rec_f = float(np.asarray(recall_at_k(res_f.ids, jnp.asarray(gt))).mean())
    rec_q = float(np.asarray(recall_at_k(res_q.ids, jnp.asarray(gt))).mean())
    assert rec_q >= rec_f - 0.01
    # the quantized run actually traversed on the panel...
    assert int(np.asarray(res_q.ndist_q).sum()) > 0
    # ...and the fp32 run never touched it
    assert int(np.asarray(res_f.ndist_q).sum()) == 0


def test_quant_requires_panel():
    """precision != fp32 with no panel attached degrades to fp32 scoring
    (ndist_q stays 0) rather than erroring — the trace-time switch."""
    data = RNG.normal(0, 1, (300, 24)).astype(np.float32)
    idx = build_ada_index(
        data, k=5, m=6, ef_construction=40, ef_cap=48, num_samples=8
    )
    cfg_q = dataclasses.replace(idx.search_cfg, precision="int8")
    qs = jnp.asarray(RNG.normal(0, 1, (4, 24)).astype(np.float32))
    res = search(idx.graph, qs, jnp.full((4,), 48, jnp.int32), cfg_q)
    assert int(np.asarray(res.ndist_q).sum()) == 0


def test_invalid_precision_rejected():
    with pytest.raises(ValueError):
        SearchConfig(k=5, ef_cap=32, precision="int4")
    with pytest.raises(ValueError):
        SearchSpec(target_recall=0.9, precision="int4")


# ------------------------------------- epoch snapshots under insert/delete

def test_epoch_snapshot_invariance_under_mutation():
    """A graph snapshot captured before insert/delete answers identically
    afterwards — the panel rides the immutable DeviceGraph, and the live
    index's panel grows append-only (prefix codes frozen)."""
    rng = np.random.default_rng(3)
    data = rng.normal(0, 1, (500, 24)).astype(np.float32)
    idx = build_ada_index(
        data, k=5, m=6, ef_construction=40, ef_cap=48, num_samples=8
    )
    idx.ensure_panel("int8")
    g0 = idx.graph
    p0 = panel_of(g0)
    assert p0 is not None and p0.codes.shape[0] == 500
    cfg_q = dataclasses.replace(idx.search_cfg, precision="int8")
    qs = jnp.asarray(rng.normal(0, 1, (8, 24)).astype(np.float32))
    ef = jnp.full((8,), 48, jnp.int32)
    before = search(g0, qs, ef, cfg_q)

    idx.insert(rng.normal(0, 1, (40, 24)).astype(np.float32))
    p1 = panel_of(idx.graph)
    assert p1 is not None and p1.codes.shape[0] == idx.graph.vectors.shape[0]
    # live panel grew append-only: the pre-insert prefix is bit-identical
    np.testing.assert_array_equal(np.asarray(p1.codes[:500]),
                                  np.asarray(p0.codes))
    np.testing.assert_array_equal(np.asarray(p1.dim_scale),
                                  np.asarray(p0.dim_scale))

    idx.delete(np.arange(10))
    p2 = panel_of(idx.graph)
    assert p2 is not None and p2.codes.shape[0] == idx.graph.vectors.shape[0]

    # the old snapshot still answers bit-identically (panel and all)
    after = search(g0, qs, ef, cfg_q)
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(after.ids))
    np.testing.assert_array_equal(np.asarray(before.dists),
                                  np.asarray(after.dists))
    assert panel_of(g0).codes.shape[0] == 500  # snapshot panel untouched


def test_resident_bytes_accounting():
    data = RNG.normal(0, 1, (300, 24)).astype(np.float32)
    idx = build_ada_index(
        data, k=5, m=6, ef_construction=40, ef_cap=48, num_samples=8
    )
    rb = graph_resident_bytes(idx.graph)
    assert rb["quantized"] == 0
    assert rb["fp32"] == idx.graph.vectors.size * 4
    idx.ensure_panel("int8")
    rb = graph_resident_bytes(idx.graph)
    assert rb["quantized"] == panel_bytes(panel_of(idx.graph))
    assert 0 < rb["quantized"] < rb["fp32"]
    # detach restores the fp32-only footprint
    idx.ensure_panel("fp32")
    assert graph_resident_bytes(idx.graph)["quantized"] == 0


def test_attach_detach_roundtrip():
    data = jnp.asarray(RNG.normal(0, 1, (100, 16)).astype(np.float32))
    from repro.index.search import DeviceGraph

    g = DeviceGraph(
        base_adj=jnp.zeros((100, 4), jnp.int32),
        upper_adj=jnp.zeros((1, 100, 2), jnp.int32),
        entry=jnp.asarray(0, jnp.int32),
        vectors=data,
        alive=jnp.ones((100,), bool),
    )
    assert panel_of(g) is None
    panel = calibrate_panel(data)
    g2 = attach_panel(g, panel)
    got = panel_of(g2)
    assert isinstance(got, QuantizedPanel)
    np.testing.assert_array_equal(np.asarray(got.codes),
                                  np.asarray(panel.codes))
    assert panel_of(attach_panel(g2, None)) is None
