"""Fault-injection suite: the FaultPlan harness drives the scheduler's
*production* recovery paths — dispatch retry, backend-ladder fallback
(bit-identical ids to the healthy path), NaN-row isolation inside a shared
estimation pass, injected latency, clock skew, and mid-flight index mutation
(StalePlanError)."""
import numpy as np
import pytest

from repro.api import RouterConfig, SchedulerConfig
from repro.serve import (
    STATUS_OK,
    STATUS_REJECTED,
    TERMINAL_STATUSES,
    AdaServeScheduler,
    DispatchFailedError,
    FaultInjector,
    FaultPlan,
    SearchRequest,
    StalePlanError,
)
from tests.test_scheduler import FakeClock, _queries


@pytest.fixture(scope="module")
def kernel_index(small_db):
    """A small index built *on kernels* so the runtime backend ladder has an
    oracle rung below the primary; skipped where Pallas cannot interpret."""
    from repro.index import build_ada_index
    from repro.plan import probe_interpret

    if not probe_interpret():
        pytest.skip("no working Pallas interpret lowering on this host")
    data, _, _ = small_db
    return build_ada_index(
        data[:1500], k=5, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=32, use_distance_kernel=True,
    )


def _run(index, queries, chaos=None, cfg=None, **kw):
    kw.setdefault("default_target_recall", index.target_recall)
    sched = AdaServeScheduler(
        index.router(RouterConfig()), cfg, chaos=chaos, **kw
    )
    tickets = [sched.submit(SearchRequest(query=row)) for row in queries]
    responses = sched.drain()
    by_uid = {r.ticket.uid: r for r in responses}
    return sched, [by_uid[t.uid] for t in tickets]


def test_empty_fault_plan_is_inert(small_db, small_index):
    q = _queries(small_db, nq=4, seed=61)
    _, healthy = _run(small_index, q)
    chaos = FaultInjector(FaultPlan())
    sched, faulted = _run(small_index, q, chaos=chaos)
    for h, f in zip(healthy, faulted):
        np.testing.assert_array_equal(h.ids, f.ids)
        np.testing.assert_array_equal(h.dists, f.dists)
    assert chaos.dispatches > 0 and chaos.faults_raised == 0
    assert sched.stats.kernel_retries == 0
    assert sched.stats.kernel_fallbacks == 0


def test_dispatch_fault_retry_recovers(small_db, small_index):
    """One injected failure: the retry (same backend) recovers; results are
    bit-identical to the healthy path."""
    q = _queries(small_db, nq=4, seed=62)
    _, healthy = _run(small_index, q)
    chaos = FaultInjector(FaultPlan(fail_dispatches=(0,), fail_attempts=1))
    sched, faulted = _run(small_index, q, chaos=chaos)
    assert chaos.faults_raised == 1
    assert sched.stats.kernel_retries == 1
    assert sched.stats.kernel_fallbacks == 0
    assert all(r.status == STATUS_OK for r in faulted)
    retried = [r for r in faulted if r.stats.dispatch_retries == 1]
    assert retried  # the failed dispatch's requests record the retry
    for h, f in zip(healthy, faulted):
        np.testing.assert_array_equal(h.ids, f.ids)
        np.testing.assert_array_equal(h.dists, f.dists)


def test_dispatch_fault_falls_back_to_oracle(small_db, kernel_index):
    """Two injected failures burn the primary + its retry: the dispatch falls
    down the backend ladder to the jnp oracle, records the fallback, and the
    returned neighbor ids are bit-identical to the healthy path."""
    q = _queries(small_db, nq=4, seed=63)
    _, healthy = _run(kernel_index, q)
    chaos = FaultInjector(FaultPlan(fail_dispatches=(0,), fail_attempts=2))
    sched, faulted = _run(kernel_index, q, chaos=chaos)
    assert chaos.faults_raised == 2
    assert sched.stats.kernel_retries == 1
    assert sched.stats.kernel_fallbacks == 1
    fell_back = [r for r in faulted if r.stats.fallback_backend == "oracle"]
    assert fell_back and all(r.stats.dispatch_retries == 2 for r in fell_back)
    assert all(r.status == STATUS_OK for r in faulted)
    for h, f in zip(healthy, faulted):
        np.testing.assert_array_equal(h.ids, f.ids)
        np.testing.assert_allclose(h.dists, f.dists, rtol=1e-4, atol=1e-5)


def test_ladder_exhaustion_raises_typed_error(small_db, small_index):
    """An oracle-built index has no rung below primary+retry: a persistent
    fault surfaces as DispatchFailedError, not a bare injected exception."""
    q = _queries(small_db, nq=2, seed=64)
    chaos = FaultInjector(FaultPlan(fail_dispatches=(0,), fail_attempts=5))
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
        chaos=chaos,
    )
    for row in q:
        sched.submit(SearchRequest(query=row))
    with pytest.raises(DispatchFailedError):
        sched.drain()


def test_nan_rows_isolated_from_cohabitants(small_db, small_index):
    """Injected NaN rows (corruption past submit validation) are shed as
    REJECTED by the estimation-pass screen; cohabiting requests in the same
    admission batch serve bit-identically to a healthy run."""
    q = _queries(small_db, nq=5, seed=65)
    _, healthy = _run(small_index, q)
    chaos = FaultInjector(FaultPlan(nan_uids=(1, 3)))  # uids count from 0
    sched, faulted = _run(small_index, q, chaos=chaos)
    assert [r.status for r in faulted] == [
        STATUS_OK, STATUS_REJECTED, STATUS_OK, STATUS_REJECTED, STATUS_OK,
    ]
    for i in (1, 3):
        assert faulted[i].stats.reject_reason == "non-finite query values"
        assert (faulted[i].ids == -1).all()
    for i in (0, 2, 4):  # cohabitants unaffected, bit-identical
        np.testing.assert_array_equal(healthy[i].ids, faulted[i].ids)
        np.testing.assert_array_equal(healthy[i].dists, faulted[i].dists)
    assert sched.stats.rejected == 2
    assert all(r.status in TERMINAL_STATUSES for r in faulted)


def test_injected_dispatch_latency_shows_in_walls(small_db, small_index):
    q = _queries(small_db, nq=2, seed=66)
    chaos = FaultInjector(FaultPlan(dispatch_latency_s=0.05))
    sched, responses = _run(small_index, q, chaos=chaos)
    assert all(r.status == STATUS_OK for r in responses)
    assert max(t.wall_s for t in sched.stats.tiers) >= 0.05


def test_clock_skew_shifts_timestamps_consistently(small_db, small_index):
    q = _queries(small_db, nq=1, seed=67)
    clock = FakeClock(5.0)
    chaos = FaultInjector(FaultPlan(clock_skew_s=100.0))
    sched = AdaServeScheduler(
        small_index.router(RouterConfig()),
        default_target_recall=small_index.target_recall,
        clock=clock,
        chaos=chaos,
    )
    t = sched.submit(SearchRequest(query=q[0], deadline_s=1.0))
    assert t.submit_t == pytest.approx(105.0)
    assert t.deadline_t == pytest.approx(106.0)  # deadline math stays
    #   relative — a skewed-but-consistent clock never flips OK to TIMED_OUT
    (r,) = sched.drain()
    assert r.status == STATUS_OK
    assert r.stats.done_t <= t.deadline_t


def test_midflight_mutation_raises_stale_plan_error(small_db):
    from repro.index import build_ada_index

    data, _, _ = small_db
    idx = build_ada_index(
        data[:1200], k=5, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=32,
    )
    chaos = FaultInjector(
        FaultPlan(mutate_at_dispatch=0),
        mutate_fn=lambda: idx.insert(data[1200:1205]),
    )
    sched = AdaServeScheduler(
        idx.router(),
        default_target_recall=idx.target_recall,
        version_probe=lambda: idx._graph_version,
        chaos=chaos,
    )
    q = _queries(small_db, nq=2, seed=68)
    for row in q:
        sched.submit(SearchRequest(query=row))
    sched.flush()  # dispatch 0 mutates the index mid-flight
    with pytest.raises(StalePlanError, match="graph version"):
        sched.poll(block=True)


def test_midflight_mutation_absorbed_by_registered_scheduler(small_db):
    """The same chaos fault against an *index-registered* scheduler is
    absorbed, not refused: the mutation lands between dispatch and
    materialization, the deferred seam rebinds at the end of the tick, and
    every ticket still reaches exactly one terminal status."""
    from repro.index import build_ada_index

    data, _, _ = small_db
    idx = build_ada_index(
        data[:1200], k=5, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=32,
    )
    sched = idx.scheduler()  # registered: the index absorbs it on mutation
    sched._chaos = FaultInjector(
        FaultPlan(mutate_at_dispatch=0),
        mutate_fn=lambda: idx.insert(data[1200:1205]),
    )
    q = _queries(small_db, nq=2, seed=68)
    tickets = [sched.submit(SearchRequest(query=row)) for row in q]
    sched.flush()  # dispatch 0 mutates the index mid-flight: absorbed
    rs = sched.poll(block=True)
    assert sorted(r.ticket.uid for r in rs) == sorted(t.uid for t in tickets)
    assert all(r.status in TERMINAL_STATUSES for r in rs)
    # both admitted pre-mutation -> both pinned to the pre-mutation epoch
    assert all(r.stats.epoch == rs[0].stats.epoch for r in rs)
    assert sched.stats.mutations == 1
    # the seam stays live: post-mutation work binds the new epoch
    t3 = sched.submit(SearchRequest(query=q[0]))
    (r3,) = sched.drain()
    assert r3.ticket.uid == t3.uid
    assert r3.stats.epoch == rs[0].stats.epoch + 1
