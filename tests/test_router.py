"""Ada-ef query router: phase-split equivalence, bucketing/scatter order
restoration, beam auto-tuning, telemetry, and engine integration.

Routed execution goes through the declarative facade (``index.plan`` with a
``routed``-mode :class:`repro.api.SearchSpec`); the router itself is an
internal lowering target reached via ``SpecOverrides``."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RouterConfig, SearchSpec, SpecOverrides
from repro.index import auto_beam, recall_at_k
from repro.serve.bucketing import (
    assign_tiers,
    bucket_indices,
    pad_indices,
    pad_shape,
    scatter_results,
)
from repro.serve.router import QueryRouter
from repro.serve.tiers import tier_ladder


def _routed_plan(index, rcfg=None, **spec_kw):
    """A routed-mode plan; ``rcfg`` pins the router policy via overrides."""
    overrides = SpecOverrides() if rcfg is None else SpecOverrides(router=rcfg)
    return index.plan(SearchSpec(mode="routed", overrides=overrides, **spec_kw))


def _queries(small_db, nq=64, seed=1):
    data, centers, w = small_db
    rng = np.random.default_rng(seed)
    qc = rng.choice(len(centers), size=nq, p=w)
    return (centers[qc] + 0.3 * rng.normal(0, 1, (nq, centers.shape[1]))).astype(
        np.float32
    )


def _gt(data, q, k=10):
    from repro.index import brute_force_topk, prepare_database, prepare_queries

    vp = prepare_database(jnp.asarray(data), "cos_dist")
    qp = prepare_queries(jnp.asarray(q), "cos_dist")
    return brute_force_topk(qp, vp, k=k)[1]


# --------------------------------------------------------------------------
# auto_beam
# --------------------------------------------------------------------------


def test_auto_beam_small_ef_is_single_pop():
    for ef in (1, 10, 32, 63):
        assert auto_beam(ef) == 1


def test_auto_beam_monotone_and_bounded():
    prev = 0
    for ef in (10, 64, 100, 128, 200, 256, 600, 5000):
        b = auto_beam(ef)
        assert b >= prev
        assert 1 <= b <= 8
        assert isinstance(b, int)
        prev = b


def test_auto_beam_respects_cap():
    assert auto_beam(600, max_beam=4) == 4
    assert auto_beam(600, max_beam=1) == 1


# --------------------------------------------------------------------------
# bucketing primitives
# --------------------------------------------------------------------------


def test_pad_shape_pow2_and_floor():
    assert pad_shape(1) == 8
    assert pad_shape(8) == 8
    assert pad_shape(9) == 16
    assert pad_shape(100) == 128
    assert pad_shape(3, min_shape=1) == 4
    with pytest.raises(ValueError):
        pad_shape(0)


def test_assign_tiers_first_fit():
    efs = np.asarray([10, 64, 65, 128, 200, 400])
    assert assign_tiers(efs, (64, 128, 400)).tolist() == [0, 0, 1, 1, 2, 2]
    with pytest.raises(ValueError):
        assign_tiers(np.asarray([401]), (64, 128, 400))


@pytest.mark.parametrize("seed", range(8))
def test_scatter_restores_order_under_random_permutations(seed):
    """Property: partition by random tiers, pad, process (identity tagged by
    position), scatter -> request order restored exactly."""
    rng = np.random.default_rng(seed)
    batch = int(rng.integers(1, 200))
    num_tiers = int(rng.integers(1, 5))
    assign = rng.integers(0, num_tiers, batch)
    payload = rng.normal(0, 1, (batch, 3)).astype(np.float32)

    buckets = []
    for idx in bucket_indices(assign, num_tiers):
        if len(idx) == 0:
            continue
        shape = pad_shape(len(idx), min_shape=4)
        idx_pad = pad_indices(idx, shape)
        # "process" the padded bucket: carry the original row + its position
        part = (payload[idx_pad], idx_pad.astype(np.int32))
        buckets.append((idx, part))

    out_payload, out_pos = scatter_results(buckets, batch)
    np.testing.assert_array_equal(out_payload, payload)
    np.testing.assert_array_equal(out_pos, np.arange(batch, dtype=np.int32))


def test_scatter_rejects_incomplete_cover():
    with pytest.raises(ValueError):
        scatter_results([(np.asarray([0, 1]), np.zeros((2, 1)))], 3)


# --------------------------------------------------------------------------
# tier ladder
# --------------------------------------------------------------------------


def test_tier_ladder_shapes_and_beams(small_index):
    base = small_index.search_cfg  # ef_cap=240, beam=1
    tiers = tier_ladder(base)
    assert [t.ef for t in tiers] == [64, 128, 240]
    assert tiers[-1].ef == base.ef_cap  # catch-all rung always present
    for t in tiers:
        assert t.cfg.ef_cap == t.ef
        assert t.beam == auto_beam(t.ef)
        assert t.cfg.max_iters == base.iters()  # never under-iterate a tier
    fixed = tier_ladder(base, beam_mode="fixed")
    assert all(t.beam == base.beam for t in fixed)
    with pytest.raises(ValueError):
        tier_ladder(base, beam_mode="wide")


# --------------------------------------------------------------------------
# router equivalence vs the monolithic adaptive search
# --------------------------------------------------------------------------


def test_router_estimates_match_adaptive(small_db, small_index):
    q = _queries(small_db, nq=48)
    res = small_index.query(q)
    router = QueryRouter(
        small_index.graph, small_index.stats, small_index.table,
        small_index.search_cfg, small_index.ada_cfg,
        RouterConfig(beam_mode="fixed"),
    )
    ef_np, _ = router.estimate(q, small_index.target_recall)
    np.testing.assert_array_equal(ef_np, np.asarray(res.ef_used))


@pytest.mark.parametrize("nq", [13, 64])  # non-pow2 exercises padding
def test_routed_matches_unrouted_adaptive(small_db, small_index, nq):
    """Lossless estimation + fixed beams: the routed plan must reproduce
    the monolithic ``adaptive_search`` per query — same ids, same ef, same
    ndist — for every query (each estimated ef fits its tier by ladder
    construction; tombstone-free fixture, see resize_state's deletion
    caveat)."""
    q = _queries(small_db, nq=nq, seed=3)
    mono = small_index.query(q)
    res, stats = _routed_plan(
        small_index, RouterConfig(beam_mode="fixed")
    ).search(q, with_stats=True)
    np.testing.assert_array_equal(res.ids, np.asarray(mono.ids))
    np.testing.assert_array_equal(res.ef_used, np.asarray(mono.ef_used))
    np.testing.assert_array_equal(res.ndist, np.asarray(mono.ndist))
    np.testing.assert_allclose(res.dists, np.asarray(mono.dists), rtol=1e-6)
    assert sum(t.count for t in stats.tiers) == nq


def test_routed_recall_at_target_on_clustered_corpus(small_db, small_index):
    """Default (auto-beam) routing on the clustered fixture: recall at the
    declarative target must be no worse than the monolithic path."""
    data, _, _ = small_db
    q = _queries(small_db, nq=96, seed=5)
    gt = _gt(data, q)
    mono = small_index.query(q)
    # explicit default policy: plans are keyed by spec, not installed state
    res = _routed_plan(small_index, RouterConfig()).search(q)
    rec_mono = float(recall_at_k(jnp.asarray(np.asarray(mono.ids)), gt).mean())
    rec_routed = float(recall_at_k(jnp.asarray(res.ids), gt).mean())
    assert rec_routed >= small_index.target_recall - 0.03, rec_routed
    assert rec_routed >= rec_mono - 0.005, (rec_routed, rec_mono)


def test_auto_beam_tiers_never_lose_recall(small_db, small_index):
    """Acceptance: beam=auto tiers never lose recall vs beam=1 tiers."""
    data, _, _ = small_db
    q = _queries(small_db, nq=96, seed=9)
    gt = _gt(data, q)
    res_a = _routed_plan(small_index, RouterConfig()).search(q)
    res_1 = _routed_plan(
        small_index, RouterConfig(beam_mode="fixed")  # base beam == 1
    ).search(q)
    rec_a = float(recall_at_k(jnp.asarray(res_a.ids), gt).mean())
    rec_1 = float(recall_at_k(jnp.asarray(res_1.ids), gt).mean())
    assert rec_a >= rec_1 - 1e-6, (rec_a, rec_1)


def test_tier_ladder_inherits_batch_hoisted(small_index):
    import dataclasses as _dc

    base = small_index.search_cfg  # batch_hoisted == False
    assert all(not t.cfg.batch_hoisted for t in tier_ladder(base))
    hoisted = tier_ladder(_dc.replace(base, batch_hoisted=True))
    assert all(t.cfg.batch_hoisted for t in hoisted)


@pytest.mark.parametrize("nq", [13, 64])
def test_routed_batch_hoisted_matches_unrouted(small_db, small_index, nq):
    """The batch-hoisted tier loop through the router reproduces the
    monolithic (vmap-path) adaptive_search per query — the serving-side
    golden equivalence for ISSUE 3 (this is also the loop the planner
    lowers serving modes to by default)."""
    q = _queries(small_db, nq=nq, seed=3)
    mono = small_index.query(q)
    plan = _routed_plan(
        small_index, RouterConfig(beam_mode="fixed", batch_hoisted=True)
    )
    assert plan.loop == "batch_hoisted"
    res, stats = plan.search(q, with_stats=True)
    np.testing.assert_array_equal(res.ids, np.asarray(mono.ids))
    np.testing.assert_array_equal(res.ef_used, np.asarray(mono.ef_used))
    np.testing.assert_array_equal(res.ndist, np.asarray(mono.ndist))
    assert sum(t.count for t in stats.tiers) == nq


def test_router_estimation_matched_table(small_db, small_index):
    """Lossy estimation budgets get a table built from proxies scored at that
    budget; lossless routers keep the full-budget table object."""
    lossless = small_index.router(RouterConfig())
    assert lossless.est_table is small_index.table
    assert not lossless.est_matched

    # nominally capped but at/above the full budget: effectively lossless,
    # so no redundant matched-table build and no false telemetry
    huge = small_index.router(RouterConfig(est_lmax=10_000))
    assert not huge.est_matched
    assert huge.est_table is small_index.table

    # explicit opt-out recovers the old biased-low-estimate behavior
    optout = small_index.router(
        RouterConfig(est_lmax=16, est_matched_table=False, ef_margin=1.25)
    )
    assert not optout.est_matched
    assert optout.est_table is small_index.table

    capped = small_index.router(RouterConfig(est_lmax=16))
    assert capped.est_matched
    assert capped.est_table is not small_index.table  # lazy-built on access
    # same ladder and group axis — only the score units moved
    assert capped.est_table.num_groups == small_index.table.num_groups

    q = _queries(small_db, nq=64, seed=21)
    res, stats = _routed_plan(
        small_index, RouterConfig(est_lmax=16)
    ).search(q, with_stats=True)
    assert stats.est_matched
    assert stats.as_dict()["est_matched"] is True
    # margin-free lossy routing with the matched table still lands near target
    data, _, _ = small_db
    gt = _gt(data, q)
    rec = float(recall_at_k(jnp.asarray(res.ids), gt).mean())
    assert rec >= small_index.target_recall - 0.05, rec


def test_router_matched_table_only_with_builder(small_db, small_index):
    """Directly constructed routers (no builder) keep the legacy behavior —
    the full table plus whatever ef_margin the caller configured."""
    router = QueryRouter(
        small_index.graph, small_index.stats, small_index.table,
        small_index.search_cfg, small_index.ada_cfg,
        RouterConfig(est_lmax=16, ef_margin=1.25),
    )
    assert not router.est_matched
    assert router.est_table is small_index.table


def test_router_capped_estimation_budget(small_db, small_index):
    """est_lmax caps the collection goal: cheaper estimation, and the lossy
    estimates still land within the ladder (recall sanity, not exactness)."""
    data, _, _ = small_db
    q = _queries(small_db, nq=64, seed=11)
    gt = _gt(data, q)
    _, st_full = _routed_plan(small_index, RouterConfig()).search(
        q, with_stats=True
    )
    res, st_cap = _routed_plan(
        small_index, RouterConfig(est_lmax=32, ef_margin=1.25)
    ).search(q, with_stats=True)
    assert st_cap.est_ndist_total < st_full.est_ndist_total
    rec = float(recall_at_k(jnp.asarray(res.ids), gt).mean())
    assert rec >= small_index.target_recall - 0.05, rec


def test_router_stats_telemetry(small_db, small_index):
    q = _queries(small_db, nq=37, seed=13)
    res, stats = _routed_plan(small_index, RouterConfig()).search(
        q, with_stats=True
    )
    assert stats.batch == 37
    assert sum(t.count for t in stats.tiers) == 37
    for t in stats.tiers:
        assert t.padded_to >= t.count
        assert t.padded_to == pad_shape(t.count)
        assert t.ndist_total > 0
        assert t.wall_s >= 0.0
    assert 0.0 <= stats.padding_waste < 1.0
    assert stats.ndist_total == int(res.ndist.sum())
    assert stats.est_ndist_total <= stats.ndist_total  # ndist is cumulative
    d = stats.as_dict()
    assert d["batch"] == 37 and len(d["tiers"]) == len(stats.tiers)


def test_router_invalidated_on_update(small_db):
    from repro.index import build_ada_index

    data, _, _ = small_db
    idx = build_ada_index(
        data[:1200], k=5, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=32,
    )
    r0 = idx.router()
    assert idx.router() is r0  # cached
    idx.insert(data[1200:1210])
    r1 = idx.router()
    assert r1 is not r0  # graph changed -> router rebuilt
    q = _queries(small_db, nq=8, seed=17)
    res = idx.query(q, routed=True)
    assert res.ids.shape == (8, 5)


# --------------------------------------------------------------------------
# engine integration
# --------------------------------------------------------------------------


def test_engine_serve_config_not_shared():
    from repro.serve import Engine, ServeConfig

    class _M:  # minimal model stub; decode never called before serve()
        def decode(self, *a):  # pragma: no cover - never traced
            raise AssertionError

    e1 = Engine(_M(), {}, None)
    e2 = Engine(_M(), {}, None)
    assert e1.scfg is not e2.scfg  # the old shared-default bug
    e1.scfg.max_new_tokens = 99
    assert e2.scfg.max_new_tokens == ServeConfig().max_new_tokens


def test_engine_routed_retrieval(small_db):
    from repro.configs import ARCHS
    from repro.index import build_ada_index
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig
    import jax

    data, _, _ = small_db
    cfg = ARCHS["qwen2-0.5b"].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    corpus = rng.normal(0, 1, (400, cfg.d_model)).astype(np.float32)
    index = build_ada_index(
        corpus, k=5, target_recall=0.9, m=8, ef_construction=40, ef_cap=80,
        num_samples=24,
    )
    eng = Engine(
        model, params,
        ServeConfig(max_new_tokens=2, retrieve_k=5, routed=True),
        index=index,
    )
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 8)), jnp.int32)
    res = eng.serve({"tokens": tok})
    assert res.retrieved_ids.shape == (3, 5)
    assert res.router_stats is not None
    assert res.router_stats["batch"] == 3
    assert sum(t["count"] for t in res.router_stats["tiers"]) == 3
