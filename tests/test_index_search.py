"""HNSW substrate: builder structure, static search recall, adaptive search
target-recall behavior, baselines, distributed merge."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.index import (
    SearchConfig,
    brute_force_topk,
    build_sharded,
    device_graph,
    prepare_database,
    prepare_queries,
    recall_at_k,
    retrieve_vmap,
    search,
)


def _queries(small_db, nq=64, seed=1):
    data, centers, w = small_db
    rng = np.random.default_rng(seed)
    qc = rng.choice(len(centers), size=nq, p=w)
    return (centers[qc] + 0.3 * rng.normal(0, 1, (nq, centers.shape[1]))).astype(np.float32)


def _gt(data, q, k=10):
    vp = prepare_database(jnp.asarray(data), "cos_dist")
    qp = prepare_queries(jnp.asarray(q), "cos_dist")
    return brute_force_topk(qp, vp, k=k)[1]


def test_builder_structure(small_index):
    g = small_index.host_index.freeze()
    n, m0 = g.base_adj.shape
    assert m0 == 16  # 2*M
    # every node has at least one neighbor; ids in range
    deg = (g.base_adj >= 0).sum(1)
    assert (deg > 0).all()
    assert g.base_adj.max() < n
    # bidirectionality is heuristic-pruned but the graph must be connected
    # enough for search: spot-check reachability from the entry point via BFS
    import collections

    seen = {int(g.entry)}
    dq = collections.deque(seen)
    while dq:
        u = dq.popleft()
        for v in g.base_adj[u]:
            if v >= 0 and int(v) not in seen:
                seen.add(int(v))
                dq.append(int(v))
    assert len(seen) > 0.95 * n


def test_static_search_recall_increases_with_ef(small_db, small_index):
    data, _, _ = small_db
    q = _queries(small_db)
    gt = _gt(data, q)
    recalls = []
    for ef in (10, 40, 160):
        res = small_index.query_static(q, ef)
        recalls.append(float(recall_at_k(res.ids, gt).mean()))
    assert recalls[0] < recalls[-1]
    assert recalls[-1] > 0.97


def test_search_matches_bruteforce_at_max_ef(small_db, small_index):
    data, _, _ = small_db
    q = _queries(small_db, nq=16)
    gt = _gt(data, q)
    res = small_index.query_static(q, 240)
    assert float(recall_at_k(res.ids, gt).mean()) > 0.99


def test_adaptive_search_hits_target(small_db, small_index):
    data, _, _ = small_db
    q = _queries(small_db, nq=128)
    gt = _gt(data, q)
    res = small_index.query(q)
    rec = np.asarray(recall_at_k(res.ids, gt))
    assert rec.mean() >= small_index.target_recall - 0.03, rec.mean()
    # adaptive ef must actually vary or at least stay within bounds
    efs = np.asarray(res.ef_used)
    assert efs.min() >= small_index.k
    assert efs.max() <= small_index.search_cfg.ef_cap


def test_adaptive_avoids_oversearch(small_db, small_index):
    """Ada-ef should use less work than always-max-ef for similar recall."""
    data, _, _ = small_db
    q = _queries(small_db, nq=64)
    res_ada = small_index.query(q)
    res_max = small_index.query_static(q, small_index.search_cfg.ef_cap)
    assert float(np.mean(np.asarray(res_ada.ndist))) < float(
        np.mean(np.asarray(res_max.ndist))
    )


def test_pip_baseline_terminates_early(small_db, small_index):
    data, _, _ = small_db
    q = _queries(small_db, nq=32)
    cfg = SearchConfig(k=10, ef_cap=240, patience=20)
    res_pip = search(small_index.graph, jnp.asarray(q), 240, cfg)
    res_full = small_index.query_static(q, 240)
    assert float(np.mean(np.asarray(res_pip.ndist))) <= float(
        np.mean(np.asarray(res_full.ndist))
    )


def test_deleted_nodes_not_returned(small_db):
    from repro.index import build_ada_index

    data, _, _ = small_db
    idx = build_ada_index(
        data[:1500], k=5, target_recall=0.9, m=8, ef_construction=60, ef_cap=160, num_samples=40
    )
    dead = np.arange(0, 200)
    idx.host_index.mark_deleted(dead)
    idx.graph = device_graph(idx.host_index.freeze())
    q = _queries(small_db, nq=32)
    res = idx.query_static(q, 80)
    ids = np.asarray(res.ids)
    assert not np.isin(ids[ids >= 0], dead).any()


def test_sharded_merge_equals_global_topk(small_db):
    """Distributed top-k merge must return the union-best ids."""
    data, _, _ = small_db
    sidx = build_sharded(
        data[:2000],
        num_shards=2,
        k=10,
        target_recall=0.9,
        m=8,
        ef_construction=60,
        ef_cap=160,
        num_samples=40,
    )
    q = _queries(small_db, nq=32)
    res = retrieve_vmap(sidx, q)
    gt = _gt(data[:2000], q)
    rec = float(recall_at_k(res.ids, gt).mean())
    assert rec > 0.85
    # merged ids must be globally sorted by distance
    d = np.asarray(res.dists)
    assert (np.diff(d, axis=1) >= -1e-6).all()
