"""HNSW substrate: builder structure, static search recall, adaptive search
target-recall behavior, baselines, distributed merge."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.index import (
    SearchConfig,
    brute_force_topk,
    build_sharded,
    device_graph,
    prepare_database,
    prepare_queries,
    recall_at_k,
    retrieve_vmap,
    search,
)


def _queries(small_db, nq=64, seed=1):
    data, centers, w = small_db
    rng = np.random.default_rng(seed)
    qc = rng.choice(len(centers), size=nq, p=w)
    return (centers[qc] + 0.3 * rng.normal(0, 1, (nq, centers.shape[1]))).astype(np.float32)


def _gt(data, q, k=10):
    vp = prepare_database(jnp.asarray(data), "cos_dist")
    qp = prepare_queries(jnp.asarray(q), "cos_dist")
    return brute_force_topk(qp, vp, k=k)[1]


def test_builder_structure(small_index):
    g = small_index.host_index.freeze()
    n, m0 = g.base_adj.shape
    assert m0 == 16  # 2*M
    # every node has at least one neighbor; ids in range
    deg = (g.base_adj >= 0).sum(1)
    assert (deg > 0).all()
    assert g.base_adj.max() < n
    # bidirectionality is heuristic-pruned but the graph must be connected
    # enough for search: spot-check reachability from the entry point via BFS
    import collections

    seen = {int(g.entry)}
    dq = collections.deque(seen)
    while dq:
        u = dq.popleft()
        for v in g.base_adj[u]:
            if v >= 0 and int(v) not in seen:
                seen.add(int(v))
                dq.append(int(v))
    assert len(seen) > 0.95 * n


def test_static_search_recall_increases_with_ef(small_db, small_index):
    data, _, _ = small_db
    q = _queries(small_db)
    gt = _gt(data, q)
    recalls = []
    for ef in (10, 40, 160):
        res = small_index.query_static(q, ef)
        recalls.append(float(recall_at_k(res.ids, gt).mean()))
    assert recalls[0] < recalls[-1]
    assert recalls[-1] > 0.97


def test_search_matches_bruteforce_at_max_ef(small_db, small_index):
    data, _, _ = small_db
    q = _queries(small_db, nq=16)
    gt = _gt(data, q)
    res = small_index.query_static(q, 240)
    assert float(recall_at_k(res.ids, gt).mean()) > 0.99


def test_adaptive_search_hits_target(small_db, small_index):
    data, _, _ = small_db
    q = _queries(small_db, nq=128)
    gt = _gt(data, q)
    res = small_index.query(q)
    rec = np.asarray(recall_at_k(res.ids, gt))
    assert rec.mean() >= small_index.target_recall - 0.03, rec.mean()
    # adaptive ef must actually vary or at least stay within bounds
    efs = np.asarray(res.ef_used)
    assert efs.min() >= small_index.k
    assert efs.max() <= small_index.search_cfg.ef_cap


def test_adaptive_avoids_oversearch(small_db, small_index):
    """Ada-ef should use less work than always-max-ef for similar recall."""
    data, _, _ = small_db
    q = _queries(small_db, nq=64)
    res_ada = small_index.query(q)
    res_max = small_index.query_static(q, small_index.search_cfg.ef_cap)
    assert float(np.mean(np.asarray(res_ada.ndist))) < float(
        np.mean(np.asarray(res_max.ndist))
    )


def test_pip_baseline_terminates_early(small_db, small_index):
    data, _, _ = small_db
    q = _queries(small_db, nq=32)
    cfg = SearchConfig(k=10, ef_cap=240, patience=20)
    res_pip = search(small_index.graph, jnp.asarray(q), 240, cfg)
    res_full = small_index.query_static(q, 240)
    assert float(np.mean(np.asarray(res_pip.ndist))) <= float(
        np.mean(np.asarray(res_full.ndist))
    )


def test_deleted_nodes_not_returned(small_db):
    from repro.index import build_ada_index

    data, _, _ = small_db
    idx = build_ada_index(
        data[:1500], k=5, target_recall=0.9, m=8, ef_construction=60, ef_cap=160, num_samples=40
    )
    dead = np.arange(0, 200)
    idx.host_index.mark_deleted(dead)
    idx.graph = device_graph(idx.host_index.freeze())
    q = _queries(small_db, nq=32)
    res = idx.query_static(q, 80)
    ids = np.asarray(res.ids)
    assert not np.isin(ids[ids >= 0], dead).any()


# --------------------------------------------------------------------------
# beam-batched expansion
# --------------------------------------------------------------------------


def _search_single_pop_golden(g, queries, ef, cfg):
    """Verbatim copy of the pre-refactor single-pop search loop (one candidate
    popped per iteration, concatenate + full lax.sort merges).  The beamed
    implementation at ``beam=1`` must reproduce it bit-for-bit on these
    fixtures (tie-free float32 keys; exact key ties may legitimately order
    differently under the bitonic merge — see search._merge_sorted)."""
    import jax
    from functools import partial

    from repro.index.distances import key_sign
    from repro.index.search import INF, _extract, _init_state, _not_done

    def gather_keys(g, q, ids, sign):
        safe = jnp.maximum(ids, 0)
        sims = g.vectors[safe] @ q
        vals = 1.0 - sims if sign > 0 else sims
        keys = vals * 1.0 if sign > 0 else -vals
        return jnp.where(ids >= 0, keys, INF), jnp.where(ids >= 0, vals, INF * sign)

    def merge_sorted(keys, ids, new_keys, new_ids, cap):
        all_k = jnp.concatenate([keys, new_keys])
        all_i = jnp.concatenate([ids, new_ids])
        sk, si = jax.lax.sort((all_k, all_i), num_keys=1)
        return sk[:cap], si[:cap]

    def expand(g, q, s, sign):
        n = g.vectors.shape[0]
        c_id = s.ci[0]
        ck = jnp.concatenate([s.ck[1:], jnp.full((1,), INF, s.ck.dtype)])
        ci = jnp.concatenate([s.ci[1:], jnp.full((1,), -1, s.ci.dtype)])
        nbrs = g.base_adj[jnp.maximum(c_id, 0)]
        valid = (nbrs >= 0) & ~s.visited[jnp.minimum(jnp.maximum(nbrs, 0), n - 1)]
        write_idx = jnp.where(valid, nbrs, n)
        visited = s.visited.at[write_idx].set(True)
        keys, _ = gather_keys(g, q, jnp.where(valid, nbrs, -1), sign)
        ndist = s.ndist + jnp.sum(valid).astype(jnp.int32)
        bound = jnp.take(s.rk, s.ef_dyn - 1)
        admit_c = valid & (keys < bound)
        admit_w = admit_c & g.alive[jnp.maximum(nbrs, 0)]
        keys_w = jnp.where(admit_w, keys, INF)
        keys_c = jnp.where(admit_c, keys, INF)
        ids_new = jnp.where(valid, nbrs, -1)
        rk, ri = merge_sorted(s.rk, s.ri, keys_w, ids_new, s.rk.shape[0])
        ck, ci = merge_sorted(ck, ci, keys_c, ids_new, ck.shape[0])
        return s._replace(
            ck=ck, ci=ci, rk=rk, ri=ri, visited=visited, ndist=ndist,
            iters=s.iters + 1,
        )

    @partial(jax.jit, static_argnames=("cfg",))
    def run(g, queries, ef, cfg):
        sign = key_sign(cfg.metric)
        queries = queries.astype(jnp.float32)
        queries = queries / jnp.maximum(
            jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-12
        )
        ef_b = jnp.broadcast_to(jnp.asarray(ef, jnp.int32), queries.shape[:1])
        ef_b = jnp.clip(ef_b, cfg.k, cfg.ef_cap)

        def one(q, ef1):
            s = _init_state(g, q, cfg, ef1, lmax=1, hops=1)

            def cond(s):
                go = _not_done(s) & (s.iters < cfg.iters())
                if cfg.patience > 0:
                    go = go & (s.stale < cfg.patience)
                return go

            def body(s):
                s2 = expand(g, q, s, sign)
                if cfg.patience > 0:
                    bound_k = jnp.take(s2.rk, jnp.minimum(cfg.k, s2.ef_dyn) - 1)
                    improved = bound_k < s.bound_prev
                    s2 = s2._replace(
                        stale=jnp.where(improved, 0, s.stale + 1),
                        bound_prev=jnp.minimum(bound_k, s.bound_prev),
                    )
                return s2

            s = jax.lax.while_loop(cond, body, s)
            return _extract(s, cfg, sign)

        return jax.vmap(one)(queries, ef_b)

    return run(g, queries, ef, cfg)


@pytest.mark.parametrize("ef", [10, 40, 160])
@pytest.mark.parametrize("patience", [0, 20])
def test_beam1_bit_identical_to_single_pop(small_db, small_index, ef, patience):
    q = _queries(small_db, nq=48)
    cfg = SearchConfig(k=10, ef_cap=240, patience=patience, beam=1)
    golden = _search_single_pop_golden(small_index.graph, jnp.asarray(q), ef, cfg)
    got = search(small_index.graph, jnp.asarray(q), ef, cfg)
    for field in ("ids", "dists", "ndist", "iters", "ef_used"):
        a = np.asarray(getattr(golden, field))
        b = np.asarray(getattr(got, field))
        assert (a == b).all(), f"{field}: {np.sum(a != b)} mismatches"


@pytest.mark.parametrize("beam", [2, 4, 8])
def test_beam_matches_recall_with_fewer_iterations(small_db, small_index, beam):
    data, _, _ = small_db
    q = _queries(small_db, nq=64)
    gt = _gt(data, q)
    ef = 80
    res1 = search(small_index.graph, jnp.asarray(q), ef, SearchConfig(k=10, ef_cap=240, beam=1))
    resb = search(small_index.graph, jnp.asarray(q), ef, SearchConfig(k=10, ef_cap=240, beam=beam))
    rec1 = float(recall_at_k(res1.ids, gt).mean())
    recb = float(recall_at_k(resb.ids, gt).mean())
    assert recb >= rec1 - 0.005, (recb, rec1)
    it1 = float(np.asarray(res1.iters).mean())
    itb = float(np.asarray(resb.iters).mean())
    assert itb < it1, (itb, it1)
    # beam over-expands only modestly: bounded extra distance computations
    nd1 = float(np.asarray(res1.ndist).mean())
    ndb = float(np.asarray(resb.ndist).mean())
    assert ndb <= 1.5 * nd1, (ndb, nd1)


def test_beam_adaptive_search_single_estimate(small_db, small_index):
    """Ada-ef on the beamed loop: same target behavior, one estimate/query."""
    import dataclasses as _dc

    data, _, _ = small_db
    q = _queries(small_db, nq=64)
    gt = _gt(data, q)
    cfg = _dc.replace(small_index.search_cfg, beam=4)
    from repro.index import adaptive_search

    res = adaptive_search(
        small_index.graph, jnp.asarray(q), small_index.stats, small_index.table,
        jnp.asarray(small_index.target_recall, jnp.float32), cfg,
        small_index.ada_cfg,
    )
    rec = float(recall_at_k(res.ids, gt).mean())
    assert rec >= small_index.target_recall - 0.03, rec
    efs = np.asarray(res.ef_used)
    assert (efs >= small_index.k).all() and (efs <= cfg.ef_cap).all()


def test_beam_kernel_path_matches_reference(small_db, small_index):
    """use_distance_kernel routes through the Pallas frontier kernel
    (interpret mode on CPU) and must agree with the jnp path numerically."""
    q = _queries(small_db, nq=8)
    cfg_ref = SearchConfig(k=10, ef_cap=240, beam=4)
    cfg_ker = SearchConfig(k=10, ef_cap=240, beam=4, use_distance_kernel=True)
    r_ref = search(small_index.graph, jnp.asarray(q), 40, cfg_ref)
    r_ker = search(small_index.graph, jnp.asarray(q), 40, cfg_ker)
    np.testing.assert_allclose(
        np.asarray(r_ker.dists), np.asarray(r_ref.dists), rtol=1e-4, atol=1e-4
    )
    assert (np.asarray(r_ker.ndist) == np.asarray(r_ref.ndist)).all()


def test_beam_validation():
    with pytest.raises(ValueError):
        SearchConfig(k=10, ef_cap=240, beam=0)
    with pytest.raises(ValueError):
        SearchConfig(k=10, ef_cap=240, beam=241)


# --------------------------------------------------------------------------
# batch-hoisted loop (single batched while_loop vs per-query vmap)
# --------------------------------------------------------------------------


_RESULT_FIELDS = ("ids", "dists", "ndist", "iters", "ef_used")


def _assert_results_equal(a, b, msg=""):
    for field in _RESULT_FIELDS:
        x = np.asarray(getattr(a, field))
        y = np.asarray(getattr(b, field))
        assert (x == y).all(), f"{msg}{field}: {np.sum(x != y)} mismatches"


@pytest.mark.parametrize("ef", [10, 40, 160])
@pytest.mark.parametrize("beam,patience", [(1, 0), (1, 20), (4, 0)])
def test_batch_hoisted_bit_identical_to_vmap(small_db, small_index, ef, beam, patience):
    """Golden acceptance: the batch-hoisted loop reproduces the per-query
    vmap path bit-for-bit (tie-free keys) — beam=1 and beamed, with PiP."""
    import dataclasses as _dc

    q = _queries(small_db, nq=48)
    cfg = SearchConfig(k=10, ef_cap=240, patience=patience, beam=beam)
    golden = search(small_index.graph, jnp.asarray(q), ef, cfg)
    got = search(
        small_index.graph, jnp.asarray(q), ef, _dc.replace(cfg, batch_hoisted=True)
    )
    _assert_results_equal(golden, got)


def test_batch_hoisted_adaptive_bit_identical(small_db, small_index):
    """Both Ada-ef phases run hoisted: same estimates, same phase-B results."""
    import dataclasses as _dc

    from repro.index import adaptive_search

    q = _queries(small_db, nq=32, seed=7)
    golden = small_index.query(q)
    cfg = _dc.replace(small_index.search_cfg, batch_hoisted=True)
    got = adaptive_search(
        small_index.graph, jnp.asarray(q), small_index.stats, small_index.table,
        jnp.asarray(small_index.target_recall, jnp.float32), cfg,
        small_index.ada_cfg,
    )
    _assert_results_equal(golden, got)


def _random_device_graph(rng, n, d, m0):
    """Random navigable-ish graph straight into DeviceGraph: random edges with
    ragged -1 padding, a random upper layer, and a sprinkling of tombstones."""
    from repro.index.search import DeviceGraph
    from repro.index import prepare_database

    vec = prepare_database(jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32)), "cos_dist")
    adj = rng.integers(0, n, (n, m0)).astype(np.int32)
    adj[rng.random((n, m0)) < 0.15] = -1  # ragged rows
    alive = rng.random(n) > 0.1  # tombstones exercise the W-admission mask
    return DeviceGraph(
        base_adj=jnp.asarray(adj),
        upper_adj=jnp.asarray(adj[None, :, : max(m0 // 2, 1)]),
        entry=jnp.asarray(int(rng.integers(0, n)), jnp.int32),
        vectors=vec,
        alive=jnp.asarray(alive),
    )


@pytest.mark.parametrize("seed", range(5))
def test_batch_hoisted_property_random_graphs(seed):
    """Property: on arbitrary random graphs (ragged adjacency, tombstones,
    random beam/ef/batch) the hoisted loop matches the vmap path exactly."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(200, 600))
    d = int(rng.integers(8, 64))
    m0 = int(rng.integers(4, 12))
    g = _random_device_graph(rng, n, d, m0)
    nq = int(rng.integers(1, 20))
    q = rng.normal(0, 1, (nq, d)).astype(np.float32)
    ef = int(rng.integers(5, 60))
    beam = int(rng.choice([1, 2, 3]))
    cfg = SearchConfig(k=5, ef_cap=64, beam=beam)
    golden = search(g, jnp.asarray(q), ef, cfg)
    import dataclasses as _dc

    got = search(g, jnp.asarray(q), ef, _dc.replace(cfg, batch_hoisted=True))
    _assert_results_equal(golden, got, msg=f"seed={seed} ")


def test_batch_hoisted_kernel_path_matches_reference(small_db, small_index):
    """Hoisted loop + cross-query Pallas kernel (interpret on CPU) agrees with
    the hoisted jnp path numerically and in work counted."""
    q = _queries(small_db, nq=8)
    cfg_ref = SearchConfig(k=10, ef_cap=240, beam=4, batch_hoisted=True)
    cfg_ker = SearchConfig(
        k=10, ef_cap=240, beam=4, batch_hoisted=True, use_distance_kernel=True
    )
    r_ref = search(small_index.graph, jnp.asarray(q), 40, cfg_ref)
    r_ker = search(small_index.graph, jnp.asarray(q), 40, cfg_ker)
    np.testing.assert_allclose(
        np.asarray(r_ker.dists), np.asarray(r_ref.dists), rtol=1e-4, atol=1e-4
    )
    assert (np.asarray(r_ker.ndist) == np.asarray(r_ref.ndist)).all()


def test_sharded_merge_equals_global_topk(small_db):
    """Distributed top-k merge must return the union-best ids."""
    data, _, _ = small_db
    sidx = build_sharded(
        data[:2000],
        num_shards=2,
        k=10,
        target_recall=0.9,
        m=8,
        ef_construction=60,
        ef_cap=160,
        num_samples=40,
    )
    q = _queries(small_db, nq=32)
    res = retrieve_vmap(sidx, q)
    gt = _gt(data[:2000], q)
    rec = float(recall_at_k(res.ids, gt).mean())
    assert rec > 0.85
    # merged ids must be globally sorted by distance
    d = np.asarray(res.dists)
    assert (np.diff(d, axis=1) >= -1e-6).all()
