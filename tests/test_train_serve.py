"""Training substrate: learning, microbatching equivalence, checkpoint/resume,
compressed gradient all-reduce, serving engine, elastic restore."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.train import (
    DataConfig,
    OptimizerConfig,
    TrainConfig,
    compressed_psum,
    init_optimizer,
    latest_step,
    make_batch,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)

CFG = ARCHS["qwen2-0.5b"].reduced()
SHAPE = ShapeConfig("t", 64, 8, "train")


def _setup(seed=0, microbatches=1):
    model = build_model(CFG, impl="naive")
    params = model.init(jax.random.PRNGKey(seed))
    tcfg = TrainConfig(
        microbatches=microbatches,
        opt=OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=40),
    )
    step = jax.jit(make_train_step(model, tcfg))
    return model, params, init_optimizer(params), step


def test_training_reduces_loss():
    model, params, opt, step = _setup()
    losses = []
    for i in range(10):
        params, opt, m = step(params, opt, make_batch(CFG, SHAPE, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_microbatch_equivalent_loss():
    """Accumulated microbatches must produce (nearly) the same update."""
    _, p1, o1, s1 = _setup(seed=1, microbatches=1)
    _, p2, o2, s2 = _setup(seed=1, microbatches=4)
    batch = make_batch(CFG, SHAPE, 0)
    p1n, _, m1 = s1(p1, o1, batch)
    p2n, _, m2 = s2(p2, o2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-2)
    a = jax.tree_util.tree_leaves(p1n)[3]
    b = jax.tree_util.tree_leaves(p2n)[3]
    # bf16 loss noise can flip the sign of a normalized Adam step; bound by ~2*lr
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-3)


def test_data_pipeline_deterministic():
    b1 = make_batch(CFG, SHAPE, 7, DataConfig(seed=3))
    b2 = make_batch(CFG, SHAPE, 7, DataConfig(seed=3))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = make_batch(CFG, SHAPE, 8, DataConfig(seed=3))
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_checkpoint_atomic_resume():
    model, params, opt, step = _setup()
    with tempfile.TemporaryDirectory() as d:
        for i in range(3):
            params, opt, _ = step(params, opt, make_batch(CFG, SHAPE, i))
        save_checkpoint(d, 3, {"params": params, "opt": opt})
        # a stale tmp dir from a "crashed" writer must not break restore
        os.makedirs(os.path.join(d, "step_00000009.tmp"), exist_ok=True)
        assert latest_step(d) == 3
        restored = restore_checkpoint(d, None, {"params": params, "opt": opt})
        for a, b in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(restored["params"]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(restored["opt"].step) == int(opt.step)


def test_resume_reproduces_uninterrupted_run():
    """Fault-tolerance contract: crash + resume == continuous run."""
    with tempfile.TemporaryDirectory() as d:
        model, p_a, o_a, step = _setup(seed=5)
        for i in range(6):
            p_a, o_a, _ = step(p_a, o_a, make_batch(CFG, SHAPE, i))
        # interrupted run: 3 steps, checkpoint, "crash", resume, 3 more
        _, p_b, o_b, _ = _setup(seed=5)
        for i in range(3):
            p_b, o_b, _ = step(p_b, o_b, make_batch(CFG, SHAPE, i))
        save_checkpoint(d, 3, {"params": p_b, "opt": o_b})
        restored = restore_checkpoint(d, 3, {"params": p_b, "opt": o_b})
        p_c, o_c = restored["params"], restored["opt"]
        for i in range(3, 6):
            p_c, o_c, _ = step(p_c, o_c, make_batch(CFG, SHAPE, i))
        a = jax.tree_util.tree_leaves(p_a)[3]
        c = jax.tree_util.tree_leaves(p_c)[3]
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["none", "bf16", "int8"])
def test_compressed_psum_error_feedback(mode):
    """Quantized all-reduce + EF: single-device psum must round-trip closely,
    and the residual must carry the quantization error."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (64, 64)), jnp.float32)}

    def f(grads):
        mean, res = compressed_psum(grads, ("data",), mode)
        return mean, res

    mapped = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()), check_rep=False)
    mean, res = jax.jit(mapped)(g)
    if mode == "none":
        np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(g["w"]), rtol=1e-6)
        assert float(jnp.abs(res["w"]).max()) == 0.0
    else:
        tol = 1e-2 if mode == "bf16" else 3e-2
        np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(g["w"]), atol=tol)
        # residual == g - sent (error feedback invariant)
        np.testing.assert_allclose(
            np.asarray(mean["w"] + res["w"]), np.asarray(g["w"]), rtol=1e-5, atol=1e-6
        )


def test_serving_engine_greedy_decode():
    from repro.serve import Engine, ServeConfig

    model, params, _, _ = _setup()
    eng = Engine(model, params, ServeConfig(max_new_tokens=4))
    tok = jnp.asarray(np.random.default_rng(1).integers(0, CFG.vocab_size, (2, 12)), jnp.int32)
    res = eng.serve({"tokens": tok})
    assert res.tokens.shape == (2, 4)
    assert (res.tokens >= 0).all() and (res.tokens < CFG.vocab_size).all()


def test_elastic_reshard_restore():
    """Checkpoint on one mesh restores onto another (device count change)."""
    from repro.launch.elastic import reshard_restore, surviving_mesh

    model, params, opt, step = _setup()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"params": params, "opt": opt})
        mesh = surviving_mesh(1, model_axis=1)  # single-device "survivor"
        p2, o2 = reshard_restore(d, 1, model, mesh)
        a = jax.tree_util.tree_leaves(params)[0]
        b = jax.tree_util.tree_leaves(p2)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_driver_end_to_end():
    from repro.launch.train import train_loop

    with tempfile.TemporaryDirectory() as d:
        _, _, losses = train_loop(
            "qwen2-0.5b", reduced=True, steps=12, batch=4, seq=48,
            ckpt_dir=d, ckpt_every=6, log_every=2, impl="naive",
        )
        assert latest_step(d) == 12
        assert losses[-1][1] < losses[0][1]
