"""Observability layer: metrics registry (histogram quantiles, Prometheus
export, merging), span tracer (lifecycle trees, ring bound, Chrome export),
recall auditor (deterministic sampling, EWMA alerts, edge re-arm), and the
scheduler/plan integration — trace+audit armed end to end, plus the
"disabled costs nothing" contract (no tracer/auditor objects at all)."""
import json

import numpy as np
import pytest

from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    RecallAuditor,
    SpanTracer,
    sample_uid,
)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------


def test_counter_and_gauge():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge()
    g.set(7.0)
    g.set(2.0)
    assert g.value == 2.0


def test_histogram_quantiles_bucketed():
    h = Histogram()
    for v in [0.001] * 50 + [0.01] * 45 + [0.1] * 5:
        h.observe(v)
    assert h.count == 100
    # quantile estimates land inside the owning bucket (linear interp)
    assert 0.0005 < h.p50 <= 0.0025
    assert 0.005 < h.p95 <= 0.025
    assert 0.05 < h.p99 <= 0.25
    assert h.mean == pytest.approx((50 * 0.001 + 45 * 0.01 + 5 * 0.1) / 100)
    assert np.isnan(Histogram().p50)


def test_histogram_overflow_and_merge():
    h = Histogram()
    h.observe(100.0)  # past the top bucket bound
    assert h.p99 == pytest.approx(100.0)  # overflow quantiles answer max
    a, b = Histogram(), Histogram()
    for v in (0.001, 0.002):
        a.observe(v)
    for v in (0.05, 0.07, 0.09):
        b.observe(v)
    a.merge(b)
    assert a.count == 5
    assert a.sum == pytest.approx(0.213)
    assert a.min == pytest.approx(0.001)
    assert a.max == pytest.approx(0.09)
    with pytest.raises(ValueError):
        a.merge(Histogram(buckets=(1.0, 2.0)))
    json.dumps(a.as_dict())  # JSON-able


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.counter("x", tier="a") is not reg.counter("x", tier="b")
    with pytest.raises(ValueError):
        reg.gauge("x")  # registered as counter
    reg.counter("x").inc(3)
    reg.histogram("lat").observe(0.004)
    d = reg.as_dict()
    assert d["x"]["_"] == 3
    assert d["lat"]["_"]["count"] == 1
    json.dumps(d)


def test_registry_merge_and_prometheus():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("n").inc(1)
    b.counter("n").inc(2)
    b.gauge("depth").set(4)
    b.histogram("lat", ef="64").observe(0.01)
    a.merge(b)
    assert a.counter("n").value == 3
    text = a.render_prometheus()
    assert "# TYPE n counter" in text
    assert "n 3" in text
    assert 'lat_bucket{ef="64",le="+Inf"} 1' in text
    assert 'lat_count{ef="64"} 1' in text


# --------------------------------------------------------------------------
# tracing
# --------------------------------------------------------------------------


def test_tracer_spans_and_request_complete():
    clock = FakeClock()
    tr = SpanTracer(clock=clock)
    tr.event("submit", uid=1, k=5)
    s = tr.begin("queue", uid=1, tier_ef=64)
    clock.advance(0.5)
    tr.end(s)
    tr.event("terminal", uid=1, status="ok")
    assert s.duration_s == pytest.approx(0.5)
    assert [x.name for x in tr.spans(1)] == ["submit", "queue", "terminal"]
    assert tr.request_terminal(1) == "ok"
    assert tr.request_complete(1) == "ok"
    assert tr.request_terminal(2) is None
    with pytest.raises(ValueError, match="no spans"):
        tr.request_complete(2)


def test_tracer_rejects_incomplete_trees():
    tr = SpanTracer(clock=FakeClock())
    tr.begin("queue", uid=1)  # never ended
    tr.event("terminal", uid=1, status="ok")
    with pytest.raises(ValueError, match="unclosed"):
        tr.request_complete(1)
    tr2 = SpanTracer(clock=FakeClock())
    tr2.event("submit", uid=2)
    with pytest.raises(ValueError, match="terminal"):
        tr2.request_complete(2)
    tr2.event("terminal", uid=2, status="ok")
    tr2.event("terminal", uid=2, status="ok")
    with pytest.raises(ValueError, match="exactly one terminal"):
        tr2.request_complete(2)


def test_tracer_ring_bound_and_end_idempotent():
    tr = SpanTracer(clock=FakeClock(), capacity=4)
    for i in range(7):
        tr.event("e", uid=i)
    assert len(tr.spans()) == 4
    assert tr.dropped == 3
    assert [s.uid for s in tr.spans()] == [3, 4, 5, 6]
    clock = FakeClock()
    tr2 = SpanTracer(clock=clock)
    s = tr2.begin("x")
    clock.advance(1.0)
    tr2.end(s)
    clock.advance(1.0)
    tr2.end(s)  # idempotent: first close wins
    assert s.duration_s == pytest.approx(1.0)
    assert tr2.end(None) is None  # None-tolerant
    with pytest.raises(ValueError):
        SpanTracer(capacity=0)


def test_tracer_chrome_export_round_trip(tmp_path):
    clock = FakeClock(100.0)
    tr = SpanTracer(clock=clock)
    with tr.span("estimate", batch=4):
        clock.advance(0.002)
    tr.event("terminal", uid=7, status="ok")
    tr.begin("queue", uid=7)  # left open: exported as flagged instant
    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert len(events) == 3
    by_name = {e["name"]: e for e in events}
    est = by_name["estimate"]
    assert est["ph"] == "X" and est["dur"] == pytest.approx(2000.0)
    assert est["ts"] == pytest.approx(0.0)  # origin-relative
    assert by_name["terminal"]["ph"] == "i"
    assert by_name["terminal"]["tid"] == 7
    assert by_name["queue"]["args"]["open"] is True
    assert doc["otherData"]["dropped"] == 0


# --------------------------------------------------------------------------
# recall auditor
# --------------------------------------------------------------------------


def test_sample_uid_deterministic():
    assert not any(sample_uid(u, 0.0) for u in range(100))
    assert all(sample_uid(u, 1.0) for u in range(100))
    picks = [sample_uid(u, 0.3) for u in range(1000)]
    assert picks == [sample_uid(u, 0.3) for u in range(1000)]  # stable
    assert 0.15 < np.mean(picks) < 0.45  # roughly the asked fraction


def _auditor(reference, **kw):
    kw.setdefault("fraction", 1.0)
    return RecallAuditor(reference, clock=FakeClock(), **kw)


def test_auditor_recall_and_ewma():
    ref = lambda q: np.arange(5, dtype=np.int32)[None, :]
    aud = _auditor(ref, alpha=0.5)
    aud.enqueue(0, np.zeros(4), np.arange(5), k=5, tier_ef=64,
                target=0.9, status="ok")
    aud.enqueue(1, np.zeros(4), np.array([0, 1, 9, 9, 9]), k=5, tier_ef=64,
                target=0.9, status="ok")
    assert aud.pending == 2
    assert aud.step(budget=1) == 1  # budgeted: one per idle tick
    assert aud.pending == 1
    aud.flush()
    assert aud.audited == 2
    t = aud.tier_ewmas()[64]
    # seed 1.0, then 0.5*1.0 + 0.5*0.4
    assert t["recall_ewma"] == pytest.approx(0.7)
    assert t["target_ewma"] == pytest.approx(0.9)
    assert t["samples"] == 2
    json.dumps(aud.as_dict())


def test_auditor_alert_edge_trigger_and_rearm():
    ref = lambda q: np.arange(5, dtype=np.int32)[None, :]
    alerts_seen = []
    aud = _auditor(ref, alpha=1.0, min_samples=2, margin=0.05,
                   on_alert=alerts_seen.append)
    bad = np.full(5, 99)
    good = np.arange(5)
    for uid in range(3):  # 3 bad samples, but only one (edge) alert
        aud.enqueue(uid, np.zeros(4), bad, k=5, tier_ef=32,
                    target=0.9, status="ok")
    aud.flush()
    assert len(aud.alerts) == 1 and len(alerts_seen) == 1
    a = aud.alerts[0]
    assert a.tier_ef == 32 and a.ewma == 0.0 and a.samples >= 2
    # recovery re-arms the edge; the next breach fires a second alert
    aud.enqueue(3, np.zeros(4), good, k=5, tier_ef=32, target=0.9,
                status="ok")
    aud.flush()
    assert not aud.tier_ewmas()[32]["alerting"]
    aud.enqueue(4, np.zeros(4), bad, k=5, tier_ef=32, target=0.9,
                status="ok")
    aud.flush()
    assert len(aud.alerts) == 2


def test_auditor_partial_pseudo_tier_never_alerts():
    ref = lambda q: np.arange(5, dtype=np.int32)[None, :]
    aud = _auditor(ref, alpha=1.0, min_samples=1, margin=0.0)
    for uid in range(4):
        aud.enqueue(uid, np.zeros(4), np.full(5, 99), k=5, tier_ef=0,
                    target=0.9, status="partial")
    aud.flush()
    assert aud.tier_ewmas()[0]["recall_ewma"] == 0.0
    assert aud.alerts == []


def test_auditor_pending_bound():
    ref = lambda q: np.arange(5, dtype=np.int32)[None, :]
    aud = _auditor(ref, max_pending=2)
    for uid in range(5):
        aud.enqueue(uid, np.zeros(4), np.arange(5), k=5, tier_ef=64,
                    target=0.9, status="ok")
    assert aud.pending == 2
    assert aud.overflowed == 3
    assert aud.sampled == 5
    aud.flush()
    assert aud.audited == 2


def test_auditor_validation():
    ref = lambda q: np.arange(5)[None, :]
    with pytest.raises(ValueError):
        RecallAuditor(ref, fraction=1.5)
    with pytest.raises(ValueError):
        RecallAuditor(ref, fraction=0.5, alpha=0.0)


# --------------------------------------------------------------------------
# scheduler integration
# --------------------------------------------------------------------------


def _queries(small_db, nq, seed=3):
    data, centers, w = small_db
    rng = np.random.default_rng(seed)
    qc = rng.choice(len(centers), size=nq, p=w)
    return (centers[qc] + 0.3 * rng.normal(0, 1, (nq, centers.shape[1]))
            ).astype(np.float32)


def test_scheduler_trace_audit_end_to_end(small_db, small_index):
    from repro.api import SchedulerConfig
    from repro.serve import AdaServeScheduler, SearchRequest

    q = _queries(small_db, nq=9)
    sched = AdaServeScheduler(
        small_index.router(),
        SchedulerConfig(fill=4, trace=True, audit_fraction=1.0),
        default_target_recall=small_index.target_recall,
    )
    tickets = [sched.submit(SearchRequest(query=x)) for x in q]
    responses = sched.drain()
    by_uid = {r.ticket.uid: r for r in responses}
    # every ticket owns exactly one closed span tree ending in its status
    for t in tickets:
        assert sched.tracer.request_complete(t.uid) == by_uid[t.uid].status
    # audit_fraction=1.0 + drain flush -> every request audited; any
    # alerts the auditor raised must be mirrored into the stats counter
    assert sched.auditor.audited == len(q)
    aud = sched.auditor.as_dict()
    assert sched.stats.recall_alerts == len(aud["alerts"])
    assert all(t["recall_ewma"] > 0.5 for t in aud["tiers"].values())
    # counters mirrored into the registry match the dataclass fields
    reg = sched.metrics.as_dict()
    assert reg["scheduler_submitted"]["_"] == sched.stats.submitted
    assert reg["scheduler_completed"]["_"] == sched.stats.completed
    # per-status e2e latency histograms recorded one sample per response
    e2e = reg["request_e2e_s"]
    assert sum(h["count"] for h in e2e.values()) == len(q)


def test_scheduler_observability_disabled_is_absent(small_db, small_index):
    from repro.api import SchedulerConfig
    from repro.serve import AdaServeScheduler, SearchRequest

    q = _queries(small_db, nq=3)
    sched = AdaServeScheduler(
        small_index.router(),
        SchedulerConfig(fill=4),  # trace=False, audit_fraction=0.0
        default_target_recall=small_index.target_recall,
    )
    assert sched.tracer is None
    assert sched.auditor is None
    for x in q:
        sched.submit(SearchRequest(query=x))
    assert len(sched.drain()) == 3  # lifecycle unaffected


def test_scheduler_config_obs_validation():
    from repro.api import SchedulerConfig

    with pytest.raises(ValueError):
        SchedulerConfig(audit_fraction=1.5)
    with pytest.raises(ValueError):
        SchedulerConfig(trace_capacity=0)
    with pytest.raises(ValueError):
        SchedulerConfig(audit_margin=-0.1)


def test_plan_explain_analyze(small_db, small_index):
    from repro.api import SearchSpec

    plan = small_index.plan(SearchSpec(k=5, target_recall=0.9))
    d = plan.explain()
    assert "analyze" not in d  # static explain unchanged by default
    d = plan.explain(analyze=True, nq=8)
    a = d["analyze"]
    assert a["nq"] == 8 and a["mode"] == "oneshot"
    assert a["wall_s"] > 0 and a["ndist_total"] > 0
    assert 0.0 <= a["recall"]["mean"] <= 1.0
    json.dumps(d)  # acceptance: JSON round-trippable
    text = plan.explain(fmt="text", analyze=True, nq=8)
    assert "analyze" in text and "recall" in text


def test_plan_explain_analyze_streaming(small_db, small_index):
    from repro.api import SearchSpec

    plan = small_index.plan(SearchSpec(
        k=5, target_recall=0.9, mode="streaming", deadline_ms=200,
    ))
    before = plan.metrics.as_dict().get(
        "scheduler_submitted", {}).get("_", 0)
    a = plan.explain(analyze=True, nq=8)["analyze"]
    assert a["mode"] == "streaming"
    assert sum(a["statuses"].values()) == 8
    assert a["latency"]["p99_s"] >= a["latency"]["p50_s"] >= 0
    assert a["recall"]["samples"] == 8  # analyze audits every probe
    assert a["recall"]["alerts"] == 0
    # analyze probes through a private throwaway session: only the warm
    # call (the plan's shared scheduler) lands in the plan's registry
    after = plan.metrics.as_dict().get("scheduler_submitted", {}).get("_", 0)
    assert after - before == 8
