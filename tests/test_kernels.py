"""Pallas kernels vs ref.py oracles — interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("b,n,d", [(8, 64, 32), (37, 211, 100), (128, 300, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("metric", ["cos_dist", "ip"])
def test_distance_kernel(b, n, d, dtype, metric):
    q = jnp.asarray(RNG.normal(0, 1, (b, d)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (n, d)), dtype)
    got = ops.pairwise_distance(q, v, metric=metric, use_kernel=True, interpret=True)
    want = ref.distance_ref(q, v, metric=metric)
    tol = 3e-4 if dtype == jnp.float32 else 2e-2  # accumulation-order noise at d>=256
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,f,d", [(1, 32, 48), (8, 128, 100), (13, 200, 256)])
@pytest.mark.parametrize("metric", ["cos_dist", "ip"])
def test_frontier_kernel(b, f, d, metric):
    """Fused frontier keys vs jnp oracle, incl. -1-padded / visited-masked ids."""
    n = 777
    vec = jnp.asarray(RNG.normal(0, 1, (n, d)).astype(np.float32))
    q = jnp.asarray(RNG.normal(0, 1, (b, d)).astype(np.float32))
    ids = RNG.integers(0, n, (b, f)).astype(np.int32)
    # -1 padding (short adjacency rows) + visited-masked slots, interleaved
    ids[:, ::5] = -1
    ids[:, 3::7] = -1
    ids = jnp.asarray(ids)
    got = ops.frontier_keys(ids, q, vec, metric=metric, use_kernel=True, interpret=True)
    want = ref.frontier_ref(ids, q, vec, metric=metric)
    masked = np.asarray(ids) < 0
    assert np.isposinf(np.asarray(got)[masked]).all()
    np.testing.assert_allclose(
        np.asarray(got)[~masked], np.asarray(want)[~masked], rtol=3e-4, atol=3e-4
    )


def test_frontier_kernel_all_masked_row():
    """A fully masked frontier (all ids -1) must emit +inf everywhere."""
    vec = jnp.asarray(RNG.normal(0, 1, (50, 32)).astype(np.float32))
    q = jnp.asarray(RNG.normal(0, 1, (2, 32)).astype(np.float32))
    ids = jnp.full((2, 64), -1, jnp.int32)
    got = ops.frontier_keys(ids, q, vec, use_kernel=True, interpret=True)
    assert np.isposinf(np.asarray(got)).all()


@pytest.mark.parametrize("b,f,d", [(8, 64, 32), (13, 48, 100), (3, 200, 64)])
@pytest.mark.parametrize("metric", ["cos_dist", "ip"])
def test_frontier_batch_kernel(b, f, d, metric):
    """Cross-query fused kernel (compaction + owner-select epilogue) vs the
    per-query panel oracle — padded ids, non-tile-multiple B, both metrics."""
    n = 777
    vec = jnp.asarray(RNG.normal(0, 1, (n, d)).astype(np.float32))
    q = jnp.asarray(RNG.normal(0, 1, (b, d)).astype(np.float32))
    ids = RNG.integers(0, n, (b, f)).astype(np.int32)
    ids[:, ::5] = -1
    ids[:, 3::7] = -1
    ids[0] = -1  # a converged query: whole row masked
    ids = jnp.asarray(ids)
    got = ops.frontier_keys_batch(
        ids, q, vec, metric=metric, use_kernel=True, interpret=True
    )
    want = ref.frontier_ref(ids, q, vec, metric=metric)
    masked = np.asarray(ids) < 0
    assert np.isposinf(np.asarray(got)[masked]).all()
    np.testing.assert_allclose(
        np.asarray(got)[~masked], np.asarray(want)[~masked], rtol=3e-4, atol=3e-4
    )


def test_frontier_batch_kernel_all_masked():
    """nvalid == 0: every grid tile takes the skip path and emits +inf."""
    vec = jnp.asarray(RNG.normal(0, 1, (50, 32)).astype(np.float32))
    q = jnp.asarray(RNG.normal(0, 1, (4, 32)).astype(np.float32))
    ids = jnp.full((4, 64), -1, jnp.int32)
    got = ops.frontier_keys_batch(ids, q, vec, use_kernel=True, interpret=True)
    assert np.isposinf(np.asarray(got)).all()


def test_frontier_batch_ref_matches_panel_oracle():
    """Flat (row, owner) oracle == per-query panel oracle on the same slots."""
    n, d, b, f = 300, 40, 6, 32
    vec = jnp.asarray(RNG.normal(0, 1, (n, d)).astype(np.float32))
    q = jnp.asarray(RNG.normal(0, 1, (b, d)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(-1, n, (b, f)).astype(np.int32))
    flat = ids.reshape(-1)
    owners = jnp.arange(b * f, dtype=jnp.int32) // f
    got = ref.frontier_batch_ref(flat, owners, q, vec).reshape(b, f)
    want = ref.frontier_ref(ids, q, vec)
    fin = np.isfinite(np.asarray(want))
    assert (fin == np.isfinite(np.asarray(got))).all()
    np.testing.assert_allclose(
        np.asarray(got)[fin], np.asarray(want)[fin], rtol=1e-5, atol=1e-5
    )


def test_compact_frontier_is_permutation():
    """Valid ids form a prefix; dest un-compacts exactly; counts agree."""
    ids = jnp.asarray(RNG.integers(-1, 50, (257,)).astype(np.int32))
    cids, owners, dest, nvalid = ops.compact_frontier(ids)
    cids, owners, dest = map(np.asarray, (cids, owners, dest))
    nv = int(nvalid)
    assert nv == int((np.asarray(ids) >= 0).sum())
    assert (cids[:nv] >= 0).all() and (cids[nv:] < 0).all()
    assert sorted(dest.tolist()) == list(range(len(cids)))  # true permutation
    np.testing.assert_array_equal(cids[dest], np.asarray(ids))
    # owners carry each compacted row's original slot index
    np.testing.assert_array_equal(owners[dest], np.arange(len(cids)))


def test_frontier_ref_matches_search_gather_keys():
    """The frontier oracle and the search loop's inline scorer agree (up to
    contraction-order rounding) including the +inf mask placement."""
    from repro.index.search import DeviceGraph, _gather_keys

    n, d, f = 300, 64, 40
    vec = jnp.asarray(RNG.normal(0, 1, (n, d)).astype(np.float32))
    q = jnp.asarray(RNG.normal(0, 1, (d,)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(-1, n, (f,)).astype(np.int32))
    g = DeviceGraph(
        base_adj=jnp.zeros((n, 4), jnp.int32), upper_adj=jnp.zeros((1, n, 4), jnp.int32),
        entry=jnp.asarray(0, jnp.int32), vectors=vec, alive=jnp.ones((n,), bool),
    )
    keys, _ = _gather_keys(g, q, ids, 1.0)
    want = ref.frontier_ref(ids[None], q[None], vec, metric="cos_dist")[0]
    masked = np.asarray(ids) < 0
    assert np.isposinf(np.asarray(keys)[masked]).all()
    assert np.isposinf(np.asarray(want)[masked]).all()
    np.testing.assert_allclose(
        np.asarray(keys)[~masked], np.asarray(want)[~masked], rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("b,d", [(4, 64), (17, 300), (64, 512)])
def test_qform_kernel(b, d):
    a = RNG.normal(0, 1, (d, d)).astype(np.float32)
    sigma = a @ a.T / d
    q = jnp.asarray(RNG.normal(0, 1, (b, d)).astype(np.float32))
    got = ops.quadratic_form(q, jnp.asarray(sigma), use_kernel=True, interpret=True)
    want = ref.qform_ref(q, jnp.asarray(sigma))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,l,m", [(3, 50, 5), (9, 250, 10), (40, 1057, 10)])
def test_binscore_kernel(b, l, m):
    d = jnp.asarray(np.sort(RNG.normal(1.0, 0.1, (b, l))).astype(np.float32))
    t = jnp.asarray(np.sort(RNG.normal(0.95, 0.05, (b, m)), axis=1).astype(np.float32))
    w = jnp.asarray((100 * np.exp(-np.arange(m))).astype(np.float32))
    valid = jnp.asarray((RNG.random((b, l)) < 0.8).astype(np.float32))
    got = ops.binscore_raw(d, t, w, valid, use_kernel=True, interpret=True)
    want = ref.binscore_ref(d, t, w, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_binscore_kernel_matches_core_scoring():
    """Kernel-backed score == pure-jnp score_query on the same inputs."""
    from repro.core import FDLParams, score_query

    b, l = 6, 120
    params = FDLParams(
        mu=jnp.full((b,), 0.9, jnp.float32), sigma=jnp.full((b,), 0.07, jnp.float32)
    )
    d = jnp.asarray(RNG.normal(0.85, 0.1, (b, l)).astype(np.float32))
    valid = jnp.asarray(RNG.random((b, l)) < 0.9)
    want = score_query(params, d, valid=valid)
    got = ops.score(params, d, valid=valid.astype(jnp.float32), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("h,hk,sq,skv", [(4, 4, 128, 128), (8, 2, 64, 256), (8, 1, 256, 256)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel(h, hk, sq, skv, causal):
    b, d = 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, h, sq, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(0, 1, (b, hk, skv, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(0, 1, (b, hk, skv, d)).astype(np.float32))
    got = ops.flash_attention(q, k, v, causal=causal, use_kernel=True, bq=64, bk=64, interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("s,bs", [(256, 64), (512, 128)])
def test_decode_attention_kernel(s, bs):
    b, h, hk, d = 3, 8, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, h, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(0, 1, (b, s, hk, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(0, 1, (b, s, hk, d)).astype(np.float32))
    lens = jnp.asarray([7, s // 2, s], jnp.int32)
    got = ops.decode_attention(q, k, v, lens, use_kernel=True, bs=bs, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


def test_jnp_flash_custom_vjp_gradients():
    """The model-side flash attention backward matches the naive oracle."""
    from repro.models.attention import _naive_attention, flash_attention_jnp

    b, sq, skv, h, hk, d = 2, 96, 160, 4, 2, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, sq, h, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(0, 1, (b, skv, hk, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(0, 1, (b, skv, hk, d)).astype(np.float32))

    def f_flash(q, k, v):
        return (flash_attention_jnp(q, k, v, causal=True, q_offset=skv - sq, q_block=32, kv_block=64) ** 2).sum()

    def f_naive(q, k, v):
        return (_naive_attention(q, k, v, causal=True, q_offset=skv - sq) ** 2).sum()

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    # flash uses bf16 probability tiles for the P*V / dS*Q matmuls (standard
    # production numerics); tolerance reflects bf16 mantissa vs the f32 oracle
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-2, atol=1e-2)
