"""Hypothesis property tests for the sort-based MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS
from repro.models.moe import capacity, moe_apply, moe_params, padded_experts


def _cfg(num_experts, top_k, cf=4.0):
    return dataclasses.replace(
        ARCHS["qwen3-moe-30b-a3b"].reduced(),
        num_experts=num_experts,
        num_experts_per_tok=top_k,
        num_shared_experts=0,
        capacity_factor=cf,
    )


@settings(max_examples=10, deadline=None)
@given(
    e=st.integers(min_value=4, max_value=12),
    k=st.integers(min_value=1, max_value=3),
    seed=st.integers(0, 100),
)
def test_moe_output_finite_and_shaped(e, k, seed):
    cfg = _cfg(e, min(k, e))
    p = moe_params(jax.random.PRNGKey(seed), cfg, model_axis=4)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 24, cfg.d_model)), jnp.bfloat16)
    out, aux = moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert jnp.all(jnp.isfinite(out.astype(jnp.float32)))
    assert float(aux) >= 0.0  # load-balance loss is a scaled product of means


def test_moe_matches_dense_expert_reference():
    """With capacity ample (no drops), dispatch/combine must equal the direct
    per-token top-k mixture computed densely."""
    cfg = _cfg(8, 2, cf=8.0)
    p = moe_params(jax.random.PRNGKey(0), cfg, model_axis=4)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 16, cfg.d_model)), jnp.float32).astype(jnp.bfloat16)
    out, _ = moe_apply(p, cfg, x)

    # dense reference: every token through every expert, combine top-k probs
    t = x.reshape(-1, cfg.d_model)
    e_pad = p["router"].shape[1]
    logits = (t @ p["router"].astype(jnp.bfloat16)).astype(jnp.float32)
    logits = jnp.where(jnp.arange(e_pad)[None, :] < cfg.num_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    if cfg.norm_topk_prob:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    def expert(i, xx):
        g = xx @ p["w_gate"][i].astype(xx.dtype)
        u = xx @ p["w_up"][i].astype(xx.dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xx.dtype) * u
        return h @ p["w_down"][i].astype(xx.dtype)

    all_out = jnp.stack([expert(i, t) for i in range(e_pad)])  # (E, T, D)
    ref = jnp.zeros_like(t)
    for j in range(cfg.num_experts_per_tok):
        sel = all_out[top_e[:, j], jnp.arange(t.shape[0])]
        ref = ref + sel * top_p[:, j, None].astype(sel.dtype)
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, cfg.d_model), np.float32),
        np.asarray(ref, np.float32),
        rtol=5e-2,
        atol=5e-2,
    )


def test_moe_capacity_drops_pass_residual():
    """Tokens dropped at capacity contribute zero (residual passes them)."""
    cfg = _cfg(4, 2, cf=0.01)  # absurdly tight capacity -> mass drops
    # capacity() floors at 128 slots; use many tokens to force overflow
    p = moe_params(jax.random.PRNGKey(0), cfg, model_axis=4)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 0.5, (8, 128, cfg.d_model)), jnp.bfloat16)
    out, _ = moe_apply(p, cfg, x)
    # with 1024 tokens x top-2 into 4(+pad) experts at 128-slot capacity,
    # most assignments drop; output must stay finite and bounded
    assert jnp.all(jnp.isfinite(out.astype(jnp.float32)))
    e_pad = padded_experts(cfg, 4)
    assert capacity(cfg, 8 * 128, e_pad) == 128


def test_moe_permutation_equivariance():
    """Permuting tokens permutes outputs (no positional leakage through sort)."""
    cfg = _cfg(6, 2, cf=8.0)
    p = moe_params(jax.random.PRNGKey(3), cfg, model_axis=4)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(0, 0.5, (1, 32, cfg.d_model)), jnp.bfloat16)
    perm = rng.permutation(32)
    out1, _ = moe_apply(p, cfg, x)
    out2, _ = moe_apply(p, cfg, x[:, perm])
    np.testing.assert_allclose(
        np.asarray(out1[:, perm], np.float32), np.asarray(out2, np.float32),
        rtol=2e-2, atol=2e-2,
    )
