"""Declarative facade: SearchSpec validation + round-trip, planner lowering,
plan caching/invalidation, jit-static configs, and the bit-exactness of
``plan.search`` / ``plan.submit()``+``poll()`` against the legacy execution
paths in all three modes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    RouterConfig,
    SchedulerConfig,
    SearchSpec,
    SpecOverrides,
)
from repro.serve import SearchRequest


def _queries(small_db, nq=32, seed=1):
    data, centers, w = small_db
    rng = np.random.default_rng(seed)
    qc = rng.choice(len(centers), size=nq, p=w)
    return (centers[qc] + 0.3 * rng.normal(0, 1, (nq, centers.shape[1]))).astype(
        np.float32
    )


def _toy_index(small_db, n=1200):
    from repro.index import build_ada_index

    data, _, _ = small_db
    return build_ada_index(
        data[:n], k=5, target_recall=0.9, m=8, ef_construction=60,
        ef_cap=160, num_samples=32,
    )


# --------------------------------------------------------------------------
# SearchSpec: validation, hashability, serialization round-trip
# --------------------------------------------------------------------------


def test_spec_validation():
    SearchSpec()  # all defaults legal
    with pytest.raises(ValueError):
        SearchSpec(mode="batch")
    with pytest.raises(ValueError):
        SearchSpec(backend="cuda")
    with pytest.raises(ValueError):
        SearchSpec(k=0)
    with pytest.raises(ValueError):
        SearchSpec(target_recall=1.5)
    with pytest.raises(ValueError):
        SearchSpec(deadline_ms=-1.0)
    with pytest.raises(ValueError):
        SearchSpec(max_ef=-5)


def test_spec_hashable_and_eq():
    a = SearchSpec(k=10, target_recall=0.95, mode="routed",
                   overrides=SpecOverrides(router=RouterConfig(est_lmax=32)))
    b = SearchSpec(k=10, target_recall=0.95, mode="routed",
                   overrides=SpecOverrides(router=RouterConfig(est_lmax=32)))
    c = dataclasses.replace(a, target_recall=0.9)
    assert a == b and hash(a) == hash(b)
    assert a != c
    assert len({a, b, c}) == 2


def test_spec_dict_roundtrip():
    spec = SearchSpec(
        k=7, target_recall=0.92, deadline_ms=25.0, max_ef=128,
        mode="streaming", backend="oracle",
        overrides=SpecOverrides(
            router=RouterConfig(est_lmax=32, tier_efs=(32, 64)),
            scheduler=SchedulerConfig(fill=16, est_wait_s=0.01),
        ),
    )
    d = spec.as_dict()
    assert SearchSpec.from_dict(d) == spec
    # default spec round-trips too (empty overrides)
    assert SearchSpec.from_dict(SearchSpec().as_dict()) == SearchSpec()


# --------------------------------------------------------------------------
# static pytrees: specs/configs cross jit boundaries without retracing
# --------------------------------------------------------------------------


def test_spec_crosses_jit_without_retrace():
    traces = []

    @jax.jit
    def f(x, spec):
        traces.append(1)
        return x * spec.k

    spec_kw = dict(
        k=3, mode="routed",
        overrides=SpecOverrides(router=RouterConfig(est_lmax=32)),
    )
    out = f(jnp.ones(4), SearchSpec(**spec_kw))
    np.testing.assert_array_equal(np.asarray(out), 3.0 * np.ones(4))
    f(jnp.ones(4), SearchSpec(**spec_kw))  # equal spec, fresh instance
    assert len(traces) == 1  # no retrace: the spec is a static pytree
    f(jnp.ones(4), SearchSpec(**dict(spec_kw, k=4)))
    assert len(traces) == 2  # different spec -> different compile-cache entry


def test_router_scheduler_configs_cross_jit_without_retrace():
    """Satellite: RouterConfig/SchedulerConfig are registered static pytrees
    with dataclass hash/eq, so plans carrying them jit-key on policy value."""
    traces = []

    @jax.jit
    def g(x, rcfg, scfg):
        traces.append(1)
        return x + rcfg.est_lmax + scfg.fill

    g(jnp.zeros(2), RouterConfig(est_lmax=16), SchedulerConfig(fill=8))
    g(jnp.zeros(2), RouterConfig(est_lmax=16), SchedulerConfig(fill=8))
    assert len(traces) == 1
    out = g(jnp.zeros(2), RouterConfig(est_lmax=32), SchedulerConfig(fill=8))
    assert len(traces) == 2
    np.testing.assert_array_equal(np.asarray(out), np.full(2, 40.0))
    # zero leaves: tree_flatten carries the config entirely in the treedef
    leaves, treedef = jax.tree_util.tree_flatten(RouterConfig(est_lmax=16))
    assert leaves == []
    assert treedef.unflatten([]) == RouterConfig(est_lmax=16)


# --------------------------------------------------------------------------
# plan cache: equal specs share one entry; updates invalidate
# --------------------------------------------------------------------------


def test_plan_cache_equal_specs_share_entry(small_index):
    a = small_index.plan(SearchSpec(k=10, target_recall=0.9))
    b = small_index.plan(SearchSpec(k=10, target_recall=0.9))
    assert a is b  # equal (distinct) specs -> one cache entry
    assert a == b and hash(a) == hash(b)
    c = small_index.plan(SearchSpec(k=10, target_recall=0.9, mode="routed"))
    assert c is not a
    # keyword convenience builds the same spec
    assert small_index.plan(k=10, target_recall=0.9) is a
    with pytest.raises(ValueError):
        small_index.plan(SearchSpec(), k=10)  # spec and kwargs are exclusive


def test_plan_survives_update_by_revalidation(small_db):
    idx = _toy_index(small_db)
    q = _queries(small_db, nq=8, seed=17)
    p0 = idx.plan(SearchSpec())
    p0.search(q)
    assert idx.plan(SearchSpec()) is p0  # cached

    idx.insert(small_db[0][1200:1210])
    # default on_mutation="revalidate": the mutation re-keys the held plan
    # under the new shape signature — same object, already rebound
    assert idx.plan(SearchSpec()) is p0
    assert not p0.stale
    assert p0.revalidate() == "fresh"  # nothing left to do
    assert p0.search(q).ids.shape == (8, 5)

    idx.delete(np.asarray([0, 1]))  # tombstone: shape signature unchanged
    assert idx.plan(SearchSpec()) is p0 and not p0.stale
    res = p0.search(q)
    assert res.ids.shape == (8, 5)
    assert not np.isin(np.asarray(res.ids), [0, 1]).any()  # dead rows masked


def test_strict_plan_refuses_after_mutation(small_db):
    idx = _toy_index(small_db)
    q = _queries(small_db, nq=4, seed=17)
    strict = idx.plan(SearchSpec(on_mutation="strict"))
    strict.search(q)
    idx.insert(small_db[0][1200:1205])
    assert strict.stale  # the mutation could not revalidate it
    with pytest.raises(RuntimeError, match="stale"):
        strict.search(q)  # held strict plans refuse to run post-mutation
    with pytest.raises(RuntimeError, match="stale"):
        strict.submit(q[0])
    with pytest.raises(RuntimeError, match="stale"):
        strict.step(force=True)  # the whole lifecycle surface refuses, not
    with pytest.raises(RuntimeError, match="stale"):
        strict.drain()           # just the entry points
    with pytest.raises(RuntimeError, match="strict"):
        strict.revalidate()      # even explicit revalidation is refused
    # ...and the mutation evicted it: same spec -> a fresh plan
    p1 = idx.plan(SearchSpec(on_mutation="strict"))
    assert p1 is not strict and not p1.stale
    assert p1.search(q).ids.shape == (4, 5)


# --------------------------------------------------------------------------
# bit-exactness vs the legacy execution paths (the acceptance property)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(3))
def test_plan_search_matches_legacy_paths(small_db, small_index, seed):
    """3-seed property: ``plan.search`` reproduces the pre-redesign paths
    bit-exactly — the fused ``adaptive_search`` for oneshot (== legacy
    ``query(routed=False)``), and the lossless fixed-beam routed dispatch
    (== legacy ``query(routed=True)`` under the same policy)."""
    from repro.index.search import adaptive_search

    rng = np.random.default_rng(2000 + seed)
    nq = int(rng.integers(9, 40))
    q = _queries(small_db, nq=nq, seed=seed)
    target = small_index.target_recall

    # the pre-redesign monolithic path, invoked directly
    ref = adaptive_search(
        small_index.graph,
        jnp.asarray(q),
        small_index.stats,
        small_index.table,
        jnp.asarray(target, jnp.float32),
        small_index.search_cfg,
        small_index.ada_cfg,
    )
    res = small_index.plan(SearchSpec()).search(q)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.ndist), np.asarray(ref.ndist))
    legacy = small_index.query(q)
    np.testing.assert_array_equal(np.asarray(legacy.ids), np.asarray(ref.ids))

    routed = small_index.plan(SearchSpec(
        mode="routed",
        overrides=SpecOverrides(router=RouterConfig(beam_mode="fixed")),
    )).search(q)
    np.testing.assert_array_equal(routed.ids, np.asarray(ref.ids))
    np.testing.assert_array_equal(routed.ef_used, np.asarray(ref.ef_used))


@pytest.mark.parametrize("mode", ["oneshot", "routed", "streaming"])
def test_submit_poll_matches_search_in_every_mode(small_db, small_index, mode):
    """The lifecycle surface of a plan (submit/flush/poll) returns ids
    bit-identical to its own batch ``search()`` — in *every* mode (a oneshot
    plan's lifecycle path lowers to the lossless fixed-beam policy, so it
    reproduces the fused search)."""
    q = _queries(small_db, nq=11, seed=23)
    plan = small_index.plan(SearchSpec(mode=mode))
    batch = plan.search(q)
    tickets = [plan.submit(row) for row in q]
    plan.flush()
    by_uid = {r.ticket.uid: r for r in plan.poll(block=True)}
    ids = np.stack([by_uid[t.uid].ids for t in tickets])
    np.testing.assert_array_equal(ids, np.asarray(batch.ids))
    assert plan.pending == 0
    if mode == "oneshot":
        # ...and the fused path is the same ids again (lossless fixed-beam)
        np.testing.assert_array_equal(
            ids, np.asarray(small_index.query(q).ids)
        )


def test_submit_accepts_requests_and_fills_spec_defaults(small_db, small_index):
    q = _queries(small_db, nq=2, seed=29)
    plan = small_index.plan(SearchSpec(k=3, deadline_ms=40.0, mode="streaming"))
    t_bare = plan.submit(q[0])                       # bare (d,) query
    t_req = plan.submit(SearchRequest(query=q[1], deadline_s=0.5))
    assert t_bare.deadline_t is not None             # spec deadline applied
    assert t_req.deadline_t - t_req.submit_t == pytest.approx(0.5)
    responses = plan.drain()
    assert all(r.ids.shape == (3,) for r in responses)  # spec.k applied


# --------------------------------------------------------------------------
# planner decisions: k/max_ef/deadline lowering, backend probe
# --------------------------------------------------------------------------


def test_spec_k_slices_results(small_db, small_index):
    q = _queries(small_db, nq=6, seed=31)
    res = small_index.plan(SearchSpec(k=3)).search(q)
    assert np.asarray(res.ids).shape == (6, 3)
    full = small_index.plan(SearchSpec()).search(q)
    np.testing.assert_array_equal(
        np.asarray(res.ids), np.asarray(full.ids)[:, :3]
    )
    with pytest.raises(ValueError):
        small_index.plan(SearchSpec(k=small_index.k + 1))


def test_max_ef_bounds_exploration(small_db, small_index):
    q = _queries(small_db, nq=16, seed=37)
    plan = small_index.plan(SearchSpec(max_ef=32))
    assert plan.search_cfg.ef_cap == 32
    res = plan.search(q)
    assert int(np.asarray(res.ef_used).max()) <= 32
    assert any("max_ef" in n for n in plan.explain()["notes"])


def test_deadline_lowers_drain_policy(small_index):
    plan = small_index.plan(SearchSpec(mode="streaming", deadline_ms=100.0))
    assert plan.scheduler_cfg.est_wait_s == pytest.approx(0.05)
    assert plan.deadline_s == pytest.approx(0.1)
    # explicit scheduler override wins over the derivation
    pinned = small_index.plan(SearchSpec(
        mode="streaming", deadline_ms=100.0,
        overrides=SpecOverrides(scheduler=SchedulerConfig(fill=16)),
    ))
    assert pinned.scheduler_cfg == SchedulerConfig(fill=16)


def test_backend_resolution_off_tpu(small_index):
    """Capability probe replaces the old live use_distance_kernel flag."""
    from repro.plan import probe_interpret, resolve_backend

    if jax.default_backend() == "tpu":  # pragma: no cover - CI is CPU
        pytest.skip("CPU-only planner assertions")
    plan = small_index.plan(SearchSpec())
    assert plan.backend == "oracle"  # auto: index built without kernels
    assert not plan.search_cfg.use_distance_kernel
    assert probe_interpret()  # Pallas interpret mode works on CPU
    interp = small_index.plan(SearchSpec(backend="interpret"))
    assert interp.backend == "interpret"
    assert interp.search_cfg.use_distance_kernel
    # an explicit pallas request degrades to interpret off-TPU, never errors
    assert resolve_backend("pallas", False)[0] == "interpret"
    oracle = small_index.plan(SearchSpec(backend="oracle"))
    assert oracle.backend == "oracle"


def test_serving_modes_lower_to_batch_hoisted(small_index):
    assert small_index.plan(SearchSpec()).loop == "vmap"  # inherit the build
    assert small_index.plan(SearchSpec(mode="routed")).loop == "batch_hoisted"
    assert small_index.plan(SearchSpec(mode="streaming")).loop == "batch_hoisted"
    # an explicit search override pins the loop
    pinned = small_index.plan(SearchSpec(
        mode="routed",
        overrides=SpecOverrides(search=small_index.search_cfg),
    ))
    assert pinned.loop == "vmap"


# --------------------------------------------------------------------------
# explain: every derived decision, round-tripped
# --------------------------------------------------------------------------


def test_explain_roundtrips_every_decision(small_index):
    spec = SearchSpec(
        k=5, target_recall=0.9, deadline_ms=50.0, mode="streaming",
        overrides=SpecOverrides(router=RouterConfig(est_lmax=32)),
    )
    plan = small_index.plan(spec)
    d = plan.explain()
    # the spec itself round-trips out of the explain dict
    assert SearchSpec.from_dict(d["spec"]) == spec
    # every lowered decision is recorded verbatim
    assert d["mode"] == plan.mode == "streaming"
    assert d["loop"] == plan.loop
    assert d["backend"]["resolved"] == plan.backend
    assert d["k"] == {"index": small_index.k, "request": 5}
    assert d["target_recall"] == plan.target_recall == 0.9
    assert d["deadline_s"] == plan.deadline_s
    assert d["search"]["ef_cap"] == plan.search_cfg.ef_cap
    assert d["search"]["batch_hoisted"] == plan.search_cfg.batch_hoisted
    assert d["search"]["use_distance_kernel"] == plan.search_cfg.use_distance_kernel
    assert d["estimation"]["lossless"] is False  # est_lmax=32 truncates
    assert d["estimation"]["matched_table"] is True
    assert [t["ef"] for t in d["tiers"]] == [t.ef for t in plan.router.tiers]
    assert d["tiers"][-1]["ef"] == d["search"]["ef_cap"]  # catch-all rung
    assert d["scheduler"]["fill"] == plan.scheduler_cfg.fill
    assert d["scheduler"]["est_wait_s"] == plan.scheduler_cfg.est_wait_s
    assert d["cache"]["shape_signature"] == list(plan._shape_sig)
    # the text rendering carries the same plan, human-readable
    text = plan.explain(fmt="text")
    assert "mode=streaming" in text and "tiers:" in text
    with pytest.raises(ValueError):
        plan.explain(fmt="json")


def test_explain_is_json_serializable(small_index):
    import json

    d = small_index.plan(SearchSpec(mode="routed")).explain()
    assert json.loads(json.dumps(d)) == d
