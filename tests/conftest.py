"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the single real CPU
device; only launch/dryrun.py materializes the 512-device host platform."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_clustered(rng, n, d, nc=32, spread=0.3, zipf=False):
    """Clustered vectors (Zipf-skewed sizes when zipf=True, paper §7.1)."""
    centers = rng.normal(0, 1, (nc, d))
    if zipf:
        w = 1.0 / np.arange(1, nc + 1)
    else:
        w = np.ones(nc)
    w = w / w.sum()
    assign = rng.choice(nc, size=n, p=w)
    return (centers[assign] + spread * rng.normal(0, 1, (n, d))).astype(np.float32), centers, w


@pytest.fixture(scope="session")
def small_db(rng):
    data, centers, w = make_clustered(rng, 3000, 48, nc=24, zipf=True)
    return data, centers, w


@pytest.fixture(scope="session")
def small_index(small_db):
    from repro.index import build_ada_index

    data, _, _ = small_db
    return build_ada_index(
        data, k=10, target_recall=0.9, m=8, ef_construction=80, ef_cap=240, num_samples=80
    )
