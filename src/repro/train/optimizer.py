"""Hand-rolled AdamW (+ global-norm clipping, cosine schedule) — no optax.

fp32 master weights and moments; works on arbitrary parameter pytrees; states
are pytrees so they pjit-shard exactly like the parameters.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: Array
    m: object
    v: object


def lr_schedule(cfg: OptimizerConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_optimizer(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(cfg: OptimizerConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        step_val = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_val).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "lr": lr,
        "grad_norm": gnorm,
    }
