"""Fault-tolerant checkpointing: atomic, manifest-based, async-capable,
elastic (mesh-reshard on restore).

Layout::

    <dir>/step_000123/
        manifest.json     # step, leaf paths, shapes/dtypes, tree structure
        arrays.npz        # one entry per leaf (host-gathered)
    <dir>/LATEST          # atomically updated pointer

Writes go to ``step_X.tmp`` and are renamed only after fsync — a preempted
writer never corrupts the latest checkpoint (restart-after-failure contract).
``restore`` accepts a target sharding tree, so a checkpoint taken on one mesh
restores onto another (elastic scale-up/down); single-process here, multi-host
would shard ``arrays.npz`` per host with the same manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

SEP = "||"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: Any, *, blocking: bool = True) -> str:
    """Atomic checkpoint write; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"

    arrays, _ = _flatten(tree)

    def _write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in arrays.items()
            },
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = os.path.join(directory, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(directory, "LATEST"))

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _ASYNC_THREADS.append(t)
    return final


_ASYNC_THREADS: list = []


def wait_async():
    for t in _ASYNC_THREADS:
        t.join()
    _ASYNC_THREADS.clear()


def latest_step(directory: str) -> Optional[int]:
    pointer = os.path.join(directory, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(directory, name)):
        return None
    return int(name.split("_")[-1])


def restore_checkpoint(
    directory: str,
    step: Optional[int],
    example_tree: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of ``example_tree`` (abstract ok).

    ``shardings`` (optional pytree of NamedSharding, same structure) re-shards
    onto the *current* mesh — this is the elastic-restart path.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
    out = []
    for i, (p, leaf) in enumerate(leaves):
        key = SEP.join(str(x) for x in p)
        arr = data[key]
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        else:
            arr = jax.numpy.asarray(arr)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
