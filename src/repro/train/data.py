"""Deterministic, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step) — ``jax.random.fold_in`` chains
— so restart-after-failure reproduces the exact token stream with no state
files (the checkpoint stores only the step).  Token distribution is Zipfian
with per-document topic drift so the loss curve is non-trivial (the model can
actually learn structure: topic-conditional bigrams).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    num_topics: int = 64
    zipf_a: float = 1.2


@partial(jax.jit, static_argnames=("vocab", "batch", "seq", "cfg"))
def _synth_tokens(step: Array, *, vocab: int, batch: int, seq: int, cfg: DataConfig) -> Array:
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipfian unigram over vocab via inverse-CDF on uniform
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = ranks ** (-cfg.zipf_a)
    probs = probs / probs.sum()
    logits = jnp.log(probs)
    # per-sequence topic shifts a window of the vocab to be more likely
    topic = jax.random.randint(k1, (batch, 1), 0, cfg.num_topics)
    topic_boost = jnp.where(
        (jnp.arange(vocab)[None, :] // max(vocab // cfg.num_topics, 1)) == topic,
        2.0,
        0.0,
    )
    seq_logits = (logits[None, :] + topic_boost)[:, None, :]  # (B, 1, V)
    tok = jax.random.categorical(k2, seq_logits, shape=(batch, seq))
    # bigram structure: with prob .25 repeat previous token + 1 (learnable)
    rep = jax.random.bernoulli(k3, 0.25, (batch, seq))
    shifted = jnp.concatenate([tok[:, :1], (tok[:, :-1] + 1) % vocab], axis=1)
    tok = jnp.where(rep, shifted, tok)
    return tok.astype(jnp.int32)


def make_batch(
    arch: ArchConfig, shape: ShapeConfig, step: int, cfg: DataConfig = DataConfig()
) -> Dict[str, Array]:
    """Build the batch dict for a train step (or prefill request batch)."""
    b, s = shape.global_batch, shape.seq_len
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 1), step)
    if arch.family == "vlm":
        npatch = arch.num_frontend_tokens
        tokens = _synth_tokens(jnp.asarray(step), vocab=arch.vocab_size, batch=b, seq=s - npatch + 1, cfg=cfg)
        patches = jax.random.normal(key, (b, npatch, arch.frontend_dim), jnp.float32)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            "patches": patches,
        }
    tokens = _synth_tokens(jnp.asarray(step), vocab=arch.vocab_size, batch=b, seq=s + 1, cfg=cfg)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if arch.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, s, arch.frontend_dim), jnp.float32)
    return batch
