"""Training substrate: optimizer, train step, data pipeline, checkpointing."""
from .optimizer import (  # noqa: F401
    AdamWState,
    OptimizerConfig,
    adamw_update,
    init_optimizer,
    lr_schedule,
    global_norm,
)
from .train_step import (  # noqa: F401
    TrainConfig,
    make_train_step,
    make_compressed_dp_step,
    compressed_psum,
)
from .data import DataConfig, make_batch  # noqa: F401
from .checkpoint import (  # noqa: F401
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    wait_async,
)
