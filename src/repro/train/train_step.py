"""Training step construction: gradient accumulation, remat (in the models),
optional error-feedback gradient compression for the DP all-reduce.

Two variants:

- :func:`make_train_step` — the GSPMD path.  Loss/grads computed on the global
  batch; XLA partitions over the mesh and inserts the gradient collectives.
  Microbatching = ``lax.scan`` over microbatch slices with fp32 accumulation;
  buffers donated.
- :func:`make_compressed_dp_step` — shard_map over the data axes with an
  explicit compressed gradient all-reduce (bf16 or int8 + fp32 error
  feedback).  This is the "distributed-optimization trick" path: collective
  bytes drop 2x/4x; the residual carries quantization error to the next step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model
from .optimizer import AdamWState, OptimizerConfig, adamw_update

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    compress: str = "none"      # none | bf16 | int8
    opt: OptimizerConfig = OptimizerConfig()


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return x.reshape((n, b // n) + x.shape[1:])

    return jax.tree_util.tree_map(split, batch)


def make_loss_and_grad(model: Model):
    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    return jax.value_and_grad(loss_fn, has_aux=True)


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch, rng) -> (params, opt_state, metrics)."""
    vg = make_loss_and_grad(model)

    def train_step(params, opt_state: AdamWState, batch: dict):
        if tcfg.microbatches > 1:
            mb = _split_microbatches(batch, tcfg.microbatches)

            def body(acc, mbatch):
                (loss, metrics), grads = vg(params, mbatch)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc_g, grads
                )
                return (acc_g, acc_l + loss), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(body, (zero, jnp.zeros(())), mb)
            inv = 1.0 / tcfg.microbatches
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            loss = loss_sum * inv
            metrics = {}
        else:
            (loss, metrics), grads = vg(params, batch)
        new_params, new_state, opt_metrics = adamw_update(
            tcfg.opt, grads, opt_state, params
        )
        out = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_state, out

    return train_step


# --------------------------------------------------------------------------
# compressed data-parallel all-reduce (shard_map path)
# --------------------------------------------------------------------------


def _quantize_int8(g: Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads, axes, mode: str, residual=None):
    """All-reduce grads over ``axes`` with lossy compression + error feedback.

    Returns (mean_grads, new_residual).  ``residual`` is the fp32 carry of the
    quantization error (EF-SGD style); ``None`` initializes to zeros.
    """
    if residual is None:
        residual = jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    # product of mesh axis sizes, computed portably inside the mapped context
    # (jax.lax.axis_size does not exist; psum of 1 over the axes is the size)
    n_dev = jax.lax.psum(jnp.ones((), jnp.int32), axes)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        if mode == "bf16":
            sent = g32.astype(jnp.bfloat16)
            summed = jax.lax.psum(sent.astype(jnp.float32), axes)
            new_r = g32 - sent.astype(jnp.float32)
        elif mode == "int8":
            q, scale = _quantize_int8(g32)
            deq = q.astype(jnp.float32) * scale
            summed = jax.lax.psum(deq, axes)
            new_r = g32 - deq
        else:
            summed = jax.lax.psum(g32, axes)
            new_r = jnp.zeros_like(g32)
        return summed / n_dev, new_r

    flat, tree = jax.tree_util.tree_flatten(grads)
    rflat, _ = jax.tree_util.tree_flatten(residual)
    out = [one(g, r) for g, r in zip(flat, rflat)]
    mean = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_res = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    return mean, new_res


def make_compressed_dp_step(model: Model, tcfg: TrainConfig, mesh, dp_axes=("data",)):
    """shard_map training step: params replicated over dp axes, batch sharded,
    gradient all-reduce compressed per ``tcfg.compress``."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    vg = make_loss_and_grad(model)

    def local_step(params, opt_state, batch, residual):
        (loss, metrics), grads = vg(params, batch)
        grads, new_residual = compressed_psum(grads, dp_axes, tcfg.compress, residual)
        loss = jax.lax.pmean(loss, dp_axes)
        new_params, new_state, opt_metrics = adamw_update(
            tcfg.opt, grads, opt_state, params
        )
        return new_params, new_state, new_residual, {"loss": loss, **opt_metrics}

    batch_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])
    mapped = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(), P(), batch_spec, P()),
        out_specs=(P(), P(), P(), P()),
        check_rep=False,
    )
    return jax.jit(mapped, donate_argnums=(0, 1, 3))
