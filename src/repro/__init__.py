"""repro: Ada-ef (Distribution-Aware Adaptive HNSW Search) + multi-pod JAX framework.

Public search surface: build a declarative :class:`repro.api.SearchSpec`
and lower it with ``index.plan(spec)`` into an executable
:class:`repro.plan.ExecutionPlan` (see :mod:`repro.api`).
"""
__version__ = "1.0.0"

_FACADE = ("SearchSpec", "SpecOverrides")


def __getattr__(name):
    # lazy: `import repro` stays side-effect free; `repro.SearchSpec` pulls
    # the facade (and its jax imports) only when actually used
    if name in _FACADE:
        from repro import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
