"""repro: Ada-ef (Distribution-Aware Adaptive HNSW Search) + multi-pod JAX framework."""
__version__ = "1.0.0"
