"""Quantized vector panels for the estimation tier (multi-stage re-rank).

The phase-A estimation pass and the coarse frontier scoring inside phase B
exist only to *rank* candidates — they never emit final neighbors — so they
do not need fp32 distance bandwidth.  This module calibrates an immutable
:class:`QuantizedPanel` over the database panel:

    x[i, j]  ≈  zero[j] + dim_scale[j] * row_scale[i] * codes[i, j]

- ``zero`` (per-dimension zero-point) centers each dimension (all-zeros in
  the symmetric default, the per-dim mean in asymmetric mode),
- ``dim_scale`` (per-dimension scale) normalizes dimensions to a comparable
  range so one int8 grid covers skewed per-dim distributions,
- ``row_scale`` (per-row scale) absorbs per-vector magnitude, which makes
  the scheme **append-exact**: a row inserted after calibration gets its own
  ``row_scale`` from the frozen ``zero``/``dim_scale``, so incremental
  re-quantization touches only the appended rows and never clips.

Scoring folds cleanly onto an int8 MXU matmul: with the query pre-scaled by
``dim_scale`` and itself quantized (``q' = q * dim_scale ≈ q_scale * q_codes``),

    q · x̂[i]  =  q · zero  +  row_scale[i] * q_scale * (q_codes · codes[i])

so the inner product is a pure ``int8 x int8 -> fp32`` contraction with a
per-row scale + per-query (scale, correction) epilogue — exactly the shape
:mod:`repro.kernels.frontier_q` implements.  ``int8`` is the default;
``fp8`` (e4m3) is available where the installed jax exposes the dtype and
runs through the jnp reference scorer (the Pallas kernel is int8-only).

Everything here is plain jnp on immutable arrays; the panel is a pytree
(NamedTuple of arrays) so it rides inside :class:`DeviceGraph` snapshots and
``EpochManager`` epochs without special handling.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

PRECISION_FP32 = "fp32"
PRECISION_INT8 = "int8"
PRECISION_FP8 = "fp8"
PRECISIONS = (PRECISION_FP32, PRECISION_INT8, PRECISION_FP8)

_EPS = 1e-12
_INT8_MAX = 127.0


def fp8_dtype():
    """The fp8 storage dtype, or None when this jax build lacks it."""
    return getattr(jnp, "float8_e4m3fn", None)


def supported_precisions() -> Tuple[str, ...]:
    """Quantized precisions this environment can actually calibrate."""
    out = [PRECISION_FP32, PRECISION_INT8]
    if fp8_dtype() is not None:
        out.append(PRECISION_FP8)
    return tuple(out)


class QuantizedPanel(NamedTuple):
    """Immutable quantized database panel (see module docstring for the
    dequantization identity).  ``codes.dtype`` carries the precision."""

    codes: Array       # (n, d) int8 (or fp8) codes
    row_scale: Array   # (n,) float32 per-row scale
    dim_scale: Array   # (d,) float32 per-dimension scale
    zero: Array        # (d,) float32 per-dimension zero-point

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    @property
    def d(self) -> int:
        return self.codes.shape[1]


def panel_precision(panel: Optional[QuantizedPanel]) -> str:
    if panel is None:
        return PRECISION_FP32
    if panel.codes.dtype == jnp.int8:
        return PRECISION_INT8
    return PRECISION_FP8


def _encode_rows(
    x: Array, zero: Array, dim_scale: Array, precision: str
) -> Tuple[Array, Array]:
    """Quantize rows against frozen (zero, dim_scale); returns (codes,
    row_scale).  Per-row scales are computed from the rows themselves, so
    this is exact for calibration rows and appended rows alike (no clip)."""
    y = (x - zero[None, :]) / dim_scale[None, :]
    if precision == PRECISION_INT8:
        row_scale = jnp.maximum(jnp.abs(y).max(axis=1), _EPS) / _INT8_MAX
        codes = jnp.clip(
            jnp.round(y / row_scale[:, None]), -_INT8_MAX, _INT8_MAX
        ).astype(jnp.int8)
        return codes, row_scale.astype(jnp.float32)
    dt = fp8_dtype()
    if dt is None:
        raise ValueError(
            "fp8 panels need a jax build with float8_e4m3fn; "
            "use precision='int8'"
        )
    # fp8 e4m3 covers [-448, 448] with best resolution near 1: normalize
    # rows into [-1, 1] so every element sits in the dense mantissa range.
    row_scale = jnp.maximum(jnp.abs(y).max(axis=1), _EPS)
    codes = (y / row_scale[:, None]).astype(dt)
    return codes, row_scale.astype(jnp.float32)


def calibrate_panel(
    vectors: Array, *, precision: str = PRECISION_INT8, symmetric: bool = True
) -> QuantizedPanel:
    """Calibrate a quantized panel over the (prepared) database vectors.

    ``symmetric=False`` centers each dimension on its mean (asymmetric
    zero-point) — better code utilization for uncentered data at the cost of
    one per-query correction term in the scorer (computed automatically).
    """
    if precision not in (PRECISION_INT8, PRECISION_FP8):
        raise ValueError(
            f"precision={precision!r} not in ('int8', 'fp8') "
            "(fp32 needs no panel)"
        )
    x = jnp.asarray(vectors, jnp.float32)
    if x.ndim != 2 or x.shape[0] == 0:
        raise ValueError(f"expected a nonempty (n, d) panel, got {x.shape}")
    zero = (
        jnp.zeros((x.shape[1],), jnp.float32)
        if symmetric
        else x.mean(axis=0).astype(jnp.float32)
    )
    dim_scale = jnp.maximum(
        jnp.abs(x - zero[None, :]).max(axis=0), _EPS
    ).astype(jnp.float32)
    codes, row_scale = _encode_rows(x, zero, dim_scale, precision)
    return QuantizedPanel(
        codes=codes, row_scale=row_scale, dim_scale=dim_scale, zero=zero
    )


def append_rows(panel: QuantizedPanel, new_vectors: Array) -> QuantizedPanel:
    """Quantize appended rows against the panel's frozen calibration.

    This is the incremental-insert path: only the appended rows are encoded
    (each gets its own ``row_scale``, so nothing clips even when new rows
    fall outside the calibration range), and the existing codes are shared
    by reference — an epoch snapshot taken before the insert still sees its
    own exact panel.
    """
    x = jnp.asarray(new_vectors, jnp.float32)
    if x.ndim != 2 or x.shape[1] != panel.d:
        raise ValueError(
            f"appended rows {x.shape} do not match panel dim {panel.d}"
        )
    if x.shape[0] == 0:
        return panel
    codes, row_scale = _encode_rows(
        x, panel.zero, panel.dim_scale, panel_precision(panel)
    )
    return panel._replace(
        codes=jnp.concatenate([panel.codes, codes]),
        row_scale=jnp.concatenate([panel.row_scale, row_scale]),
    )


def dequantize_panel(panel: QuantizedPanel) -> Array:
    """Reconstruct the fp32 panel (the oracle the parity tests score)."""
    y = panel.codes.astype(jnp.float32) * panel.row_scale[:, None]
    return panel.zero[None, :] + panel.dim_scale[None, :] * y


def roundtrip_bound(panel: QuantizedPanel) -> Array:
    """Elementwise |x - dequant(x)| upper bound for int8 panels: half a code
    step, ``0.5 * dim_scale[j] * row_scale[i]``."""
    return 0.5 * panel.row_scale[:, None] * panel.dim_scale[None, :]


def quantize_queries(
    panel: QuantizedPanel, queries: Array
) -> Tuple[Array, Array, Array]:
    """Quantize a (B, d) query block for scoring against ``panel``.

    Returns ``(q_codes, q_scale, corr)`` with
    ``q · x̂[i] ≈ corr_b + row_scale[i] * q_scale_b * (q_codes_b · codes_i)``.
    For fp8 panels the query stays fp32 (``q_codes`` fp32, ``q_scale`` the
    identity fold) — fp8 scoring runs through the jnp reference anyway.
    """
    q = jnp.asarray(queries, jnp.float32)
    qp = q * panel.dim_scale[None, :]
    corr = q @ panel.zero
    if panel_precision(panel) == PRECISION_INT8:
        q_scale = jnp.maximum(jnp.abs(qp).max(axis=1), _EPS) / _INT8_MAX
        q_codes = jnp.clip(
            jnp.round(qp / q_scale[:, None]), -_INT8_MAX, _INT8_MAX
        ).astype(jnp.int8)
        return q_codes, q_scale.astype(jnp.float32), corr
    return qp, jnp.ones((q.shape[0],), jnp.float32), corr


# ---------------------------------------------------------------------------
# resident-byte accounting (the memory lever the ROADMAP item is about)
# ---------------------------------------------------------------------------


def _nbytes(a: Optional[Array]) -> int:
    return 0 if a is None else int(a.size) * a.dtype.itemsize


def panel_bytes(panel: Optional[QuantizedPanel]) -> int:
    """Resident bytes of the quantized panel (codes + all scales)."""
    if panel is None:
        return 0
    return sum(_nbytes(a) for a in panel)


def bytes_per_distance(d: int, precision: str) -> int:
    """Vector bytes touched per distance evaluation at a given precision."""
    itemsize = {PRECISION_FP32: 4, PRECISION_INT8: 1}.get(precision, 1)
    return int(d) * itemsize


def graph_resident_bytes(graph) -> dict:
    """Per-panel resident bytes of a :class:`DeviceGraph`-shaped snapshot:
    the fp32 vector panel, the quantized panel (0 when absent), and the
    graph structure arrays (adjacency / entry / alive)."""
    return {
        "fp32": _nbytes(graph.vectors),
        "quantized": sum(
            _nbytes(getattr(graph, f, None))
            for f in ("qcodes", "qrow_scale", "qdim_scale", "qzero")
        ),
        "graph": (
            _nbytes(graph.base_adj)
            + _nbytes(graph.upper_adj)
            + _nbytes(graph.entry)
            + _nbytes(graph.alive)
        ),
    }


def attach_panel(graph, panel: Optional[QuantizedPanel]):
    """Bind a quantized panel onto a :class:`DeviceGraph` snapshot (returns
    a new graph tuple sharing every array).  ``panel=None`` detaches."""
    if panel is None:
        return graph._replace(
            qcodes=None, qrow_scale=None, qdim_scale=None, qzero=None
        )
    if panel.n != graph.vectors.shape[0]:
        raise ValueError(
            f"panel rows {panel.n} != graph rows {graph.vectors.shape[0]}"
        )
    return graph._replace(
        qcodes=panel.codes,
        qrow_scale=panel.row_scale,
        qdim_scale=panel.dim_scale,
        qzero=panel.zero,
    )


def panel_of(graph) -> Optional[QuantizedPanel]:
    """The quantized panel bound to a graph snapshot, or None."""
    if getattr(graph, "qcodes", None) is None:
        return None
    return QuantizedPanel(
        codes=graph.qcodes,
        row_scale=graph.qrow_scale,
        dim_scale=graph.qdim_scale,
        zero=graph.qzero,
    )
