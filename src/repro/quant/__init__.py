"""Quantized estimation tier: panel calibration + multi-stage re-rank support.

See :mod:`repro.quant.calibrate` for the quantization scheme and
:mod:`repro.kernels.frontier_q` for the int8 Pallas scorer it feeds.
"""
from .calibrate import (
    PRECISION_FP32,
    PRECISION_FP8,
    PRECISION_INT8,
    PRECISIONS,
    QuantizedPanel,
    append_rows,
    attach_panel,
    bytes_per_distance,
    calibrate_panel,
    dequantize_panel,
    fp8_dtype,
    graph_resident_bytes,
    panel_bytes,
    panel_of,
    panel_precision,
    quantize_queries,
    roundtrip_bound,
    supported_precisions,
)

__all__ = [
    "PRECISION_FP32",
    "PRECISION_FP8",
    "PRECISION_INT8",
    "PRECISIONS",
    "QuantizedPanel",
    "append_rows",
    "attach_panel",
    "bytes_per_distance",
    "calibrate_panel",
    "dequantize_panel",
    "fp8_dtype",
    "graph_resident_bytes",
    "panel_bytes",
    "panel_of",
    "panel_precision",
    "quantize_queries",
    "roundtrip_bound",
    "supported_precisions",
]
