"""Dataset-level statistics for FDL estimation (paper §5.4, §6.3).

Offline we precompute, for a database ``V`` of shape ``(n, d)``:

- the **mean vector** ``E[v_i]`` per column (``(d,)``),
- the **covariance matrix** ``Cov(v_i, v_j)`` (``(d, d)``), whose diagonal is the
  per-column variance.

Both are needed online to evaluate the FDL Gaussian moments
``mu_IP = q . mean`` and ``sigma^2_IP + Delta_IP = q Sigma q^T`` (Thm 5.2 + Eq. 1).

For cosine metrics the same statistics are computed over the *row-normalized*
database (paper §5.2): ``v_hat = v / ||v||``.

§6.3 gives exact streaming **merge** (insertion) and **unmerge** (deletion)
formulas; we implement both, and they are exact (tested against recomputation).

Covariance modes
----------------
``full``      the paper's d x d matrix (default; d up to a few thousand).
``diag``      variance-only (Delta = 0) — the i.i.d. Theorem-5.2 model.
``lowrank``   diag + rank-r correction ``Sigma ~ D + U U^T`` via randomized PCA of
              the centered data — a beyond-paper option that cuts the online
              quadratic form from O(d^2) to O(d r) and storage from O(d^2) to
              O(d r); used by the perf hillclimb.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DatasetStats:
    """Sufficient statistics of a (possibly normalized) vector database.

    Attributes
    ----------
    n:        number of rows summarized (scalar int32 array so it stays a leaf).
    mean:     (d,) column means.
    cov:      (d, d) column covariance (``full`` mode) or None.
    var:      (d,) column variances (always present; = diag(cov) in full mode).
    cov_u:    (d, r) low-rank factor (``lowrank`` mode) or None.
    """

    n: Array
    mean: Array
    var: Array
    cov: Optional[Array] = None
    cov_u: Optional[Array] = None

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.n, self.mean, self.var, self.cov, self.cov_u), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def dim(self) -> int:
        return self.mean.shape[-1]

    @property
    def mode(self) -> str:
        if self.cov is not None:
            return "full"
        if self.cov_u is not None:
            return "lowrank"
        return "diag"


def _normalize_rows(v: Array, eps: float = 1e-12) -> Array:
    nrm = jnp.linalg.norm(v, axis=-1, keepdims=True)
    return v / jnp.maximum(nrm, eps)


@partial(jax.jit, static_argnames=("mode", "rank", "normalize"))
def compute_stats(
    v: Array,
    *,
    mode: str = "full",
    rank: int = 16,
    normalize: bool = False,
) -> DatasetStats:
    """Compute :class:`DatasetStats` of database ``v`` with shape ``(n, d)``.

    ``normalize=True`` computes the statistics of the row-normalized database
    (needed for cosine similarity / distance, paper §5.2).
    """
    v = v.astype(jnp.float32)
    if normalize:
        v = _normalize_rows(v)
    n = v.shape[0]
    mean = jnp.mean(v, axis=0)
    centered = v - mean
    # Unbiased (n-1) as in the paper.
    denom = jnp.maximum(n - 1, 1)
    var = jnp.sum(centered * centered, axis=0) / denom
    cov = cov_u = None
    if mode == "full":
        cov = centered.T @ centered / denom
        var = jnp.diagonal(cov)
    elif mode == "lowrank":
        # Randomized range finder on the centered matrix: Sigma ~ diag + U U^T.
        key = jax.random.PRNGKey(0)
        omega = jax.random.normal(key, (v.shape[1], rank), dtype=v.dtype)
        y = centered @ omega  # (n, r)
        q, _ = jnp.linalg.qr(centered.T @ y)  # (d, r) orthonormal basis
        b = centered @ q  # (n, r)
        # Sigma ~= q (b^T b / denom) q^T ; fold the small (r,r) eigh into U.
        core = b.T @ b / denom
        w, vecs = jnp.linalg.eigh(core)
        w = jnp.maximum(w, 0.0)
        cov_u = q @ (vecs * jnp.sqrt(w)[None, :])
    elif mode != "diag":
        raise ValueError(f"unknown covariance mode: {mode}")
    return DatasetStats(
        n=jnp.asarray(n, jnp.int32), mean=mean, var=var, cov=cov, cov_u=cov_u
    )


# ---------------------------------------------------------------------------
# §6.3 — exact streaming updates
# ---------------------------------------------------------------------------


@jax.jit
def merge_stats(a: DatasetStats, b: DatasetStats) -> DatasetStats:
    """Exact merge of two stats (paper §6.3, insertion formulas).

    M'' = (n M + n' M') / n''
    S'' = [ (n-1) S + (n'-1) S' + n n'/n'' (M - M')^T (M - M') ] / (n'' - 1)
    """
    n_a = a.n.astype(jnp.float32)
    n_b = b.n.astype(jnp.float32)
    n_ab = n_a + n_b
    mean = (n_a * a.mean + n_b * b.mean) / n_ab
    dm = a.mean - b.mean
    coeff = n_a * n_b / n_ab
    denom = jnp.maximum(n_ab - 1.0, 1.0)
    var = ((n_a - 1.0) * a.var + (n_b - 1.0) * b.var + coeff * dm * dm) / denom
    cov = None
    if a.cov is not None and b.cov is not None:
        cov = (
            (n_a - 1.0) * a.cov + (n_b - 1.0) * b.cov + coeff * jnp.outer(dm, dm)
        ) / denom
        var = jnp.diagonal(cov)
    return DatasetStats(
        n=(a.n + b.n).astype(jnp.int32), mean=mean, var=var, cov=cov, cov_u=None
    )


@jax.jit
def unmerge_stats(ab: DatasetStats, b: DatasetStats) -> DatasetStats:
    """Exact removal of ``b`` from the merged stats (paper §6.3, deletion).

    M = (n'' M'' - n' M') / n
    S = [ (n''-1) S'' - (n'-1) S' - n' n''/n (M'' - M')^T (M'' - M') ] / (n - 1)

    Note the paper's deletion formula uses (M'' - M'); with M recovered first the
    identity  n n'/n'' (M - M') = n' n''/n (M'' - M') * (n/n'')... we use the
    direct algebraic inverse of merge for exactness.
    """
    n_ab = ab.n.astype(jnp.float32)
    n_b = b.n.astype(jnp.float32)
    n_a = n_ab - n_b
    mean = (n_ab * ab.mean - n_b * b.mean) / n_a
    dm = mean - b.mean  # (M - M') of the merge we are inverting
    coeff = n_a * n_b / n_ab
    denom = jnp.maximum(n_a - 1.0, 1.0)
    var = (
        (n_ab - 1.0) * ab.var - (n_b - 1.0) * b.var - coeff * dm * dm
    ) / denom
    cov = None
    if ab.cov is not None and b.cov is not None:
        cov = (
            (n_ab - 1.0) * ab.cov
            - (n_b - 1.0) * b.cov
            - coeff * jnp.outer(dm, dm)
        ) / denom
        var = jnp.diagonal(cov)
    return DatasetStats(
        n=(ab.n - b.n).astype(jnp.int32), mean=mean, var=var, cov=cov, cov_u=None
    )


# ---------------------------------------------------------------------------
# Online quadratic form  q Sigma q^T  (paper §5.4 "online computation")
# ---------------------------------------------------------------------------


def quadratic_form(stats: DatasetStats, q: Array) -> Array:
    """``q Sigma q^T`` for a single query or batch ``(..., d)`` of queries.

    full:    q Sigma q^T           (O(d^2), optionally via the Pallas kernel)
    diag:    sum(q^2 var)          (Theorem 5.2 i.i.d. model, Delta = 0)
    lowrank: sum(q^2 var_resid) + ||U^T q||^2
    """
    q = q.astype(jnp.float32)
    if stats.cov is not None:
        return jnp.einsum("...i,ij,...j->...", q, stats.cov, q)
    if stats.cov_u is not None:
        proj = jnp.einsum("...d,dr->...r", q, stats.cov_u)
        resid = jnp.maximum(
            stats.var - jnp.sum(stats.cov_u * stats.cov_u, axis=-1), 0.0
        )
        return jnp.sum(q * q * resid, axis=-1) + jnp.sum(proj * proj, axis=-1)
    return jnp.sum(q * q * stats.var, axis=-1)


def stats_nbytes(stats: DatasetStats) -> int:
    """Storage footprint of the offline statistics (for Table-3 style reporting)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(stats):
        total += leaf.size * leaf.dtype.itemsize
    return int(total)


def as_numpy(stats: DatasetStats) -> dict:
    out = {"n": np.asarray(stats.n), "mean": np.asarray(stats.mean), "var": np.asarray(stats.var)}
    if stats.cov is not None:
        out["cov"] = np.asarray(stats.cov)
    if stats.cov_u is not None:
        out["cov_u"] = np.asarray(stats.cov_u)
    return out
