"""FDL (Full Distance List) distribution estimation (paper §5).

Given precomputed :class:`~repro.core.stats.DatasetStats` and a query ``q``,
estimate the Gaussian ``N(mu, sigma^2)`` that the FDL converges to (Thm 5.2):

- inner product  (Eq. 1):  mu = q . mean(V),        sigma^2 = q Sigma q^T
- cosine similarity (Eq. 2): same with q and V row-normalized
- cosine distance (Eq. 3):   affine map  mu -> 1 - mu_CS, sigma unchanged

The online cost is one matvec (``q Sigma``) + two dots — no database access.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .stats import DatasetStats, quadratic_form

Array = jax.Array

METRIC_IP = "ip"            # inner-product *similarity* (larger = closer)
METRIC_COSINE_SIM = "cos_sim"
METRIC_COSINE_DIST = "cos_dist"  # 1 - cos_sim (smaller = closer) — paper default

METRICS = (METRIC_IP, METRIC_COSINE_SIM, METRIC_COSINE_DIST)


class FDLParams(NamedTuple):
    """Per-query Gaussian parameters of the FDL."""

    mu: Array     # (...,)
    sigma: Array  # (...,)


def _normalize(q: Array, eps: float = 1e-12) -> Array:
    return q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), eps)


@partial(jax.jit, static_argnames=("metric",))
def estimate_fdl(stats: DatasetStats, q: Array, *, metric: str = METRIC_COSINE_DIST) -> FDLParams:
    """Estimate the FDL Gaussian for query/queries ``q`` of shape ``(..., d)``.

    For cosine metrics, ``stats`` must have been computed with ``normalize=True``
    (statistics of the row-normalized database, §5.2).
    """
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; expected one of {METRICS}")
    q = q.astype(jnp.float32)
    if metric in (METRIC_COSINE_SIM, METRIC_COSINE_DIST):
        q = _normalize(q)
    mu = jnp.einsum("...d,d->...", q, stats.mean)
    var = quadratic_form(stats, q)
    sigma = jnp.sqrt(jnp.maximum(var, 1e-20))
    if metric == METRIC_COSINE_DIST:
        mu = 1.0 - mu  # affine map, Eq. (3); sigma preserved
    return FDLParams(mu=mu, sigma=sigma)


def fdl_quantile(params: FDLParams, p: Array) -> Array:
    """p-th percentile distance of the estimated FDL (inverse CDF).

    For *distance* metrics small quantiles are the nearest neighbors. For
    *similarity* metrics callers should pass ``1 - p`` (handled by scoring).
    """
    return params.mu + params.sigma * jax.scipy.special.ndtri(p)


def fdl_cdf(params: FDLParams, x: Array) -> Array:
    """P[FDL <= x] under the estimated Gaussian."""
    z = (x - params.mu[..., None]) / params.sigma[..., None]
    return jax.scipy.special.ndtr(z)
