"""ESTIMATE-EF (paper Algorithm 1) — the end-to-end per-query ef estimator.

Combines the FDL Gaussian moments (§5), the quantile-bin query score (§6.1) and
the ef-estimation table lookup (§6.2).  Pure jnp, jittable, batched: inside the
adaptive search it is invoked under ``lax.cond`` once ``l`` distances have been
collected.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .ef_table import EfTable, lookup_ef
from .fdl import METRIC_COSINE_DIST, estimate_fdl
from .scoring import DEFAULT_DELTA, DEFAULT_M, DECAY_EXP, score_query
from .stats import DatasetStats

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    metric: str = METRIC_COSINE_DIST
    m: int = DEFAULT_M
    delta: float = DEFAULT_DELTA
    decay: str = DECAY_EXP
    use_kernel: bool = False  # route scoring through the Pallas binscore kernel


@partial(jax.jit, static_argnames=("config",))
def estimate_ef(
    stats: DatasetStats,
    table: EfTable,
    q: Array,
    distances: Array,
    target_recall: Array,
    *,
    valid: Optional[Array] = None,
    config: EstimatorConfig = EstimatorConfig(),
) -> Array:
    """Algorithm 1.  ``q``: (..., d); ``distances``: (..., L) collected list D.

    Returns int32 estimated ef with the leading batch shape of ``q``.
    """
    params = estimate_fdl(stats, q, metric=config.metric)       # lines 1-2
    if config.use_kernel:
        from repro.kernels import ops as kernel_ops

        score = kernel_ops.score(
            params,
            distances,
            valid=valid,
            m=config.m,
            delta=config.delta,
            metric=config.metric,
            decay=config.decay,
        )
    else:
        score = score_query(                                     # lines 3-5
            params,
            distances,
            valid=valid,
            m=config.m,
            delta=config.delta,
            metric=config.metric,
            decay=config.decay,
        )
    return lookup_ef(table, score, target_recall)                # lines 6-11


@partial(jax.jit, static_argnames=("config",))
def query_scores(
    stats: DatasetStats,
    q: Array,
    distances: Array,
    *,
    valid: Optional[Array] = None,
    config: EstimatorConfig = EstimatorConfig(),
) -> Array:
    """Score-only entry point (used by offline table construction)."""
    params = estimate_fdl(stats, q, metric=config.metric)
    return score_query(
        params,
        distances,
        valid=valid,
        m=config.m,
        delta=config.delta,
        metric=config.metric,
        decay=config.decay,
    )
