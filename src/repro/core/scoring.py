"""Query scoring model (paper §6.1, Eqs. 4-6).

The estimated FDL Gaussian is discretized into ``m`` consecutive quantile bins
of width ``delta``; the distances collected near the entry point are counted
into the bins; the score is a weighted, normalized sum of bin counts with
exponentially decaying weights ``w_i = 100 * e^{-i+1}``.

High score  =>  many collected distances sit in the extreme-favorable quantiles
            =>  "easy" query  =>  small ef suffices (paper Appendix C example).

All functions are jittable and batched: ``distances`` may be ``(L,)`` or
``(B, L)`` with an optional validity mask (fixed-shape search buffers pad).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .fdl import (
    METRIC_COSINE_DIST,
    METRIC_COSINE_SIM,
    METRIC_IP,
    FDLParams,
)

Array = jax.Array

DECAY_EXP = "exp"
DECAY_LINEAR = "linear"
DECAY_NONE = "none"

DEFAULT_M = 10        # number of quantile bins
DEFAULT_DELTA = 1e-3  # quantile width per bin (paper uses delta = 0.001)


def bin_weights(m: int, decay: str = DECAY_EXP) -> Array:
    """Per-bin importance weights (paper Eq. 6 + Table-10 ablation variants)."""
    i = jnp.arange(1, m + 1, dtype=jnp.float32)
    if decay == DECAY_EXP:
        return 100.0 * jnp.exp(-i + 1.0)      # w_i = 100 e^{-i+1}
    if decay == DECAY_LINEAR:
        return 100.0 * (m - i + 1.0) / m      # linearly decreasing
    if decay == DECAY_NONE:
        return jnp.full((m,), 100.0 / m)      # uniform
    raise ValueError(f"unknown decay {decay!r}")


@partial(jax.jit, static_argnames=("m", "metric"))
def bin_thresholds(
    params: FDLParams,
    *,
    m: int = DEFAULT_M,
    delta: float = DEFAULT_DELTA,
    metric: str = METRIC_COSINE_DIST,
) -> Array:
    """Quantile thresholds  theta_i = mu + sigma * ndtri(delta * i)  (Eq. 4).

    Returns ``(..., m)``. For similarity metrics (larger = closer) the favorable
    tail is the upper one: theta_i = mu + sigma * ndtri(1 - delta * i), and bin
    membership flips direction (handled in :func:`bin_counts`).
    """
    i = jnp.arange(1, m + 1, dtype=jnp.float32)
    if metric in (METRIC_IP, METRIC_COSINE_SIM):
        qs = 1.0 - delta * i
    else:
        qs = delta * i
    z = jax.scipy.special.ndtri(qs)
    return params.mu[..., None] + params.sigma[..., None] * z


@partial(jax.jit, static_argnames=("metric",))
def bin_counts(
    distances: Array,
    thresholds: Array,
    *,
    valid: Optional[Array] = None,
    metric: str = METRIC_COSINE_DIST,
) -> Array:
    """Count collected distances into quantile bins (Eq. 5).

    distances:  (..., L) collected values (distance *or* similarity, per metric)
    thresholds: (..., m) from :func:`bin_thresholds`
    valid:      optional (..., L) bool mask for padded entries
    Returns (..., m) float32 counts.
    """
    d = distances[..., :, None]          # (..., L, 1)
    t = thresholds[..., None, :]         # (..., 1, m)
    if metric in (METRIC_IP, METRIC_COSINE_SIM):
        # larger = closer: bin_1 is d > theta_1 (top delta quantile); bin_i is
        # theta_i < d <= theta_{i-1}.
        below = d > t                    # (..., L, m) cumulative membership
    else:
        below = d <= t
    # Convert cumulative membership into per-bin membership: bin_i = cum_i - cum_{i-1}.
    cum = below.astype(jnp.float32)
    per_bin = jnp.diff(cum, axis=-1, prepend=jnp.zeros_like(cum[..., :1]))
    if valid is not None:
        per_bin = per_bin * valid[..., :, None].astype(jnp.float32)
    return jnp.sum(per_bin, axis=-2)     # (..., m)


@partial(jax.jit, static_argnames=("decay",))
def query_score(
    counts: Array,
    num_collected: Array,
    *,
    decay: str = DECAY_EXP,
) -> Array:
    """Weighted, normalized score  s(q) = sum_i w_i * c_i / |D|  (Eq. 6)."""
    m = counts.shape[-1]
    w = bin_weights(m, decay)
    denom = jnp.maximum(num_collected.astype(jnp.float32), 1.0)
    return jnp.sum(counts * w, axis=-1) / denom


@partial(jax.jit, static_argnames=("m", "metric", "decay"))
def score_query(
    params: FDLParams,
    distances: Array,
    *,
    valid: Optional[Array] = None,
    m: int = DEFAULT_M,
    delta: float = DEFAULT_DELTA,
    metric: str = METRIC_COSINE_DIST,
    decay: str = DECAY_EXP,
) -> Array:
    """End-to-end scoring: thresholds -> counts -> weighted score.

    This is the pure-jnp reference path; ``repro.kernels.binscore`` provides the
    fused Pallas kernel with identical semantics.
    """
    thresholds = bin_thresholds(params, m=m, delta=delta, metric=metric)
    counts = bin_counts(distances, thresholds, valid=valid, metric=metric)
    if valid is None:
        num = jnp.full(counts.shape[:-1], distances.shape[-1], jnp.float32)
    else:
        num = jnp.sum(valid.astype(jnp.float32), axis=-1)
    return query_score(counts, num, decay=decay)
