"""Ada-ef core: the paper's contribution (FDL theory + query scoring + ef table)."""
from .stats import (  # noqa: F401
    DatasetStats,
    compute_stats,
    merge_stats,
    unmerge_stats,
    quadratic_form,
    stats_nbytes,
)
from .fdl import (  # noqa: F401
    FDLParams,
    estimate_fdl,
    fdl_quantile,
    fdl_cdf,
    METRIC_IP,
    METRIC_COSINE_SIM,
    METRIC_COSINE_DIST,
)
from .scoring import (  # noqa: F401
    bin_thresholds,
    bin_counts,
    bin_weights,
    query_score,
    score_query,
    DECAY_EXP,
    DECAY_LINEAR,
    DECAY_NONE,
)
from .ef_table import EfTable, build_ef_table, default_ef_ladder, lookup_ef  # noqa: F401
from .estimator import EstimatorConfig, estimate_ef, query_scores  # noqa: F401
