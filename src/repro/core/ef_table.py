"""EF-estimation table (paper §6.2) + WAE.

Offline, ``G`` data vectors (default 200) are sampled as proxy queries; each gets
a query score (integer-cast) and is searched with a ladder of increasing ef
values until the target recall is met.  The resulting ``score -> [(ef, recall)]``
mapping is stored densely:

    ef_ladder   (E,)   ascending candidate ef values
    recall      (S, E) average recall of score-group s at ef_ladder[e]
    counts      (S,)   number of proxies in score-group s (g_i in the WAE)
    wae         ()     weighted-average ef  =  sum_i g_i ef_i / G   (paper §6.2)

Score groups with no proxies inherit the nearest populated group (preferring the
*lower* = harder score so the fallback over-searches rather than under-searches).

The online lookup (Algorithm 1, lines 6-11) is pure jnp and fully batched.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

MAX_SCORE = 100  # w_1 = 100 and sum_i c_i <= |D|  =>  s(q) in [0, 100]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EfTable:
    ef_ladder: Array  # (E,) int32, ascending
    recall: Array     # (S, E) float32
    counts: Array     # (S,) int32
    wae: Array        # () float32

    def tree_flatten(self):
        return (self.ef_ladder, self.recall, self.counts, self.wae), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_groups(self) -> int:
        return self.recall.shape[0]

    def nbytes(self) -> int:
        return int(sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(self)))


def default_ef_ladder(k: int, ef_max: int = 5000) -> np.ndarray:
    """Geometric ladder from k/4 to ef_max (paper probes progressively larger ef)."""
    vals = []
    ef = max(k // 4, 8)
    while ef < ef_max:
        vals.append(ef)
        ef = int(np.ceil(ef * 1.6))
    vals.append(ef_max)
    return np.unique(np.asarray(vals, np.int32))


def build_ef_table(
    proxy_scores: np.ndarray,
    recall_at_ef: Callable[[int, np.ndarray], np.ndarray],
    *,
    target_recall: float,
    ef_ladder: Sequence[int],
    num_groups: int = MAX_SCORE + 1,
) -> EfTable:
    """Construct the ef-estimation table (offline, adaptive probing).

    Parameters
    ----------
    proxy_scores: (G,) float scores of the sampled proxy queries.
    recall_at_ef: callable ``(ef, subset_indices) -> (len(subset),) recalls`` —
        runs the *actual HNSW search* for the given proxies at that ef and
        evaluates recall against their ground truth.  Evaluation is adaptive:
        once a score group's average recall reaches the target, larger efs are
        not probed for it (its recall is carried forward), matching §6.2.
    """
    g = np.clip(np.floor(np.asarray(proxy_scores)).astype(np.int64), 0, num_groups - 1)
    ladder = np.asarray(sorted(int(e) for e in ef_ladder), np.int64)
    num_e = len(ladder)
    recall_tbl = np.zeros((num_groups, num_e), np.float32)
    counts = np.bincount(g, minlength=num_groups).astype(np.int32)

    active = np.ones(len(g), bool)  # proxies whose group has not hit target yet
    last_group_recall = np.zeros(num_groups, np.float32)
    for e, ef in enumerate(ladder):
        idx = np.nonzero(active)[0]
        per_proxy = np.zeros(len(g), np.float32)
        if len(idx) > 0:
            per_proxy[idx] = np.asarray(recall_at_ef(int(ef), idx))
        # Per-group mean over *probed* proxies; carried forward for satisfied groups.
        for s in np.unique(g):
            members = g == s
            if active[members].any():
                last_group_recall[s] = float(per_proxy[members & active].mean())
            recall_tbl[s, e] = last_group_recall[s]
        # Deactivate satisfied groups (adaptive probing).
        for s in np.unique(g):
            if last_group_recall[s] >= target_recall:
                active[g == s] = False
        if not active.any():
            recall_tbl[:, e + 1:] = recall_tbl[:, e : e + 1]
            break

    # Fill empty score groups from the nearest populated one (prefer lower score).
    populated = np.nonzero(counts > 0)[0]
    if len(populated) == 0:
        raise ValueError("no proxy queries provided")
    for s in range(num_groups):
        if counts[s] == 0:
            below = populated[populated < s]
            src = below.max() if len(below) else populated.min()
            recall_tbl[s] = recall_tbl[src]

    # WAE over populated groups: smallest ef meeting target (else ladder max).
    wae_num = 0.0
    for s in populated:
        meets = np.nonzero(recall_tbl[s] >= target_recall)[0]
        ef_s = ladder[meets[0]] if len(meets) else ladder[-1]
        wae_num += counts[s] * float(ef_s)
    wae = wae_num / max(int(counts.sum()), 1)

    return EfTable(
        ef_ladder=jnp.asarray(ladder, jnp.int32),
        recall=jnp.asarray(recall_tbl),
        counts=jnp.asarray(counts),
        wae=jnp.asarray(wae, jnp.float32),
    )


@jax.jit
def lookup_ef(table: EfTable, score: Array, target_recall: Array) -> Array:
    """Algorithm 1, lines 6-11 — batched.

    Pick the smallest ladder ef whose recorded recall for the score group meets
    the target; floor it at WAE; if no ladder entry meets the target, return the
    largest ef of the row.
    """
    s = jnp.clip(jnp.floor(score).astype(jnp.int32), 0, table.num_groups - 1)
    row = table.recall[s]                      # (..., E)
    meets = row >= target_recall
    any_meets = jnp.any(meets, axis=-1)
    first = jnp.argmax(meets, axis=-1)         # first True (0 if none)
    ef_meet = table.ef_ladder[first]
    ef_meet = jnp.maximum(ef_meet, table.wae.astype(jnp.int32))  # line 10
    ef_fallback = table.ef_ladder[-1]          # line 7 default: largest EF
    return jnp.where(any_meets, ef_meet, ef_fallback).astype(jnp.int32)
