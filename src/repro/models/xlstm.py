"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, chunk-parallel) and
sLSTM (scalar memory, recurrent scan).

mLSTM runs in a chunked parallel form analogous to SSD: within-chunk
decay-masked attention + inter-chunk carried (C, n) state — O(S) in sequence
length, which is what qualifies xlstm-350m for the ``long_500k`` cell.
Stabilization: input gates are exp-capped (documented simplification of the
paper's m_t stabilizer; numerically equivalent in the regimes we train).

sLSTM is inherently sequential (recurrent R h_{t-1} term): ``lax.scan`` over
time with per-head block-diagonal recurrence, exactly as published.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.partitioning import constrain
from .layers import cast, dense_init, rmsnorm, rmsnorm_params

Array = jax.Array


class MLSTMCache(NamedTuple):
    c: Array   # (B, H, dk, dv) fp32
    n: Array   # (B, H, dk) fp32
    f_acc: Array  # (B, H) running log-decay (kept for interface symmetry)


class SLSTMCache(NamedTuple):
    c: Array   # (B, H, P)
    n: Array   # (B, H, P)
    h: Array   # (B, H, P)


def _dims(cfg: ArchConfig):
    h = cfg.num_heads
    p = cfg.d_model // h
    return h, p


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def mlstm_params(key, cfg: ArchConfig) -> dict:
    h, p = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "wqkv": dense_init(ks[0], (d, 3 * d)),
        "wif": dense_init(ks[1], (d, 2 * h), scale=0.01),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),  # init forget ~ sigmoid(3)
        "wz": dense_init(ks[2], (d, d)),
        "norm": rmsnorm_params(d),
        "wo": dense_init(ks[3], (d, d)),
    }


def _mlstm_chunked(q, k, v, log_f, log_i, chunk: int, init: Optional[MLSTMCache]):
    """q/k/v (B, S, H, P); log_f/log_i (B, S, H). Returns (y, cache)."""
    b, s, h, p = q.shape
    c = min(chunk, s)
    s_pad = (s + c - 1) // c * c
    pad = s_pad - s
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
    nc = s_pad // c
    qc = q.reshape(b, nc, c, h, p).astype(jnp.float32) / (p ** 0.5)
    kc = k.reshape(b, nc, c, h, p).astype(jnp.float32)
    vc = v.reshape(b, nc, c, h, p).astype(jnp.float32)
    lf = log_f.reshape(b, nc, c, h).astype(jnp.float32)
    li = jnp.minimum(log_i.reshape(b, nc, c, h).astype(jnp.float32), 10.0)

    f_cum = jnp.cumsum(lf, axis=2)                          # (b, nc, c, h)
    # intra: score[i,j] = (q_i . k_j) exp(F_i - F_j) i_j  (j <= i)
    qk = jnp.einsum("bkihp,bkjhp->bkhij", qc, kc)
    dec = f_cum[:, :, :, None, :] - f_cum[:, :, None, :, :]  # (b,nc,i,j,h)
    gate = jnp.exp(dec + li[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((c, c), bool))
    gate = jnp.where(tri[None, None, :, :, None], gate, 0.0)
    scores = qk * jnp.moveaxis(gate, -1, 2)                  # (b,nc,h,i,j)
    num_intra = jnp.einsum("bkhij,bkjhp->bkihp", scores, vc)
    den_intra = jnp.sum(scores, axis=-1)                     # (b,nc,h,i)

    # inter-chunk state
    dec_end = jnp.exp(f_cum[:, :, -1:, :] - f_cum + li)      # (b,nc,c,h)
    c_chunk = jnp.einsum("bkjh,bkjhp,bkjhq->bkhpq", dec_end, kc, vc)
    n_chunk = jnp.einsum("bkjh,bkjhp->bkhp", dec_end, kc)
    chunk_decay = jnp.exp(f_cum[:, :, -1, :])                # (b,nc,h)

    def step(carry, inp):
        cs, ns = carry
        ck, nk, cd, q_k, fc = inp
        qd = q_k * jnp.exp(fc)[..., None]                    # (b,c,h,p)
        num_inter = jnp.einsum("bihp,bhpq->bihq", qd, cs)
        den_inter = jnp.einsum("bihp,bhp->bih", qd, ns)
        cs = cs * cd[:, :, None, None] + ck
        ns = ns * cd[:, :, None] + nk
        return (cs, ns), (num_inter, den_inter)

    if init is None:
        c0 = jnp.zeros((b, h, p, p), jnp.float32)
        n0 = jnp.zeros((b, h, p), jnp.float32)
    else:
        c0, n0 = init.c, init.n
    xs = (
        jnp.moveaxis(c_chunk, 1, 0),
        jnp.moveaxis(n_chunk, 1, 0),
        jnp.moveaxis(chunk_decay, 1, 0),
        jnp.moveaxis(qc, 1, 0),
        jnp.moveaxis(f_cum, 1, 0),
    )
    (cf, nf), (num_inter, den_inter) = jax.lax.scan(step, (c0, n0), xs)
    num = num_intra + jnp.moveaxis(num_inter, 0, 1)
    den = jnp.transpose(den_intra, (0, 1, 3, 2)) + jnp.moveaxis(den_inter, 0, 1)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y = y.reshape(b, s_pad, h, p)[:, :s]
    cache = MLSTMCache(c=cf, n=nf, f_acc=jnp.zeros((b, h), jnp.float32))
    return y, cache


def mlstm_full(p, cfg: ArchConfig, x: Array, cache=None) -> Tuple[Array, MLSTMCache]:
    b, s, d = x.shape
    h, pd = _dims(cfg)
    qkv = x @ cast(p["wqkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, pd)
    k = k.reshape(b, s, h, pd)
    v = v.reshape(b, s, h, pd)
    gates = (x @ cast(p["wif"])).astype(jnp.float32)
    gi, gf = jnp.split(gates, 2, axis=-1)
    log_i = gi + p["b_i"]
    log_f = jax.nn.log_sigmoid(gf + p["b_f"])
    y, new_cache = _mlstm_chunked(q, k, v, log_f, log_i, cfg.ssm_chunk or 256, cache)
    y = y.reshape(b, s, d).astype(x.dtype)
    z = jax.nn.silu((x @ cast(p["wz"])).astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(p["norm"], y * z, cfg.norm_eps)
    return y @ cast(p["wo"]), new_cache


def mlstm_step(p, cfg: ArchConfig, x: Array, cache: MLSTMCache) -> Tuple[Array, MLSTMCache]:
    """x (B, 1, D) single-token decode."""
    b, _, d = x.shape
    h, pd = _dims(cfg)
    x0 = x[:, 0]
    qkv = x0 @ cast(p["wqkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, h, pd).astype(jnp.float32) / (pd ** 0.5)
    k = k.reshape(b, h, pd).astype(jnp.float32)
    v = v.reshape(b, h, pd).astype(jnp.float32)
    gates = (x0 @ cast(p["wif"])).astype(jnp.float32)
    gi, gf = jnp.split(gates, 2, axis=-1)
    i_t = jnp.exp(jnp.minimum(gi + p["b_i"], 10.0))
    f_t = jax.nn.sigmoid(gf + p["b_f"])
    c_new = cache.c * f_t[:, :, None, None] + i_t[:, :, None, None] * (
        k[:, :, :, None] * v[:, :, None, :]
    )
    n_new = cache.n * f_t[:, :, None] + i_t[:, :, None] * k
    num = jnp.einsum("bhp,bhpq->bhq", q, c_new)
    den = jnp.einsum("bhp,bhp->bh", q, n_new)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y = y.reshape(b, 1, d).astype(x.dtype)
    z = jax.nn.silu((x0 @ cast(p["wz"])).astype(jnp.float32)).astype(x.dtype)[:, None]
    y = rmsnorm(p["norm"], y * z, cfg.norm_eps)
    return y @ cast(p["wo"]), MLSTMCache(c=c_new, n=n_new, f_acc=cache.f_acc)


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_params(key, cfg: ArchConfig) -> dict:
    h, p = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "wx": dense_init(ks[0], (d, 4 * d)),
        "r": dense_init(ks[1], (h, p, 4 * p), scale=0.1),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "norm": rmsnorm_params(d),
        "wo": dense_init(ks[2], (d, d)),
    }


def _slstm_cell(p, cfg, wx_t, state: SLSTMCache):
    """One recurrence step. wx_t (B, 4D) precomputed input projection."""
    h, pd = _dims(cfg)
    b = wx_t.shape[0]
    rh = jnp.einsum("bhp,hpq->bhq", state.h, p["r"].astype(jnp.float32))  # (B,H,4P)
    pre = wx_t.astype(jnp.float32).reshape(b, h, 4 * pd) + rh + p["b"].reshape(h, 4 * pd)
    gi, gf, gz, go = jnp.split(pre, 4, axis=-1)  # each (B,H,P)
    i_t = jnp.exp(jnp.minimum(gi, 10.0))
    f_t = jax.nn.sigmoid(gf)
    z_t = jnp.tanh(gz)
    o_t = jax.nn.sigmoid(go)
    c_new = f_t * state.c + i_t * z_t
    n_new = f_t * state.n + i_t
    h_new = o_t * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return SLSTMCache(c=c_new, n=n_new, h=h_new)


def slstm_full(p, cfg: ArchConfig, x: Array, cache=None) -> Tuple[Array, SLSTMCache]:
    b, s, d = x.shape
    h, pd = _dims(cfg)
    wx = x @ cast(p["wx"])                                   # (B, S, 4D)
    state = cache or SLSTMCache(
        c=jnp.zeros((b, h, pd), jnp.float32),
        n=jnp.zeros((b, h, pd), jnp.float32),
        h=jnp.zeros((b, h, pd), jnp.float32),
    )

    def step(st, wx_t):
        st = _slstm_cell(p, cfg, wx_t, st)
        return st, st.h

    # remat the per-timestep cell: autodiff-of-scan otherwise stacks ~8 gate
    # tensors x 4096 steps as backward residuals (EXPERIMENTS.md §Perf cell 2)
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return y @ cast(p["wo"]), state


def slstm_step(p, cfg: ArchConfig, x: Array, cache: SLSTMCache) -> Tuple[Array, SLSTMCache]:
    b, _, d = x.shape
    wx = x[:, 0] @ cast(p["wx"])
    state = _slstm_cell(p, cfg, wx, cache)
    h, pd = _dims(cfg)
    y = state.h.reshape(b, 1, d).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return y @ cast(p["wo"]), state


def init_mlstm_cache(cfg: ArchConfig, batch: int) -> MLSTMCache:
    h, p = _dims(cfg)
    return MLSTMCache(
        c=jnp.zeros((batch, h, p, p), jnp.float32),
        n=jnp.zeros((batch, h, p), jnp.float32),
        f_acc=jnp.zeros((batch, h), jnp.float32),
    )


def init_slstm_cache(cfg: ArchConfig, batch: int) -> SLSTMCache:
    h, p = _dims(cfg)
    z = jnp.zeros((batch, h, p), jnp.float32)
    return SLSTMCache(c=z, n=z, h=z)
