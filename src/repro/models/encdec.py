"""Encoder-decoder stack (seamless-m4t backbone; audio frontend stubbed).

Encoder: bidirectional self-attention over precomputed frame embeddings
(the modality frontend is a stub per the assignment — ``input_specs()``
provides (B, S_enc, frontend_dim) frames).  Decoder: causal self-attention +
cross-attention to the encoder memory.  Cross-attention K/V are projected once
per layer at prefill and carried in the cache for decode.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.partitioning import constrain
from .attention import (
    attention_decode,
    attention_cross,
    attention_full,
    attention_params,
    cross_memory,
)
from .layers import cast, rmsnorm, rmsnorm_params, swiglu, swiglu_params
from .transformer import remat_policy, stacked_init

Array = jax.Array


class EncDecCache(NamedTuple):
    self_k: Array    # (L, B, S, Hk, hd)
    self_v: Array
    cross_k: Array   # (L, B, Sm, Hk, hd) — static after prefill
    cross_v: Array


def encoder_layer_init(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_params(cfg.d_model),
        "attn": attention_params(k1, cfg),
        "ln2": rmsnorm_params(cfg.d_model),
        "mlp": swiglu_params(k2, cfg.d_model, cfg.d_ff),
    }


def decoder_layer_init(key, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_params(cfg.d_model),
        "attn": attention_params(k1, cfg),
        "lnx": rmsnorm_params(cfg.d_model),
        "xattn": attention_params(k2, cfg),
        "ln2": rmsnorm_params(cfg.d_model),
        "mlp": swiglu_params(k3, cfg.d_model, cfg.d_ff),
    }


def encdec_init(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "encoder": stacked_init(encoder_layer_init, k1, cfg, cfg.num_encoder_layers),
        "decoder": stacked_init(decoder_layer_init, k2, cfg, cfg.num_layers),
    }


def encoder_full(params, cfg: ArchConfig, x: Array, *, impl="jnp_flash") -> Array:
    def body(h, lp):
        a_in = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        out, _ = attention_full(lp["attn"], cfg, a_in, causal=False, impl=impl)
        h = h + out
        h = h + swiglu(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        h = constrain(h, "act_btd")
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=remat_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return x


def decoder_full(
    params,
    cfg: ArchConfig,
    x: Array,
    memory: Array,
    *,
    impl="jnp_flash",
    want_cache: bool = False,
):
    def body(h, lp):
        a_in = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        out, kv = attention_full(lp["attn"], cfg, a_in, causal=True, impl=impl)
        h = h + out
        mem_kv = cross_memory(lp["xattn"], cfg, memory)
        h = h + attention_cross(
            lp["xattn"], cfg, rmsnorm(lp["lnx"], h, cfg.norm_eps), mem_kv, impl=impl
        )
        h = h + swiglu(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        h = constrain(h, "act_btd")
        ys = (kv, mem_kv) if want_cache else None
        return h, ys

    if cfg.remat:
        body = jax.checkpoint(body, policy=remat_policy(cfg))
    x, ys = jax.lax.scan(body, x, params["decoder"])
    cache = None
    if want_cache:
        (sk, sv), (ck, cv) = ys
        cache = EncDecCache(self_k=sk, self_v=sv, cross_k=ck, cross_v=cv)
    return x, cache


def decoder_step(
    params,
    cfg: ArchConfig,
    x: Array,            # (B, 1, D)
    cache: EncDecCache,
    pos: Array,
    *,
    impl="jnp_flash",
):
    def body(h, xs):
        lp, sk, sv, ck, cv = xs
        a_in = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        out, sk, sv = attention_decode(lp["attn"], cfg, a_in, sk, sv, pos, impl=impl)
        h = h + out
        h = h + attention_cross(
            lp["xattn"], cfg, rmsnorm(lp["lnx"], h, cfg.norm_eps), (ck, cv), impl=impl
        )
        h = h + swiglu(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        return h, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x, (params["decoder"], cache.self_k, cache.self_v, cache.cross_k, cache.cross_v)
    )
    return x, EncDecCache(self_k=sk, self_v=sv, cross_k=cache.cross_k, cross_v=cache.cross_v)
