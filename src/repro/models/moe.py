"""Mixture-of-Experts layer: top-k routing, sort-based dispatch, grouped GEMM.

TPU adaptation notes (DESIGN.md §3): no dynamic per-expert ragged shapes —
token->expert assignment is materialized as a *static-capacity* slot table via
an argsort over expert ids (stable), and expert computation is one batched
``(E, C, D) x (E, D, F)`` dot_general (grouped GEMM).  Overflowing tokens are
dropped (standard capacity-factor semantics), dropped tokens pass through the
residual unchanged.  Flop cost is the honest ``T*k*cf * 3*D*F`` — no GShard
one-hot dispatch einsums (those are quadratic in tokens and would poison the
roofline).

Expert padding: when num_experts doesn't divide the mesh's model axis (e.g.
qwen2-moe's 60), experts are padded to ``E_pad`` with router logits masked to
-inf, so dead experts are never routed to (semantics preserved, layout even).

Shared experts (qwen2-moe) run as a dense SwiGLU with a sigmoid gate, fused
alongside the routed path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.partitioning import constrain
from .layers import cast, dense_init, swiglu, swiglu_params

Array = jax.Array


def padded_experts(cfg: ArchConfig, model_axis: int = 16) -> int:
    e = cfg.num_experts
    return (e + model_axis - 1) // model_axis * model_axis


def capacity(cfg: ArchConfig, tokens: int, e_pad: int) -> int:
    c = int(tokens * cfg.num_experts_per_tok * cfg.capacity_factor / cfg.num_experts)
    return max((c + 127) // 128 * 128, 128)


def moe_params(key, cfg: ArchConfig, model_axis: int = 16) -> dict:
    e_pad = padded_experts(cfg, model_axis)
    ks = jax.random.split(key, 6)
    f = cfg.moe_d_ff
    p = {
        "router": dense_init(ks[0], (cfg.d_model, e_pad)),
        "w_gate": dense_init(ks[1], (e_pad, cfg.d_model, f)),
        "w_up": dense_init(ks[2], (e_pad, cfg.d_model, f)),
        "w_down": dense_init(ks[3], (e_pad, f, cfg.d_model)),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = swiglu_params(ks[4], cfg.d_model, cfg.num_shared_experts * f)
        p["shared_gate"] = dense_init(ks[5], (cfg.d_model, 1))
    return p


def moe_apply(p: dict, cfg: ArchConfig, x: Array) -> Tuple[Array, Array]:
    """x (B, S, D) -> (out (B, S, D), aux_loss ())."""
    b, s, d = x.shape
    t = b * s
    e_pad = p["router"].shape[1]
    e_real = cfg.num_experts
    k = cfg.num_experts_per_tok
    cap = capacity(cfg, t, e_pad)

    xf = x.reshape(t, d)
    logits = (xf @ cast(p["router"])).astype(jnp.float32)       # (T, E_pad)
    logits = jnp.where(jnp.arange(e_pad)[None, :] < e_real, logits, -1e30)
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs_full, k)                  # (T, k)
    if cfg.norm_topk_prob:
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs_full[:, :e_real], axis=0)
    ce = jnp.zeros((e_pad,)).at[top_e.reshape(-1)].add(1.0)[:e_real] / (t * k)
    aux = e_real * jnp.sum(me * ce)

    # ---- sort-based dispatch ------------------------------------------------
    e_flat = top_e.reshape(-1)                                   # (T*k,)
    t_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    w_flat = top_p.reshape(-1).astype(jnp.float32)
    order = jnp.argsort(e_flat)                                  # stable
    e_sort = e_flat[order]
    t_sort = t_flat[order]
    w_sort = w_flat[order]
    counts = jnp.bincount(e_flat, length=e_pad)                  # (E_pad,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sort].astype(jnp.int32)
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_sort * cap + pos_in_e, e_pad * cap)  # drop slot

    token_of_slot = jnp.full((e_pad * cap + 1,), 0, jnp.int32).at[slot].set(
        t_sort, mode="drop"
    )[: e_pad * cap]
    weight_of_slot = jnp.zeros((e_pad * cap + 1,), jnp.float32).at[slot].set(
        w_sort, mode="drop"
    )[: e_pad * cap]
    valid_slot = jnp.zeros((e_pad * cap + 1,), jnp.float32).at[slot].set(
        keep.astype(jnp.float32), mode="drop"
    )[: e_pad * cap]

    xg = xf[token_of_slot] * valid_slot[:, None].astype(xf.dtype)
    xg = xg.reshape(e_pad, cap, d)
    xg = constrain(xg, "moe_ecd")

    # ---- grouped GEMM expert MLP (SwiGLU) ----------------------------------
    g = jnp.einsum("ecd,edf->ecf", xg, cast(p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", xg, cast(p["w_up"]))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    h = constrain(h, "moe_ecf")
    yg = jnp.einsum("ecf,efd->ecd", h, cast(p["w_down"]))
    yg = constrain(yg, "moe_ecd")

    # ---- combine ------------------------------------------------------------
    yflat = yg.reshape(e_pad * cap, d) * (weight_of_slot * valid_slot)[:, None].astype(
        yg.dtype
    )
    out = jnp.zeros((t, d), yg.dtype).at[token_of_slot].add(yflat)
    out = constrain(out.reshape(b, s, d), "act_btd")

    if cfg.num_shared_experts > 0:
        gate = jax.nn.sigmoid((xf @ cast(p["shared_gate"])).astype(jnp.float32))
        shared = swiglu(p["shared"], x) * gate.reshape(b, s, 1).astype(x.dtype)
        out = out + shared
    return out, aux
