"""Layer stacks: uniform decoder (dense/MoE), Zamba2 hybrid, xLSTM.

All stacks scan over *stacked* per-layer parameters (leading axis = layer), so
the lowered HLO contains one while-loop body per stack regardless of depth —
essential to keep 64-layer dry-run compiles tractable and remat policies
uniform.  Residual-stream activations are sharding-annotated via
``repro.partitioning.constrain`` at every block boundary.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.partitioning import constrain
from .attention import attention_decode, attention_full
from .layers import cast, rmsnorm, rmsnorm_params, swiglu, swiglu_params
from .mamba2 import (
    MambaCache,
    init_mamba_cache,
    mamba2_full,
    mamba2_params,
    mamba2_step,
)
from .moe import moe_apply, moe_params
from .xlstm import (
    MLSTMCache,
    SLSTMCache,
    init_mlstm_cache,
    init_slstm_cache,
    mlstm_full,
    mlstm_params,
    mlstm_step,
    slstm_full,
    slstm_params,
    slstm_step,
)
from .attention import attention_params

Array = jax.Array

def remat_policy(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


REMAT_POLICY = jax.checkpoint_policies.nothing_saveable


# ==========================================================================
# uniform decoder stack (dense / MoE / vlm backbone / enc-dec halves)
# ==========================================================================


def standard_layer_init(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_params(cfg.d_model),
        "attn": attention_params(k1, cfg),
        "ln2": rmsnorm_params(cfg.d_model),
    }
    if cfg.is_moe:
        p["moe"] = moe_params(k2, cfg)
    else:
        p["mlp"] = swiglu_params(k2, cfg.d_model, cfg.d_ff)
    return p


def stacked_init(layer_init, key, cfg: ArchConfig, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_init(k, cfg))(keys)


def standard_stack_full(
    layers: dict,
    cfg: ArchConfig,
    x: Array,
    *,
    causal: bool = True,
    impl: str = "jnp_flash",
    positions: Optional[Array] = None,
    want_cache: bool = False,
):
    """Whole-sequence pass.  Returns (x, aux_loss, kv_caches | None)."""

    def body(carry, lp):
        h, aux = carry
        a_in = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        attn_out, kv = attention_full(
            lp["attn"], cfg, a_in, causal=causal, impl=impl, positions=positions
        )
        h = h + attn_out
        h = constrain(h, "act_btd")
        m_in = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        if cfg.is_moe:
            m_out, a = moe_apply(lp["moe"], cfg, m_in)
            aux = aux + a
        else:
            m_out = swiglu(lp["mlp"], m_in)
        h = h + m_out
        h = constrain(h, "act_btd")
        ys = kv if want_cache else None
        return (h, aux), ys

    if cfg.remat:
        body = jax.checkpoint(body, policy=remat_policy(cfg))
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), layers)
    return x, aux, caches


def standard_stack_step(
    layers: dict,
    cfg: ArchConfig,
    x: Array,                 # (B, 1, D)
    cache_k: Array,           # (L, B, S, Hk, hd)
    cache_v: Array,
    pos: Array,               # (B,)
    *,
    impl: str = "jnp_flash",
):
    def body(h, xs):
        lp, ck, cv = xs
        a_in = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        attn_out, ck, cv = attention_decode(lp["attn"], cfg, a_in, ck, cv, pos, impl=impl)
        h = h + attn_out
        m_in = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        if cfg.is_moe:
            m_out, _ = moe_apply(lp["moe"], cfg, m_in)
        else:
            m_out = swiglu(lp["mlp"], m_in)
        h = h + m_out
        return h, (ck, cv)

    x, (cache_k, cache_v) = jax.lax.scan(body, x, (layers, cache_k, cache_v))
    return x, cache_k, cache_v


# ==========================================================================
# Zamba2 hybrid stack: Mamba2 backbone + shared attention block
# ==========================================================================


class Zamba2Cache(NamedTuple):
    mamba: MambaCache          # stacked (L, ...)
    shared_k: Array            # (nseg, B, S, Hk, hd)
    shared_v: Array


def zamba2_shared_init(key, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "in_proj": jax.random.truncated_normal(k3, -2, 2, (2 * cfg.d_model, cfg.d_model), jnp.float32)
        * (1.0 / jnp.sqrt(2 * cfg.d_model)),
        "ln1": rmsnorm_params(cfg.d_model),
        "attn": attention_params(k1, cfg),
        "ln2": rmsnorm_params(cfg.d_model),
        "mlp": swiglu_params(k2, cfg.d_model, cfg.d_ff),
    }


def zamba2_init(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "mamba": stacked_init(lambda k, c: mamba2_params(k, c), k1, cfg, cfg.num_layers),
        "shared": zamba2_shared_init(k2, cfg),
    }


def _shared_block_full(sp, cfg, x, x0, impl, pos=None):
    u = jnp.concatenate([x, x0], axis=-1) @ cast(sp["in_proj"])
    a_in = rmsnorm(sp["ln1"], u, cfg.norm_eps)
    attn_out, kv = attention_full(sp["attn"], cfg, a_in, causal=True, impl=impl)
    u = u + attn_out
    m_in = rmsnorm(sp["ln2"], u, cfg.norm_eps)
    u = u + swiglu(sp["mlp"], m_in)
    return x + u, kv


def zamba2_full(params, cfg: ArchConfig, x: Array, *, impl="jnp_flash", want_cache=False):
    every = cfg.shared_attn_every or cfg.num_layers
    nseg = max(cfg.num_layers // every, 1)
    x0 = x
    mamba_stacked = params["mamba"]
    seg_params = jax.tree_util.tree_map(
        lambda a: a.reshape((nseg, every) + a.shape[1:]), mamba_stacked
    )

    def seg_body(carry, sp_seg):
        h = carry

        def layer_body(hh, lp):
            out, cache = mamba2_full(lp, cfg, hh)
            hh = hh + out
            hh = constrain(hh, "act_btd")
            return hh, cache

        inner = layer_body
        if cfg.remat:
            inner = jax.checkpoint(inner, policy=remat_policy(cfg))
        h, caches = jax.lax.scan(inner, h, sp_seg)
        h, kv = _shared_block_full(params["shared"], cfg, h, x0, impl)
        h = constrain(h, "act_btd")
        return h, (caches, kv)

    x, (mcaches, kvs) = jax.lax.scan(seg_body, x, seg_params)
    if not want_cache:
        return x, jnp.zeros((), jnp.float32), None
    mcaches = jax.tree_util.tree_map(
        lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), mcaches
    )
    cache = Zamba2Cache(mamba=mcaches, shared_k=kvs[0], shared_v=kvs[1])
    return x, jnp.zeros((), jnp.float32), cache


def zamba2_step(params, cfg: ArchConfig, x: Array, cache: Zamba2Cache, pos: Array, x0_embed: Array, *, impl="jnp_flash"):
    every = cfg.shared_attn_every or cfg.num_layers
    nseg = max(cfg.num_layers // every, 1)
    seg_params = jax.tree_util.tree_map(
        lambda a: a.reshape((nseg, every) + a.shape[1:]), params["mamba"]
    )
    seg_mcache = jax.tree_util.tree_map(
        lambda a: a.reshape((nseg, every) + a.shape[1:]), cache.mamba
    )

    def seg_body(h, xs):
        sp_seg, mc_seg, ck, cv = xs

        def layer_body(hh, lxs):
            lp, lc = lxs
            out, lc = mamba2_step(lp, cfg, hh, lc)
            return hh + out, lc

        h, mc_seg = jax.lax.scan(layer_body, h, (sp_seg, mc_seg))
        sp = params["shared"]
        u = jnp.concatenate([h, x0_embed], axis=-1) @ cast(sp["in_proj"])
        a_in = rmsnorm(sp["ln1"], u, cfg.norm_eps)
        attn_out, ck, cv = attention_decode(sp["attn"], cfg, a_in, ck, cv, pos, impl=impl)
        u = u + attn_out
        u = u + swiglu(sp["mlp"], rmsnorm(sp["ln2"], u, cfg.norm_eps))
        return h + u, (mc_seg, ck, cv)

    x, (mc, ck, cv) = jax.lax.scan(
        seg_body, x, (seg_params, seg_mcache, cache.shared_k, cache.shared_v)
    )
    mc = jax.tree_util.tree_map(
        lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), mc
    )
    return x, Zamba2Cache(mamba=mc, shared_k=ck, shared_v=cv)


def init_zamba2_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Zamba2Cache:
    every = cfg.shared_attn_every or cfg.num_layers
    nseg = max(cfg.num_layers // every, 1)
    mc = init_mamba_cache(cfg, batch, dtype)
    mc = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape).copy(), mc
    )
    kv_shape = (nseg, batch, max_seq, cfg.num_kv_heads, cfg.hd)
    return Zamba2Cache(
        mamba=mc, shared_k=jnp.zeros(kv_shape, dtype), shared_v=jnp.zeros(kv_shape, dtype)
    )


# ==========================================================================
# xLSTM stack: (slstm_every - 1) mLSTM + 1 sLSTM per group
# ==========================================================================


class XLSTMCache(NamedTuple):
    mlstm: MLSTMCache   # stacked (G, m_per, ...)
    slstm: SLSTMCache   # stacked (G, ...)


def xlstm_init(key, cfg: ArchConfig) -> dict:
    every = cfg.slstm_every or cfg.num_layers
    groups = max(cfg.num_layers // every, 1)
    m_per = every - 1
    k1, k2 = jax.random.split(key)
    gkeys = jax.random.split(k1, groups)
    mk = jax.vmap(
        lambda k: stacked_init(lambda kk, c: mlstm_params(kk, c), k, cfg, m_per)
    )(gkeys)
    sk = stacked_init(lambda kk, c: slstm_params(kk, c), k2, cfg, groups)
    return {"mlstm": mk, "slstm": sk}


def xlstm_full(params, cfg: ArchConfig, x: Array, *, impl="jnp_flash", want_cache=False):
    def group_body(h, gp):
        mp, sp = gp

        def m_body(hh, lp):
            out, c = mlstm_full(lp, cfg, hh)
            hh = hh + out
            hh = constrain(hh, "act_btd")
            return hh, c

        inner = m_body
        if cfg.remat:
            inner = jax.checkpoint(inner, policy=remat_policy(cfg))
        h, mcaches = jax.lax.scan(inner, h, mp)
        out, scache = slstm_full(sp, cfg, h)
        h = h + out
        h = constrain(h, "act_btd")
        return h, (mcaches, scache)

    x, (mc, sc) = jax.lax.scan(group_body, x, (params["mlstm"], params["slstm"]))
    cache = XLSTMCache(mlstm=mc, slstm=sc) if want_cache else None
    return x, jnp.zeros((), jnp.float32), cache


def xlstm_step(params, cfg: ArchConfig, x: Array, cache: XLSTMCache, pos: Array, *, impl="jnp_flash"):
    def group_body(h, xs):
        mp, sp, mc, sc = xs

        def m_body(hh, lxs):
            lp, lc = lxs
            out, lc = mlstm_step(lp, cfg, hh, lc)
            return hh + out, lc

        h, mc = jax.lax.scan(m_body, h, (mp, mc))
        out, sc = slstm_step(sp, cfg, h, sc)
        return h + out, (mc, sc)

    x, (mc, sc) = jax.lax.scan(
        group_body, x, (params["mlstm"], params["slstm"], cache.mlstm, cache.slstm)
    )
    return x, XLSTMCache(mlstm=mc, slstm=sc)


def init_xlstm_cache(cfg: ArchConfig, batch: int) -> XLSTMCache:
    every = cfg.slstm_every or cfg.num_layers
    groups = max(cfg.num_layers // every, 1)
    m_per = every - 1
    mc = init_mlstm_cache(cfg, batch)
    mc = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None, None], (groups, m_per) + a.shape).copy(), mc
    )
    sc = init_slstm_cache(cfg, batch)
    sc = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (groups,) + a.shape).copy(), sc
    )
    return XLSTMCache(mlstm=mc, slstm=sc)
