"""Model zoo: assigned architectures behind a unified Model API."""
from .model_zoo import Model, build_model  # noqa: F401
