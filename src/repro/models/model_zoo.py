"""Unified model API over all assigned architectures.

Every architecture exposes the same four entry points (built by
:func:`build_model`):

- ``init(key)``                          -> params pytree (fp32)
- ``loss_fn(params, batch)``             -> (loss, metrics)        [train]
- ``prefill(params, batch)``             -> (last_logits, cache)   [serve]
- ``decode(params, tokens, cache, pos)`` -> (logits, cache)        [serve]
- ``init_cache(batch, max_seq)``         -> cache pytree
- ``input_specs(shape)``                 -> abstract inputs (dry-run)

Modality frontends (audio frames / vision patches) are stubs per the
assignment: ``input_specs`` provides precomputed frame/patch embeddings and
the model owns only the projector.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.partitioning import constrain
from .encdec import (
    EncDecCache,
    decoder_full,
    decoder_step,
    encdec_init,
    encoder_full,
)
from .layers import (
    cast,
    dense_init,
    embed_init,
    rmsnorm,
    rmsnorm_params,
    softmax_cross_entropy,
)
from .transformer import (
    XLSTMCache,
    Zamba2Cache,
    init_xlstm_cache,
    init_zamba2_cache,
    stacked_init,
    standard_layer_init,
    standard_stack_full,
    standard_stack_step,
    xlstm_full,
    xlstm_init,
    xlstm_step,
    zamba2_full,
    zamba2_init,
    zamba2_step,
)

Array = jax.Array
AUX_COEF = 0.01


# --------------------------------------------------------------------------
# shared head / embedding helpers
# --------------------------------------------------------------------------


def _head_init(key, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "embed": embed_init(k1, (cfg.vocab_size, cfg.d_model)),
        "final_norm": rmsnorm_params(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size))
    if cfg.frontend != "none":
        p["frontend_proj"] = {
            "w1": dense_init(k3, (cfg.frontend_dim, cfg.d_model)),
            "w2": dense_init(jax.random.fold_in(k3, 1), (cfg.d_model, cfg.d_model)),
        }
    return p


def _embed(params, cfg: ArchConfig, tokens: Array) -> Array:
    x = params["embed"][tokens].astype(jnp.bfloat16)
    return constrain(x, "act_btd")


def _logits(params, cfg: ArchConfig, x: Array) -> Array:
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ cast(w)
    return constrain(logits, "logits")


def _frontend(params, x_raw: Array) -> Array:
    fp = params["frontend_proj"]
    h = jax.nn.gelu((x_raw.astype(jnp.bfloat16) @ cast(fp["w1"])).astype(jnp.float32))
    return (h.astype(jnp.bfloat16) @ cast(fp["w2"]))


def chunked_cross_entropy(
    params, cfg: ArchConfig, x: Array, labels: Array, mask: Optional[Array], chunk: int = 1024
) -> Array:
    """CE without materializing the full (B, S, V) logits: scan over S chunks.

    Memory-side beyond-paper optimization (see EXPERIMENTS.md §Perf); flops
    identical to the full-logits path.
    """
    b, s, d = x.shape
    c = min(chunk, s)
    if s % c:
        pad = c - s % c
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None else jnp.ones((b, s), bool), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((b, x.shape[1]), bool)
    nch = x.shape[1] // c
    xc = jnp.moveaxis(x.reshape(b, nch, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nch, c), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, nch, c), 1, 0)

    def body(acc, inp):
        xx, ll, mm = inp
        logits = _logits(params, cfg, xx).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm.astype(jnp.float32)
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mm.astype(jnp.float32))), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# --------------------------------------------------------------------------
# family backbones: full + step
# --------------------------------------------------------------------------


def _backbone_init(key, cfg: ArchConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return {"layers": stacked_init(standard_layer_init, key, cfg, cfg.num_layers)}
    if cfg.family == "hybrid":
        return zamba2_init(key, cfg)
    if cfg.family == "ssm":
        return xlstm_init(key, cfg)
    if cfg.family == "audio":
        return encdec_init(key, cfg)
    raise ValueError(cfg.family)


def _backbone_full(params, cfg: ArchConfig, x, *, impl, want_cache, memory=None):
    if cfg.family in ("dense", "moe", "vlm"):
        h, aux, kv = standard_stack_full(
            params["layers"], cfg, x, impl=impl, want_cache=want_cache
        )
        cache = None
        if want_cache:
            cache = {"k": kv[0], "v": kv[1]}
        return h, aux, cache
    if cfg.family == "hybrid":
        return zamba2_full(params, cfg, x, impl=impl, want_cache=want_cache)
    if cfg.family == "ssm":
        return xlstm_full(params, cfg, x, impl=impl, want_cache=want_cache)
    if cfg.family == "audio":
        h, cache = decoder_full(params, cfg, x, memory, impl=impl, want_cache=want_cache)
        return h, jnp.zeros(()), cache
    raise ValueError(cfg.family)


# --------------------------------------------------------------------------
# the Model container
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    impl: str = "jnp_flash"

    # ----------------------------------------------------------- init
    def init(self, key) -> dict:
        k1, k2 = jax.random.split(key)
        params = _head_init(k1, self.cfg)
        params.update(_backbone_init(k2, self.cfg))
        return params

    def abstract_params(self) -> Any:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ----------------------------------------------------------- train
    def loss_fn(self, params, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if cfg.family == "audio":
            frames = batch["frames"]
            memory = _frontend(params, frames)
            memory = encoder_full(params, cfg, memory, impl=self.impl)
            x = _embed(params, cfg, tokens)
            h, aux, _ = _backbone_full(
                params, cfg, x, impl=self.impl, want_cache=False, memory=memory
            )
        elif cfg.family == "vlm":
            patches = batch["patches"]
            pe = _frontend(params, patches)
            te = _embed(params, cfg, tokens)
            x = jnp.concatenate([pe, te], axis=1)
            x = constrain(x, "act_btd")
            h, aux, _ = _backbone_full(params, cfg, x, impl=self.impl, want_cache=False)
            npatch = patches.shape[1]
            h = h[:, npatch:]
            # labels/mask already aligned to the text region
        else:
            x = _embed(params, cfg, tokens)
            h, aux, _ = _backbone_full(params, cfg, x, impl=self.impl, want_cache=False)
        loss = chunked_cross_entropy(params, cfg, h, labels, mask)
        total = loss + AUX_COEF * aux
        return total, {"ce": loss, "aux": aux}

    # ----------------------------------------------------------- serve
    def prefill(self, params, batch: Dict[str, Array]):
        cfg = self.cfg
        tokens = batch["tokens"]
        memory = None
        if cfg.family == "audio":
            frames = batch["frames"]
            memory = _frontend(params, frames)
            memory = encoder_full(params, cfg, memory, impl=self.impl)
            x = _embed(params, cfg, tokens)
        elif cfg.family == "vlm":
            pe = _frontend(params, batch["patches"])
            te = _embed(params, cfg, tokens)
            x = jnp.concatenate([pe, te], axis=1)
        else:
            x = _embed(params, cfg, tokens)
        h, _, cache = _backbone_full(
            params, cfg, x, impl=self.impl, want_cache=True, memory=memory
        )
        logits = _logits(params, cfg, h[:, -1:])
        return logits, cache

    def decode(self, params, tokens: Array, cache, pos: Array):
        """tokens (B, 1) int32; pos (B,) absolute position of this token."""
        cfg = self.cfg
        x = _embed(params, cfg, tokens)
        if cfg.family in ("dense", "moe", "vlm"):
            h, ck, cv = standard_stack_step(
                params["layers"], cfg, x, cache["k"], cache["v"], pos, impl=self.impl
            )
            new_cache = {"k": ck, "v": cv}
        elif cfg.family == "hybrid":
            h, new_cache = zamba2_step(params, cfg, x, cache, pos, x, impl=self.impl)
        elif cfg.family == "ssm":
            h, new_cache = xlstm_step(params, cfg, x, cache, pos, impl=self.impl)
        elif cfg.family == "audio":
            h, new_cache = decoder_step(params, cfg, x, cache, pos, impl=self.impl)
        else:
            raise ValueError(cfg.family)
        logits = _logits(params, cfg, h)
        return logits, new_cache

    # ----------------------------------------------------------- caches
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.hd)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if cfg.family == "hybrid":
            return init_zamba2_cache(cfg, batch, max_seq, dtype)
        if cfg.family == "ssm":
            return init_xlstm_cache(cfg, batch)
        if cfg.family == "audio":
            kv = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.hd)
            return EncDecCache(
                self_k=jnp.zeros(kv, dtype),
                self_v=jnp.zeros(kv, dtype),
                cross_k=jnp.zeros(kv, dtype),
                cross_v=jnp.zeros(kv, dtype),
            )
        raise ValueError(cfg.family)

    def abstract_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq, dtype))

    # ----------------------------------------------------------- dry-run inputs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """Abstract (ShapeDtypeStruct) inputs for every entry point."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        tok = jax.ShapeDtypeStruct((b, s), i32)
        if shape.kind == "train":
            batch = {"tokens": tok, "labels": tok}
            if cfg.family == "audio":
                batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.float32)
            if cfg.family == "vlm":
                npatch = cfg.num_frontend_tokens
                batch["tokens"] = jax.ShapeDtypeStruct((b, s - npatch), i32)
                batch["labels"] = jax.ShapeDtypeStruct((b, s - npatch), i32)
                batch["patches"] = jax.ShapeDtypeStruct((b, npatch, cfg.frontend_dim), jnp.float32)
            return batch
        if shape.kind == "prefill":
            batch = {"tokens": tok}
            if cfg.family == "audio":
                batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.float32)
            if cfg.family == "vlm":
                npatch = cfg.num_frontend_tokens
                batch["tokens"] = jax.ShapeDtypeStruct((b, s - npatch), i32)
                batch["patches"] = jax.ShapeDtypeStruct((b, npatch, cfg.frontend_dim), jnp.float32)
            return batch
        # decode: one token + cache of length s
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache": self.abstract_cache(b, s),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }


def build_model(cfg: ArchConfig, impl: str = "jnp_flash") -> Model:
    return Model(cfg=cfg, impl=impl)
