"""Mamba-2 (SSD) block — chunked parallel scan for train/prefill, O(1)-state
recurrence for decode.  Used by zamba2 (hybrid backbone).

Layout follows the reference SSD formulation (Dao & Gu 2024) in its
single-group ("MVA") form: heads H with head dim P, shared state dim N.
Train/prefill splits the sequence into chunks of ``cfg.ssm_chunk``:
intra-chunk attention-like term + inter-chunk carried state via ``lax.scan``
— no (S, S) matrices, memory O(B * chunk^2 * H) per step.

Decode carries (conv_state, ssm_state); cost independent of context length —
this is why zamba2/xlstm run the ``long_500k`` cell that quadratic archs skip.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import cast, dense_init, rmsnorm, rmsnorm_params

Array = jax.Array


class MambaCache(NamedTuple):
    conv: Array   # (B, W-1, d_conv_ch)
    ssm: Array    # (B, H, N, P) fp32


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    h = cfg.ssm_heads or max(d_inner // 64, 1)
    p = d_inner // h
    n = cfg.ssm_state
    return d_inner, h, p, n


def mamba2_params(key, cfg: ArchConfig) -> dict:
    d_inner, h, p, n = _dims(cfg)
    conv_ch = d_inner + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * d_inner + 2 * n + h)),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_ch), scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))),  # softplus^-1(0.01)
        "norm": rmsnorm_params(d_inner),
        "out_proj": dense_init(ks[2], (d_inner, cfg.d_model)),
    }


def _causal_conv_full(w: Array, b: Array, x: Array) -> Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):  # width is tiny (4): unrolled taps
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i][None, None, :]
    return (out + b[None, None, :]).astype(x.dtype)


def _segsum_decay(da_cum: Array) -> Array:
    """exp(da_cum_i - da_cum_j) lower-triangular; da_cum (..., c, h)."""
    diff = da_cum[..., :, None, :] - da_cum[..., None, :, :]   # (..., i, j, h)
    c = da_cum.shape[-2]
    tri = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(tri[..., None], jnp.exp(diff), 0.0)


def ssd_full(
    x: Array,       # (B, S, H, P)
    dt: Array,      # (B, S, H) post-softplus
    a: Array,       # (H,) negative
    bmat: Array,    # (B, S, N)
    cmat: Array,    # (B, S, N)
    chunk: int,
    init_state: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Chunked SSD; returns (y (B,S,H,P), final_state (B,H,N,P))."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    c = min(chunk, s)
    s_pad = (s + c - 1) // c * c
    pad = s_pad - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = s_pad // c

    xc = x.reshape(b, nc, c, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, c, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, c, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, c, n).astype(jnp.float32)

    da = dtc * a[None, None, None, :]                      # (b, nc, c, h)
    da_cum = jnp.cumsum(da, axis=2)
    decay = _segsum_decay(da_cum)                          # (b, nc, c, c, h)
    cb = jnp.einsum("bkin,bkjn->bkij", cc, bc)             # (b, nc, c, c)
    scores = cb[..., None] * decay * dtc[:, :, None, :, :]  # (b,nc,i,j,h)
    y_intra = jnp.einsum("bkijh,bkjhp->bkihp", scores, xc)

    # per-chunk state contribution and total chunk decay
    decay_to_end = jnp.exp(da_cum[:, :, -1:, :] - da_cum)  # (b, nc, c, h)
    s_chunk = jnp.einsum(
        "bkjn,bkjh,bkjhp->bkhnp", bc, dtc * decay_to_end, xc
    )                                                      # (b, nc, h, n, p)
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])             # (b, nc, h)

    def step(state, inp):
        s_k, cd_k, c_k, dac_k = inp
        # y_inter_i = (C_i exp(da_cum_i)) . state
        y_inter = jnp.einsum(
            "bin,bih,bhnp->bihp", c_k, jnp.exp(dac_k), state
        )
        new_state = state * cd_k[:, :, None, None] + s_k
        return new_state, y_inter

    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, n, p), jnp.float32)
    )
    xs = (
        jnp.moveaxis(s_chunk, 1, 0),
        jnp.moveaxis(chunk_decay, 1, 0),
        jnp.moveaxis(cc, 1, 0),
        jnp.moveaxis(da_cum, 1, 0),
    )
    final_state, y_inter = jax.lax.scan(step, s0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    y = y.reshape(b, s_pad, h, p)[:, :s]
    return y, final_state


def mamba2_full(
    p: dict, cfg: ArchConfig, u: Array, cache: Optional[MambaCache] = None
) -> Tuple[Array, MambaCache]:
    """Whole-sequence forward (train / prefill). u (B, S, D)."""
    d_inner, h, pd, n = _dims(cfg)
    b, s, _ = u.shape
    zxbcdt = u @ cast(p["in_proj"])
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    xbc = _causal_conv_full(p["conv_w"], p["conv_b"], xbc)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(u.dtype)
    x, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, state = ssd_full(
        x.reshape(b, s, h, pd), dt, a, bmat, cmat, cfg.ssm_chunk
    )
    y = y + x.reshape(b, s, h, pd).astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = y @ cast(p["out_proj"])
    # conv cache = last (W-1) pre-activation conv inputs
    xbc_raw = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)[1]
    w1 = cfg.ssm_conv_width - 1
    tail = xbc_raw[:, -w1:, :] if s >= w1 else jnp.pad(xbc_raw, ((0, 0), (w1 - s, 0), (0, 0)))
    return out, MambaCache(conv=tail, ssm=state)


def mamba2_step(
    p: dict, cfg: ArchConfig, u: Array, cache: MambaCache
) -> Tuple[Array, MambaCache]:
    """Single-token decode. u (B, 1, D)."""
    d_inner, h, pd, n = _dims(cfg)
    b = u.shape[0]
    zxbcdt = u[:, 0] @ cast(p["in_proj"])
    z, xbc_new, dt_raw = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    # rolling conv state
    conv_in = jnp.concatenate([cache.conv, xbc_new[:, None, :]], axis=1)  # (B, W, C)
    w = p["conv_w"]
    xbc = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32), w) + p["conv_b"]
    xbc = jax.nn.silu(xbc).astype(u.dtype)
    x, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)                                               # (B, H)
    xh = x.reshape(b, h, pd).astype(jnp.float32)
    inc = jnp.einsum("bn,bh,bhp->bhnp", bmat.astype(jnp.float32), dt, xh)
    state = cache.ssm * da[:, :, None, None] + inc
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), state)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    y = rmsnorm(p["norm"], y[:, None, :], cfg.norm_eps)[:, 0]
    out = (y @ cast(p["out_proj"]))[:, None, :]
    return out, MambaCache(conv=conv_in[:, 1:], ssm=state)


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> MambaCache:
    d_inner, h, pd, n = _dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, d_inner + 2 * n), dtype),
        ssm=jnp.zeros((batch, h, n, pd), jnp.float32),
    )
