"""GQA attention layer (qk-norm / QKV-bias variants) with three exec paths:

- ``jnp_flash``  — blocked online-softmax attention in pure jnp (double
  ``lax.scan`` over q/kv blocks).  This is what the dry-run lowers: the
  (Sq, Skv) score matrix is never materialized, so 32k-prefill memory stays
  bounded; XLA/TPU fuses each block's QK^T-softmax-PV chain.
- ``pallas``     — `repro.kernels.flash_attention` (TPU deployment path).
- ``naive``      — materialized reference (smoke tests / tiny shapes).

Decode attends against a pre-allocated KV cache with a runtime length.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.partitioning import constrain
from .layers import apply_rope, cast, dense_init, rmsnorm, rmsnorm_params

Array = jax.Array


def attention_params(key, cfg: ArchConfig) -> dict:
    """Head-major 3D projection weights: the head dim is a real tensor axis so
    weight sharding pads identically to activation sharding (40 heads on a
    16-way model axis) — flattened (D, H*hd) layouts forced per-layer
    all-gathers at every reshape boundary (EXPERIMENTS.md §Perf, iter 3)."""
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.num_heads, hd), scale=1.0 / (cfg.d_model ** 0.5)),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads, hd), scale=1.0 / (cfg.d_model ** 0.5)),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads, hd), scale=1.0 / (cfg.d_model ** 0.5)),
        "wo": dense_init(ks[3], (cfg.num_heads, hd, cfg.d_model), scale=1.0 / ((cfg.num_heads * hd) ** 0.5)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, hd), jnp.float32)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd), jnp.float32)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_params(hd)
        p["k_norm"] = rmsnorm_params(hd)
    return p


def _project_qkv(p: dict, cfg: ArchConfig, x: Array, positions: Array, rope: bool = True):
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", x, cast(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", x, cast(p["wv"]))
    if cfg.qkv_bias:
        q = q + cast(p["bq"])
        k = k + cast(p["bk"])
        v = v + cast(p["bv"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # pin layouts: heads shard on "model" when divisible (rules decide), else
    # replicate — prevents GSPMD from inventing activation reshards inside the
    # attention scans (which showed up as per-layer (B,S,D) all-reduces).
    q = constrain(q, "act_q_bshd")
    k = constrain(k, "act_kv_bshd")
    v = constrain(v, "act_kv_bshd")
    return q, k, v


# --------------------------------------------------------------------------
# blocked attention in pure jnp (lowered by the dry-run)
# --------------------------------------------------------------------------

NEG = -1e30


def flash_attention_jnp(
    q: Array,            # (B, Sq, H, hd)
    k: Array,            # (B, Skv, Hk, hd)
    v: Array,            # (B, Skv, Hk, hd)
    *,
    causal: bool,
    q_offset: int = 0,   # absolute position of q row 0 minus kv row 0
    q_block: int = 512,
    kv_block: int = 1024,
    kv_len: Optional[Array] = None,  # (B,) runtime valid kv length
) -> Array:
    """Blocked online-softmax attention.

    The differentiable path (kv_len=None: train/prefill) routes through a
    ``custom_vjp`` whose backward recomputes the score blocks (true
    flash-attention backward) — without it, autodiff-of-scan saves every
    (qb, kb) probability tile and training memory explodes.  The decode path
    (runtime kv_len) has no backward and uses the plain scan.
    """
    if kv_len is None:
        b, sq, h, hd = q.shape
        skv = k.shape[1]
        qb = min(q_block, sq)
        kb = min(kv_block, skv)
        sq_p = (sq + qb - 1) // qb * qb
        skv_p = (skv + kb - 1) // kb * kb
        qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        # padded kv columns masked via a virtual kv_len == skv
        out = _flash_cvjp(qp, kp, vp, causal, q_offset, qb, kb, skv)
        return out[:, :sq]
    return _flash_scan(
        q, k, v, causal=causal, q_offset=q_offset, q_block=q_block,
        kv_block=kv_block, kv_len=kv_len,
    )


# ---- differentiable core (custom_vjp, padded block-multiple inputs) --------


def _fwd_blocks(q, k, v, causal, q_offset, qb, kb, valid_kv):
    """Returns (o, lse) with o (B, Sq, H, hd), lse (B, H, Sq)."""
    b, sq, h, hd = q.shape
    skv, hk = k.shape[1], k.shape[2]
    rep = h // hk
    scale = 1.0 / (hd ** 0.5)
    nq, nk = sq // qb, skv // kb
    qs = jnp.moveaxis(q.reshape(b, nq, qb, h, hd), 1, 0)
    # hoist the GQA head-repeat out of the loops: an in-loop repeat of the
    # (replicated) kv against model-sharded q heads made GSPMD reshard 50 MB
    # blocks x nq x nk x layers (EXPERIMENTS.md §Perf, iter 2)
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    from repro.partitioning import constrain as _constrain

    kf = _constrain(kf, "act_q_bshd")
    vf = _constrain(vf, "act_q_bshd")
    ks = jnp.moveaxis(kf.reshape(b, nk, kb, h, hd), 1, 0)
    vs = jnp.moveaxis(vf.reshape(b, nk, kb, h, hd), 1, 0)

    def q_step(_, iq_qi):
        iq, qi = iq_qi
        qi = qi.astype(jnp.float32) * scale

        def kv_step(carry, ik_kv):
            m_p, l_p, acc = carry
            ik, kr, vr = ik_kv
            kr = kr.astype(jnp.float32)
            vr = vr.astype(jnp.float32)
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kr)
            qpos = iq * qb + jnp.arange(qb)[:, None] + q_offset
            kpos = ik * kb + jnp.arange(kb)[None, :]
            mask = kpos < valid_kv
            if causal:
                mask = mask & (qpos >= kpos)
            s = jnp.where(mask[None, None], s, NEG)
            m_c = jnp.max(s, axis=-1, keepdims=True)
            m_n = jnp.maximum(m_p, m_c)
            alpha = jnp.exp(m_p - m_n)
            p = jnp.exp(s - m_n)
            l_n = l_p * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhqk,bkhd->bhqd", p, vr)
            return (m_n, l_n, acc), None

        m0 = jnp.full((b, h, qb, 1), NEG, jnp.float32)
        l0 = jnp.zeros((b, h, qb, 1), jnp.float32)
        a0 = jnp.zeros((b, h, qb, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        o = (acc / jnp.maximum(l_f, 1e-30)).astype(q.dtype)       # (B,H,qb,hd)
        lse = (m_f + jnp.log(jnp.maximum(l_f, 1e-30)))[..., 0]    # (B,H,qb)
        return None, (o, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    o = jnp.moveaxis(outs, 0, 1)                                  # (B,nq,H,qb,hd)
    o = jnp.transpose(o, (0, 1, 3, 2, 4)).reshape(b, sq, h, hd)
    lse = jnp.moveaxis(lses, 0, 2).reshape(b, h, sq)              # (B,H,Sq)
    return o, lse


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_cvjp(q, k, v, causal, q_offset, qb, kb, valid_kv):
    o, _ = _fwd_blocks(q, k, v, causal, q_offset, qb, kb, valid_kv)
    return o


def _flash_cvjp_fwd(q, k, v, causal, q_offset, qb, kb, valid_kv):
    o, lse = _fwd_blocks(q, k, v, causal, q_offset, qb, kb, valid_kv)
    return o, (q, k, v, o, lse)


def _flash_cvjp_bwd(causal, q_offset, qb, kb, valid_kv, res, do):
    q, k, v, o, lse = res
    b, sq, h, hd = q.shape
    skv, hk = k.shape[1], k.shape[2]
    rep = h // hk
    scale = 1.0 / (hd ** 0.5)
    nq, nk = sq // qb, skv // kb

    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    delta = jnp.sum(dof * o.astype(jnp.float32), axis=-1)          # (B,Sq,H)
    delta = jnp.transpose(delta, (0, 2, 1))                        # (B,H,Sq)

    qs = jnp.moveaxis(qf.reshape(b, nq, qb, h, hd), 1, 0)
    dos = jnp.moveaxis(dof.reshape(b, nq, qb, h, hd), 1, 0)
    lses = jnp.moveaxis(lse.reshape(b, h, nq, qb), 2, 0)           # (nq,B,H,qb)
    deltas = jnp.moveaxis(delta.reshape(b, h, nq, qb), 2, 0)
    from repro.partitioning import constrain as _constrain

    kf = _constrain(jnp.repeat(k, rep, axis=2), "act_q_bshd").astype(jnp.float32)
    vf = _constrain(jnp.repeat(v, rep, axis=2), "act_q_bshd").astype(jnp.float32)
    ks = jnp.moveaxis(kf.reshape(b, nk, kb, h, hd), 1, 0)
    vs = jnp.moveaxis(vf.reshape(b, nk, kb, h, hd), 1, 0)

    def _p_ds(iq, ik, qi, kr, lse_i, delta_i, do_i, vr):
        """Recompute probability and score-grad tiles for block (iq, ik)."""
        s = jnp.einsum("bqhd,bkhd->bhqk", qi * scale, kr)
        qpos = iq * qb + jnp.arange(qb)[:, None] + q_offset
        kpos = ik * kb + jnp.arange(kb)[None, :]
        mask = kpos < valid_kv
        if causal:
            mask = mask & (qpos >= kpos)
        s = jnp.where(mask[None, None], s, NEG)
        p = jnp.exp(s - lse_i[..., None])                          # (B,H,qb,kb)
        dp = jnp.einsum("bqhd,bkhd->bhqk", do_i, vr)
        ds = p * (dp - delta_i[..., None]) * scale
        return p, ds

    # ---- dq: outer q blocks, inner kv blocks ------------------------------
    def dq_qstep(_, inp):
        iq, qi, do_i, lse_i, delta_i = inp

        def kv_step(dq_acc, ik_kv):
            ik, kr, vr = ik_kv
            p, ds = _p_ds(iq, ik, qi, kr, lse_i, delta_i, do_i, vr)
            dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds, kr)
            return dq_acc, None

        dq0 = jnp.zeros((b, qb, h, hd), jnp.float32)
        dq_i, _ = jax.lax.scan(kv_step, dq0, (jnp.arange(nk), ks, vs))
        return None, dq_i

    _, dq_blocks = jax.lax.scan(dq_qstep, None, (jnp.arange(nq), qs, dos, lses, deltas))
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(b, sq, h, hd)

    # ---- dk/dv: outer kv blocks, inner q blocks ---------------------------
    # Accumulate in FULL head space and do the GQA group-reduce ONCE at the
    # end: a (hk, rep) reshape of the model-axis-sharded head dim inside the
    # inner loop forced GSPMD to all-gather 400 MB activation blocks on every
    # (iq, ik) step (2 x 515 GB/chip on qwen3-14b train_4k); hoisting the
    # reshape out removes those collectives (EXPERIMENTS.md §Perf, iter 1).
    def dkv_kstep(_, inp):
        ik, kr, vr = inp

        def q_step(carry, iq_q):
            dk_acc, dv_acc = carry
            iq, qi, do_i, lse_i, delta_i = iq_q
            p, ds = _p_ds(iq, ik, qi, kr, lse_i, delta_i, do_i, vr)
            dk_acc = dk_acc + jnp.einsum("bhqk,bqhd->bkhd", ds, qi)  # (B,kb,H,hd)
            dv_acc = dv_acc + jnp.einsum("bhqk,bqhd->bkhd", p, do_i)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, kb, h, hd), jnp.float32)
        (dk_j, dv_j), _ = jax.lax.scan(
            q_step, (z, z), (jnp.arange(nq), qs, dos, lses, deltas)
        )
        return None, (dk_j, dv_j)

    _, (dk_blocks, dv_blocks) = jax.lax.scan(dkv_kstep, None, (jnp.arange(nk), ks, vs))
    dk_full = jnp.moveaxis(dk_blocks, 0, 1).reshape(b, skv, h, hd)
    dv_full = jnp.moveaxis(dv_blocks, 0, 1).reshape(b, skv, h, hd)
    dk = dk_full.reshape(b, skv, hk, rep, hd).sum(3)
    dv = dv_full.reshape(b, skv, hk, rep, hd).sum(3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_cvjp.defvjp(_flash_cvjp_fwd, _flash_cvjp_bwd)


def _flash_scan(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool,
    q_offset: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    kv_len: Optional[Array] = None,
) -> Array:
    b, sq, h, hd = q.shape
    skv, hk = k.shape[1], k.shape[2]
    rep = h // hk
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    # pad to block multiples
    sq_p = (sq + qb - 1) // qb * qb
    skv_p = (skv + kb - 1) // kb * kb
    q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    if kv_len is None:
        kv_len = jnp.full((b,), skv, jnp.int32)

    scale = 1.0 / (hd ** 0.5)
    nq, nk = sq_p // qb, skv_p // kb
    # (nq, B, qb, H, hd) / (nk, B, kb, Hk, hd)
    qs = jnp.moveaxis(q.reshape(b, nq, qb, h, hd), 1, 0)
    ks = jnp.moveaxis(k.reshape(b, nk, kb, hk, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kb, hk, hd), 1, 0)

    def q_step(_, iq_qi):
        iq, qi = iq_qi                                   # qi (B, qb, H, hd)
        qi = (qi.astype(jnp.float32) * scale).astype(qi.dtype)

        def kv_step(carry, ik_kv):
            m_p, l_p, acc = carry
            ik, ki, vi = ik_kv                           # ki (B, kb, Hk, hd)
            kr = jnp.repeat(ki, rep, axis=2)             # (B, kb, H, hd)
            vr = jnp.repeat(vi, rep, axis=2)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qi, kr, preferred_element_type=jnp.float32
            )                                            # (B, H, qb, kb)
            qpos = iq * qb + jnp.arange(qb)[:, None] + q_offset
            kpos = ik * kb + jnp.arange(kb)[None, :]
            mask = kpos < kv_len[:, None, None, None]    # runtime length
            if causal:
                mask = mask & (qpos >= kpos)[None, None]
            s = jnp.where(mask, s, NEG)
            m_c = jnp.max(s, axis=-1, keepdims=True)     # (B, H, qb, 1)
            m_n = jnp.maximum(m_p, m_c)
            alpha = jnp.exp(m_p - m_n)
            p = jnp.exp(s - m_n)
            l_n = l_p * alpha + jnp.sum(p, axis=-1, keepdims=True)
            pv = jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vr.dtype), vr,
                preferred_element_type=jnp.float32,
            )
            acc = acc * alpha + pv
            return (m_n, l_n, acc), None

        m0 = jnp.full((b, h, qb, 1), NEG, jnp.float32)
        l0 = jnp.zeros((b, h, qb, 1), jnp.float32)
        a0 = jnp.zeros((b, h, qb, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l_f, 1e-30)              # (B, H, qb, hd)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1)                       # (B, nq, H, qb, hd)
    out = jnp.transpose(out, (0, 1, 3, 2, 4)).reshape(b, sq_p, h, hd)
    return out[:, :sq]


def _decode_attention_onepass(q, k, v, kv_len: Array) -> Array:
    """q (B, 1, H, hd); k/v (B, S, Hk, hd); kv_len (B,) -> (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    skv, hk = k.shape[1], k.shape[2]
    rep = h // hk
    # bf16 cache reads with fp32 accumulation: casting the whole cache to
    # f32 doubled decode HBM traffic (§Perf cell 3, iter 3)
    qg = (q[:, 0] / (hd ** 0.5)).astype(k.dtype).reshape(b, hk, rep, hd)
    s = jnp.einsum("bgrd,bsgd->bgrs", qg, k, preferred_element_type=jnp.float32)
    mask = jnp.arange(skv)[None, None, None, :] < kv_len[:, None, None, None]
    s = jnp.where(mask, s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum(
        "bgrs,bsgd->bgrd", p.astype(k.dtype), v, preferred_element_type=jnp.float32
    )
    o = (o / jnp.maximum(l, 1e-30)).reshape(b, h, hd)
    return o[:, None].astype(q.dtype)


def _naive_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                     kv_len: Optional[Array] = None) -> Array:
    b, sq, h, hd = q.shape
    skv, hk = k.shape[1], k.shape[2]
    rep = h // hk
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr, preferred_element_type=jnp.float32)
    s = s / (hd ** 0.5)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool) if not causal else (qpos >= kpos)
    mask = mask[None, None]
    if kv_len is not None:
        mask = mask & (kpos[None, None] < kv_len[:, None, None, None])
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vr.dtype), vr)
    return out


# --------------------------------------------------------------------------
# layer-level apply
# --------------------------------------------------------------------------


def attention_full(
    p: dict,
    cfg: ArchConfig,
    x: Array,                      # (B, S, D)
    *,
    causal: bool = True,
    impl: str = "jnp_flash",
    positions: Optional[Array] = None,
    rope: bool = True,
) -> Tuple[Array, Tuple[Array, Array]]:
    """Whole-sequence attention (train / prefill).  Returns (out, (k, v))."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions, rope=rope)
    if impl == "pallas":
        from repro.kernels import ops as kops

        o = kops.flash_attention(
            jnp.transpose(q, (0, 2, 1, 3)),
            jnp.transpose(k, (0, 2, 1, 3)),
            jnp.transpose(v, (0, 2, 1, 3)),
            causal=causal,
            use_kernel=True,
        )
        o = jnp.transpose(o, (0, 2, 1, 3))
    elif impl == "naive":
        o = _naive_attention(q, k, v, causal=causal)
    else:
        o = flash_attention_jnp(q, k, v, causal=causal)
    out = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"]))
    return out, (k, v)


def attention_cross(
    p: dict,
    cfg: ArchConfig,
    x: Array,                      # (B, Sq, D)
    memory_kv: Tuple[Array, Array],  # precomputed (B, Sm, Hk, hd) pair
    *,
    impl: str = "jnp_flash",
) -> Array:
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
    k, v = memory_kv
    if impl == "naive":
        o = _naive_attention(q, k, v, causal=False)
    else:
        o = flash_attention_jnp(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"]))


def cross_memory(p: dict, cfg: ArchConfig, memory: Array) -> Tuple[Array, Array]:
    """Project encoder memory once into cross-attention K/V."""
    b, s, _ = memory.shape
    hd = cfg.hd
    k = jnp.einsum("bsd,dhk->bshk", memory, cast(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", memory, cast(p["wv"]))
    if cfg.qk_norm:
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return k, v


def attention_decode(
    p: dict,
    cfg: ArchConfig,
    x: Array,                      # (B, 1, D)
    cache_k: Array,                # (B, Smax, Hk, hd)
    cache_v: Array,
    pos: Array,                    # (B,) current position (= kv_len so far)
    *,
    impl: str = "jnp_flash",
    kv_block: int = 1024,
) -> Tuple[Array, Array, Array]:
    """One-token decode: update cache at ``pos``, attend over the valid prefix."""
    b = x.shape[0]
    positions = pos[:, None]
    q, k, v = _project_qkv(p, cfg, x, positions)
    # scatter new kv at pos
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, pos].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, pos].set(v[:, 0].astype(cache_v.dtype))
    kv_len = pos + 1
    if impl == "pallas":
        from repro.kernels import ops as kops

        o = kops.decode_attention(
            q[:, 0], cache_k, cache_v, kv_len, use_kernel=True
        )[:, None]
    else:
        # single-token decode: one-pass masked attention over the whole cache.
        # The blocked scan dynamic-sliced the model-axis-sharded seq dim,
        # forcing GSPMD to all-gather 537 MB cache blocks per layer per block
        # (52 GB/step on qwen3-moe decode_32k — §Perf cell 3, iter 2).  The
        # unblocked contraction partitions cleanly over the sharded seq dim
        # (partial softmax stats all-reduce is bytes, not gigabytes), and the
        # score row is only (B, H, S) ~ tens of MB even at 524k context.
        o = _decode_attention_onepass(
            q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), kv_len
        )
    out = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"]))
    return out, cache_k, cache_v
