"""Shared model layers: norms, projections, RoPE, embeddings, losses.

Parameter convention: nested dicts of fp32 ``jnp`` arrays (pytrees).  Compute
runs in bf16 (cast at the layer boundary); reductions and softmax in fp32.
No flax/optax dependency — everything is explicit and pjit-friendly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
COMPUTE_DTYPE = jnp.bfloat16


def cast(x: Array) -> Array:
    return x.astype(COMPUTE_DTYPE)


# ------------------------------------------------------------------ init
def dense_init(key, shape, scale: Optional[float] = None) -> Array:
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale)


def embed_init(key, shape) -> Array:
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)


# ------------------------------------------------------------------ norms
def rmsnorm_params(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_params(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# ------------------------------------------------------------------ RoPE
def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, H, hd); positions (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLP
def swiglu_params(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff)),
        "w_up": dense_init(k2, (d_model, d_ff)),
        "w_down": dense_init(k3, (d_ff, d_model)),
    }


def swiglu(params: dict, x: Array) -> Array:
    g = x @ cast(params["w_gate"])
    u = x @ cast(params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return h @ cast(params["w_down"])


# ------------------------------------------------------------------ loss
def softmax_cross_entropy(logits: Array, labels: Array, mask: Optional[Array] = None) -> Array:
    """logits (B, S, V) [bf16 ok], labels (B, S) int32; mean over valid tokens."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------- grad barrier
@jax.custom_vjp
def bf16_grad_barrier(x: Array) -> Array:
    """Identity fwd; backward casts the residual-stream cotangent to bf16.

    XLA was fusing rmsnorm's fp32 upcast *before* the row-parallel all-reduce,
    moving 2x the bytes per layer (EXPERIMENTS.md §Perf, iter 4).  bf16
    gradient all-reduce on the residual stream is standard LLM practice; the
    optimizer still accumulates in fp32.
    """
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, ct):
    return (ct.astype(COMPUTE_DTYPE).astype(ct.dtype),)


bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)
