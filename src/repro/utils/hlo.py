"""HLO post-compile analysis: execution-weighted cost extraction for rooflines.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a while
body from ``lax.scan`` over 64 layers contributes 1/64th of its true flops.
This module re-derives execution-weighted costs directly from the optimized
HLO text:

1. split the module into computations (regions),
2. build a name -> shape map per computation,
3. per computation, accumulate
   - collective output bytes (all-reduce / all-gather / reduce-scatter /
     all-to-all / collective-permute, sync and async -start forms),
   - dot flops (2 * out_elems * contracted_size) — matmuls dominate LLM flops,
   - materialized bytes (sum of op output bytes; x2 for write+read) as the
     HBM-traffic proxy,
4. propagate bottom-up through while (x trip count from ``known_trip_count``
   backend config, falling back to the loop-bound constant in the condition),
   fusion/call edges (x1), and conditionals (worst-case branch).

Byte convention for collectives: output size of the op (all-gather = gathered
size, reduce-scatter = shard size, all-reduce = full size) — a consistent
proxy for per-chip link traffic.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_RE_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_RE_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)")
_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_RE_TRIP = re.compile(r"known_trip_count[^0-9]*(\d+)")
_RE_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _sig_bytes(sig: str) -> int:
    return sum(
        _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
        for dt, dims in _RE_SHAPE.findall(sig)
    )


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _RE_HEADER.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = [line]
            continue
        comps[cur].append(line)
        if line.startswith("}"):
            cur = None
    return comps


def _entry_name(text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.MULTILINE)
    return m.group(1) if m else ""


class HloCost(dict):
    @property
    def flops(self) -> float:
        return self.get("flops", 0.0)

    @property
    def bytes(self) -> float:
        return self.get("bytes", 0.0)

    def collectives(self) -> Dict[str, float]:
        return {k: v for k, v in self.items() if k in COLLECTIVE_KINDS}

    @property
    def collective_total(self) -> float:
        return sum(self.collectives().values())


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    entry = _entry_name(text)
    if entry not in comps:
        entry = next(iter(comps)) if comps else ""
    memo: Dict[str, Dict[str, float]] = {}

    def analyze(name: str, stack=(), in_fusion: bool = False) -> Dict[str, float]:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        if not name or name in stack or name not in comps:
            return {}
        lines = comps[name]
        shapes: Dict[str, str] = {}
        for ln in lines:
            d = _RE_DEF.match(ln)
            if d:
                shapes[d.group(1)] = d.group(2)
        acc: Dict[str, float] = defaultdict(float)
        for ln in lines:
            d = _RE_DEF.match(ln)
            if not d:
                continue
            out_name, out_sig, op = d.groups()
            out_bytes = _sig_bytes(out_sig)
            if op == "dynamic-update-slice":
                # in-place slice write: traffic = the update operand, not the
                # whole aliased buffer (scan output stacking was overcounted)
                om = _RE_OPERANDS.search(ln[ln.index("(") :])
                ops_ = [o.strip().lstrip("%") for o in om.group(1).split(",")] if om else []
                if len(ops_) >= 2 and ops_[1] in shapes:
                    out_bytes = _sig_bytes(shapes[ops_[1]])
            elif op == "fusion":
                # fusions rooted at a dynamic-update-slice alias their output
                # buffer; the written bytes are the update slice, not the
                # whole scan-output stack
                am = re.search(r"calls=%?([\w\.\-]+)", ln)
                if am and am.group(1) in comps:
                    dus_lines = []
                    fshapes: Dict[str, str] = {}
                    for fl in comps[am.group(1)]:
                        fd = _RE_DEF.match(fl)
                        if fd:
                            fshapes[fd.group(1)] = fd.group(2)
                            if fd.group(3) == "dynamic-update-slice":
                                dus_lines.append(fl)
                    # an in-place-update fusion (possibly bitcast/convert
                    # rooted): written bytes = the update slice
                    if len(dus_lines) == 1 and "dynamic-update-slice(" in dus_lines[0]:
                        fom = _RE_OPERANDS.search(
                            dus_lines[0][dus_lines[0].index("dynamic-update-slice(") :]
                        )
                        fops = (
                            [o.strip().lstrip("%") for o in fom.group(1).split(",")]
                            if fom
                            else []
                        )
                        if len(fops) >= 2 and fops[1] in fshapes:
                            out_bytes = _sig_bytes(fshapes[fops[1]])
            if not in_fusion and op not in (
                "bitcast",
                "tuple",
                "get-tuple-element",
                "parameter",
                "constant",
                "after-all",
                "partition-id",
                "replica-id",
            ):
                # fusion-internal ops never touch HBM; only the fusion's own
                # output (counted at the call site) does.  Zero-cost view ops
                # excluded above.
                acc["bytes"] += 2.0 * out_bytes  # write + subsequent read proxy
            base_op = op.replace("-start", "").replace("-done", "")
            if base_op in COLLECTIVE_KINDS and not op.endswith("-done"):
                acc[base_op] += out_bytes
            if op == "dot":
                om = _RE_OPERANDS.search(ln[ln.index("dot(") :])
                operands = [o.strip() for o in om.group(1).split(",")] if om else []
                lhs = operands[0].lstrip("%") if operands else ""
                lhs_sig = shapes.get(lhs, "")
                lcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
                contracted = 1
                if lhs_sig and lcd:
                    mdims = _RE_SHAPE.search(lhs_sig)
                    if mdims:
                        dims = [int(x) for x in mdims.group(2).split(",") if x]
                        for idx in lcd.group(1).split(","):
                            if idx.strip():
                                contracted *= dims[int(idx)]
                out_elems = sum(_shape_elems(dm) for _, dm in _RE_SHAPE.findall(out_sig))
                acc["flops"] += 2.0 * out_elems * contracted
            elif op == "convolution":
                # rough: 2 * out_elems * (kernel_elems) — uncommon in our models
                out_elems = sum(_shape_elems(dm) for _, dm in _RE_SHAPE.findall(out_sig))
                acc["flops"] += 2.0 * out_elems
            if op == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", ln)
                bm = re.search(r"body=%?([\w\.\-]+)", ln)
                trips = 1
                tm = _RE_TRIP.search(ln)
                if tm:
                    trips = int(tm.group(1))
                elif cm and cm.group(1) in comps:
                    consts = [
                        int(c)
                        for c in re.findall(r"constant\((\d+)\)", "\n".join(comps[cm.group(1)]))
                    ]
                    trips = max(consts) if consts else 1
                if bm:
                    sub = analyze(bm.group(1), stack + (name,), in_fusion)
                    for k, v in sub.items():
                        acc[k] += trips * v
                if cm:
                    sub = analyze(cm.group(1), stack + (name,), in_fusion)
                    for k, v in sub.items():
                        acc[k] += trips * v
            elif op in ("fusion", "call", "custom-call", "async-start"):
                child_fused = in_fusion or op in ("fusion", "custom-call")
                for attr in ("calls", "to_apply", "called_computations"):
                    am = re.search(rf"{attr}=\{{?%?([\w\.\-]+)", ln)
                    if am:
                        sub = analyze(am.group(1), stack + (name,), child_fused)
                        for k, v in sub.items():
                            acc[k] += v
                        break
            elif op == "conditional":
                branches = []
                bm = re.search(r"branch_computations=\{([^}]*)\}", ln)
                if bm:
                    branches = re.findall(r"%?([\w\.\-]+)", bm.group(1))
                else:
                    tm2 = re.search(
                        r"true_computation=%?([\w\.\-]+), false_computation=%?([\w\.\-]+)", ln
                    )
                    if tm2:
                        branches = [tm2.group(1), tm2.group(2)]
                subs = [analyze(b, stack + (name,), in_fusion) for b in branches if b]
                if subs:
                    for k in set().union(*subs):
                        acc[k] += max(s.get(k, 0.0) for s in subs)
        memo[key] = dict(acc)
        return memo[key]

    return HloCost(analyze(entry))


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    cost = analyze_hlo(hlo_text)
    out = {k: int(v) for k, v in cost.collectives().items()}
    out["total"] = int(cost.collective_total)
    return out


def count_ops(hlo_text: str, name: str) -> int:
    return len(re.findall(rf"\b{name}\(", hlo_text))
