"""Epoch-versioned index snapshots — mutation as a first-class event.

The paper's §6.3 claim is that Ada-ef is *update-friendly* (exact stats
merge/unmerge, incremental proxy ground truth, a cheap ef-table rebuild).
The serving stack honors that claim through **epochs**: every
``insert``/``delete`` publishes an immutable :class:`Epoch` — a frozen
bundle of the post-mutation graph arrays, dataset statistics, ef table and
a monotone version — instead of yanking references out from under live
consumers.

Two properties make this cheap:

1. JAX arrays are immutable.  "Pinning an epoch" is nothing more than
   holding references to its arrays: an in-flight tier dispatch that
   captured the pre-mutation :class:`~repro.index.search.DeviceGraph`
   keeps those device buffers alive (ordinary refcounting) and completes
   against the exact snapshot it was dispatched on — deleted rows cannot
   leak into *new* work, because new work binds the new epoch.
2. A tombstone delete preserves every compiled shape (``n`` is unchanged),
   and an insert changes only the leading axis — so a held
   :class:`repro.plan.ExecutionPlan` can *revalidate* (swap array
   references, keep shape-keyed jit caches warm when the signature
   matches) rather than die with ``StalePlanError``.

The :class:`EpochManager` owns the version counter and the publication
history; :class:`repro.index.pipeline.AdaEfIndex` holds one and routes
every mutation through it, and schedulers stamp the epoch a request was
served under into its :class:`repro.serve.api.RequestStats`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


class IndexMutationError(ValueError):
    """A structurally invalid ``insert``/``delete`` was refused *before*
    touching any state: out-of-range or already-tombstoned delete ids, or
    a deletion that would leave fewer than ``k`` alive rows (no valid
    top-k ground truth can exist for the estimation proxies).  The index
    is untouched when this raises — no version bump, no cache drop."""


@dataclasses.dataclass(frozen=True)
class Epoch:
    """One immutable index snapshot: everything a consumer (router,
    scheduler dispatch, held plan) binds when it starts work.

    Consumers pin an epoch simply by holding it (or any of its arrays);
    the device buffers stay alive until the last pin drops.  ``alive_rows``
    is host-side metadata for telemetry/validation — the authoritative
    per-row mask lives in ``graph.alive``.
    """

    version: int           # monotone; mirrors AdaEfIndex._graph_version
    graph: object          # DeviceGraph (immutable jax arrays)
    stats: object          # DatasetStats at this epoch
    table: object          # EfTable at this epoch
    n: int = 0             # total rows (tombstones included)
    alive_rows: int = 0    # rows serving results at this epoch

    def as_dict(self) -> dict:
        return {
            "version": self.version,
            "n": self.n,
            "alive_rows": self.alive_rows,
        }


class EpochManager:
    """Publication point for index mutations.

    ``current`` is the epoch new work binds; :meth:`publish` installs the
    post-mutation snapshot and retires the previous one (retired epochs
    are *not* kept alive here — only consumers that pinned them do that,
    so memory is bounded by in-flight work, not by churn history).
    """

    def __init__(self, first: Epoch):
        self._current = first
        self.published = 0           # publish() calls absorbed (telemetry)
        self._retired: List[int] = []  # versions superseded, oldest first

    @property
    def current(self) -> Epoch:
        return self._current

    @property
    def version(self) -> int:
        return self._current.version

    @property
    def retired_versions(self) -> List[int]:
        """Versions that have been superseded (history of churn)."""
        return list(self._retired)

    def pin(self) -> Epoch:
        """The current epoch, for a consumer about to start work on it.
        (Holding the returned object keeps its arrays alive.)"""
        return self._current

    def publish(self, *, version: int, graph, stats, table,
                n: int = 0, alive_rows: int = 0) -> Epoch:
        """Install the post-mutation snapshot as the current epoch."""
        if version <= self._current.version:
            raise ValueError(
                f"epoch version must be monotone: {version} <= "
                f"{self._current.version}"
            )
        self._retired.append(self._current.version)
        self._current = Epoch(
            version=version, graph=graph, stats=stats, table=table,
            n=n, alive_rows=alive_rows,
        )
        self.published += 1
        return self._current

    def as_dict(self) -> dict:
        d = self._current.as_dict()
        d["published"] = self.published
        d["retired"] = list(self._retired)
        return d


def epoch_of(index, version: Optional[int] = None) -> Epoch:
    """Build an :class:`Epoch` view of an ``AdaEfIndex``'s current state
    (used to seed the manager lazily for indexes built before any
    mutation)."""
    alive = index.host_index.alive[: index.host_index.n]
    return Epoch(
        version=index._graph_version if version is None else version,
        graph=index.graph,
        stats=index.stats,
        table=index.table,
        n=int(index.host_index.n),
        alive_rows=int(alive.sum()),
    )
