"""Distance computation for the ANN substrate.

HNSWlib computes one AVX dot product per (query, node) pair; on TPU we compute
whole frontiers as MXU contractions.  Two shapes matter:

- ``pairwise(Q, V)``      : (B, d) x (n, d)   -> (B, n)     brute force / oracle
- ``gathered(Q, V, ids)`` : (B, d), (B, G) ids -> (B, G)    frontier expansion

The perf-critical paths dispatch to the Pallas kernels in ``repro.kernels``
when ``use_kernel=True`` (TPU target; validated in interpret mode on CPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.fdl import METRIC_COSINE_DIST, METRIC_COSINE_SIM, METRIC_IP

Array = jax.Array


def normalize_rows(x: Array, eps: float = 1e-12) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def prepare_database(v: Array, metric: str) -> Array:
    """Pre-normalize once for cosine metrics so the hot loop is a pure matmul."""
    v = v.astype(jnp.float32)
    if metric in (METRIC_COSINE_SIM, METRIC_COSINE_DIST):
        return normalize_rows(v)
    return v


def prepare_queries(q: Array, metric: str) -> Array:
    q = q.astype(jnp.float32)
    if metric in (METRIC_COSINE_SIM, METRIC_COSINE_DIST):
        return normalize_rows(q)
    return q


@partial(jax.jit, static_argnames=("metric",))
def pairwise(q: Array, v: Array, *, metric: str = METRIC_COSINE_DIST) -> Array:
    """Distances between all queries (B, d) and all rows (n, d) -> (B, n).

    Inputs must already be prepared (normalized for cosine metrics).
    Convention: output is oriented so that *smaller = closer* for distance
    metrics and handled by callers for similarity metrics via ``key_sign``.
    """
    sims = q @ v.T
    if metric == METRIC_COSINE_DIST:
        return 1.0 - sims
    return sims


@partial(jax.jit, static_argnames=("metric",))
def gathered(q: Array, v: Array, ids: Array, *, metric: str = METRIC_COSINE_DIST) -> Array:
    """Distances from each query to its own gathered candidate rows.

    q: (B, d); ids: (B, G) int32 (negative = padding, distance -> +inf/-inf);
    returns (B, G).
    """
    safe = jnp.maximum(ids, 0)
    rows = v[safe]                      # (B, G, d)
    sims = jnp.einsum("bd,bgd->bg", q, rows)
    if metric == METRIC_COSINE_DIST:
        out = 1.0 - sims
        pad = jnp.inf
    else:
        out = sims
        pad = -jnp.inf
    return jnp.where(ids >= 0, out, pad)


def key_sign(metric: str) -> float:
    """+1 if smaller = closer (distances), -1 if larger = closer (similarities).

    The search loops operate on ``key = key_sign * value`` so that smaller keys
    are always better, uniformly across metrics.
    """
    return 1.0 if metric == METRIC_COSINE_DIST else -1.0


@partial(jax.jit, static_argnames=("k", "metric"))
def brute_force_topk(q: Array, v: Array, *, k: int, metric: str = METRIC_COSINE_DIST):
    """Exact top-k oracle (ground truth).  Returns (dists, ids) each (B, k)."""
    d = pairwise(q, v, metric=metric)
    key = d * key_sign(metric)
    neg_key, ids = jax.lax.top_k(-key, k)
    return -neg_key * key_sign(metric), ids


def brute_force_topk_chunked(q, v, *, k: int, metric: str = METRIC_COSINE_DIST, chunk: int = 8192):
    """Host-side chunked oracle for large n (keeps the (B, n) matrix bounded).

    ``q`` must be prepared; raw database chunks are prepared here (idempotent
    for already-normalized rows).
    """
    import numpy as np

    q = jnp.asarray(q)
    best_d = None
    best_i = None
    sign = key_sign(metric)
    for start in range(0, v.shape[0], chunk):
        block = prepare_database(jnp.asarray(v[start : start + chunk]), metric)
        d = pairwise(q, block, metric=metric)
        ids = jnp.arange(start, start + block.shape[0], dtype=jnp.int32)[None, :]
        ids = jnp.broadcast_to(ids, d.shape)
        if best_d is None:
            cat_d, cat_i = d, ids
        else:
            cat_d = jnp.concatenate([best_d, d], axis=1)
            cat_i = jnp.concatenate([best_i, ids], axis=1)
        key = cat_d * sign
        _, sel = jax.lax.top_k(-key, min(k, cat_d.shape[1]))
        best_d = jnp.take_along_axis(cat_d, sel, axis=1)
        best_i = jnp.take_along_axis(cat_i, sel, axis=1)
    return np.asarray(best_d), np.asarray(best_i)
