"""Adaptive-search baselines from the paper's evaluation (§7.1).

- **Static HNSW** — `search.search` with fixed ef (HNSWlib/FAISS behavior).
- **PiP** (Patience in Proximity) — saturation early-termination; built into
  `search.search` via ``SearchConfig.patience``.
- **LAET-style** — learned single-shot prediction of the required search
  effort from runtime features collected early in the search.  The original
  uses Gradient-Boosted Decision Trees; lightgbm is unavailable offline, so we
  use a small MLP regressor trained in JAX (documented substitution — the
  feature design follows the paper: first-l distance statistics).
- **DARTH-style** — declarative recall via a learned *recall predictor*
  checked periodically during the search; search stops once the predicted
  recall reaches the target.

Both learned baselines share the offline pipeline the paper describes: sample
"learn vectors", compute their ground truth, generate training data by running
searches, train the model.  That offline cost asymmetry (vs Ada-ef's closed-
form statistics) is exactly what Table 2/3 measures.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .distances import brute_force_topk_chunked, prepare_queries
from .pipeline import collect_distances
from .search import (
    AdaEfConfig,
    DeviceGraph,
    SearchConfig,
    SearchResult,
    recall_at_k,
    search,
)

Array = jax.Array


# --------------------------------------------------------------------------
# tiny MLP (offline-trainable; no optax/sklearn available)
# --------------------------------------------------------------------------


class MLP(NamedTuple):
    w1: Array
    b1: Array
    w2: Array
    b2: Array
    mu: Array   # feature standardization
    sd: Array


def _mlp_init(key, d_in: int, d_hidden: int = 32) -> MLP:
    k1, k2 = jax.random.split(key)
    return MLP(
        w1=jax.random.normal(k1, (d_in, d_hidden)) * (1.0 / np.sqrt(d_in)),
        b1=jnp.zeros((d_hidden,)),
        w2=jax.random.normal(k2, (d_hidden, 1)) * (1.0 / np.sqrt(d_hidden)),
        b2=jnp.zeros((1,)),
        mu=jnp.zeros((d_in,)),
        sd=jnp.ones((d_in,)),
    )


def _mlp_apply(p: MLP, x: Array) -> Array:
    x = (x - p.mu) / p.sd
    h = jax.nn.gelu(x @ p.w1 + p.b1)
    return (h @ p.w2 + p.b2)[..., 0]


def _fit_mlp(x: np.ndarray, y: np.ndarray, *, steps: int = 2000, lr: float = 1e-2, seed: int = 0) -> MLP:
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    p = _mlp_init(jax.random.PRNGKey(seed), x.shape[1])
    p = p._replace(mu=jnp.mean(x, 0), sd=jnp.maximum(jnp.std(x, 0), 1e-6))

    def loss(p):
        pred = _mlp_apply(p, x)
        return jnp.mean((pred - y) ** 2)

    # plain Adam, hand-rolled
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    gfn = jax.jit(jax.grad(loss))

    @jax.jit
    def step(i, p, m, v):
        g = gfn(p)
        m = jax.tree_util.tree_map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree_util.tree_map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree_util.tree_map(lambda a: a / (1 - 0.9 ** (i + 1)), m)
        vh = jax.tree_util.tree_map(lambda a: a / (1 - 0.999 ** (i + 1)), v)
        p = jax.tree_util.tree_map(
            lambda pp, a, b: pp - lr * a / (jnp.sqrt(b) + 1e-8), p, mh, vh
        )
        return p, m, v

    for i in range(steps):
        p, m, v = step(i, p, m, v)
    return p


# --------------------------------------------------------------------------
# features: statistics of the first-l collected distances
# --------------------------------------------------------------------------


def _runtime_features(dbuf: Array, dcount: Array) -> Array:
    """Per-query features from the collected distance list (LAET §4 style)."""
    lmax = dbuf.shape[-1]
    valid = jnp.arange(lmax)[None, :] < dcount[:, None]
    big = jnp.where(valid, dbuf, jnp.inf)
    small = jnp.where(valid, dbuf, -jnp.inf)
    cnt = jnp.maximum(dcount.astype(jnp.float32), 1.0)
    mean = jnp.sum(jnp.where(valid, dbuf, 0.0), -1) / cnt
    var = jnp.sum(jnp.where(valid, (dbuf - mean[:, None]) ** 2, 0.0), -1) / cnt
    mn = jnp.min(big, -1)
    mx = jnp.max(small, -1)
    sorted_d = jnp.sort(big, -1)
    p10 = sorted_d[:, jnp.maximum(lmax // 10, 1) - 1]
    p25 = sorted_d[:, jnp.maximum(lmax // 4, 1) - 1]
    return jnp.stack([mn, p10, p25, mean, jnp.sqrt(var + 1e-12), mx, cnt], axis=-1)


# --------------------------------------------------------------------------
# LAET-style: single-shot ef prediction
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LaetBaseline:
    graph: DeviceGraph
    cfg: SearchConfig
    ada: AdaEfConfig
    model: MLP
    offline_seconds: dict

    def query(self, queries, target_recall: float = 0.95) -> SearchResult:
        q = jnp.asarray(queries)
        dbuf, dcount = collect_distances(self.graph, q, self.cfg, self.ada)
        feats = _runtime_features(dbuf, dcount)
        log_ef = _mlp_apply(self.model, feats)
        ef = jnp.clip(
            jnp.exp2(log_ef).astype(jnp.int32), self.cfg.k, self.cfg.ef_cap
        )
        return search(self.graph, q, ef, self.cfg)


def fit_laet(
    graph: DeviceGraph,
    data: np.ndarray,
    *,
    cfg: SearchConfig,
    target_recall: float = 0.95,
    num_learn: int = 1000,
    beam: Optional[int] = None,
    seed: int = 0,
) -> LaetBaseline:
    """Offline pipeline: learn-vector GT -> training data -> model training.

    Mirrors the paper's three offline steps (LVec GT / TData / Train) so the
    Table-2 comparison is like-for-like.  ``beam`` (when given) overrides
    ``cfg.beam`` so the baseline's searches run the same beamed expansion as
    the Ada-ef index it is compared against.
    """
    if beam is not None:
        cfg = dataclasses.replace(cfg, beam=beam)
    rng = np.random.default_rng(seed)
    ada = AdaEfConfig()
    t = {}

    t0 = time.perf_counter()
    ids = rng.choice(len(data), size=min(num_learn, len(data)), replace=False)
    lv = data[ids]
    qs = prepare_queries(jnp.asarray(lv), cfg.metric)
    _, gt = brute_force_topk_chunked(qs, data, k=cfg.k, metric=cfg.metric)
    t["lvec_gt_s"] = time.perf_counter() - t0

    # training data: minimal ladder ef achieving target recall per learn vector
    t0 = time.perf_counter()
    from repro.core import default_ef_ladder

    ladder = default_ef_ladder(cfg.k, ef_max=cfg.ef_cap)
    gt_j = jnp.asarray(gt)
    need = np.full(len(ids), float(ladder[-1]))
    unresolved = np.ones(len(ids), bool)
    for ef in ladder:
        if not unresolved.any():
            break
        sub = np.nonzero(unresolved)[0]
        res = search(graph, jnp.asarray(lv[sub]), int(ef), cfg)
        rec = np.asarray(recall_at_k(res.ids, gt_j[sub]))
        hit = rec >= target_recall
        need[sub[hit]] = float(ef)
        unresolved[sub[hit]] = False
    dbuf, dcount = collect_distances(graph, jnp.asarray(lv), cfg, ada)
    feats = np.asarray(_runtime_features(dbuf, dcount))
    t["tdata_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    model = _fit_mlp(feats, np.log2(need), seed=seed)
    t["train_s"] = time.perf_counter() - t0

    return LaetBaseline(graph=graph, cfg=cfg, ada=ada, model=model, offline_seconds=t)


# --------------------------------------------------------------------------
# DARTH-style: periodic recall prediction during search
# --------------------------------------------------------------------------


@dataclasses.dataclass
class DarthBaseline:
    """Declarative recall via periodic predicted-recall checks.

    We reuse the LAET feature/effort model but *iteratively*: search proceeds
    in rounds of increasing ef; after each round the recall predictor (an MLP
    on current result-list statistics) estimates recall and stops when the
    prediction clears the target.  This captures DARTH's check-predict-continue
    control flow (prediction intervals) without GBDTs.
    """

    graph: DeviceGraph
    cfg: SearchConfig
    model: MLP  # predicts recall from (result stats, ef)
    offline_seconds: dict
    rounds: tuple = (1, 2, 4, 8)  # ef multipliers over k per round

    def query(self, queries, target_recall: float = 0.95) -> SearchResult:
        q = jnp.asarray(queries)
        b = q.shape[0]
        done = np.zeros(b, bool)
        out: Optional[SearchResult] = None
        total_ndist = np.zeros(b, np.int64)
        for mult in self.rounds:
            ef = min(self.cfg.k * mult, self.cfg.ef_cap)
            res = search(self.graph, q, ef, self.cfg)
            feats = _result_features(res, ef, self.cfg.k)
            pred = np.asarray(_mlp_apply(self.model, feats))
            total_ndist = np.where(done, total_ndist, total_ndist + np.asarray(res.ndist))
            if out is None:
                out = jax.tree_util.tree_map(np.asarray, res)
            else:
                upd = ~done
                out = SearchResult(
                    ids=np.where(upd[:, None], np.asarray(res.ids), out.ids),
                    dists=np.where(upd[:, None], np.asarray(res.dists), out.dists),
                    ndist=out.ndist,
                    iters=np.where(upd, np.asarray(res.iters), out.iters),
                    ef_used=np.where(upd, ef, out.ef_used),
                )
            done |= pred >= target_recall
            if done.all():
                break
        return out._replace(ndist=total_ndist)


def _result_features(res: SearchResult, ef: int, k: int) -> Array:
    d = res.dists
    return jnp.stack(
        [
            d[:, 0],
            d[:, k // 2],
            d[:, k - 1],
            jnp.mean(d, -1),
            jnp.std(d, -1),
            jnp.full((d.shape[0],), float(ef)),
            res.ndist.astype(jnp.float32),
        ],
        axis=-1,
    )


def fit_darth(
    graph: DeviceGraph,
    data: np.ndarray,
    *,
    cfg: SearchConfig,
    num_learn: int = 1000,
    beam: Optional[int] = None,
    seed: int = 0,
) -> DarthBaseline:
    if beam is not None:
        cfg = dataclasses.replace(cfg, beam=beam)
    rng = np.random.default_rng(seed)
    t = {}
    t0 = time.perf_counter()
    ids = rng.choice(len(data), size=min(num_learn, len(data)), replace=False)
    lv = data[ids]
    qs = prepare_queries(jnp.asarray(lv), cfg.metric)
    _, gt = brute_force_topk_chunked(qs, data, k=cfg.k, metric=cfg.metric)
    gt_j = jnp.asarray(gt)
    t["lvec_gt_s"] = time.perf_counter() - t0

    # training data: (result features at several ef) -> actual recall
    t0 = time.perf_counter()
    feats_all, y_all = [], []
    for mult in (1, 2, 4, 8):
        ef = min(cfg.k * mult, cfg.ef_cap)
        res = search(graph, jnp.asarray(lv), ef, cfg)
        feats_all.append(np.asarray(_result_features(res, ef, cfg.k)))
        y_all.append(np.asarray(recall_at_k(res.ids, gt_j)))
    x = np.concatenate(feats_all)
    y = np.concatenate(y_all)
    t["tdata_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    model = _fit_mlp(x, y, seed=seed)
    t["train_s"] = time.perf_counter() - t0
    return DarthBaseline(graph=graph, cfg=cfg, model=model, offline_seconds=t)
