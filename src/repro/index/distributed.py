"""Distributed (multi-device / multi-pod) vector search.

Scale-out layout (DESIGN.md §3): the database is partitioned into ``S`` shards;
each shard owns a *local* HNSW sub-index plus its own Ada-ef statistics and
ef-estimation table (the paper's machinery applied to the shard's
sub-database).  A query is broadcast to all shards, each runs adaptive-ef
search locally, and the global result is a k-way merge of per-shard top-k —
the standard layout of production vector databases (Milvus, Vespa, ES).

Two execution paths with identical math:

- :func:`retrieve_vmap`    — ``vmap`` over the stacked shard axis (single
  device; used by tests/benchmarks on CPU),
- :func:`retrieve_sharded` — ``shard_map`` over a mesh axis with one shard per
  device and an ``all_gather`` + static merge (the production path; lowered
  and compiled against the 512-device mesh in the multi-pod dry-run).

Shard statistics merge with the §6.3 formulas (`merge_stats` is associative),
so a *global* FDL model is also available for cross-shard scoring.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import DatasetStats, EfTable, merge_stats
from .distances import key_sign
from .pipeline import AdaEfIndex, build_ada_index
from .search import (
    AdaEfConfig,
    DeviceGraph,
    SearchConfig,
    SearchResult,
    adaptive_search,
)

Array = jax.Array


@dataclasses.dataclass
class ShardedAdaEfIndex:
    """Stacked per-shard arrays: leading axis = shard."""

    graph: DeviceGraph        # each leaf has leading shard axis
    stats: DatasetStats       # stacked
    table: EfTable            # stacked
    shard_offsets: Array      # (S,) global id of each shard's row 0
    shard_size: int
    num_shards: int
    k: int
    target_recall: float
    search_cfg: SearchConfig
    ada_cfg: AdaEfConfig
    global_stats: DatasetStats  # §6.3 merge of all shard stats


def build_sharded(
    data: np.ndarray,
    *,
    num_shards: int,
    k: int,
    target_recall: float = 0.95,
    **kwargs,
) -> ShardedAdaEfIndex:
    """Partition ``data`` row-round-robin-free (contiguous blocks) and build
    one AdaEfIndex per shard; stack the device arrays."""
    n = len(data) - len(data) % num_shards
    data = np.asarray(data[:n], np.float32)
    shard_size = n // num_shards
    shards: list[AdaEfIndex] = []
    for s in range(num_shards):
        block = data[s * shard_size : (s + 1) * shard_size]
        shards.append(
            build_ada_index(block, k=k, target_recall=target_recall, seed=s, **kwargs)
        )
    # shards may have different upper-level counts: pad to the max
    max_lv = max(s.graph.upper_adj.shape[0] for s in shards)
    padded = []
    for sh in shards:
        g = sh.graph
        lv = g.upper_adj.shape[0]
        if lv < max_lv:
            pad = jnp.full((max_lv - lv,) + g.upper_adj.shape[1:], -1, g.upper_adj.dtype)
            g = g._replace(upper_adj=jnp.concatenate([g.upper_adj, pad], axis=0))
        padded.append(g)
    graph = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)
    stats = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[s.stats for s in shards])
    table = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[s.table for s in shards])
    gstats = shards[0].stats
    for s in shards[1:]:
        gstats = merge_stats(gstats, s.stats)
    return ShardedAdaEfIndex(
        graph=graph,
        stats=stats,
        table=table,
        shard_offsets=jnp.arange(num_shards, dtype=jnp.int32) * shard_size,
        shard_size=shard_size,
        num_shards=num_shards,
        k=k,
        target_recall=target_recall,
        search_cfg=shards[0].search_cfg,
        ada_cfg=shards[0].ada_cfg,
        global_stats=gstats,
    )


def _merge_topk(keys: Array, gids: Array, k: int):
    """(S, B, k) per-shard results -> (B, k) global top-k."""
    s, b, kk = keys.shape
    flat_k = jnp.transpose(keys, (1, 0, 2)).reshape(b, s * kk)
    flat_i = jnp.transpose(gids, (1, 0, 2)).reshape(b, s * kk)
    neg, sel = jax.lax.top_k(-flat_k, k)
    return -neg, jnp.take_along_axis(flat_i, sel, axis=1)


@partial(jax.jit, static_argnames=("cfg", "ada", "k"))
def _retrieve_stacked(
    graph: DeviceGraph,
    stats: DatasetStats,
    table: EfTable,
    offsets: Array,
    queries: Array,
    target_recall: Array,
    cfg: SearchConfig,
    ada: AdaEfConfig,
    k: int,
) -> SearchResult:
    sign = key_sign(cfg.metric)

    def per_shard(g, st, tb, off):
        res = adaptive_search(g, queries, st, tb, target_recall, cfg, ada)
        gid = jnp.where(res.ids >= 0, res.ids + off, -1)
        key = jnp.where(res.ids >= 0, res.dists * sign, jnp.inf)
        return key, gid, res.ndist, res.ef_used

    keys, gids, ndist, efs = jax.vmap(per_shard)(graph, stats, table, offsets)
    mk, mi = _merge_topk(keys, gids, k)
    return SearchResult(
        ids=mi,
        dists=mk * sign,
        ndist=jnp.sum(ndist, axis=0),           # total work across shards
        iters=jnp.zeros_like(mi[:, 0]),
        ef_used=jnp.max(efs, axis=0),
    )


def retrieve_vmap(
    idx: ShardedAdaEfIndex, queries, target_recall: Optional[float] = None
) -> SearchResult:
    r = idx.target_recall if target_recall is None else target_recall
    return _retrieve_stacked(
        idx.graph,
        idx.stats,
        idx.table,
        idx.shard_offsets,
        jnp.asarray(queries),
        jnp.asarray(r, jnp.float32),
        idx.search_cfg,
        idx.ada_cfg,
        idx.k,
    )


# --------------------------------------------------------------------------
# shard_map production path (one shard per device along mesh axis "shard")
# --------------------------------------------------------------------------


def make_retrieve_step(mesh: Mesh, axis: str, cfg: SearchConfig, ada: AdaEfConfig, k: int):
    """Build the jitted multi-device retrieve step for the dry-run / serving.

    Inputs are the *stacked* shard arrays sharded along ``axis``; queries and
    target are replicated; output is the merged global top-k (replicated).
    """
    sign = key_sign(cfg.metric)

    def local(graph, stats, table, offsets, queries, target_recall):
        # leaves arrive with leading local shard axis of size S/devices
        def per_shard(g, st, tb, off):
            res = adaptive_search(g, queries, st, tb, target_recall, cfg, ada)
            gid = jnp.where(res.ids >= 0, res.ids + off, -1)
            key = jnp.where(res.ids >= 0, res.dists * sign, jnp.inf)
            return key, gid, res.ndist

        keys, gids, ndist = jax.vmap(per_shard)(graph, stats, table, offsets)
        keys = jax.lax.all_gather(keys, axis, axis=0, tiled=True)   # (S, B, k)
        gids = jax.lax.all_gather(gids, axis, axis=0, tiled=True)
        mk, mi = _merge_topk(keys, gids, k)
        total = jax.lax.psum(jnp.sum(ndist, axis=0), axis)
        return mk * sign, mi, total

    shard_spec = P(axis)
    from jax.experimental.shard_map import shard_map

    mapped = shard_map(
        local,
        mesh=mesh,
        in_specs=(shard_spec, shard_spec, shard_spec, shard_spec, P(), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    return jax.jit(mapped)
