"""End-to-end Ada-ef pipeline (paper Figure 2).

Offline stage:
  1. dataset-level statistics (mean vector + covariance) — §5,
  2. sample G data vectors as proxy queries + their ground truth — §6.2,
  3. build the ef-estimation table by probing the real HNSW search — §6.2.

Online stage: :func:`repro.index.search.adaptive_search` (Alg. 2).

The pipeline also implements §6.3 incremental updates: ``insert``/``delete``
update the HNSW index, merge/unmerge the statistics, refresh the sample ground
truth incrementally, and rebuild only the (cheap) ef table.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DatasetStats,
    EfTable,
    EstimatorConfig,
    build_ef_table,
    compute_stats,
    default_ef_ladder,
    estimate_fdl,
    merge_stats,
    unmerge_stats,
)
from repro.core.scoring import score_query
from repro.core.fdl import METRIC_COSINE_DIST, METRIC_COSINE_SIM
from .distances import brute_force_topk_chunked, prepare_queries
from .epochs import Epoch, EpochManager, IndexMutationError, epoch_of
from .hnsw import HNSWIndex, HNSWParams, build_index
from .search import (
    AdaEfConfig,
    DeviceGraph,
    SearchConfig,
    SearchResult,
    collect_distances,  # noqa: F401  (re-export; impl lives with the phases)
    device_graph,
    recall_at_k,
    search,
)

Array = jax.Array


@dataclasses.dataclass
class OfflineTimings:
    stats_s: float = 0.0
    sample_s: float = 0.0
    ef_table_s: float = 0.0

    @property
    def total_s(self) -> float:
        return self.stats_s + self.sample_s + self.ef_table_s


@dataclasses.dataclass
class AdaEfIndex:
    """HNSW index + the Ada-ef offline artifacts; the deployable unit."""

    host_index: HNSWIndex
    graph: DeviceGraph
    stats: DatasetStats
    table: EfTable
    k: int
    target_recall: float
    search_cfg: SearchConfig
    ada_cfg: AdaEfConfig
    sample_ids: np.ndarray          # proxy-query row ids
    sample_gt: np.ndarray           # (G, k) ground-truth ids of proxies
    timings: OfflineTimings
    raw_data: Optional[np.ndarray] = None  # kept for incremental GT refresh
    _router: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False
    )  # lazily built QueryRouter; invalidated on graph updates
    _router_cfg: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False
    )  # installed RouterConfig; survives invalidation-triggered rebuilds
    _scheduler: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False
    )  # lazily built AdaServeScheduler; invalidated alongside the router
    _scheduler_cfg: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False
    )  # installed SchedulerConfig; survives invalidation-triggered rebuilds
    _probe_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )  # {ef: per-proxy recalls} shared by main + estimation-matched table
    #   builds (the probe searches are score-independent); cleared on updates
    _qpanels: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )  # {precision: QuantizedPanel} lazily calibrated quantized panels;
    #   survives mutations (insert appends rows in place of a recalibration,
    #   tombstone deletes leave the row panel untouched)
    _qactive: Optional[str] = dataclasses.field(
        default=None, repr=False, compare=False
    )  # precision of the panel currently attached to ``graph`` (one at a
    #   time: the DeviceGraph carries a single panel)
    _attributes: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False
    )  # repro.filter.AttributeStore — per-row metadata for filtered search;
    #   attached via attach_attributes(), appended on insert, untouched by
    #   tombstone deletes (alive already hides dead rows)
    _plans: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )  # {(SearchSpec, shape-signature): ExecutionPlan}; dropped on updates
    _graph_version: int = dataclasses.field(
        default=0, repr=False, compare=False
    )  # bumped on insert/delete so held plans can detect staleness
    _epochs: Optional[EpochManager] = dataclasses.field(
        default=None, repr=False, compare=False
    )  # lazily seeded EpochManager; every mutation publishes through it

    # ------------------------------------------------------------- online API
    def plan(self, spec=None, **spec_kwargs):
        """Lower a declarative :class:`repro.api.SearchSpec` into a cached
        :class:`repro.plan.ExecutionPlan` — the one public search surface.

        Pass a spec, or its fields as keywords (``index.plan(k=10,
        target_recall=0.95, mode="streaming")``).  Plans are cached keyed by
        ``(spec, shape-signature)``: two equal specs share one plan (and its
        compiled executors).  ``insert``/``delete`` *revalidate* cached
        plans against the post-mutation epoch (strict
        ``on_mutation="strict"`` plans are dropped instead), so a plan
        handle obtained here keeps working across mutations."""
        from repro.api import SearchSpec
        from repro.plan import plan_spec, shape_signature

        if spec is None:
            spec = SearchSpec(**spec_kwargs)
        elif spec_kwargs:
            raise ValueError("pass a SearchSpec or its fields, not both")
        key = (spec, shape_signature(self))
        cached = self._plans.get(key)
        if cached is None:
            cached = self._plans[key] = plan_spec(self, spec)
        return cached

    def query(
        self, queries, target_recall: Optional[float] = None, *, routed: bool = False
    ) -> SearchResult:
        """Ada-ef search through the declarative facade.  ``routed=True``
        lowers to the ef-bucketed serving dispatch (estimate at small
        capacity, per-tier batched search) instead of the monolithic fused
        ``adaptive_search`` — both are one-line specs over :meth:`plan`."""
        from repro.api import MODE_ONESHOT, MODE_ROUTED

        plan = self.plan(mode=MODE_ROUTED if routed else MODE_ONESHOT)
        return plan.search(queries, target_recall=target_recall)

    def router(self, router_cfg=None):
        """The (cached) ef-bucketed query router for this index.  Passing a
        ``RouterConfig`` installs it: rebuilds now *and* after any
        ``insert``/``delete``-triggered invalidation, so a tuned serving
        policy survives index updates.  Routers with a lossy estimation
        budget get an estimation-matched ef table (built here, from the same
        proxies) so their score lookups see the truncation bias they will
        produce online."""
        from repro.serve.router import QueryRouter  # deferred: serve -> index

        if router_cfg is not None:
            self._router_cfg = router_cfg
            self._router = None
        if self._router is None:
            self._router = QueryRouter(
                self.graph,
                self.stats,
                self.table,
                self.search_cfg,
                self.ada_cfg,
                self._router_cfg,
                est_table_builder=self.estimation_table,
            )
        return self._router

    def scheduler(self, scheduler_cfg=None, router_cfg=None):
        """The (cached) continuous-batching scheduler over :meth:`router` —
        the request-lifecycle serving surface (``submit``/``step``/``poll``).
        Passing a ``SchedulerConfig`` (and/or ``RouterConfig``) installs it
        for this and every rebuild.  The scheduler is index-registered:
        ``insert``/``delete`` route through its mutation seam
        (:meth:`repro.serve.scheduler.AdaServeScheduler.absorb_mutation`),
        so pending requests are fenced and complete against the
        pre-mutation epoch while new submits bind the post-mutation one —
        mutating under live traffic is supported, no drain required."""
        from repro.serve.scheduler import AdaServeScheduler

        if scheduler_cfg is not None:
            self._scheduler_cfg = scheduler_cfg
            self._scheduler = None
        if router_cfg is not None:
            self.router(router_cfg)  # also clears _router -> rebuild below
            self._scheduler = None
        router = self.router()
        if self._scheduler is None or self._scheduler.router is not router:
            self._scheduler = AdaServeScheduler(
                router,
                self._scheduler_cfg,
                default_target_recall=self.target_recall,
                version_probe=lambda: self._graph_version,
                router_probe=lambda: self.router(),
            )
        return self._scheduler

    # --------------------------------------------------------------- epochs
    @property
    def epochs(self) -> EpochManager:
        """The index's epoch publication point (lazily seeded from the
        current state).  Every ``insert``/``delete`` publishes the
        post-mutation snapshot here; consumers pin an epoch by holding it."""
        if self._epochs is None:
            self._epochs = EpochManager(epoch_of(self))
        return self._epochs

    @property
    def epoch(self) -> Epoch:
        """The current epoch — what new work binds."""
        return self.epochs.current

    def query_static(self, queries, ef: int) -> SearchResult:
        return search(self.graph, jnp.asarray(queries), ef, self.search_cfg)

    # ------------------------------------------------------ attribute store
    @property
    def attributes(self):
        """The per-row :class:`repro.filter.AttributeStore` (``None`` until
        :meth:`attach_attributes`).  The planner compiles ``SearchSpec.
        filter`` predicates against it and reads its histograms for
        selectivity-aware lowering."""
        return self._attributes

    def attach_attributes(
        self, *, tenant=None, categorical=None, numeric=None
    ):
        """Attach per-row metadata columns for filtered search.

        Columns must cover every current row (tombstoned rows included —
        ``alive`` already hides them from results).  Like
        :meth:`ensure_panel`, attachment is *not* a mutation: same vectors,
        no version bump, no epoch publication.  Cached *filtered* plans are
        dropped (their selectivity estimates may change); unfiltered plans
        and their warm executors are untouched.  Subsequent ``insert``
        batches extend the store — pass their attributes through
        ``insert(..., attributes=...)`` or the new rows get never-matching
        fills.  Returns the attached store."""
        from repro.filter import AttributeStore

        n = int(self.graph.alive.shape[0])
        self._attributes = AttributeStore(
            n, tenant=tenant, categorical=categorical, numeric=numeric
        )
        self._plans = {
            key: p for key, p in self._plans.items() if key[0].filter is None
        }
        return self._attributes

    # ------------------------------------------------------- quantized panel
    def ensure_panel(self, precision: str):
        """Materialize (and attach) the quantized estimation panel.

        Lazily calibrates an int8/fp8 :class:`repro.quant.QuantizedPanel`
        over the prepared vector table, caches it per precision, and
        attaches it to ``self.graph`` — from then on every consumer that
        binds the graph (router tiers, scheduler dispatches, epochs, held
        plans) carries the panel; fp32 searches ignore it.  Calibration is
        *not* a mutation: same data, no version bump, no epoch publication
        — only the router/scheduler caches are dropped so new dispatches
        bind the panel-carrying graph.  ``fp32`` detaches.  Idempotent per
        precision.  Returns the attached panel (or ``None`` for fp32).
        """
        from repro.quant import attach_panel, calibrate_panel

        if precision == self._qactive:
            from repro.quant import panel_of

            return panel_of(self.graph)
        if precision == "fp32":
            self.graph = attach_panel(self.graph, None)
            self._qactive = None
            self._router = None
            return None
        panel = self._qpanels.get(precision)
        if panel is None:
            panel = calibrate_panel(self.graph.vectors, precision=precision)
            self._qpanels[precision] = panel
        self.graph = attach_panel(self.graph, panel)
        self._qactive = precision
        self._router = None  # next router()/scheduler() binds the new graph
        return panel

    # -------------------------------------------------------------- updates
    def _noop_mutation(self) -> dict:
        """Empty insert/delete batch: nothing changed, so no version bump,
        no cache drop, no epoch publication (held plans stay fresh)."""
        self.timings = OfflineTimings()
        return {
            "index_s": 0.0, "stats_s": 0.0, "sample_s": 0.0,
            "ef_table_s": 0.0, "noop": True,
        }

    def _mutate(self, body):
        """Run one mutation under the epoch protocol.

        Prologue: drop the reference caches that alias the pre-mutation
        arrays and bump the version.  ``body()`` rebuilds graph/stats/table.
        Epilogue: publish the post-mutation :class:`Epoch`, then rebind
        every registered consumer — held plans revalidate (strict plans are
        dropped from the cache and refuse on use), and the index scheduler
        plus every plan session absorb through the scheduler's mutation
        seam, so pending tickets complete against the pre-mutation epoch
        (its arrays stay pinned by the old router/dispatches) while new
        work binds the new one.
        """
        from repro.plan import shape_signature
        from repro.serve.api import StalePlanError

        self.epochs  # materialize the manager: the pre-mutation epoch exists
        self._router = None        # router caches graph/stats/table refs
        self._probe_cache.clear()  # probe recalls depend on graph + samples
        self._graph_version += 1   # consumers detect the epoch swap off this
        out = body()
        e = epoch_of(self)
        self._epochs.publish(
            version=e.version, graph=e.graph, stats=e.stats, table=e.table,
            n=e.n, alive_rows=e.alive_rows,
        )
        plans, self._plans = self._plans, {}
        sig = shape_signature(self)
        for (spec, _old_sig), plan in plans.items():
            try:
                plan.revalidate()
            except StalePlanError:
                continue  # strict plan: dropped here; held refs keep raising
            self._plans[(spec, sig)] = plan
        if self._scheduler is not None:
            self._scheduler.absorb_mutation(router=self.router())
        return out

    def insert(
        self,
        new_data: np.ndarray,
        *,
        refresh_table: bool = True,
        attributes: Optional[dict] = None,
    ):
        """§6.3 insertion: index add + stats merge + incremental GT + table.

        Structurally invalid batches (wrong dimensionality, NaN/Inf rows)
        raise :class:`IndexMutationError` before any state is touched; an
        empty batch is a version-preserving no-op.  Under live consumers
        (plans, schedulers) the mutation is absorbed through the epoch
        protocol — see :meth:`_mutate`.

        ``attributes`` carries the inserted rows' metadata when an
        :class:`repro.filter.AttributeStore` is attached — a dict with any
        of ``tenant`` (sequence), ``categorical`` (name -> sequence),
        ``numeric`` (name -> sequence).  Columns left out get
        never-matching fills, so unattributed rows fail predicates instead
        of silently passing them."""
        new_data = np.atleast_2d(np.asarray(new_data, np.float32))
        if new_data.size == 0:
            return self._noop_mutation()
        dim = self.raw_data.shape[1]
        if new_data.ndim != 2 or new_data.shape[1] != dim:
            raise IndexMutationError(
                f"insert: expected (m, {dim}) rows, got {new_data.shape}"
            )
        if not np.isfinite(new_data).all():
            raise IndexMutationError("insert: rows contain NaN/Inf values")
        if attributes and self._attributes is None:
            raise IndexMutationError(
                "insert: attributes passed but no AttributeStore is "
                "attached; call attach_attributes(...) first"
            )
        return self._mutate(
            lambda: self._insert_body(new_data, refresh_table, attributes)
        )

    def _refresh_panels(self, inserted_from: Optional[int] = None):
        """Carry the quantized panels across a mutation.

        ``inserted_from`` = row count before an insert: each cached panel
        gets the appended (prepared) rows quantized under its frozen
        calibration — append-exact per-row scales, no recalibration of the
        resident codes (see :func:`repro.quant.append_rows`).  Tombstone
        deletes pass ``None``: the row panel is untouched (rows stay
        resident; ``g.alive`` masks them at admission).  Either way the
        active panel is re-attached to the freshly rebuilt graph so the
        post-mutation epoch snapshot carries it."""
        if not self._qpanels:
            return
        from repro.quant import append_rows, attach_panel

        if inserted_from is not None:
            new_rows = self.graph.vectors[inserted_from:]
            self._qpanels = {
                p: append_rows(panel, new_rows)
                for p, panel in self._qpanels.items()
            }
        if self._qactive is not None:
            self.graph = attach_panel(self.graph, self._qpanels[self._qactive])

    def _insert_body(
        self,
        new_data: np.ndarray,
        refresh_table: bool,
        attributes: Optional[dict] = None,
    ) -> dict:
        t0 = time.perf_counter()
        old_n = int(self.host_index.n)
        self.host_index.add(new_data)
        self.graph = device_graph(self.host_index.freeze())
        self._refresh_panels(inserted_from=old_n)
        if self._attributes is not None:
            attrs = attributes or {}
            self._attributes.append(
                len(new_data),
                tenant=attrs.get("tenant"),
                categorical=attrs.get("categorical"),
                numeric=attrs.get("numeric"),
            )
        t_index = time.perf_counter() - t0

        t0 = time.perf_counter()
        normalize = self.search_cfg.metric in (METRIC_COSINE_DIST, METRIC_COSINE_SIM)
        new_stats = compute_stats(
            jnp.asarray(new_data), mode=self.stats.mode, normalize=normalize
        )
        self.stats = merge_stats(self.stats, new_stats)
        t_stats = time.perf_counter() - t0

        t0 = time.perf_counter()
        # incremental GT: distances of proxies to ONLY the new rows (paper §6.3)
        qs = prepare_queries(jnp.asarray(self.raw_data[self.sample_ids]), self.search_cfg.metric)
        nd, ni = brute_force_topk_chunked(
            qs, new_data, k=min(self.k, len(new_data)), metric=self.search_cfg.metric
        )
        base_n = len(self.raw_data)
        self.raw_data = np.concatenate([self.raw_data, new_data], axis=0)
        self._merge_gt(nd, ni + base_n)
        t_sample = time.perf_counter() - t0

        t_table = 0.0
        if refresh_table:
            t0 = time.perf_counter()
            self._rebuild_table()
            t_table = time.perf_counter() - t0
        self.timings = OfflineTimings(t_stats, t_sample, t_table)
        return {"index_s": t_index, "stats_s": t_stats, "sample_s": t_sample, "ef_table_s": t_table}

    def delete(self, ids: np.ndarray, *, refresh_table: bool = True):
        """§6.3 deletion: tombstone + stats unmerge + GT refresh + table.

        Validated before any state is touched (:class:`IndexMutationError`):
        ids must be in range and not already tombstoned (a second stats
        unmerge would corrupt the dataset statistics), and the deletion must
        leave at least ``k`` alive rows (otherwise no valid top-k ground
        truth remains for the estimation proxies).  Duplicated ids within
        one batch are collapsed.  Deleting the HNSW entry point is *legal*:
        search masks dead nodes at entry and expansion (``g.alive``), so a
        tombstoned entry still routes but never surfaces as a result.  If
        every proxy query is deleted, fresh proxies are resampled from the
        survivors.  An empty batch is a version-preserving no-op."""
        ids = np.asarray(ids, np.int64).ravel()
        if ids.size == 0:
            return self._noop_mutation()
        n = int(self.host_index.n)
        oob = ids[(ids < 0) | (ids >= n)]
        if oob.size:
            raise IndexMutationError(
                f"delete: ids out of range [0, {n}): "
                f"{np.unique(oob)[:8].tolist()}"
            )
        ids = np.unique(ids)
        already = ids[~self.host_index.alive[ids]]
        if already.size:
            raise IndexMutationError(
                f"delete: ids already tombstoned: {already[:8].tolist()} "
                "(a second stats unmerge would corrupt the dataset "
                "statistics)"
            )
        alive_after = int(self.host_index.alive[:n].sum()) - len(ids)
        if alive_after < self.k:
            raise IndexMutationError(
                f"delete: {len(ids)} deletion(s) would leave {alive_after} "
                f"alive rows < k={self.k} — no valid top-{self.k} ground "
                "truth would remain for the estimation proxies"
            )
        return self._mutate(lambda: self._delete_body(ids, refresh_table))

    def _delete_body(self, ids: np.ndarray, refresh_table: bool) -> dict:
        t0 = time.perf_counter()
        self.host_index.mark_deleted(ids)
        self.graph = device_graph(self.host_index.freeze())
        self._refresh_panels()
        t_index = time.perf_counter() - t0

        t0 = time.perf_counter()
        normalize = self.search_cfg.metric in (METRIC_COSINE_DIST, METRIC_COSINE_SIM)
        del_stats = compute_stats(
            jnp.asarray(self.raw_data[ids]), mode=self.stats.mode, normalize=normalize
        )
        self.stats = unmerge_stats(self.stats, del_stats)
        t_stats = time.perf_counter() - t0

        t0 = time.perf_counter()
        # drop deleted proxies; refresh GT rows that contained deleted ids.
        # The authoritative mask (host_index.alive) also excludes rows
        # tombstoned by *earlier* deletes, so a refreshed ground truth can
        # never resurrect them.
        alive_mask = self.host_index.alive[: self.host_index.n].copy()
        keep = alive_mask[self.sample_ids]
        self.sample_ids = self.sample_ids[keep]
        self.sample_gt = self.sample_gt[keep]
        alive_rows = np.nonzero(alive_mask)[0]
        if len(self.sample_ids) == 0:
            # every proxy was tombstoned: resample from the survivors so
            # the estimation path stays serviceable (the alive-row floor in
            # delete() guarantees a valid top-k ground truth exists)
            rng = np.random.default_rng(self._graph_version)
            g = min(max(len(keep), 1), len(alive_rows))
            self.sample_ids = np.sort(
                rng.choice(alive_rows, size=g, replace=False)
            )
            qs = prepare_queries(
                jnp.asarray(self.raw_data[self.sample_ids]), self.search_cfg.metric
            )
            _, gi = brute_force_topk_chunked(
                qs, self.raw_data[alive_rows], k=self.k, metric=self.search_cfg.metric
            )
            self.sample_gt = alive_rows[gi]
        else:
            dirty = ~alive_mask[self.sample_gt].all(axis=1)
            if dirty.any():
                qs = prepare_queries(
                    jnp.asarray(self.raw_data[self.sample_ids[dirty]]), self.search_cfg.metric
                )
                _, gi = brute_force_topk_chunked(
                    qs, self.raw_data[alive_rows], k=self.k, metric=self.search_cfg.metric
                )
                self.sample_gt[dirty] = alive_rows[gi]
        t_sample = time.perf_counter() - t0

        t_table = 0.0
        if refresh_table:
            t0 = time.perf_counter()
            self._rebuild_table()
            t_table = time.perf_counter() - t0
        self.timings = OfflineTimings(t_stats, t_sample, t_table)
        return {"index_s": t_index, "stats_s": t_stats, "sample_s": t_sample, "ef_table_s": t_table}

    # -------------------------------------------------------------- internals
    def _merge_gt(self, new_d: np.ndarray, new_i: np.ndarray):
        """Merge top-k over the new rows into the stored proxy ground truth."""
        from .distances import gathered, prepare_database

        qs = prepare_queries(jnp.asarray(self.raw_data[self.sample_ids]), self.search_cfg.metric)
        vp = prepare_database(jnp.asarray(self.raw_data), self.search_cfg.metric)
        old_d = np.asarray(
            gathered(qs, vp, jnp.asarray(self.sample_gt), metric=self.search_cfg.metric)
        )
        cat_d = np.concatenate([old_d, new_d], axis=1)
        cat_i = np.concatenate([self.sample_gt, new_i], axis=1)
        from .distances import key_sign

        order = np.argsort(cat_d * key_sign(self.search_cfg.metric), axis=1)[:, : self.k]
        self.sample_gt = np.take_along_axis(cat_i, order, axis=1)

    def _proxy_scores(
        self,
        cfg: Optional[SearchConfig] = None,
        ada: Optional[AdaEfConfig] = None,
    ) -> np.ndarray:
        """Quantile-bin scores of the sample proxies, collecting distances
        under ``cfg``/``ada`` (defaults: the index's own full-budget search)."""
        cfg = cfg if cfg is not None else self.search_cfg
        ada = ada if ada is not None else self.ada_cfg
        qs = jnp.asarray(self.raw_data[self.sample_ids])
        dbuf, dcount = collect_distances(self.graph, qs, cfg, ada)
        qs_p = prepare_queries(qs, cfg.metric)
        params = estimate_fdl(self.stats, qs_p, metric=ada.estimator.metric)
        valid = jnp.arange(dbuf.shape[1])[None, :] < dcount[:, None]
        scores = score_query(
            params,
            dbuf,
            valid=valid,
            m=ada.estimator.m,
            delta=ada.estimator.delta,
            metric=ada.estimator.metric,
            decay=ada.estimator.decay,
        )
        return np.asarray(scores)

    def _recall_probe(self, precision: str = "fp32"):
        """``(ef, subset) -> recalls`` closure for :func:`build_ef_table` —
        always probes the *full-budget* search: the score axis is what an
        estimation-matched table changes, not the ef/recall relationship.

        Probes the whole sample batch per ef and memoizes it in
        ``_probe_cache`` keyed ``(ef, precision)``: the adaptive ladder
        would otherwise recompile the vmapped search per shrinking subset
        shape (so the original already padded every probe to the full batch
        — same device work), and per-proxy recall at a given ef is
        subset-independent, so the main table build and any
        estimation-matched builds for lossy routers share one set of
        searches instead of each paying the full ladder.  A non-fp32
        ``precision`` probes the quantized search (panel traversal + fp32
        re-rank) so a quantized router's table reflects the ef->recall
        curve it will actually serve; quantized and fp32 builds coexist in
        the one cache."""
        qs = jnp.asarray(self.raw_data[self.sample_ids])
        gt = jnp.asarray(self.sample_gt)
        cfg = (
            self.search_cfg
            if precision == "fp32"
            else dataclasses.replace(self.search_cfg, precision=precision)
        )

        def recall_at_ef(ef: int, subset: np.ndarray) -> np.ndarray:
            key = (int(ef), precision)
            if key not in self._probe_cache:
                res = search(self.graph, qs, int(ef), cfg)
                self._probe_cache[key] = np.asarray(recall_at_k(res.ids, gt))
            return self._probe_cache[key][subset]

        return recall_at_ef

    def estimation_table(
        self, est_cfg: SearchConfig, est_ada: AdaEfConfig
    ) -> EfTable:
        """EfTable whose proxy *scores* are collected at a router's (possibly
        truncated) estimation budget (ROADMAP: estimation-matched ef table).

        ``RouterConfig.est_lmax``/``est_cap`` truncate the online distance
        collection, which skews scores toward "easy" relative to the main
        table's full 2-hop collections; scoring the proxies through the same
        truncated ``est_cfg``/``est_ada`` puts the table's score axis in the
        router's units, so ``ef_margin`` no longer has to compensate for the
        bias.  Recall probing keeps the full search budget (the search
        itself is not lossy) but inherits the router's scoring precision,
        sharing the memoized probes with every same-precision build.
        """
        scores = self._proxy_scores(cfg=est_cfg, ada=est_ada)
        return build_ef_table(
            scores,
            self._recall_probe(est_cfg.precision),
            target_recall=self.target_recall,
            ef_ladder=default_ef_ladder(self.k, ef_max=self.search_cfg.ef_cap),
        )

    def _rebuild_table(self):
        self.table = build_ef_table(
            self._proxy_scores(),
            self._recall_probe(),
            target_recall=self.target_recall,
            ef_ladder=default_ef_ladder(self.k, ef_max=self.search_cfg.ef_cap),
        )


def build_ada_index(
    data: np.ndarray,
    *,
    k: int,
    target_recall: float = 0.95,
    metric: str = METRIC_COSINE_DIST,
    m: int = 16,
    ef_construction: int = 200,
    ef_cap: int = 600,
    num_samples: int = 200,
    cov_mode: str = "full",
    beam: int = 1,
    use_distance_kernel: bool = False,
    batch_hoisted: bool = False,
    ada_cfg: Optional[AdaEfConfig] = None,
    host_index: Optional[HNSWIndex] = None,
    seed: int = 0,
) -> AdaEfIndex:
    """Offline stage of Figure 2; returns the deployable AdaEfIndex.

    ``beam`` widens the online base-layer expansion (candidates popped per
    loop iteration); ``use_distance_kernel`` routes frontier scoring through
    the fused Pallas kernel; ``batch_hoisted`` replaces the per-query
    ``vmap(while_loop)`` with the single batched loop (cross-query frontier
    contraction).  All three thread into every search this index runs
    (online queries, ef-table probing, proxy distance collection).
    """
    data = np.asarray(data, np.float32)
    if host_index is None:
        host_index = build_index(
            data, m=m, ef_construction=ef_construction, metric=metric, seed=seed
        )
    graph = device_graph(host_index.freeze())
    cfg = SearchConfig(
        k=k, ef_cap=ef_cap, metric=metric, beam=beam,
        use_distance_kernel=use_distance_kernel, batch_hoisted=batch_hoisted,
    )
    ada = ada_cfg or AdaEfConfig(estimator=EstimatorConfig(metric=metric))

    # (i) dataset statistics
    t0 = time.perf_counter()
    normalize = metric in (METRIC_COSINE_DIST, METRIC_COSINE_SIM)
    stats = compute_stats(jnp.asarray(data), mode=cov_mode, normalize=normalize)
    jax.block_until_ready(stats.mean)
    t_stats = time.perf_counter() - t0

    # (ii) sample proxies + ground truth
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    sample_ids = rng.choice(len(data), size=min(num_samples, len(data)), replace=False)
    qs = prepare_queries(jnp.asarray(data[sample_ids]), metric)
    _, gt = brute_force_topk_chunked(qs, data, k=k, metric=metric)
    t_sample = time.perf_counter() - t0

    out = AdaEfIndex(
        host_index=host_index,
        graph=graph,
        stats=stats,
        table=None,  # built below
        k=k,
        target_recall=target_recall,
        search_cfg=cfg,
        ada_cfg=ada,
        sample_ids=sample_ids,
        sample_gt=gt,
        timings=OfflineTimings(),
        raw_data=data,
    )

    # (iii) ef-estimation table
    t0 = time.perf_counter()
    out._rebuild_table()
    t_table = time.perf_counter() - t0
    out.timings = OfflineTimings(t_stats, t_sample, t_table)
    return out
