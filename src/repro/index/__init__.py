"""ANN substrate: HNSW construction, batched JAX search, Ada-ef pipeline."""
from .distances import (  # noqa: F401
    brute_force_topk,
    brute_force_topk_chunked,
    gathered,
    key_sign,
    pairwise,
    prepare_database,
    prepare_queries,
)
from .hnsw import HNSWGraph, HNSWIndex, HNSWParams, build_index  # noqa: F401
from .search import (  # noqa: F401
    AdaEfConfig,
    DeviceGraph,
    SearchConfig,
    SearchResult,
    adaptive_search,
    auto_beam,
    device_graph,
    estimate_pass,
    estimation_config,
    recall_at_k,
    resume_at_ef,
    search,
    resize_state,
)
from .epochs import (  # noqa: F401
    Epoch,
    EpochManager,
    IndexMutationError,
    epoch_of,
)
from .pipeline import AdaEfIndex, build_ada_index, collect_distances  # noqa: F401
from .baselines import DarthBaseline, LaetBaseline, fit_darth, fit_laet  # noqa: F401
from .distributed import (  # noqa: F401
    ShardedAdaEfIndex,
    build_sharded,
    make_retrieve_step,
    retrieve_vmap,
)
