"""HNSW index construction (Malkov & Yashunin) — host-side offline job.

The paper treats index construction as given (it operates on *pre-built*
indexes; §3 "we consider scenarios where an HNSW index has already been
constructed").  We implement the reference construction algorithm in numpy —
random geometric levels, efConstruction best-first insertion, and the
select-neighbors *heuristic* with keepPrunedConnections — and export the graph
as flat, static-shape arrays that the JAX/TPU search consumes:

    base_adj  : (n, M0)          int32, -1 padded   (level-0 adjacency, M0 = 2M)
    upper_adj : (L, n, M)        int32, -1 padded   (levels 1..L)
    levels    : (n,)             int32              (node's top level)
    entry     : ()               int32
    vectors   : (n, d)           float32            (prepared: normalized for cosine)

Supports incremental ``add`` (used by the §7.5 update benchmarks) and soft
``delete`` via a tombstone mask (HNSWlib has no in-place delete either; the
paper rebuilds — we benchmark both paths).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

import numpy as np

from repro.core.fdl import METRIC_COSINE_DIST
from .distances import key_sign

Array = np.ndarray


@dataclasses.dataclass
class HNSWParams:
    m: int = 16                 # max outgoing degree, upper layers
    ef_construction: int = 200
    metric: str = METRIC_COSINE_DIST
    seed: int = 0
    keep_pruned: bool = True

    @property
    def m0(self) -> int:        # base-layer max degree (hnswlib: 2M)
        return 2 * self.m


class HNSWIndex:
    """Mutable host-side index.  ``freeze()`` exports JAX-ready arrays."""

    def __init__(self, dim: int, params: Optional[HNSWParams] = None, capacity: int = 1024):
        self.p = params or HNSWParams()
        self.dim = dim
        self.rng = np.random.default_rng(self.p.seed)
        self.ml = 1.0 / np.log(self.p.m)
        self.sign = key_sign(self.p.metric)
        self.vectors = np.zeros((capacity, dim), np.float32)
        self.n = 0
        self.levels = np.zeros((capacity,), np.int32)
        self.alive = np.zeros((capacity,), bool)
        # adjacency per level: level 0 has degree M0, others M.
        self.neighbors: List[List[np.ndarray]] = []  # neighbors[node][level] -> int32 ids
        self.entry = -1
        self.max_level = -1

    # ------------------------------------------------------------------ utils
    def _grow(self, need: int):
        cap = self.vectors.shape[0]
        if need <= cap:
            return
        new_cap = max(need, cap * 2)
        self.vectors = np.resize(self.vectors, (new_cap, self.dim))
        self.levels = np.resize(self.levels, (new_cap,))
        self.alive = np.resize(self.alive, (new_cap,))
        self.alive[self.n:] = False

    def _dist(self, q: Array, ids: Array) -> Array:
        """Keys (smaller = better) from q to rows ids."""
        sims = self.vectors[ids] @ q
        if self.p.metric == METRIC_COSINE_DIST:
            return 1.0 - sims
        return -sims  # similarity -> key

    def _prepare(self, x: Array) -> Array:
        x = np.asarray(x, np.float32)
        if self.p.metric == METRIC_COSINE_DIST or self.p.metric == "cos_sim":
            nrm = np.linalg.norm(x, axis=-1, keepdims=True)
            x = x / np.maximum(nrm, 1e-12)
        return x

    # ----------------------------------------------------------- search layer
    def _search_layer(self, q: Array, eps: List[int], ef: int, level: int):
        """Best-first search on one layer; returns [(key, id)] sorted ascending."""
        visited = set(eps)
        ep_keys = self._dist(q, np.asarray(eps, np.int64))
        cand = [(float(k), e) for k, e in zip(ep_keys, eps)]
        heapq.heapify(cand)
        res = [(-float(k), e) for k, e in zip(ep_keys, eps)]  # max-heap by key
        heapq.heapify(res)
        while cand:
            ck, c = heapq.heappop(cand)
            fk = -res[0][0]
            if ck > fk and len(res) >= ef:
                break
            nbrs = self.neighbors[c][level] if level < len(self.neighbors[c]) else None
            if nbrs is None or len(nbrs) == 0:
                continue
            new = [int(x) for x in nbrs if int(x) not in visited]
            if not new:
                continue
            visited.update(new)
            keys = self._dist(q, np.asarray(new, np.int64))
            for nk, nid in zip(keys, new):
                nk = float(nk)
                if len(res) < ef or nk < -res[0][0]:
                    heapq.heappush(cand, (nk, nid))
                    heapq.heappush(res, (-nk, nid))
                    if len(res) > ef:
                        heapq.heappop(res)
        out = sorted(((-nk, nid) for nk, nid in res))
        return out

    # ----------------------------------------------- select neighbors (Alg 4)
    def _select_heuristic(self, cand: List, m: int):
        """HNSW Algorithm 4 with keepPrunedConnections."""
        cand = sorted(cand)  # by key ascending
        selected: List[int] = []
        discarded: List = []
        for key, cid in cand:
            if len(selected) >= m:
                break
            ok = True
            if selected:
                d_to_sel = self._dist(self.vectors[cid], np.asarray(selected, np.int64))
                if np.any(d_to_sel < key):
                    ok = False
            if ok:
                selected.append(cid)
            else:
                discarded.append((key, cid))
        if self.p.keep_pruned:
            for key, cid in discarded:
                if len(selected) >= m:
                    break
                selected.append(cid)
        return selected

    # ------------------------------------------------------------------- add
    def add(self, data: Array):
        """Insert a batch of raw vectors (rows)."""
        data = self._prepare(np.atleast_2d(data))
        for row in data:
            self._insert(row)

    def _insert(self, q: Array):
        self._grow(self.n + 1)
        idx = self.n
        self.n += 1
        self.vectors[idx] = q
        self.alive[idx] = True
        lvl = int(-np.log(max(self.rng.random(), 1e-12)) * self.ml)
        self.levels[idx] = lvl
        self.neighbors.append([np.empty(0, np.int32) for _ in range(lvl + 1)])

        if self.entry < 0:
            self.entry = idx
            self.max_level = lvl
            return

        ep = [self.entry]
        # zoom down through levels above lvl
        for level in range(self.max_level, lvl, -1):
            res = self._search_layer(q, ep, 1, level)
            ep = [res[0][1]]
        # insert at each level from min(lvl, max_level) down to 0
        for level in range(min(lvl, self.max_level), -1, -1):
            res = self._search_layer(q, ep, self.p.ef_construction, level)
            m_l = self.p.m0 if level == 0 else self.p.m
            selected = self._select_heuristic(res, self.p.m)
            self.neighbors[idx][level] = np.asarray(selected, np.int32)
            # bidirectional edges + shrink
            for s in selected:
                cur = self.neighbors[s][level]
                cur = np.append(cur, idx).astype(np.int32)
                if len(cur) > m_l:
                    keys = self._dist(self.vectors[s], cur.astype(np.int64))
                    cur = np.asarray(
                        self._select_heuristic(list(zip(keys.tolist(), cur.tolist())), m_l),
                        np.int32,
                    )
                self.neighbors[s][level] = cur
            ep = [r[1] for r in res]
        if lvl > self.max_level:
            self.max_level = lvl
            self.entry = idx

    # ---------------------------------------------------------------- delete
    def mark_deleted(self, ids):
        """Tombstone delete (search filters dead results; graph keeps routing)."""
        self.alive[np.asarray(ids, np.int64)] = False

    # ---------------------------------------------------------------- export
    def freeze(self) -> "HNSWGraph":
        n = self.n
        m0, m = self.p.m0, self.p.m
        nlv = max(self.max_level, 0)
        base = np.full((n, m0), -1, np.int32)
        upper = np.full((nlv, n, m), -1, np.int32)
        for i in range(n):
            lv = self.neighbors[i]
            b = lv[0][:m0]
            base[i, : len(b)] = b
            for l in range(1, min(len(lv), nlv + 1)):
                u = lv[l][:m]
                upper[l - 1, i, : len(u)] = u
        return HNSWGraph(
            base_adj=base,
            upper_adj=upper,
            levels=self.levels[:n].copy(),
            entry=np.int32(self.entry),
            vectors=self.vectors[:n].copy(),
            alive=self.alive[:n].copy(),
            metric=self.p.metric,
            m=self.p.m,
        )


@dataclasses.dataclass
class HNSWGraph:
    """Frozen, array-only graph (host numpy; move to device via jnp.asarray)."""

    base_adj: Array    # (n, M0)
    upper_adj: Array   # (L, n, M)
    levels: Array      # (n,)
    entry: Array       # ()
    vectors: Array     # (n, d) prepared
    alive: Array       # (n,) bool
    metric: str
    m: int

    @property
    def n(self) -> int:
        return self.base_adj.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def num_upper_levels(self) -> int:
        return self.upper_adj.shape[0]

    def nbytes(self) -> int:
        return int(
            self.base_adj.nbytes
            + self.upper_adj.nbytes
            + self.levels.nbytes
            + self.vectors.nbytes
            + self.alive.nbytes
        )


def build_index(
    data: Array,
    *,
    m: int = 16,
    ef_construction: int = 200,
    metric: str = METRIC_COSINE_DIST,
    seed: int = 0,
) -> HNSWIndex:
    data = np.asarray(data, np.float32)
    idx = HNSWIndex(
        data.shape[1],
        HNSWParams(m=m, ef_construction=ef_construction, metric=metric, seed=seed),
        capacity=len(data),
    )
    idx.add(data)
    return idx
