"""Batched HNSW search in JAX (static shapes, `lax.while_loop`).

TPU adaptation of HNSWlib's pointer-chasing best-first search:

- candidate heap ``C`` and result heap ``W`` are fixed-capacity *sorted arrays*
  of (key, id) pairs (key = ``key_sign(metric) * value`` so smaller = better),
- the visited set is a per-query bitmask with a spare slot for padded writes,
- one loop iteration pops the top-``beam`` unexpanded candidates, gathers
  their ``beam x M0`` adjacency rows, and scores the whole deduplicated
  frontier as **one** ``(beam * M0, d)`` contraction (optionally routed through
  the fused Pallas frontier kernel via ``SearchConfig.use_distance_kernel``),
- new entries merge into ``C``/``W`` with a *partial bitonic merge* (sort the
  frontier, one bitonic split against the sorted run, log2(cap) merge stages)
  instead of re-sorting the full ``2 x ef_cap`` concatenation,
- queries batch via ``vmap`` (JAX's while-loop batching rule applies per-element
  masking, so early-finishing queries stop updating their state) — or, with
  ``SearchConfig.batch_hoisted``, via a hand-hoisted batched loop (below).

Batch-hoisted loop (``SearchConfig.batch_hoisted``): the per-query
``vmap(while_loop)`` lowers to a single loop whose body runs every op batched
and then ``select``s the *entire* carried state per element — every iteration
copies each query's ``(n+1,)`` visited bitmap through a select, and the MXU
sees B tiny per-query frontier matvecs.  The hoisted loop runs the same
algorithm as one explicit ``lax.while_loop`` over the batched state with a
per-query ``done`` mask, but commits updates through *masked writes* instead
of whole-state selects: finished queries' frontier slots emit ``-1`` ids (so
their rows are compacted away and never admitted anywhere), their visited
writes land on the spare slot, their W merge is a value-level no-op (all-+inf
incoming keys leave a sorted run bit-identical), and only the C pop-shift and
the scalar counters need an explicit ``where``.  Frontier scoring can then
contract the whole batch's compacted ``(B*F, d)`` row panel against the query
block as one cross-query MXU matmul (``ops.frontier_keys_batch``, fused
Pallas kernel with owner-select epilogue and done-tile skipping) instead of B
matvecs, and the partial bitonic merge runs once over the ``(B, cap)`` panel.
Per-query state trajectories are identical to the vmap path, so results match
bit-for-bit on tie-free keys; the vmap path stays as the golden oracle.

Beam-batched expansion (``SearchConfig.beam``): sequential best-first pops one
candidate, merges, and only then chooses the next pop, so each pop sees the
tightest possible bound.  Multi-pop expands the current top-``beam`` in one
iteration — candidates ranked 2..beam may be ones sequential search would have
skipped after the bound tightened, so the beam *slightly over-expands* (a few
extra distance computations, ``ndist`` grows modestly with beam).  Recall is
preserved because over-expansion only ever *adds* scored nodes: every node the
sequential search admits into ``W`` is also scored and admitted by the beamed
search (admission uses the same ``W[ef_dyn - 1]`` bound, which is only looser
at pop time), and extra nodes can only displace worse ones.  In exchange, the
loop runs ~beam x fewer iterations, each one a wider MXU-friendly contraction
— the hardware-utilization trade CAGRA-style GPU/TPU graph ANN makes.
``beam=1`` reproduces the single-pop search bit-for-bit on tie-free keys
(exactly-equal float32 keys — e.g. duplicate vectors — may order differently
across the cutoff: the partial bitonic merge is not tie-stable the way the
old full stable sort was; the surviving key multiset, and hence recall, is
identical either way).

Termination policies:
- static ef (standard HNSW; also with PiP patience early-termination),
- **Ada-ef** (paper Alg. 2): phase A collects the first ``l`` distances with
  ef = inf, calls ESTIMATE-EF once, phase B continues with the estimated ef.
  The phases are also exposed as separately jittable entry points —
  :func:`estimate_pass` (phase A + ESTIMATE-EF at a reduced
  :func:`estimation_config` capacity) and :func:`resume_at_ef` (phase B over
  a carried, :func:`resize_state`-fitted ``SearchState``) — which is what the
  serving router (``repro.serve.router``) dispatches per ef tier;
  :func:`adaptive_search` is their fused full-capacity composition.

The dynamic ef trick: capacities are static (``ef_cap``) while the *effective*
ef is a runtime int32 — every bound reads ``W[ef_dyn - 1]`` with a dynamic
index, which is exactly "truncate W to ef" semantics for the search control.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DatasetStats, EfTable, EstimatorConfig, estimate_ef
from repro.core.fdl import METRIC_COSINE_DIST
from repro.kernels import ops
from .distances import key_sign, prepare_queries
from .hnsw import HNSWGraph

Array = jax.Array
INF = jnp.inf


class DeviceGraph(NamedTuple):
    base_adj: Array   # (n, M0) int32, -1 pad
    upper_adj: Array  # (L, n, M) int32, -1 pad
    entry: Array      # () int32
    vectors: Array    # (n, d) float32 prepared
    alive: Array      # (n,) bool
    # Optional quantized panel (repro.quant.attach_panel): int8/fp8 codes +
    # scales for the estimation tier.  None fields are empty pytree nodes, so
    # a panel-free graph jits exactly as before.
    qcodes: Optional[Array] = None      # (n, d) int8 / fp8 codes
    qrow_scale: Optional[Array] = None  # (n,) float32 per-row scale
    qdim_scale: Optional[Array] = None  # (d,) float32 per-dim scale
    qzero: Optional[Array] = None       # (d,) float32 per-dim zero-point
    # Optional predicate validity mask (repro.filter): True = row passes the
    # query's FilterSpec.  Composes with ``alive`` exactly like tombstones —
    # masked-out rows stay traversable (C) but never surface in results (W)
    # under ``SearchConfig.filter_mode == "pre"``; under ``"post"`` the
    # traversal ignores it and a heap epilogue drops failing rows.  None (the
    # default) is an empty pytree node, so unfiltered graphs jit unchanged.
    fmask: Optional[Array] = None       # (n,) bool predicate validity


def device_graph(g: HNSWGraph) -> DeviceGraph:
    return DeviceGraph(
        base_adj=jnp.asarray(g.base_adj),
        upper_adj=jnp.asarray(g.upper_adj),
        entry=jnp.asarray(g.entry, jnp.int32),
        vectors=jnp.asarray(g.vectors, jnp.float32),
        alive=jnp.asarray(g.alive),
    )


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    k: int
    ef_cap: int                   # static W/C capacity (>= any runtime ef)
    metric: str = METRIC_COSINE_DIST
    max_iters: int = 0            # 0 -> auto (4 * ef_cap + 64)
    patience: int = 0             # >0 enables PiP early termination
    beam: int = 1                 # candidates popped + expanded per iteration
    use_distance_kernel: bool = False  # route frontier scoring through Pallas
    batch_hoisted: bool = False   # single batched loop instead of vmap(while)
    precision: str = "fp32"       # estimation/frontier scoring: fp32|int8|fp8
    #   (non-fp32 requires a graph with an attached quantized panel and adds
    #    an fp32 re-rank of the final ef candidates before top-k emission)
    filter_mode: str = "off"      # predicate lowering: off|pre|post
    #   "pre"  - g.fmask joins the W admission mask (tombstone semantics:
    #            failing rows traverse but never surface); "post" - traversal
    #            runs unfiltered and a heap epilogue drops failing rows (the
    #            planner overqueries ef to compensate).  Requires g.fmask.

    def iters(self) -> int:
        return self.max_iters if self.max_iters > 0 else 4 * self.ef_cap + 64

    def __post_init__(self):
        if self.k > self.ef_cap:
            raise ValueError(f"k={self.k} > ef_cap={self.ef_cap}")
        if not 1 <= self.beam <= self.ef_cap:
            raise ValueError(f"beam={self.beam} not in [1, ef_cap={self.ef_cap}]")
        if self.precision not in ("fp32", "int8", "fp8"):
            raise ValueError(f"unknown precision {self.precision!r}")
        if self.filter_mode not in ("off", "pre", "post"):
            raise ValueError(f"unknown filter_mode {self.filter_mode!r}")


def auto_beam(ef: int, max_beam: int = 8) -> int:
    """Beam width from an ef (estimate): small ef -> 1, large ef -> wide beam.

    Power-of-two thresholds tuned from the BENCH_online beam sweep: a wider
    beam trades a few percent extra distance computations for ~beam x fewer
    loop iterations, which only pays off once the search runs long enough
    (large ef) to amortize the over-expansion.  Beam over-expansion never
    loses recall (see the module docstring), so this is latency tuning only.
    """
    ef = int(ef)
    if ef < 64:
        beam = 1
    elif ef < 128:
        beam = 2
    elif ef < 256:
        beam = 4
    else:
        beam = 8
    return max(1, min(beam, int(max_beam)))


class SearchState(NamedTuple):
    ck: Array        # (C,) candidate keys, sorted ascending, +inf empty
    ci: Array        # (C,) candidate ids
    rk: Array        # (W,) result keys, sorted ascending, +inf empty
    ri: Array        # (W,) result ids
    visited: Array   # (n+1,) bool
    ef_dyn: Array    # () int32 effective ef
    ndist: Array     # () int32 distance computations so far
    iters: Array     # () int32
    dbuf: Array      # (lmax,) collected raw distances (metric orientation)
    dcount: Array    # () int32 number collected
    lgoal: Array     # () int32 collection goal (|2-hop(ep)| by default)
    stale: Array     # () int32 PiP staleness counter
    bound_prev: Array  # () float32 previous top-k bound (PiP)
    ndist_q: Array   # () int32 quantized-tier distances (subset of ndist)


class SearchResult(NamedTuple):
    ids: Array       # (B, k)
    dists: Array     # (B, k) metric-oriented values
    ndist: Array     # (B,) distance computations (the paper's cost proxy)
    iters: Array     # (B,)
    ef_used: Array   # (B,) effective ef at termination
    ndist_q: Optional[Array] = None  # (B,) quantized-tier distances (None
    #   when the producer predates / bypasses the quantized estimation tier)


# --------------------------------------------------------------------------
# upper-layer greedy descent
# --------------------------------------------------------------------------


def _gather_keys(g: DeviceGraph, q: Array, ids: Array, sign: float):
    """Keys from q to graph rows; padded ids (-1) -> +inf."""
    safe = jnp.maximum(ids, 0)
    sims = g.vectors[safe] @ q
    vals = 1.0 - sims if sign > 0 else sims  # cos_dist vs similarity
    keys = vals * 1.0 if sign > 0 else -vals
    return jnp.where(ids >= 0, keys, INF), jnp.where(ids >= 0, vals, INF * sign)


def _use_quant(g: DeviceGraph, cfg: "SearchConfig") -> bool:
    """Frontier scoring goes through the quantized panel (trace-time switch)."""
    return cfg.precision != "fp32" and g.qcodes is not None


def _filter_mode(g: DeviceGraph, cfg: "SearchConfig") -> str:
    """Active predicate lowering (trace-time switch): ``cfg.filter_mode``
    applies only when the graph actually carries a mask (``g.fmask``)."""
    return cfg.filter_mode if g.fmask is not None else "off"


def _gather_keys_q(g: DeviceGraph, q: Array, ids: Array, sign: float):
    """Quantized-panel analogue of :func:`_gather_keys` (per-query vmap path).

    Dequantize-and-score in fp32 against the fp32 query — the batch-hoisted
    loop instead routes through the fused int8 kernel with the query itself
    quantized (``ops.frontier_keys_batch``); both land within the panel's
    round-trip bound of the fp32 keys.
    """
    safe = jnp.maximum(ids, 0)
    rows = g.qcodes[safe].astype(jnp.float32) * g.qrow_scale[safe][..., None]
    rows = g.qzero[None, :] + g.qdim_scale[None, :] * rows
    sims = rows @ q
    vals = 1.0 - sims if sign > 0 else sims
    keys = vals * 1.0 if sign > 0 else -vals
    return jnp.where(ids >= 0, keys, INF), jnp.where(ids >= 0, vals, INF * sign)


def _descend(g: DeviceGraph, q: Array, sign: float):
    """Greedy top-down walk through the upper layers; returns base entry id+key."""
    ep = g.entry
    ep_key, _ = _gather_keys(g, q, ep[None], sign)
    ep_key = ep_key[0]
    num_levels = g.upper_adj.shape[0]
    for level in range(num_levels - 1, -1, -1):
        adj_l = g.upper_adj[level]

        def cond(c):
            _, _, moved = c
            return moved

        def body(c):
            cur, cur_key, _ = c
            nbrs = adj_l[cur]
            keys, _ = _gather_keys(g, q, nbrs, sign)
            j = jnp.argmin(keys)
            bk, bi = keys[j], nbrs[j]
            better = bk < cur_key
            return (
                jnp.where(better, bi, cur),
                jnp.where(better, bk, cur_key),
                better,
            )

        ep, ep_key, _ = jax.lax.while_loop(
            cond, body, (ep, ep_key, jnp.asarray(True))
        )
    return ep, ep_key


# --------------------------------------------------------------------------
# base-layer expansion step (shared by all policies)
# --------------------------------------------------------------------------


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def _bitonic_merge_network(keys: Array, ids: Array):
    """Sort *bitonic* (keys, ids) runs ascending along the last axis; the run
    length must be a power of 2.  Arbitrary leading batch dims: the batched
    search merges a whole ``(B, P)`` panel in one pass, and the per-query path
    calls it on ``(P,)`` runs — the compare-exchanges are position-wise, so
    both produce bit-identical rows.

    log2(P) compare-exchange stages at strides P/2 .. 1; each stage operates on
    contiguous 2s-blocks (reshape, no gathers), so it lowers to pure VPU
    selects on TPU.
    """
    lead = keys.shape[:-1]
    p = keys.shape[-1]
    s = p // 2
    while s >= 1:
        kk = keys.reshape(lead + (-1, 2, s))
        ii = ids.reshape(lead + (-1, 2, s))
        swap = kk[..., 0, :] > kk[..., 1, :]
        keys = jnp.stack(
            [
                jnp.where(swap, kk[..., 1, :], kk[..., 0, :]),
                jnp.where(swap, kk[..., 0, :], kk[..., 1, :]),
            ],
            axis=-2,
        ).reshape(lead + (p,))
        ids = jnp.stack(
            [
                jnp.where(swap, ii[..., 1, :], ii[..., 0, :]),
                jnp.where(swap, ii[..., 0, :], ii[..., 1, :]),
            ],
            axis=-2,
        ).reshape(lead + (p,))
        s //= 2
    return keys, ids


def _merge_sorted(keys: Array, ids: Array, new_keys: Array, new_ids: Array, cap: int):
    """Merge unsorted new entries into a sorted run, keeping the best ``cap``.

    Operates along the last axis with arbitrary leading batch dims (the
    batch-hoisted loop merges the whole ``(B, cap + F)`` panel at once; the
    per-query vmap path passes 1-D runs and gets the same rows bit-for-bit).

    Partial bitonic merge instead of the previous concatenate + full
    ``(cap + F)`` lax.sort: sort the F new entries, pad both runs to
    P = next_pow2(cap), take the position-wise min against the *reversed* new
    run (one bitonic split — yields the best P of the union, itself a bitonic
    sequence), then run the log2(P)-stage merge network.  O(P log P)
    compare-exchanges vs the full sort's O(P log^2 P), and the discarded worst
    half is never sorted at all.

    Unlike the stable full sort, ties between *distinct entries with equal
    keys* may come out in a different relative order; the kept key multiset
    is identical, so search results differ only in which of two exactly
    equidistant ids survives a capacity cutoff.
    """
    lead = keys.shape[:-1]
    nk, ni = jax.lax.sort((new_keys, new_ids), num_keys=1)
    nk, ni = nk[..., :cap], ni[..., :cap]
    m = nk.shape[-1]
    p = _next_pow2(cap)
    ak = jnp.concatenate([keys, jnp.full(lead + (p - cap,), INF, keys.dtype)], axis=-1)
    ai = jnp.concatenate([ids, jnp.full(lead + (p - cap,), -1, ids.dtype)], axis=-1)
    bk = jnp.full(lead + (p,), INF, nk.dtype).at[..., :m].set(nk)[..., ::-1]
    bi = jnp.full(lead + (p,), -1, ni.dtype).at[..., :m].set(ni)[..., ::-1]
    take_a = ak <= bk  # ties keep the incumbent entry (stable-sort behavior)
    mk = jnp.where(take_a, ak, bk)
    mi = jnp.where(take_a, ai, bi)
    mk, mi = _bitonic_merge_network(mk, mi)
    return mk[..., :cap], mi[..., :cap]


def _expand(
    g: DeviceGraph,
    q: Array,
    s: SearchState,
    cfg: SearchConfig,
    sign: float,
    collect: bool,
    lmax: int,
):
    """Pop the top-``beam`` candidates, score their joint frontier, merge.

    The ``beam`` adjacency rows are flattened into one ``(beam * M0,)``
    frontier; visited / padded / repeated ids are masked so every distance is
    computed (and counted in ``ndist``) exactly once, then the whole frontier
    is evaluated as a single contraction — through the fused Pallas kernel
    when ``cfg.use_distance_kernel`` is set.
    """
    n = g.vectors.shape[0]
    beam = cfg.beam
    bound = jnp.take(s.rk, s.ef_dyn - 1)
    pk = s.ck[:beam]
    pi = s.ci[:beam]
    # Sequential best-first would have stopped before expanding any candidate
    # whose key exceeds the current bound; the bound only ever tightens, so
    # such candidates can be dropped outright when the beam pops them.
    pvalid = jnp.isfinite(pk) & (pk <= bound) & (pi >= 0)
    # pop front (arrays are sorted; shift left by beam)
    ck = jnp.concatenate([s.ck[beam:], jnp.full((beam,), INF, s.ck.dtype)])
    ci = jnp.concatenate([s.ci[beam:], jnp.full((beam,), -1, s.ci.dtype)])

    nbrs = g.base_adj[jnp.maximum(pi, 0)]                     # (beam, M0)
    nbrs = jnp.where(pvalid[:, None], nbrs, -1).reshape(-1)   # flat frontier
    valid = (nbrs >= 0) & ~s.visited[jnp.minimum(jnp.maximum(nbrs, 0), n - 1)]
    if beam > 1:
        # First-occurrence dedup: one node may appear in several popped
        # adjacency rows; sequential expansion skips repeats via the visited
        # set, so score and count each frontier node exactly once.
        eq = (nbrs[:, None] == nbrs[None, :]) & valid[None, :]
        dup = jnp.tril(eq, k=-1).any(axis=1)
        valid = valid & ~dup
    # mark visited (padded/invalid writes go to spare slot n)
    write_idx = jnp.where(valid, nbrs, n)
    visited = s.visited.at[write_idx].set(True)

    ids_new = jnp.where(valid, nbrs, -1)
    quant = _use_quant(g, cfg)
    if quant:
        # quantized estimation tier: the fused int8 kernel is batch-only, so
        # the per-query path scores via the jnp dequantize scorer
        keys, _ = _gather_keys_q(g, q, ids_new, sign)
    elif cfg.use_distance_kernel:
        keys = ops.frontier_keys(
            ids_new, q, g.vectors, metric=cfg.metric, use_kernel=True
        )
    else:
        keys, _ = _gather_keys(g, q, ids_new, sign)
    vals = keys * sign  # metric orientation (exact: sign is +-1)
    nnew = jnp.sum(valid).astype(jnp.int32)
    ndist = s.ndist + nnew
    ndist_q = s.ndist_q + nnew if quant else s.ndist_q

    # admission: key < W[ef_dyn - 1]  (inf while W not full  => always admit)
    admit_c = valid & (keys < bound)
    admit_w = admit_c & g.alive[jnp.maximum(nbrs, 0)]
    if _filter_mode(g, cfg) == "pre":
        # predicate mask rides the tombstone seam: failing rows keep routing
        # the traversal through C but never enter the result heap
        admit_w = admit_w & g.fmask[jnp.maximum(nbrs, 0)]

    keys_w = jnp.where(admit_w, keys, INF)
    keys_c = jnp.where(admit_c, keys, INF)

    rk, ri = _merge_sorted(s.rk, s.ri, keys_w, ids_new, s.rk.shape[0])
    ck, ci = _merge_sorted(ck, ci, keys_c, ids_new, ck.shape[0])

    dbuf, dcount = s.dbuf, s.dcount
    if collect:
        # record every *computed* distance (Alg. 2 lines 19-20)
        offs = jnp.cumsum(valid.astype(jnp.int32)) - 1
        pos = s.dcount + offs
        ok = valid & (pos < lmax)
        dbuf = s.dbuf.at[jnp.where(ok, pos, lmax)].set(
            jnp.where(ok, vals, 0.0), mode="drop"
        )
        dcount = jnp.minimum(s.dcount + jnp.sum(valid).astype(jnp.int32), lmax)

    # PiP bookkeeping: did the k-th best improve this iteration?
    return s._replace(
        ck=ck,
        ci=ci,
        rk=rk,
        ri=ri,
        visited=visited,
        ndist=ndist,
        ndist_q=ndist_q,
        iters=s.iters + 1,
        dbuf=dbuf,
        dcount=dcount,
    )


def _not_done(s: SearchState) -> Array:
    bound = jnp.take(s.rk, s.ef_dyn - 1)
    return (s.ck[0] <= bound) & jnp.isfinite(s.ck[0])


# --------------------------------------------------------------------------
# batch-hoisted loop (SearchConfig.batch_hoisted)
# --------------------------------------------------------------------------


def _expand_batch(
    g: DeviceGraph,
    qs: Array,
    s: SearchState,
    cfg: SearchConfig,
    sign: float,
    collect: bool,
    lmax: int,
    active: Array,
):
    """One iteration of the batch-hoisted loop: :func:`_expand` over a whole
    batched state, with per-query ``active`` masking through writes.

    Mirrors ``_expand`` op for op so per-query trajectories are bit-identical
    to the vmap path: inactive queries pop nothing (their frontier emits
    ``-1`` ids, so every downstream admission/merge/collect is a value-level
    no-op and their counters add zero), and only the C pop-shift needs an
    explicit ``where`` — W is left bit-identical by merging all-+inf keys
    into a sorted run, and visited writes land on the spare slot.  The
    frontier is scored either by the cross-query fused kernel over the
    compacted ``(B*F,)`` row panel, or by the vmapped jnp scorer (the exact
    function the per-query path uses, for the bit-exact golden comparison).
    """
    n = g.vectors.shape[0]
    beam = cfg.beam
    bsz = qs.shape[0]
    rows = jnp.arange(bsz)
    bound = s.rk[rows, s.ef_dyn - 1]
    pk = s.ck[:, :beam]
    pi = s.ci[:, :beam]
    pvalid = (
        jnp.isfinite(pk) & (pk <= bound[:, None]) & (pi >= 0) & active[:, None]
    )
    ck = jnp.concatenate(
        [s.ck[:, beam:], jnp.full((bsz, beam), INF, s.ck.dtype)], axis=-1
    )
    ci = jnp.concatenate(
        [s.ci[:, beam:], jnp.full((bsz, beam), -1, s.ci.dtype)], axis=-1
    )

    nbrs = g.base_adj[jnp.maximum(pi, 0)]                        # (B, beam, M0)
    nbrs = jnp.where(pvalid[:, :, None], nbrs, -1).reshape(bsz, -1)
    vis = jnp.take_along_axis(
        s.visited, jnp.minimum(jnp.maximum(nbrs, 0), n - 1), axis=-1
    )
    valid = (nbrs >= 0) & ~vis
    if beam > 1:
        eq = (nbrs[:, :, None] == nbrs[:, None, :]) & valid[:, None, :]
        dup = jnp.tril(eq, k=-1).any(axis=-1)
        valid = valid & ~dup
    write_idx = jnp.where(valid, nbrs, n)
    visited = s.visited.at[rows[:, None], write_idx].set(True)

    ids_new = jnp.where(valid, nbrs, -1)
    quant = _use_quant(g, cfg)
    if quant:
        # quantized estimation tier: same compaction + ladder as the fp32
        # batch path, scored through the int8 kernel (or its jnp oracle)
        keys = ops.frontier_keys_batch(
            ids_new, qs, g.vectors, metric=cfg.metric,
            use_kernel=cfg.use_distance_kernel,
            qpanel=(g.qcodes, g.qrow_scale, g.qdim_scale, g.qzero),
        )
    elif cfg.use_distance_kernel:
        keys = ops.frontier_keys_batch(
            ids_new, qs, g.vectors, metric=cfg.metric, use_kernel=True
        )
    else:
        keys = jax.vmap(
            lambda ids1, q1: _gather_keys(g, q1, ids1, sign)[0]
        )(ids_new, qs)
    vals = keys * sign
    nnew = jnp.sum(valid, axis=-1).astype(jnp.int32)
    ndist = s.ndist + nnew
    ndist_q = s.ndist_q + nnew if quant else s.ndist_q

    admit_c = valid & (keys < bound[:, None])
    admit_w = admit_c & g.alive[jnp.maximum(nbrs, 0)]
    if _filter_mode(g, cfg) == "pre":
        admit_w = admit_w & g.fmask[jnp.maximum(nbrs, 0)]

    keys_w = jnp.where(admit_w, keys, INF)
    keys_c = jnp.where(admit_c, keys, INF)

    rk, ri = _merge_sorted(s.rk, s.ri, keys_w, ids_new, s.rk.shape[-1])
    ck, ci = _merge_sorted(ck, ci, keys_c, ids_new, ck.shape[-1])
    # undo the pop-shift for inactive queries (the only state leaf whose
    # batched update is not already a value-level no-op for them)
    ck = jnp.where(active[:, None], ck, s.ck)
    ci = jnp.where(active[:, None], ci, s.ci)

    dbuf, dcount = s.dbuf, s.dcount
    if collect:
        offs = jnp.cumsum(valid.astype(jnp.int32), axis=-1) - 1
        pos = s.dcount[:, None] + offs
        ok = valid & (pos < lmax)
        dbuf = s.dbuf.at[rows[:, None], jnp.where(ok, pos, lmax)].set(
            jnp.where(ok, vals, 0.0), mode="drop"
        )
        dcount = jnp.minimum(
            s.dcount + jnp.sum(valid, axis=-1).astype(jnp.int32), lmax
        )

    return s._replace(
        ck=ck,
        ci=ci,
        rk=rk,
        ri=ri,
        visited=visited,
        ndist=ndist,
        ndist_q=ndist_q,
        iters=s.iters + active.astype(jnp.int32),
        dbuf=dbuf,
        dcount=dcount,
    )


def _active_mask(
    s: SearchState, cfg: SearchConfig, phase_a: bool, patience: bool
) -> Array:
    """Per-query continue predicate of the batched loop — the exact conjunction
    each per-query policy evaluates in its vmapped ``cond``."""
    rows = jnp.arange(s.rk.shape[0])
    bound = s.rk[rows, s.ef_dyn - 1]
    go = (s.ck[:, 0] <= bound) & jnp.isfinite(s.ck[:, 0])
    go = go & (s.iters < cfg.iters())
    if phase_a:
        go = go & (s.dcount < s.lgoal)
    if patience and cfg.patience > 0:
        go = go & (s.stale < cfg.patience)
    return go


def _run_hoisted(
    g: DeviceGraph,
    qs: Array,
    s: SearchState,
    cfg: SearchConfig,
    sign: float,
    *,
    collect: bool,
    lmax: int,
    phase_a: bool = False,
    patience: bool = False,
) -> SearchState:
    """Drive a batched :class:`SearchState` to joint termination in one
    ``lax.while_loop`` (the batch-hoisted core shared by every policy).

    The per-query active mask is carried alongside the state so each
    iteration evaluates the termination predicate once (the vmapped loop's
    batching rule evaluates its cond per iteration too, but our body would
    otherwise re-derive the same mask a second time)."""

    def cond(carry):
        _, act = carry
        return jnp.any(act)

    def body(carry):
        s, act = carry
        s2 = _expand_batch(g, qs, s, cfg, sign, collect, lmax, act)
        if patience and cfg.patience > 0:
            rows = jnp.arange(s2.rk.shape[0])
            bound_k = s2.rk[rows, jnp.minimum(cfg.k, s2.ef_dyn) - 1]
            improved = bound_k < s.bound_prev
            s2 = s2._replace(
                stale=jnp.where(
                    act, jnp.where(improved, 0, s.stale + 1), s.stale
                ),
                bound_prev=jnp.where(
                    act, jnp.minimum(bound_k, s.bound_prev), s.bound_prev
                ),
            )
        return s2, _active_mask(s2, cfg, phase_a, patience)

    s, _ = jax.lax.while_loop(
        cond, body, (s, _active_mask(s, cfg, phase_a, patience))
    )
    return s


# --------------------------------------------------------------------------
# initialization
# --------------------------------------------------------------------------


def _two_hop_goal(g: DeviceGraph, ep: Array, hops: int, lmax: int) -> Array:
    """l = number of nodes reachable within ``hops`` hops of ep (paper §4)."""
    if hops <= 1:
        nb1 = g.base_adj[ep]
        cnt = 1 + jnp.sum(nb1 >= 0)
        return jnp.minimum(cnt, lmax).astype(jnp.int32)
    nb1 = g.base_adj[ep]                       # (M0,)
    nb2 = g.base_adj[jnp.maximum(nb1, 0)]      # (M0, M0)
    nb2 = jnp.where((nb1 >= 0)[:, None], nb2, -1)
    if hops >= 3:
        nb3 = g.base_adj[jnp.maximum(nb2, 0)]
        nb3 = jnp.where((nb2 >= 0)[..., None], nb3, -1)
        ids = jnp.concatenate([ep[None], nb1.ravel(), nb2.ravel(), nb3.ravel()])
    else:
        ids = jnp.concatenate([ep[None], nb1.ravel(), nb2.ravel()])
    sids = jnp.sort(ids)
    uniq = (sids >= 0) & jnp.concatenate([jnp.asarray([True]), sids[1:] != sids[:-1]])
    cnt = jnp.sum(uniq)
    return jnp.minimum(cnt, lmax).astype(jnp.int32)


def _init_state(
    g: DeviceGraph, q: Array, cfg: SearchConfig, ef0: Array, lmax: int, hops: int
) -> SearchState:
    sign = key_sign(cfg.metric)
    n = g.vectors.shape[0]
    ep, ep_key = _descend(g, q, sign)
    cap = cfg.ef_cap
    ck = jnp.full((cap,), INF).at[0].set(ep_key)
    ci = jnp.full((cap,), -1, jnp.int32).at[0].set(ep)
    ep_alive = g.alive[ep]
    if _filter_mode(g, cfg) == "pre":
        ep_alive = ep_alive & g.fmask[ep]
    rk = jnp.full((cap,), INF).at[0].set(jnp.where(ep_alive, ep_key, INF))
    ri = jnp.full((cap,), -1, jnp.int32).at[0].set(jnp.where(ep_alive, ep, -1))
    rk, ri = jax.lax.sort((rk, ri), num_keys=1)
    visited = jnp.zeros((n + 1,), bool).at[ep].set(True)
    dbuf = jnp.zeros((lmax,), jnp.float32).at[0].set(ep_key * sign)  # D <- dist(ep, q)
    return SearchState(
        ck=ck,
        ci=ci,
        rk=rk,
        ri=ri,
        visited=visited,
        ef_dyn=ef0.astype(jnp.int32),
        ndist=jnp.asarray(1, jnp.int32),
        iters=jnp.asarray(0, jnp.int32),
        dbuf=dbuf,
        dcount=jnp.asarray(1, jnp.int32),
        lgoal=_two_hop_goal(g, ep, hops, lmax),
        stale=jnp.asarray(0, jnp.int32),
        bound_prev=jnp.asarray(INF, jnp.float32),
        ndist_q=jnp.asarray(0, jnp.int32),
    )


def _extract(s: SearchState, cfg: SearchConfig, sign: float) -> SearchResult:
    # last-axis slicing: works on a single state (vmap path) and on a whole
    # batched state (batch-hoisted path) alike
    rk = s.rk[..., : cfg.k]
    ri = s.ri[..., : cfg.k]
    return SearchResult(
        ids=jnp.where(jnp.isfinite(rk), ri, -1),
        dists=rk * sign,
        ndist=s.ndist,
        iters=s.iters,
        ef_used=s.ef_dyn,
        ndist_q=s.ndist_q,
    )


def _rerank_fp32(g: DeviceGraph, q: Array, s: SearchState, sign: float) -> SearchState:
    """Multi-stage re-rank: fp32 re-score + re-sort of the result heap.

    Closes the quantized search: traversal admitted W under approximate int8
    keys, so the final ef candidates (the whole W array — re-rank depth = the
    tier's ``ef_cap``) are re-scored against the fp32 vector panel and
    re-sorted before top-k emission.  The fp32 re-scores count toward
    ``ndist`` (they read full-precision rows) but not ``ndist_q``.  Shape-
    polymorphic over a single ``(W,)`` state and a batched ``(B, W)`` state.
    """
    safe = jnp.maximum(s.ri, 0)
    sims = jnp.einsum("...wd,...d->...w", g.vectors[safe], q)
    keys = (1.0 - sims) if sign > 0 else -sims
    live = (s.ri >= 0) & jnp.isfinite(s.rk)
    keys = jnp.where(live, keys, INF)
    rk, ri = jax.lax.sort((keys, s.ri), num_keys=1)
    return s._replace(
        rk=rk,
        ri=ri,
        ndist=s.ndist + jnp.sum(live, axis=-1).astype(jnp.int32),
    )


def _filter_heap(g: DeviceGraph, s: SearchState) -> SearchState:
    """Post-filter epilogue: drop result-heap entries failing ``g.fmask``.

    The ``filter_mode == "post"`` lowering runs the traversal unfiltered (the
    planner inflates ef by ~1/selectivity to overquery), then this epilogue
    masks failing rows to (+inf, -1) and re-sorts the heap so the passing
    subset forms the result prefix — same shape polymorphism over ``(W,)``
    and ``(B, W)`` states as :func:`_rerank_fp32`.
    """
    ok = (s.ri >= 0) & g.fmask[jnp.maximum(s.ri, 0)]
    rk, ri = jax.lax.sort(
        (jnp.where(ok, s.rk, INF), jnp.where(ok, s.ri, -1)), num_keys=1
    )
    return s._replace(rk=rk, ri=ri)


# --------------------------------------------------------------------------
# policy: static ef (+ optional PiP patience)
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg",))
def search(g: DeviceGraph, queries: Array, ef: Array, cfg: SearchConfig) -> SearchResult:
    """Standard HNSW search with a (runtime) static ef, batched over queries.

    ``ef`` may be a scalar or a per-query (B,) int array (this is also the
    execution path for *pre-estimated* adaptive efs).
    """
    sign = key_sign(cfg.metric)
    queries = prepare_queries(queries, cfg.metric)
    ef_b = jnp.broadcast_to(jnp.asarray(ef, jnp.int32), queries.shape[:1])
    ef_b = jnp.clip(ef_b, cfg.k, cfg.ef_cap)

    quant = _use_quant(g, cfg)
    fpost = _filter_mode(g, cfg) == "post"
    if cfg.batch_hoisted:
        s = jax.vmap(lambda q, e: _init_state(g, q, cfg, e, lmax=1, hops=1))(
            queries, ef_b
        )
        s = _run_hoisted(
            g, queries, s, cfg, sign, collect=False, lmax=1, patience=True
        )
        if quant:
            s = _rerank_fp32(g, queries, s, sign)
        if fpost:
            s = _filter_heap(g, s)
        return _extract(s, cfg, sign)

    def one(q, ef1):
        s = _init_state(g, q, cfg, ef1, lmax=1, hops=1)

        def cond(s):
            go = _not_done(s) & (s.iters < cfg.iters())
            if cfg.patience > 0:
                go = go & (s.stale < cfg.patience)
            return go

        def body(s):
            s2 = _expand(g, q, s, cfg, sign, collect=False, lmax=1)
            if cfg.patience > 0:
                bound_k = jnp.take(s2.rk, jnp.minimum(cfg.k, s2.ef_dyn) - 1)
                improved = bound_k < s.bound_prev
                s2 = s2._replace(
                    stale=jnp.where(improved, 0, s.stale + 1),
                    bound_prev=jnp.minimum(bound_k, s.bound_prev),
                )
            return s2

        s = jax.lax.while_loop(cond, body, s)
        if quant:
            s = _rerank_fp32(g, q, s, sign)
        if fpost:
            s = _filter_heap(g, s)
        return _extract(s, cfg, sign)

    return jax.vmap(one)(queries, ef_b)


# --------------------------------------------------------------------------
# policy: Ada-ef (paper Algorithm 2), split into composable phases
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdaEfConfig:
    hops: int = 2                 # |D| bound = |hops-hop(ep)| (Table 8 ablation)
    lmax: int = 0                 # D buffer capacity; 0 -> auto 1 + M0 + M0^2
    estimator: EstimatorConfig = EstimatorConfig()

    def buf(self, m0: int) -> int:
        if self.lmax > 0:
            return self.lmax
        if self.hops <= 1:
            return 1 + m0
        return 1 + m0 + m0 * m0  # capped 2-hop budget (also used for hops=3)


def estimation_config(
    cfg: SearchConfig, m0: int, ada: AdaEfConfig, est_cap: int = 0
) -> SearchConfig:
    """Phase-A-only SearchConfig at reduced state capacity.

    Phase A admits every scored node while W is below capacity (its bound is
    +inf until W fills), and it terminates after ~``lmax`` collected
    distances, so a capacity of ``lmax + beam*M0`` (one iteration of
    overshoot) is *lossless*: W/C never fill, the bound stays +inf, and the
    collected distance list is bit-identical to a full ``ef_cap``-capacity
    run of :func:`adaptive_search` phase A.  ``est_cap > 0`` forces a smaller
    (lossy) capacity: the W bound turns finite once ``est_cap`` nodes are
    scored, pruning collection early — cheaper estimation that biases scores
    toward "easy" (router callers compensate via ``ef_margin``).

    ``max_iters`` is pinned to the base config's budget so phase A sees the
    same iteration limit it would inside the fused search.
    """
    lossless = ada.buf(m0) + cfg.beam * m0
    cap = min(cfg.ef_cap, est_cap if est_cap > 0 else lossless)
    cap = max(cap, cfg.k, cfg.beam)
    return dataclasses.replace(cfg, ef_cap=cap, max_iters=cfg.iters(), patience=0)


def _phase_a_batch(
    g: DeviceGraph,
    queries: Array,
    cfg: SearchConfig,
    ada: AdaEfConfig,
    real: Optional[Array] = None,
):
    """Phase A (Alg. 2 lines 1-20): expand at ef=inf until ``lgoal`` distances
    are collected.  ``queries`` must already be prepared; returns the batched
    :class:`SearchState` (C/W sized ``cfg.ef_cap``, dbuf sized ``ada.buf``).

    ``real`` is an optional per-query bool mask marking batch-padding rows
    (``False``): their collection goal is clamped to the already-collected
    entry-point distance, so the phase-A predicate is false from iteration 0
    and a padding row costs exactly one distance computation (the entry
    point) instead of a full phase-A run.  Real rows are untouched — their
    trajectories are bit-identical with or without the mask.
    """
    sign = key_sign(cfg.metric)
    m0 = g.base_adj.shape[1]
    lmax = ada.buf(m0)
    ef_inf = jnp.asarray(cfg.ef_cap, jnp.int32)

    def clamp(s: SearchState) -> SearchState:
        if real is None:
            return s
        return s._replace(lgoal=jnp.where(real, s.lgoal, s.dcount))

    if cfg.batch_hoisted:
        s = jax.vmap(
            lambda q: _init_state(g, q, cfg, ef_inf, lmax=lmax, hops=ada.hops)
        )(queries)
        return _run_hoisted(
            g, queries, clamp(s), cfg, sign, collect=True, lmax=lmax, phase_a=True
        )

    def one(q):
        return _init_state(g, q, cfg, ef_inf, lmax=lmax, hops=ada.hops)

    def drive(s, q):
        def cond(s):
            return _not_done(s) & (s.dcount < s.lgoal) & (s.iters < cfg.iters())

        def body(s):
            return _expand(g, q, s, cfg, sign, collect=True, lmax=lmax)

        return jax.lax.while_loop(cond, body, s)

    s = clamp(jax.vmap(one)(queries))
    return jax.vmap(drive)(s, queries)


def _estimate_from_states(
    states: SearchState,
    queries: Array,
    stats: DatasetStats,
    table: EfTable,
    target_recall: Array,
    cfg: SearchConfig,
    ada: AdaEfConfig,
) -> Array:
    """ESTIMATE-EF (Algorithm 1) over collected phase-A states, batched once.

    The returned ef is clipped to ``[k, cfg.ef_cap]`` — pass the *base*
    (full-capacity) config here even when phase A ran at a reduced
    estimation capacity, so large estimates are not truncated to the
    estimation budget."""
    lmax = states.dbuf.shape[-1]
    valid = jnp.arange(lmax)[None, :] < states.dcount[:, None]
    ef_est = estimate_ef(
        stats,
        table,
        queries,
        states.dbuf,
        jnp.asarray(target_recall, jnp.float32),
        valid=valid,
        config=ada.estimator,
    )
    return jnp.clip(ef_est, cfg.k, cfg.ef_cap)


def _phase_b_batch(
    g: DeviceGraph, queries: Array, states: SearchState, ef: Array, cfg: SearchConfig
) -> SearchResult:
    """Phase B (Alg. 2 lines 21-24): continue batched states at per-query ef.

    ``states`` array capacities must match ``cfg.ef_cap`` (see
    :func:`resize_state`); the W truncation to the runtime ef happens
    dynamically through ``ef_dyn``."""
    sign = key_sign(cfg.metric)
    lmax = states.dbuf.shape[-1]
    quant = _use_quant(g, cfg)
    fpost = _filter_mode(g, cfg) == "post"

    if cfg.batch_hoisted:
        s = states._replace(ef_dyn=ef.astype(jnp.int32))
        s = _run_hoisted(g, queries, s, cfg, sign, collect=False, lmax=lmax)
        if quant:
            s = _rerank_fp32(g, queries, s, sign)
        if fpost:
            s = _filter_heap(g, s)
        return _extract(s, cfg, sign)._replace(ef_used=ef)

    def one(s: SearchState, q, ef1):
        s = s._replace(ef_dyn=ef1)

        def cond(s):
            return _not_done(s) & (s.iters < cfg.iters())

        def body(s):
            return _expand(g, q, s, cfg, sign, collect=False, lmax=lmax)

        s = jax.lax.while_loop(cond, body, s)
        if quant:
            s = _rerank_fp32(g, q, s, sign)
        if fpost:
            s = _filter_heap(g, s)
        return _extract(s, cfg, sign)

    res = jax.vmap(one)(states, queries, ef)
    return res._replace(ef_used=ef)


def resize_state(states: SearchState, cap: int) -> SearchState:
    """Re-capacity a (batched) phase-A state to C/W size ``cap``.

    Shrinking keeps the best ``cap`` entries, which is exact as long as the
    state is only ever resumed at ``ef <= cap``: the admission bound reads
    ``W[ef-1]``, merges only let new entries displace *worse* ones, and any
    candidate beyond position ``cap`` of C is already outside the W bound
    (it can never be popped by a search whose W holds ``cap`` better nodes).
    Caveat: that last argument leans on C ⊆ W-admitted, which tombstones
    break — deleted nodes enter C (they must stay traversable) but not W, so
    on a graph with many tombstones near a query the truncation may drop a
    live candidate still inside the bound (recall-benign in practice: the
    routed path then merely explores slightly less than the monolithic one,
    and deletions are followed by a table rebuild anyway).  Growing pads the
    sorted tails with empty (+inf / -1) slots — bit-exact when the source
    state never filled its own capacity (the lossless estimation case).  The
    collection buffer is dropped to one slot either way — resumed searches
    never collect.
    """

    def _fit(a: Array, fill) -> Array:
        cur = a.shape[-1]
        if cap <= cur:
            return a[..., :cap]
        pad = jnp.full(a.shape[:-1] + (cap - cur,), fill, a.dtype)
        return jnp.concatenate([a, pad], axis=-1)

    return states._replace(
        ck=_fit(states.ck, INF),
        ci=_fit(states.ci, -1),
        rk=_fit(states.rk, INF),
        ri=_fit(states.ri, -1),
        dbuf=states.dbuf[..., :1],
    )


@partial(jax.jit, static_argnames=("cfg", "ada"))
def collect_distances(
    g: DeviceGraph, queries: Array, cfg: SearchConfig, ada: AdaEfConfig
):
    """Phase A only, returning the collected (dbuf, dcount) — the offline
    proxy-scoring entry point (pipeline table builds, LAET/DARTH features)."""
    states = _phase_a_batch(g, prepare_queries(queries, cfg.metric), cfg, ada)
    return states.dbuf, states.dcount


@partial(jax.jit, static_argnames=("cfg", "ada", "ef_cap_out"))
def estimate_pass(
    g: DeviceGraph,
    queries: Array,
    stats: DatasetStats,
    table: EfTable,
    target_recall: Array,
    cfg: SearchConfig,
    ada: AdaEfConfig = AdaEfConfig(),
    ef_cap_out: Optional[int] = None,
    num_real: Optional[Array] = None,
):
    """Estimation pass: phase A + ESTIMATE-EF for a whole batch, no phase B.

    Run it at a *small* capacity (see :func:`estimation_config`) to price the
    per-query ef estimate at a fraction of a full search; the returned states
    can be resumed tier-by-tier via :func:`resume_at_ef`.  Returns
    ``(ef_est, states)`` with ``ef_est`` clipped to ``[k, ef_cap_out or
    cfg.ef_cap]``.

    ``target_recall`` may be a scalar or a per-query ``(B, 1)`` array (the
    continuous-batching scheduler mixes requests with different declarative
    targets in one pass).  ``num_real`` (runtime scalar) marks rows at or
    beyond it as batch padding: they skip phase A entirely (one distance
    computation each) instead of running a full collection at real cost;
    rows below ``num_real`` are bit-identical to an unmasked pass.
    """
    queries = prepare_queries(queries, cfg.metric)
    real = (
        None
        if num_real is None
        else jnp.arange(queries.shape[0]) < jnp.asarray(num_real, jnp.int32)
    )
    states = _phase_a_batch(g, queries, cfg, ada, real=real)
    clip_cfg = cfg if ef_cap_out is None else dataclasses.replace(cfg, ef_cap=ef_cap_out)
    ef_est = _estimate_from_states(
        states, queries, stats, table, target_recall, clip_cfg, ada
    )
    return ef_est, states


@partial(jax.jit, static_argnames=("cfg",))
def resume_at_ef(
    g: DeviceGraph,
    queries: Array,
    states: SearchState,
    ef: Array,
    cfg: SearchConfig,
) -> SearchResult:
    """Phase B as a first-class entry point: continue phase-A states at the
    given per-query ef (scalar or (B,)).  State capacities must equal
    ``cfg.ef_cap`` — use :func:`resize_state` to fit an estimation-pass
    state onto a tier.  ``ndist``/``iters`` keep accumulating, so the
    result's cost counters cover both phases, directly comparable to
    :func:`adaptive_search`."""
    queries = prepare_queries(queries, cfg.metric)
    ef_b = jnp.broadcast_to(jnp.asarray(ef, jnp.int32), queries.shape[:1])
    ef_b = jnp.clip(ef_b, cfg.k, cfg.ef_cap)
    return _phase_b_batch(g, queries, states, ef_b, cfg)


@partial(jax.jit, static_argnames=("cfg", "ada"))
def adaptive_search(
    g: DeviceGraph,
    queries: Array,
    stats: DatasetStats,
    table: EfTable,
    target_recall: Array,
    cfg: SearchConfig,
    ada: AdaEfConfig = AdaEfConfig(),
) -> SearchResult:
    """Paper Algorithm 2: ef = inf until ``l`` distances collected, then
    ESTIMATE-EF once, then continue with the estimated ef.

    Monolithic composition of the split phases: every query runs both phases
    at full ``ef_cap`` capacity in one fused computation.  The routed serving
    path (:mod:`repro.serve.router`) runs the same phases as separate
    dispatches with per-tier capacities."""
    queries = prepare_queries(queries, cfg.metric)
    states = _phase_a_batch(g, queries, cfg, ada)
    ef_est = _estimate_from_states(
        states, queries, stats, table, target_recall, cfg, ada
    )
    return _phase_b_batch(g, queries, states, ef_est, cfg)


# --------------------------------------------------------------------------
# recall
# --------------------------------------------------------------------------


def recall_at_k(pred_ids: Array, true_ids: Array) -> Array:
    """Recall@k = |pred ∩ true| / k, batched. Arrays (B, k) int32."""
    eq = pred_ids[:, :, None] == true_ids[:, None, :]
    eq = eq & (pred_ids >= 0)[:, :, None]
    hits = jnp.sum(jnp.any(eq, axis=-1), axis=-1)
    return hits.astype(jnp.float32) / true_ids.shape[1]


def as_host(res: SearchResult):
    return jax.tree_util.tree_map(np.asarray, res)
