"""Assigned-architecture configs (exact sizes from the assignment table)."""
from .base import (  # noqa: F401
    ArchConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    cell_applicable,
    shape_by_name,
)
from .qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE_30B_A3B
from .qwen2_moe_a2_7b import CONFIG as QWEN2_MOE_A2_7B
from .qwen3_14b import CONFIG as QWEN3_14B
from .stablelm_1_6b import CONFIG as STABLELM_1_6B
from .qwen1_5_32b import CONFIG as QWEN1_5_32B
from .qwen2_0_5b import CONFIG as QWEN2_0_5B
from .seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T_LARGE_V2
from .zamba2_2_7b import CONFIG as ZAMBA2_2_7B
from .xlstm_350m import CONFIG as XLSTM_350M
from .phi_3_vision_4_2b import CONFIG as PHI_3_VISION_4_2B

ARCHS = {
    c.name: c
    for c in (
        QWEN3_MOE_30B_A3B,
        QWEN2_MOE_A2_7B,
        QWEN3_14B,
        STABLELM_1_6B,
        QWEN1_5_32B,
        QWEN2_0_5B,
        SEAMLESS_M4T_LARGE_V2,
        ZAMBA2_2_7B,
        XLSTM_350M,
        PHI_3_VISION_4_2B,
    )
}


def arch_by_name(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
