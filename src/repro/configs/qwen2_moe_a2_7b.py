"""qwen2-moe-a2.7b — 24L d_model=2048 16H (kv=16) MoE 60e top-4 + 4 shared, moe_ff=1408.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    num_experts=60,      # padded to 64 for the 16-way model axis (router-masked)
    num_experts_per_tok=4,
    num_shared_experts=4,
    norm_topk_prob=False,
    rope_theta=1e6,
)
