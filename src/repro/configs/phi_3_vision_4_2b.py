"""phi-3-vision-4.2b — 32L d_model=3072 32H d_ff=8192 + CLIP stub frontend.
input_specs provides precomputed patch embeddings. [hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    frontend="vision_patches",
    frontend_dim=1024,       # CLIP-L/14 embedding dim
    num_frontend_tokens=576, # 24x24 patch grid stub
    rope_theta=10000.0,
)
