"""zamba2-2.7b — 54L Mamba2 backbone d_model=2560 + shared attention block (32H),
d_ff=10240, ssm_state=64. [arXiv:2411.15242; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=40,           # d_inner = 2*2560 = 5120 -> 40 heads x 128
    ssm_expand=2,
    shared_attn_every=6,    # shared block applied every 6 mamba layers
    sub_quadratic=True,
    rope_theta=10000.0,
)
