"""qwen3-moe-30b-a3b — 48L d_model=2048 32H (GQA kv=4) MoE 128e top-8, moe_ff=768.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,            # listed ff = per-expert moe ff
    moe_d_ff=768,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    num_experts=128,
    num_experts_per_tok=8,
    norm_topk_prob=True,
    rope_theta=1e6,
)
