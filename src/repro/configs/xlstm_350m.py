"""xlstm-350m — 24L d_model=1024 4H, sLSTM + mLSTM blocks (1 sLSTM per 6).
[arXiv:2405.04517; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                 # xLSTM blocks own their projections; no separate FFN
    vocab_size=50304,
    slstm_every=6,          # groups of 5 mLSTM + 1 sLSTM
    ssm_chunk=256,
    sub_quadratic=True,
)
