"""Architecture + shape configuration system.

One :class:`ArchConfig` per assigned architecture (exact sizes from the
assignment table), plus :class:`ShapeConfig` for the four assigned input
shapes.  ``reduced()`` produces the smoke-test scale-down of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | audio | hybrid | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-routed-expert hidden dim
    norm_topk_prob: bool = True
    capacity_factor: float = 1.25
    # --- encoder-decoder ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    slstm_every: int = 0             # xLSTM: every n-th block is sLSTM
    shared_attn_every: int = 0       # Zamba2: shared attn block cadence
    # --- modality frontend (stub) ---
    frontend: str = "none"           # none | audio_frames | vision_patches
    frontend_dim: int = 0            # precomputed embedding dim
    num_frontend_tokens: int = 0
    # --- misc ---
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots | none
    sub_quadratic: bool = False      # eligible for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def reduced(self) -> "ArchConfig":
        """Smoke-test config: same family/topology, tiny sizes."""
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 4 if self.shared_attn_every else 2)
            if not self.slstm_every
            else min(self.num_layers, max(2, self.slstm_every)),
            num_encoder_layers=min(self.num_encoder_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            moe_d_ff=128 if self.moe_d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 8),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16),
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            ssm_chunk=32,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            num_frontend_tokens=min(self.num_frontend_tokens, 16),
            shared_attn_every=min(self.shared_attn_every, 2) if self.shared_attn_every else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether the (arch x shape) dry-run cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (assignment rule)"
    return True, ""
