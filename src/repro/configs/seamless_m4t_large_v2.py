"""seamless-m4t-large-v2 — enc-dec 24L+24L d_model=1024 16H d_ff=8192 vocab=256206.
Audio frontend stubbed: input_specs provides precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,            # decoder layers
    num_encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio_frames",
    frontend_dim=160,         # fbank-frame stub embedding dim
    rope_theta=10000.0,
)
