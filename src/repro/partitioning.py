"""Logical-axis sharding context (hand-rolled flax-style ``logical axis rules``).

Model code annotates activations with *semantic* names via :func:`constrain`;
the launcher activates a mesh + a name -> PartitionSpec mapping with
:func:`axis_rules`.  Outside a context every constraint is a no-op, so models
run unmodified on a single CPU device (smoke tests) and fully sharded under
the production mesh (dry-run / training) without code changes.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_state = threading.local()


def _current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, PartitionSpec]):
    prev = _current()
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def constrain(x, name: str):
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh() -> Optional[Mesh]:
    ctx = _current()
    return ctx[0] if ctx else None
