"""Declarative search facade: one public API over router/scheduler/kernels.

The paper's core promise is *declarative* — the caller states **what** they
need (k results at a target recall, maybe under a latency budget) and the
system derives **how** to run it (exploration budget, loop strategy, kernel
dispatch, batching policy).  After four PRs of subsystems the public surface
had drifted the opposite way: callers juggled ``SearchConfig`` /
``RouterConfig`` / ``SchedulerConfig`` / ``ServeConfig`` plus a live
``use_distance_kernel`` flag.  This module restores the declarative contract:

- :class:`SearchSpec` — an immutable, hashable description of a search
  workload.  It is the *only* thing a caller has to construct.
- ``index.plan(spec)`` — the planner (:mod:`repro.plan`) lowers a spec
  against an :class:`repro.index.pipeline.AdaEfIndex` into a cached
  :class:`repro.plan.ExecutionPlan` whose ``search()`` /
  ``submit()``/``poll()`` / ``explain()`` methods execute it.

The legacy config dataclasses survive as **internal lowering targets**: the
planner derives them, and an expert can pin any of them through
:class:`SpecOverrides` (the escape hatch) — but no module outside
``serve/``/``index/`` should import them from their home modules; this
facade re-exports them for override construction.

Specs (and the plans lowered from them) are registered as *static* pytrees:
zero array leaves, the whole object rides in the treedef.  They can cross a
``jit`` boundary as ordinary arguments, and two equal specs hash equal, so
they key compile caches and the index's plan cache exactly like static
config dataclasses do.

Example::

    from repro.api import SearchSpec

    spec = SearchSpec(k=10, target_recall=0.95)
    plan = index.plan(spec)
    print(plan.explain())            # every derived decision, EXPLAIN-style
    result = plan.search(queries)    # same ids as paper Alg. 2
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# Re-exports: the four legacy config dataclasses are reachable from here (and
# only from here, outside serve/+index/) so `SpecOverrides` can be built
# without importing serving internals.
from repro.filter import FilterSpec  # noqa: F401
from repro.index.search import AdaEfConfig, SearchConfig  # noqa: F401
from repro.pytrees import register_static_config  # noqa: F401  (re-export)
from repro.serve.router import RouterConfig  # noqa: F401
from repro.serve.scheduler import SchedulerConfig  # noqa: F401

MODE_ONESHOT = "oneshot"      # one fused adaptive_search batch call
MODE_ROUTED = "routed"        # estimate -> ef-tier bucketed batch dispatch
MODE_STREAMING = "streaming"  # request lifecycle: submit()/step()/poll()
MODES = (MODE_ONESHOT, MODE_ROUTED, MODE_STREAMING)

BACKEND_AUTO = "auto"            # capability probe picks one of the below
BACKEND_PALLAS = "pallas"        # fused Pallas kernels (TPU)
BACKEND_INTERPRET = "interpret"  # Pallas kernels in interpret mode (CPU)
BACKEND_ORACLE = "oracle"        # pure-jnp reference scorers
BACKENDS = (BACKEND_AUTO, BACKEND_PALLAS, BACKEND_INTERPRET, BACKEND_ORACLE)

# Estimation-tier scoring precision (repro.quant): traversal/estimation
# distances read the quantized panel; the final ef candidates are re-ranked
# at fp32 before top-k emission (multi-stage re-rank), so the precision knob
# trades estimation *bandwidth* for a bounded re-rank cost, not recall.
PRECISION_FP32 = "fp32"
PRECISION_INT8 = "int8"
PRECISION_FP8 = "fp8"
PRECISIONS = (PRECISION_FP32, PRECISION_INT8, PRECISION_FP8)

ON_MUTATION_REVALIDATE = "revalidate"  # held plans rebind (or transparently
#   re-plan) against the post-mutation epoch; in-flight work completes on
#   the epoch it was dispatched on
ON_MUTATION_STRICT = "strict"          # held plans refuse to survive a
#   mutation: any use after insert/delete raises StalePlanError
ON_MUTATION_MODES = (ON_MUTATION_REVALIDATE, ON_MUTATION_STRICT)


def _rebuild(cls, value):
    """Reconstruct a config dataclass from ``as_dict`` output (or pass an
    instance through).  Handles the one nested config (``AdaEfConfig.
    estimator``) and tuple-valued fields that serialize as lists."""
    if value is None or isinstance(value, cls):
        return value
    kw = dict(value)
    if cls is AdaEfConfig and isinstance(kw.get("estimator"), dict):
        from repro.core import EstimatorConfig

        kw["estimator"] = EstimatorConfig(**kw["estimator"])
    if cls is RouterConfig and "tier_efs" in kw:
        kw["tier_efs"] = tuple(kw["tier_efs"])
    if cls is SchedulerConfig and kw.get("tenants"):
        from repro.serve.api import TenantSLO

        kw["tenants"] = tuple(
            (name, slo if isinstance(slo, TenantSLO) else TenantSLO(**slo))
            for name, slo in kw["tenants"]
        )
    return cls(**kw)


@register_static_config
@dataclasses.dataclass(frozen=True)
class SpecOverrides:
    """Expert escape hatch: pin any internal lowering target outright.

    Every field defaults to ``None`` = "let the planner derive it".  A
    pinned ``search`` config is taken verbatim (the planner still resolves
    the kernel flag from ``SearchSpec.backend``, which owns dispatch);
    ``router``/``scheduler``/``ada`` replace the derived policy wholesale.
    """

    search: Optional[SearchConfig] = None
    router: Optional[RouterConfig] = None
    scheduler: Optional[SchedulerConfig] = None
    ada: Optional[AdaEfConfig] = None

    def as_dict(self) -> dict:
        return {
            f.name: dataclasses.asdict(v)
            for f in dataclasses.fields(self)
            if (v := getattr(self, f.name)) is not None
        }

    @staticmethod
    def from_dict(d: dict) -> "SpecOverrides":
        return SpecOverrides(
            search=_rebuild(SearchConfig, d.get("search")),
            router=_rebuild(RouterConfig, d.get("router")),
            scheduler=_rebuild(SchedulerConfig, d.get("scheduler")),
            ada=_rebuild(AdaEfConfig, d.get("ada")),
        )

    def __bool__(self) -> bool:
        return any(
            getattr(self, f.name) is not None for f in dataclasses.fields(self)
        )


@register_static_config
@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """What to search for — the whole public knob surface.

    ``None``/``0`` fields inherit the index's build-time defaults, so
    ``SearchSpec()`` reproduces ``index.query(queries)`` exactly.

    - ``k``: results per query (``None`` -> the index's k; may only shrink).
    - ``target_recall``: declarative recall target (``None`` -> index's).
    - ``deadline_ms``: per-request latency budget; in streaming mode it
      bounds tier-queue waiting (requests drain no later than the deadline)
      and sizes the admission batching window.  ``0`` = no deadline.
    - ``max_ef``: hard cap on the exploration budget (``0`` = the index's
      ``ef_cap``); estimates above it are clamped, trading recall for a
      bounded worst case.
    - ``mode``: ``oneshot`` (one fused batch call), ``routed`` (ef-tier
      bucketed dispatch), ``streaming`` (submit/step/poll lifecycle).
    - ``backend``: kernel dispatch; ``auto`` probes capabilities (TPU ->
      ``pallas``; otherwise the index's build-time choice, i.e. ``oracle``
      unless it was built on kernels).
    - ``precision``: estimation-tier scoring precision (``fp32`` | ``int8``
      | ``fp8``).  Non-fp32 scores traversal/estimation distances against a
      calibrated quantized panel (built lazily per index, extended
      incrementally on insert) and re-ranks the final ef candidates at fp32
      before emitting top-k — ~4x less estimation distance bandwidth at a
      recall delta bounded by the re-rank.  ``fp8`` requires a jax build
      with ``float8_e4m3fn`` and always scores through the jnp oracle.
    - ``on_mutation``: what a *held* plan does when the index mutates under
      it.  ``revalidate`` (default): the plan rebinds to the new epoch —
      compiled executors survive when the shape signature is unchanged
      (tombstone deletes always; inserts re-plan transparently) and live
      schedulers are fenced so pending tickets complete against the
      pre-mutation snapshot.  ``strict``: any use after a mutation raises
      :class:`repro.serve.api.StalePlanError` — for callers that treat a
      plan as a point-in-time snapshot contract.
    - ``filter``: optional :class:`repro.filter.FilterSpec` predicate
      (tenant / categorical attrs / numeric-date ranges / id range).  The
      planner compiles it against the index's attribute store into a
      per-node validity mask, estimates its selectivity from attribute
      histograms, and lowers to pre-filter (dense mask rides the tombstone
      admission seam) or post-filter-with-overquery (ef inflated by
      ~1/selectivity, heap epilogue) — recorded in
      ``plan.explain()["filter"]``.  The recall contract then holds over
      the *filtered* ground truth.  A ``filter.tenant`` also labels the
      request for per-tenant SLO/quota resolution in streaming mode.
    - ``overrides``: :class:`SpecOverrides` expert escape hatch.
    """

    k: Optional[int] = None
    target_recall: Optional[float] = None
    deadline_ms: float = 0.0
    max_ef: int = 0
    mode: str = MODE_ONESHOT
    backend: str = BACKEND_AUTO
    precision: str = PRECISION_FP32
    on_mutation: str = ON_MUTATION_REVALIDATE
    filter: Optional[FilterSpec] = None
    overrides: SpecOverrides = SpecOverrides()

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode={self.mode!r} not in {MODES}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend={self.backend!r} not in {BACKENDS}")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision={self.precision!r} not in {PRECISIONS}"
            )
        if self.on_mutation not in ON_MUTATION_MODES:
            raise ValueError(
                f"on_mutation={self.on_mutation!r} not in {ON_MUTATION_MODES}"
            )
        if self.k is not None and self.k < 1:
            raise ValueError(f"k={self.k} must be >= 1")
        if self.target_recall is not None and not 0.0 < self.target_recall <= 1.0:
            raise ValueError(
                f"target_recall={self.target_recall} not in (0, 1]"
            )
        if self.deadline_ms < 0:
            raise ValueError(f"deadline_ms={self.deadline_ms} must be >= 0")
        if self.max_ef < 0:
            raise ValueError(f"max_ef={self.max_ef} must be >= 0")
        if self.filter is not None and not isinstance(self.filter, FilterSpec):
            raise ValueError(
                f"filter must be a FilterSpec, got {type(self.filter).__name__}"
            )
        if self.filter is not None and self.filter.trivial:
            # a no-op predicate lowers identically to no predicate; normalize
            # so both spell the same plan-cache key
            object.__setattr__(self, "filter", None)

    def as_dict(self) -> dict:
        """JSON-friendly form; ``from_dict`` round-trips it exactly."""
        d = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if f.name not in ("overrides", "filter")
        }
        d["filter"] = None if self.filter is None else self.filter.as_dict()
        d["overrides"] = self.overrides.as_dict()
        return d

    @staticmethod
    def from_dict(d: dict) -> "SearchSpec":
        d = dict(d)
        overrides = SpecOverrides.from_dict(d.pop("overrides", None) or {})
        filt = d.pop("filter", None)
        if filt is not None and not isinstance(filt, FilterSpec):
            filt = FilterSpec.from_dict(filt)
        return SearchSpec(overrides=overrides, filter=filt, **d)
