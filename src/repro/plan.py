"""Planner: lower a declarative :class:`repro.api.SearchSpec` into a
compiled :class:`ExecutionPlan` — the DB-style "query plan" of this system.

``AdaEfIndex.plan(spec)`` is the entry point (plans are cached on the index
keyed by ``(spec, shape-signature)`` and invalidated on ``insert``/
``delete``); this module is the lowering itself:

1. **Backend resolution** — a capability probe replaces the old live
   ``use_distance_kernel`` flag: ``auto`` picks fused Pallas kernels on TPU,
   falls back to the index's build-time dispatch elsewhere, and an explicit
   ``pallas``/``interpret`` request degrades gracefully (probe-verified) to
   the next backend that actually runs here.
2. **Loop strategy** — ``oneshot`` inherits the loop the index (and its
   ef table) was built with; ``routed``/``streaming`` lower to the
   batch-hoisted loop, whose one-padded-batch-per-tier shape is exactly what
   tier drains dispatch (bit-identical to the vmap loop either way).
3. **Estimation budget + tier ladder + drain policy** — the legacy
   ``RouterConfig``/``SchedulerConfig`` become derived lowering targets:
   ``oneshot`` pins fixed beams (so the lifecycle path of a oneshot plan is
   bit-identical to the fused search), a ``deadline_ms`` sizes the admission
   batching window, and :class:`repro.api.SpecOverrides` pins any of them
   outright.

Every derived decision is recorded and reported by
:meth:`ExecutionPlan.explain` — benchmarks and bug reports read the plan
instead of reverse-engineering configs.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    BACKEND_AUTO,
    BACKEND_INTERPRET,
    BACKEND_ORACLE,
    BACKEND_PALLAS,
    MODE_ONESHOT,
    ON_MUTATION_STRICT,
    RouterConfig,
    SchedulerConfig,
    SearchSpec,
    register_static_config,
)
from repro.filter import FilterCompileError, attach_mask
from repro.index.search import SearchResult, adaptive_search, recall_at_k
from repro.kernels import ops
from repro.obs import Histogram, MetricsRegistry, oracle_topk
from repro.serve.api import (
    InvalidQueryError,
    SearchRequest,
    SearchResponse,
    SearchTicket,
    StalePlanError,
)
from repro.serve.router import QueryRouter
from repro.serve.scheduler import AdaServeScheduler

_probe_cache: dict = {}

# Filtered-search lowering policy (ISSUE 10): selectivity = pass fraction.
# Below the threshold the predicate is selective enough that the dense mask
# (pre-filter on the tombstone admission seam) wins — the W bound stays loose
# so traversal widens on its own and the estimation pass runs under the mask.
# Above it, most rows pass, so unmasked traversal at inflated ef plus a heap
# epilogue (post-filter with overquery) keeps the masked scoring cost off the
# hot loop; the inflation is ~1/selectivity, capped.
FILTER_PRE_THRESHOLD = 0.5
FILTER_MAX_INFLATE = 64.0


def probe_interpret() -> bool:
    """Can the Pallas frontier kernel run here in interpret mode?  One tiny
    probe call, memoized for the process — the planner's capability check."""
    if "interpret" not in _probe_cache:
        try:
            vec = jnp.ones((8, 8), jnp.float32)
            ids = jnp.asarray([[0, 1, -1, 2]], jnp.int32)
            q = jnp.ones((1, 8), jnp.float32)
            out = ops.frontier_keys(
                ids, q, vec, use_kernel=True, interpret=True
            )
            _probe_cache["interpret"] = bool(
                np.isfinite(np.asarray(out)[0, :2]).all()
            )
        except Exception:  # pragma: no cover - no working Pallas lowering
            _probe_cache["interpret"] = False
    return _probe_cache["interpret"]


def resolve_backend(requested: str, built_on_kernels: bool):
    """Lower a spec's backend request to what actually runs on this host.

    Returns ``(resolved, use_kernel, note)``.  ``auto`` keeps the index's
    build-time dispatch off-TPU: its ef table was probed through that scorer,
    and the interpret-mode kernel is only float-close (not bit-equal) to the
    jnp oracle, so silently switching would break the bit-exactness bar.
    """
    on_tpu = jax.default_backend() == "tpu"
    if requested == BACKEND_AUTO:
        if on_tpu:
            return BACKEND_PALLAS, True, "auto: TPU -> fused Pallas kernels"
        if built_on_kernels and probe_interpret():
            return (
                BACKEND_INTERPRET,
                True,
                "auto: index built on kernels; interpret mode off-TPU",
            )
        return BACKEND_ORACLE, False, "auto: no TPU -> jnp reference scorers"
    if requested == BACKEND_PALLAS:
        if on_tpu:
            return BACKEND_PALLAS, True, "pallas: TPU backend"
        if probe_interpret():
            return (
                BACKEND_INTERPRET,
                True,
                "pallas requested off-TPU -> interpret-mode fallback",
            )
        return BACKEND_ORACLE, False, "pallas unavailable -> jnp oracle"
    if requested == BACKEND_INTERPRET:
        if probe_interpret():
            return BACKEND_INTERPRET, True, "interpret: probe ok"
        return BACKEND_ORACLE, False, "interpret probe failed -> jnp oracle"
    return BACKEND_ORACLE, False, "oracle: jnp reference scorers (explicit)"


def shape_signature(index) -> tuple:
    """The plan-cache shape key: everything about the graph that compiled
    shapes depend on.  Changes on insert/delete (n moves), never on a pure
    config change."""
    g = index.graph
    return (
        int(g.vectors.shape[0]),
        int(g.vectors.shape[1]),
        int(g.base_adj.shape[1]),
        int(g.upper_adj.shape[0]),
    )


def plan_spec(index, spec: SearchSpec) -> "ExecutionPlan":
    """Lower ``spec`` against ``index`` into an :class:`ExecutionPlan`.

    Pure policy: nothing is compiled or dispatched here (the plan's lazily
    built router/scheduler own the jit caches), so planning is cheap enough
    to run per (spec, shape) cache miss.
    """
    ov = spec.overrides
    k = index.k if spec.k is None else int(spec.k)
    if not 1 <= k <= index.k:
        raise ValueError(f"spec.k={k} not in [1, index k={index.k}]")
    target = (
        index.target_recall
        if spec.target_recall is None
        else float(spec.target_recall)
    )

    cfg = ov.search if ov.search is not None else index.search_cfg
    notes: List[str] = []
    if spec.max_ef > 0 and spec.max_ef < cfg.ef_cap:
        cap = max(int(spec.max_ef), cfg.k)
        notes.append(f"max_ef clamps ef_cap {cfg.ef_cap} -> {cap}")
        cfg = dataclasses.replace(
            cfg, ef_cap=cap, beam=min(cfg.beam, cap)
        )
    backend, use_kernel, backend_note = resolve_backend(
        spec.backend, cfg.use_distance_kernel
    )
    if ov.search is None and spec.mode != MODE_ONESHOT and not cfg.batch_hoisted:
        # tier drains dispatch one padded same-capacity batch per rung — the
        # exact shape the hoisted loop is built for (bit-identical results)
        notes.append("serving mode -> batch-hoisted loop")
        cfg = dataclasses.replace(cfg, batch_hoisted=True)
    cfg = dataclasses.replace(cfg, use_distance_kernel=use_kernel)

    # quantized estimation tier: a pinned search config owns precision
    # outright; otherwise the spec's request lowers here.  Materializing the
    # panel attaches it to the index graph, so every executor this plan
    # builds (router tiers, schedulers, epochs) carries it transparently.
    precision = ov.search.precision if ov.search is not None else spec.precision
    if precision != "fp32":
        from repro.quant import supported_precisions

        if precision not in supported_precisions():
            notes.append(
                f"precision {precision} unsupported in this jax build -> fp32"
            )
            precision = "fp32"
        else:
            index.ensure_panel(precision)
            notes.append(
                f"quantized estimation tier: {precision} panel, "
                "fp32 re-rank of the final ef candidates"
            )
    cfg = dataclasses.replace(cfg, precision=precision)

    ada = ov.ada if ov.ada is not None else index.ada_cfg
    if ov.router is not None:
        rcfg = ov.router
    elif spec.mode == MODE_ONESHOT:
        # the lifecycle path of a oneshot plan must reproduce the fused
        # search bit-for-bit: lossless estimation + the base beam per tier
        rcfg = RouterConfig(beam_mode="fixed")
        notes.append("oneshot -> lossless fixed-beam lifecycle path")
    else:
        rcfg = RouterConfig()
    if ov.scheduler is not None:
        scfg = ov.scheduler
    elif spec.deadline_ms > 0:
        # batch admissions up to half the budget; the other half covers the
        # tier-queue wait the deadline trigger itself bounds.  A deadline
        # spec also arms the degradation ladder: the caller declared latency
        # to matter, so at-risk requests demote (DEGRADED) and blown
        # deadlines answer from phase A (PARTIAL) instead of silently
        # missing — the explicit opt-out is a pinned SpecOverrides.scheduler
        scfg = SchedulerConfig(est_wait_s=spec.deadline_ms / 2e3, degrade=True)
        notes.append("deadline_ms sizes the admission batching window")
        notes.append("deadline_ms arms the degradation ladder (degrade=True)")
    else:
        scfg = SchedulerConfig()

    # filtered search (ISSUE 10): policy only — the mask itself compiles
    # lazily on first executor build (ExecutionPlan._filter_mask).  The
    # attribute store's histograms estimate the predicate's pass fraction
    # and pick the lowering; either way the recall contract is over the
    # *filtered* ground truth (pre: the estimation pass runs under the
    # mask; post: ef_margin overqueries so ~ef passing rows survive the
    # heap epilogue).
    filter_plan = None
    if spec.filter is not None:
        filt = spec.filter
        store = index.attributes
        if filt.needs_store() and store is None:
            raise FilterCompileError(
                "SearchSpec.filter references attributes (tenant/"
                "categorical/numeric ranges) but the index has no attribute "
                "store; call index.attach_attributes(...) first"
            )
        n = shape_signature(index)[0]
        if store is not None:
            sel = float(store.estimate_selectivity(filt))
        else:  # id_range-only predicates are positional: exact, no store
            lo, hi = filt.id_range
            sel = max(min(hi, n) - max(lo, 0), 0) / max(n, 1)
        pinned = ov.search is not None and ov.search.filter_mode != "off"
        if pinned:
            fmode = ov.search.filter_mode
            notes.append(f"filter_mode={fmode!r} pinned by overrides.search")
        else:
            fmode = "pre" if sel < FILTER_PRE_THRESHOLD else "post"
            if fmode == "post" and spec.mode == MODE_ONESHOT:
                # the fused oneshot path has no ef-margin seam to overquery
                # through — lower to the (always-correct) dense mask instead
                fmode = "pre"
                notes.append("oneshot filter -> pre (no overquery seam)")
        inflate = 1.0
        if fmode == "post":
            inflate = float(
                np.clip(1.0 / max(sel, 1e-3), 1.0, FILTER_MAX_INFLATE)
            )
            if ov.router is None:
                rcfg = dataclasses.replace(
                    rcfg, ef_margin=max(rcfg.ef_margin, inflate)
                )
            else:
                notes.append(
                    "pinned router: post-filter keeps its ef_margin as-is"
                )
        cfg = dataclasses.replace(cfg, filter_mode=fmode)
        notes.append(
            f"filter: selectivity~{sel:.4f} -> {fmode}-filter"
            + (f" (ef_margin -> {rcfg.ef_margin:.2f})" if fmode == "post" else "")
        )
        filter_plan = {
            "mode": fmode,
            "selectivity_estimate": sel,
            "ef_inflation": inflate,
            "pinned": bool(pinned),
            "tenant": filt.tenant,
        }

    return ExecutionPlan(
        index,
        spec,
        k=k,
        target_recall=target,
        search_cfg=cfg,
        ada_cfg=ada,
        router_cfg=rcfg,
        scheduler_cfg=scfg,
        backend=backend,
        backend_note=backend_note,
        notes=notes,
        filter_plan=filter_plan,
    )


@register_static_config
class ExecutionPlan:
    """A lowered, executable search plan bound to one index snapshot.

    Execution surface:

    - :meth:`search` — batch call in the spec's mode (fused ``oneshot`` or a
      submit-all/drain-all lifecycle barrier for ``routed``/``streaming``).
    - :meth:`submit` / :meth:`step` / :meth:`poll` / :meth:`drain` — the
      request lifecycle over the plan's (lazily built, shared) scheduler.
    - :meth:`explain` — every derived decision as a dict or EXPLAIN string.

    Plans are immutable policy + lazily built executors; they hold the
    index's graph/table references per *epoch*.  ``insert``/``delete``
    no longer kill a held plan: :meth:`revalidate` rebinds it to the
    post-mutation epoch — when the shape signature and the spec's lowering
    are unchanged (every tombstone delete) only the array references swap
    and the shape-keyed compiled executors stay warm; otherwise the plan
    transparently re-plans.  ``_check_fresh`` auto-revalidates on use, so
    the only way to see :class:`StalePlanError` from a plan is to opt in
    with ``SearchSpec(on_mutation="strict")``.  Two plans lowered from
    equal specs against the same index snapshot compare and hash equal —
    like the specs themselves, a plan is a static pytree and can cross
    ``jit`` boundaries without retriggering compilation.
    """

    def __init__(
        self,
        index,
        spec: SearchSpec,
        *,
        k: int,
        target_recall: float,
        search_cfg,
        ada_cfg,
        router_cfg,
        scheduler_cfg,
        backend: str,
        backend_note: str = "",
        notes: Sequence[str] = (),
        filter_plan: Optional[dict] = None,
    ):
        self._index = index
        self.spec = spec
        self.mode = spec.mode
        self.k = k
        self.target_recall = target_recall
        self.deadline_s = spec.deadline_ms / 1e3 if spec.deadline_ms else None
        self.search_cfg = search_cfg
        self.ada_cfg = ada_cfg
        self.router_cfg = router_cfg
        self.scheduler_cfg = scheduler_cfg
        self.backend = backend
        self._backend_note = backend_note
        self._notes = list(notes)
        self.filter_plan = filter_plan
        self._shape_sig = shape_signature(index)
        self._version = index._graph_version
        self._fmask = None  # compiled predicate mask (lazy; see _filter_mask)
        self._router: Optional[QueryRouter] = None
        self._scheduler: Optional[AdaServeScheduler] = None
        self._metrics: Optional[MetricsRegistry] = None
        self._sessions: "weakref.WeakSet" = weakref.WeakSet()  # live
        #   schedulers built through new_scheduler(); revalidation absorbs
        #   them through the mutation seam, weak refs keep one-shot barrier
        #   schedulers collectable

    # ------------------------------------------------------------- identity
    def __eq__(self, other) -> bool:
        # index identity is part of plan identity: two same-shape indexes
        # over different corpora must not share a jit compile-cache entry
        # (a plan is a static pytree — equal plans alias compiled constants)
        return (
            isinstance(other, ExecutionPlan)
            and self._index is other._index
            and self.spec == other.spec
            and self._shape_sig == other._shape_sig
            and self._version == other._version
        )

    def __hash__(self) -> int:
        return hash((id(self._index), self.spec, self._shape_sig, self._version))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"ExecutionPlan(mode={self.mode}, backend={self.backend}, "
            f"loop={self.loop}, k={self.k}, "
            f"target_recall={self.target_recall}, shape={self._shape_sig})"
        )

    @property
    def loop(self) -> str:
        hoisted = (
            self.router_cfg.batch_hoisted
            if self.router_cfg.batch_hoisted is not None
            else self.search_cfg.batch_hoisted
        )
        return "batch_hoisted" if hoisted else "vmap"

    @property
    def stale(self) -> bool:
        """Has the index been mutated since this plan was lowered?"""
        return (
            self._index._graph_version != self._version
            or shape_signature(self._index) != self._shape_sig
        )

    def _check_fresh(self):
        """Gate every use: a fresh plan passes, a mutated-under plan either
        auto-revalidates (the default) or — for strict specs — raises."""
        if not self.stale:
            return
        if self.spec.on_mutation == ON_MUTATION_STRICT:
            raise StalePlanError(
                f"stale ExecutionPlan: the index was mutated after this "
                f"plan was lowered (graph version {self._version} -> "
                f"{self._index._graph_version}) and SearchSpec("
                "on_mutation='strict') refuses revalidation by contract; "
                "call index.plan(spec) again for a fresh one"
            )
        self.revalidate()

    def revalidate(self) -> str:
        """Rebind this plan to the index's current epoch after a mutation.

        Returns the outcome: ``"fresh"`` (nothing to do), ``"rebound"``
        (shape signature and the spec's lowering are unchanged — every
        tombstone delete — so only the graph/stats/table references swap
        and the shape-keyed compiled executors stay warm), or
        ``"replanned"`` (an insert moved ``n``, or the derived policy
        changed, so the plan adopts the fresh lowering; jit caches re-key
        by shape on first use).  Live schedulers from :meth:`new_scheduler`
        (the shared lifecycle surface included) are absorbed through their
        mutation seam: pending tickets complete against the pre-mutation
        epoch, new work binds the new one.  Strict plans raise
        :class:`StalePlanError` instead of rebinding.
        """
        if not self.stale:
            return "fresh"
        if self.spec.on_mutation == ON_MUTATION_STRICT:
            self._check_fresh()  # raises the strict StalePlanError
        fresh = plan_spec(self._index, self.spec)
        rebound = (
            fresh._shape_sig == self._shape_sig
            and fresh.k == self.k
            and fresh.target_recall == self.target_recall
            and fresh.search_cfg == self.search_cfg
            and fresh.ada_cfg == self.ada_cfg
            and fresh.router_cfg == self.router_cfg
            and fresh.scheduler_cfg == self.scheduler_cfg
            and fresh.backend == self.backend
            and fresh.filter_plan == self.filter_plan
        )
        if not rebound:
            self.k = fresh.k
            self.target_recall = fresh.target_recall
            self.deadline_s = fresh.deadline_s
            self.search_cfg = fresh.search_cfg
            self.ada_cfg = fresh.ada_cfg
            self.router_cfg = fresh.router_cfg
            self.scheduler_cfg = fresh.scheduler_cfg
            self.backend = fresh.backend
            self._backend_note = fresh._backend_note
            self._notes = fresh._notes
            self.filter_plan = fresh.filter_plan
        # pass the staleness gate *before* touching executors: the session
        # absorbs below re-enter through self.router
        self._shape_sig = fresh._shape_sig
        self._version = fresh._version
        self._router = None
        self._fmask = None  # mask recompiles over the new epoch's rows
        for sched in list(self._sessions):
            sched.absorb_mutation(router=self.router)
        outcome = "rebound" if rebound else "replanned"
        self.metrics.counter("plan_revalidations", outcome=outcome).inc()
        return outcome

    def sessions(self) -> List[AdaServeScheduler]:
        """Live schedulers created through :meth:`new_scheduler` (weakly
        held — collected barrier schedulers drop out on their own)."""
        return list(self._sessions)

    # ------------------------------------------------------------ executors
    def _filter_mask(self):
        """The spec's compiled per-node validity bitmask (lazy; dropped on
        revalidate so it always describes the index's current rows)."""
        if self.spec.filter is None:
            return None
        if self._fmask is None:
            filt = self.spec.filter
            store = self._index.attributes
            n = self._shape_sig[0]
            if store is not None:
                mask = store.compile_mask(filt, n)
            else:  # id_range-only (plan_spec rejects store-needing specs)
                mask = np.zeros(n, bool)
                lo, hi = filt.id_range
                mask[max(lo, 0): max(hi, 0)] = True
            self._fmask = jnp.asarray(mask, bool)
        return self._fmask

    @property
    def _tenant(self) -> Optional[str]:
        """The spec's tenant namespace (labels lifecycle requests so the
        scheduler resolves per-tenant SLOs/quotas without extra plumbing)."""
        return None if self.spec.filter is None else self.spec.filter.tenant

    def _graph(self):
        """The graph this plan executes against: the index's current epoch,
        carrying the compiled predicate mask for filtered plans (an
        immutable masked copy — the shared index graph is never touched)."""
        g = self._index.graph
        mask = self._filter_mask()
        return g if mask is None else attach_mask(g, mask)

    @property
    def router(self) -> QueryRouter:
        """The lowered routing policy + executor (lazily built).  Filtered
        plans hand the router a mask-attached graph copy, so every executor
        built from it (tier drains, schedulers, epoch snapshots, the
        auditor's oracle) sees the predicate without extra plumbing."""
        if self._router is None:
            self._check_fresh()
            idx = self._index
            self._router = QueryRouter(
                self._graph(),
                idx.stats,
                idx.table,
                self.search_cfg,
                self.ada_cfg,
                self.router_cfg,
                est_table_builder=idx.estimation_table,
            )
        return self._router

    @property
    def metrics(self) -> MetricsRegistry:
        """The plan's metrics registry (lazily built).  Every scheduler the
        plan creates — the shared lifecycle surface, batch-call barriers,
        engine sessions through :meth:`new_scheduler` — mirrors its counters
        and latency histograms here, so one registry aggregates all traffic
        this plan ever served (export via ``as_dict()`` /
        ``render_prometheus()``; see :mod:`repro.obs.metrics`)."""
        if self._metrics is None:
            self._metrics = MetricsRegistry()
        return self._metrics

    def new_scheduler(self, cfg=None, **kwargs) -> AdaServeScheduler:
        """A private scheduler over this plan's router — for callers that
        must not share queues/polls with the plan's own lifecycle surface
        (e.g. one engine batch on an index whose plan a streaming driver
        also holds).  Compile caches are shared through the router, and the
        scheduler reports into the plan's :attr:`metrics` registry unless a
        caller passes its own.  ``cfg`` overrides the plan's lowered
        ``SchedulerConfig`` (drivers use this to arm ``trace``/
        ``audit_fraction`` without re-planning)."""
        self._check_fresh()
        kwargs.setdefault("default_target_recall", self.target_recall)
        kwargs.setdefault("metrics", self.metrics)
        idx = self._index
        kwargs.setdefault("version_probe", lambda: idx._graph_version)
        kwargs.setdefault("router_probe", lambda: self.router)
        sched = AdaServeScheduler(
            self.router, cfg or self.scheduler_cfg, **kwargs
        )
        self._sessions.add(sched)
        return sched

    @property
    def scheduler(self) -> AdaServeScheduler:
        """The plan's shared scheduler (lazily built) — the surface behind
        :meth:`submit`/:meth:`poll`.  Checks freshness on every access: a
        mutated-under plan revalidates (strict plans raise) before any
        request can drain against the wrong epoch — deleted rows must not
        come back as *new* results, while in-flight tickets complete on
        the pre-mutation snapshot they were dispatched on."""
        self._check_fresh()
        if self._scheduler is None:
            self._scheduler = self.new_scheduler()
        return self._scheduler

    # -------------------------------------------------------------- execute
    def search(
        self,
        queries,
        target_recall: Optional[float] = None,
        *,
        with_stats: bool = False,
    ):
        """Execute the plan over a query batch; results in request order.

        ``target_recall`` overrides the spec's target for this call only (a
        runtime value — no recompilation).  ``with_stats=True`` additionally
        returns the batch telemetry (a ``RouterStats`` for lifecycle modes,
        ``None`` for the fused oneshot path, which has no tier structure).
        """
        self._check_fresh()
        queries = self._validate_queries(queries)
        target = self.target_recall if target_recall is None else float(target_recall)
        if self.mode == MODE_ONESHOT:
            idx = self._index
            res = adaptive_search(
                self._graph(),  # filtered plans search the masked copy
                jnp.asarray(queries),
                idx.stats,
                idx.table,
                jnp.asarray(target, jnp.float32),
                self.search_cfg,
                self.ada_cfg,
            )
            res = self._slice_k(res)
            return (res, None) if with_stats else res

        t0 = time.perf_counter()
        # a one-shot private scheduler: the plan's shared lifecycle surface
        # (submit/poll) keeps its own queues untouched by batch calls
        sched = self.new_scheduler(default_target_recall=target)
        tickets = [
            sched.submit(SearchRequest(query=q, k=self.k, tenant=self._tenant))
            for q in queries
        ]
        by_uid = {r.ticket.uid: r for r in sched.drain()}
        ordered = [by_uid[t.uid] for t in tickets]
        out = SearchResult(
            ids=np.stack([r.ids for r in ordered]),
            dists=np.stack([r.dists for r in ordered]),
            ndist=np.asarray([r.ndist for r in ordered], np.int32),
            iters=np.asarray([r.iters for r in ordered], np.int32),
            ef_used=np.asarray([r.ef_used for r in ordered], np.int32),
            ndist_q=np.asarray([r.ndist_q for r in ordered], np.int32),
        )
        if not with_stats:
            return out
        stats = sched.router_stats()
        stats.total_wall_s = time.perf_counter() - t0
        return out, stats

    def _validate_queries(self, queries) -> np.ndarray:
        """Input hardening shared by both execution modes: typed
        :class:`InvalidQueryError` (a ``ValueError``) before anything is
        dispatched — a NaN row must never reach a fused batch search or a
        shared estimation pass."""
        arr = np.asarray(queries)
        if arr.dtype.kind not in "fiu":
            raise InvalidQueryError(
                f"queries dtype {arr.dtype} is not numeric (expected float32)"
            )
        q = arr.astype(np.float32)
        if q.ndim != 2 or len(q) == 0:
            raise InvalidQueryError(
                f"expected (B, d) queries, got {tuple(arr.shape)}"
            )
        dim = self._shape_sig[1]
        if q.shape[1] != dim:
            raise InvalidQueryError(
                f"query dimensionality {q.shape[1]} != index dim {dim}"
            )
        bad = np.nonzero(~np.isfinite(q).all(axis=1))[0]
        if bad.size:
            raise InvalidQueryError(
                f"queries contain NaN/Inf values (rows {bad.tolist()[:8]})"
            )
        return q

    def _slice_k(self, res: SearchResult) -> SearchResult:
        if self.k == self.search_cfg.k:
            return res
        return res._replace(
            ids=res.ids[..., : self.k], dists=res.dists[..., : self.k]
        )

    # ------------------------------------------------------------ lifecycle
    def submit(self, request) -> SearchTicket:
        """Admit one request into the plan's shared scheduler.  Accepts a
        :class:`SearchRequest` or a bare ``(d,)`` query; the spec's ``k``,
        ``target_recall`` and ``deadline_ms`` fill any unset fields."""
        self._check_fresh()
        if not isinstance(request, SearchRequest):
            request = SearchRequest(query=np.asarray(request, np.float32))
        patch = {}
        if request.k is None:
            patch["k"] = self.k
        if request.deadline_s is None and self.deadline_s is not None:
            patch["deadline_s"] = self.deadline_s
        if request.tenant is None and self._tenant is not None:
            patch["tenant"] = self._tenant
        if patch:
            request = dataclasses.replace(request, **patch)
        return self.scheduler.submit(request)

    def step(self, now: Optional[float] = None, *, force: bool = False) -> int:
        return self.scheduler.step(now, force=force)

    def poll(
        self, *, block: bool = False, uids: Optional[Sequence[int]] = None
    ) -> List[SearchResponse]:
        return self.scheduler.poll(block=block, uids=uids)

    def flush(self) -> int:
        return self.scheduler.flush()

    def drain(self) -> List[SearchResponse]:
        return self.scheduler.drain()

    @property
    def pending(self) -> int:
        return 0 if self._scheduler is None else self._scheduler.pending

    def router_stats(self, since=None):
        return self.scheduler.router_stats(since)

    @property
    def stats(self):
        return self.scheduler.stats

    def queue_depths(self) -> List[int]:
        return self.scheduler.queue_depths()

    # -------------------------------------------------------------- explain
    def explain(
        self,
        fmt: str = "dict",
        *,
        analyze: bool = False,
        queries=None,
        nq: int = 32,
    ):
        """Every derived decision, DB-EXPLAIN style.

        ``fmt="dict"`` returns a JSON-able dict that round-trips the spec
        (``SearchSpec.from_dict(explain()["spec"]) == plan.spec``) and
        records each lowered config verbatim; ``fmt="text"`` renders the
        human-readable plan.  Without ``analyze``, reading the plan never
        compiles or dispatches a search (the router it may build is
        policy-only until first use).

        ``analyze=True`` is the EXPLAIN ANALYZE of this system: it
        *executes* the plan's mode over ``queries`` (default: ``nq``
        deterministic corpus rows) — warm-up pass first, so compile time is
        excluded — and merges live measurements into the static tree under
        ``"analyze"``: walls, cumulative ndist, padding waste, terminal
        status split, request-latency quantiles, and achieved-recall
        samples vs the oracle ``ef_cap`` reference (100%-sampled
        :class:`repro.obs.audit.RecallAuditor` for lifecycle modes).  The
        result stays JSON round-trippable.
        """
        router = self.router
        cfg = router.base_cfg
        m0 = self._shape_sig[2]
        est_lossless = not router.est_lossy
        if self.search_cfg.use_distance_kernel:
            frontier = (
                "pallas" if self.backend == BACKEND_PALLAS else "pallas-interpret"
            )
            dispatch = (
                "ops.frontier_keys_batch"
                if cfg.batch_hoisted
                else "ops.frontier_keys"
            )
        else:
            frontier = "jnp-oracle"
            dispatch = (
                "ref.frontier_batch_ref" if cfg.batch_hoisted else "_gather_keys"
            )
        from repro.quant import graph_resident_bytes, panel_of

        quantized = cfg.precision != "fp32"
        panel = panel_of(router.graph)
        if quantized:
            if cfg.use_distance_kernel and cfg.batch_hoisted:
                frontier = frontier.replace("pallas", "pallas-int8")
            dispatch = (
                "ops.frontier_keys_batch[qpanel]"
                if cfg.batch_hoisted
                else "_gather_keys_q"
            )
        precision_d = {
            "requested": self.spec.precision,
            "resolved": cfg.precision,
            "panel_dtype": (
                str(np.dtype(panel.codes.dtype)) if panel is not None else "float32"
            ),
            "resident_bytes": graph_resident_bytes(router.graph),
            # fp32 re-rank depth = the W capacity of the tier a query lands
            # on (its ef); cfg.ef_cap is the cross-tier maximum
            "rerank_depth": cfg.ef_cap if quantized else 0,
        }
        d = {
            "spec": self.spec.as_dict(),
            "mode": self.mode,
            "loop": self.loop,
            "backend": {
                "requested": self.spec.backend,
                "resolved": self.backend,
                "note": self._backend_note,
                # what a *runtime* dispatch failure falls to, in order (the
                # scheduler retries the resolved backend once, then walks
                # these rungs; see AdaServeScheduler._attempt_ladder)
                "runtime_fallback": (
                    ["retry", "oracle"]
                    if self.search_cfg.use_distance_kernel
                    else ["retry"]
                ),
            },
            "kernels": {"frontier": frontier, "dispatch": dispatch},
            "precision": precision_d,
            "k": {"index": self._index.k, "request": self.k},
            "target_recall": self.target_recall,
            "deadline_s": self.deadline_s,
            "graph": {
                "n": self._shape_sig[0],
                "d": self._shape_sig[1],
                "m0": self._shape_sig[2],
                "upper_layers": self._shape_sig[3],
            },
            "search": {
                "ef_cap": cfg.ef_cap,
                "beam": cfg.beam,
                "metric": cfg.metric,
                "max_iters": cfg.iters(),
                "patience": cfg.patience,
                "batch_hoisted": cfg.batch_hoisted,
                "use_distance_kernel": cfg.use_distance_kernel,
                "filter_mode": cfg.filter_mode,
            },
            "filter": (
                None
                if self.filter_plan is None
                else {"spec": self.spec.filter.as_dict(), **self.filter_plan}
            ),
            "estimation": {
                "cap": router.est_cfg.ef_cap,
                "lmax": router.est_ada.buf(m0),
                "lossless": bool(est_lossless),
                "matched_table": bool(router.est_matched),
                "ef_margin": router.router_cfg.ef_margin,
            },
            "tiers": [
                {"ef": t.ef, "beam": t.beam, "max_iters": t.cfg.iters()}
                for t in router.tiers
            ],
            "scheduler": {
                "fill": self.scheduler_cfg.fill,
                "est_wait_s": self.scheduler_cfg.est_wait_s,
                "work_conserving": self.scheduler_cfg.work_conserving,
                "flush_margin_s": self.scheduler_cfg.flush_margin_s,
                "max_inflight": self.scheduler_cfg.max_inflight,
                "max_tier_queue": self.scheduler_cfg.max_tier_queue,
                "overload": self.scheduler_cfg.overload,
                "degrade": self.scheduler_cfg.degrade,
                "tenants": [name for name, _ in self.scheduler_cfg.tenants],
            },
            "pad": {
                "policy": "pow2",
                "min_shape": self.scheduler_cfg.min_shape
                or router.router_cfg.min_shape,
            },
            "cache": {
                "shape_signature": list(self._shape_sig),
                "graph_version": self._version,
            },
            "notes": list(self._notes),
        }
        if analyze:
            d["analyze"] = self._analyze(queries, nq)
        if fmt == "dict":
            return d
        if fmt != "text":
            raise ValueError(f"fmt={fmt!r} not in ('dict', 'text')")
        s = self.spec
        ov = [
            f.name
            for f in dataclasses.fields(s.overrides)
            if getattr(s.overrides, f.name) is not None
        ]
        tiers = " ".join(f"ef{t['ef']}/beam{t['beam']}" for t in d["tiers"])
        lines = [
            f"ExecutionPlan  mode={self.mode}  loop={self.loop}  "
            f"backend={self.spec.backend}->{self.backend}",
            f"  spec: k={s.k} target_recall={s.target_recall} "
            f"deadline_ms={s.deadline_ms} max_ef={s.max_ef} "
            f"overrides={ov or 'none'}",
            f"  graph: n={d['graph']['n']} d={d['graph']['d']} "
            f"m0={d['graph']['m0']} upper_layers={d['graph']['upper_layers']} "
            f"(version {self._version})",
            f"  search: k={self.k} ef_cap={cfg.ef_cap} beam={cfg.beam} "
            f"metric={cfg.metric} max_iters={cfg.iters()} "
            f"frontier={frontier} via {dispatch}",
            f"  estimation: cap={d['estimation']['cap']} "
            f"lmax={d['estimation']['lmax']} "
            f"lossless={d['estimation']['lossless']} "
            f"matched_table={d['estimation']['matched_table']} "
            f"ef_margin={d['estimation']['ef_margin']}",
            f"  precision: {self.spec.precision}->{cfg.precision} "
            f"panel={precision_d['panel_dtype']} "
            f"rerank_depth={precision_d['rerank_depth']} resident_bytes="
            + " ".join(
                f"{k}={v}" for k, v in precision_d["resident_bytes"].items()
            ),
            f"  tiers: {tiers}  (pad=pow2 min_shape={d['pad']['min_shape']})",
            f"  scheduler: fill={self.scheduler_cfg.fill} "
            f"est_wait_s={self.scheduler_cfg.est_wait_s} "
            f"work_conserving={self.scheduler_cfg.work_conserving} "
            f"flush_margin_s={self.scheduler_cfg.flush_margin_s} "
            f"max_inflight={self.scheduler_cfg.max_inflight} "
            f"overload={self.scheduler_cfg.overload} "
            f"degrade={self.scheduler_cfg.degrade}",
        ]
        if d["filter"] is not None:
            fd = d["filter"]
            lines.append(
                f"  filter: mode={fd['mode']} "
                f"selectivity~{fd['selectivity_estimate']:.4f} "
                f"ef_inflation={fd['ef_inflation']:.2f} "
                f"tenant={fd['tenant']}"
            )
        for note in self._notes:
            lines.append(f"  note: {note}")
        if analyze:
            a = d["analyze"]
            lines.append(
                f"  analyze: nq={a['nq']} wall_s={a['wall_s']:.4f} "
                f"ndist={a['ndist_total']}"
            )
            if a.get("statuses"):
                st = " ".join(f"{k}={v}" for k, v in a["statuses"].items())
                lat = a["latency"]
                lines.append(
                    f"  analyze: statuses {st} | latency "
                    f"p50={lat['p50_s'] * 1e3:.2f}ms "
                    f"p95={lat['p95_s'] * 1e3:.2f}ms "
                    f"p99={lat['p99_s'] * 1e3:.2f}ms"
                )
            if a.get("padding_waste") is not None:
                lines.append(
                    f"  analyze: padding_waste={a['padding_waste']:.3f}"
                )
            r = a["recall"]
            lines.append(
                f"  analyze: achieved recall mean={r['mean']:.4f} "
                f"min={r['min']:.4f} samples={r['samples']} "
                f"alerts={r['alerts']} (vs oracle ef_cap)"
            )
        return "\n".join(lines)

    # -------------------------------------------------------------- analyze
    def _analyze(self, queries, nq: int) -> dict:
        """Execute the plan's mode and measure it (the ``analyze=True``
        payload).  Warm-up first so walls measure steady state, oracle
        ``ef_cap`` reference for achieved recall, everything JSON-able."""
        self._check_fresh()
        idx = self._index
        if queries is None:
            # deterministic corpus-row sample: self-retrieval is a fair
            # standing probe (no external query set required) and stable
            # across calls, so analyze deltas track the plan, not the data
            rng = np.random.default_rng(0)
            n = self._shape_sig[0]
            sel = np.sort(rng.choice(n, size=min(nq, n), replace=False))
            queries = np.asarray(idx.graph.vectors)[sel]
        queries = self._validate_queries(queries)
        b = len(queries)
        # filtered plans grade against the masked oracle (oracle_topk folds
        # the graph's fmask into alive) — never unfiltered ground truth
        ref_ids = oracle_topk(self._graph(), queries, self.search_cfg)

        if self.mode == MODE_ONESHOT:
            self.search(queries)  # warm-up: compile excluded from the wall
            t0 = time.perf_counter()
            res = self.search(queries)
            ids = np.asarray(res.ids)
            wall = time.perf_counter() - t0
            recalls = np.asarray(
                recall_at_k(ids, ref_ids[:, : self.k])
            ).astype(float)
            return {
                "nq": b,
                "mode": self.mode,
                "wall_s": float(wall),
                "ndist_total": int(np.asarray(res.ndist).sum()),
                "ef_used_mean": float(np.asarray(res.ef_used).mean()),
                "statuses": None,
                "latency": None,
                "padding_waste": None,
                "tiers": None,
                "recall": {
                    "mean": float(recalls.mean()),
                    "min": float(recalls.min()),
                    "samples": int(b),
                    "alerts": 0,
                    "per_query": [float(r) for r in recalls],
                },
            }

        # lifecycle modes: a private 100%-audited scheduler with its own
        # registry, so analyze traffic never pollutes the plan's metrics
        scfg = dataclasses.replace(
            self.scheduler_cfg, trace=True, audit_fraction=1.0
        )
        self.search(queries)  # warm-up through the shared router caches
        sched = self.new_scheduler(cfg=scfg, metrics=MetricsRegistry())
        t0 = time.perf_counter()
        tickets = [
            sched.submit(SearchRequest(query=q, k=self.k, tenant=self._tenant))
            for q in queries
        ]
        responses = sched.drain()
        wall = time.perf_counter() - t0
        by_uid = {r.ticket.uid: r for r in responses}
        ordered = [by_uid[t.uid] for t in tickets]
        statuses: dict = {}
        lat = Histogram()
        for r in ordered:
            statuses[r.status] = statuses.get(r.status, 0) + 1
            lat.observe(r.stats.e2e_s)
        rstats = sched.router_stats()
        audit = sched.auditor.as_dict()
        recalls = [s["recall"] for s in sched.auditor.samples]
        return {
            "nq": b,
            "mode": self.mode,
            "wall_s": float(wall),
            "ndist_total": int(rstats.ndist_total),
            "est_ndist_total": int(rstats.est_ndist_total),
            "padding_waste": float(rstats.padding_waste),
            "statuses": statuses,
            "latency": {
                "p50_s": float(lat.p50),
                "p95_s": float(lat.p95),
                "p99_s": float(lat.p99),
                "mean_s": float(lat.mean),
            },
            "tiers": [
                {
                    "ef": t.ef,
                    "count": t.count,
                    "padded_to": t.padded_to,
                    "ndist": t.ndist_total,
                    "wall_s": float(t.wall_s),
                }
                for t in rstats.tiers
            ],
            "recall": {
                "mean": float(np.mean(recalls)) if recalls else 0.0,
                "min": float(np.min(recalls)) if recalls else 0.0,
                "samples": len(recalls),
                "alerts": len(audit["alerts"]),
                "tiers": audit["tiers"],
                "per_query": [float(r) for r in recalls],
            },
        }
