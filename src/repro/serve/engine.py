"""Batched serving engine with first-class Ada-ef retrieval (RAG shape).

Request flow (the paper's deployment context — §1 RAG pipelines):

1. ``prefill`` the prompt batch through the LM,
2. embed each request (mean-pooled final hidden states projected to the
   retrieval space),
3. **Ada-ef adaptive vector search** over the HNSW corpus at the declarative
   target recall — this is where the paper's technique sits in production,
4. greedy ``decode`` continuation (retrieved ids are surfaced to the caller
   and, in token-splicing mode, appended to the context).

Retrieval runs through the declarative facade: the engine lowers its
``ServeConfig`` (or an explicit :class:`repro.api.SearchSpec`) into an
``index.plan(spec)`` and executes that plan —

- **oneshot** — one fused ``adaptive_search`` over the whole batch
  (dispatched asynchronously; JAX overlaps it with the decode steps),
- **streaming** (``ServeConfig.routed`` or ``spec.mode != "oneshot"``) —
  the requests are *submitted* to a private scheduler session over the
  plan before the decode loop starts, flushed as independent per-ef-tier
  dispatches, and *polled* (non-blocking) between decode steps, so retrieval
  overlaps generation and the per-request lifecycle telemetry rides along in
  ``ServeResult.router_stats``.

The decode loop itself stays synchronous/batched; the retrieval stage is the
request-lifecycle seam (streaming drivers hold a plan directly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.pipeline import AdaEfIndex
from repro.models.model_zoo import Model
from .api import SearchRequest
from .kvcache import grow_cache
from .scheduler import submit_with_backoff

Array = jax.Array


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    cache_slack: int = 128
    retrieve_k: int = 10
    target_recall: float = 0.95
    routed: bool = False          # submit retrieval through the ef-tier
    #   continuous-batching scheduler (overlapping the decode loop) instead
    #   of one fused monolithic adaptive_search
    spec: Optional[object] = None  # explicit repro.api.SearchSpec for the
    #   retrieval plan; overrides retrieve_k/target_recall/routed derivation


@dataclasses.dataclass
class ServeRetrieval:
    """Batch-shaped retrieval rows reassembled from scheduler responses."""

    ids: np.ndarray               # (B, k)
    dists: np.ndarray             # (B, k)
    ef_used: np.ndarray           # (B,)


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray            # (B, max_new_tokens)
    retrieved_ids: Optional[np.ndarray]  # (B, k)
    retrieved_dists: Optional[np.ndarray]
    ef_used: Optional[np.ndarray]
    prefill_logits: np.ndarray
    router_stats: Optional[dict] = None  # RouterStats.as_dict() (+ per-request
    #   lifecycle stats under "requests") when routed


@jax.jit
def _pooled_embedding(embed_table: Array, tokens: Array) -> Array:
    return jnp.mean(embed_table[tokens].astype(jnp.float32), axis=1)


@jax.jit
def _pooled_projected_embedding(
    embed_table: Array, tokens: Array, proj: Array
) -> Array:
    return jnp.mean(embed_table[tokens].astype(jnp.float32), axis=1) @ proj


class Engine:
    def __init__(
        self,
        model: Model,
        params,
        scfg: Optional[ServeConfig] = None,
        index: Optional[AdaEfIndex] = None,
        embed_proj: Optional[Array] = None,  # (d_model, d_index) retrieval head
        *,
        spec=None,                 # repro.api.SearchSpec for the retrieval plan
        **serve_kwargs,            # ServeConfig fields (when scfg not given)
    ):
        self.model = model
        self.params = params
        if scfg is not None and serve_kwargs:
            raise ValueError("pass a ServeConfig or its fields, not both")
        # default-construct per engine: a shared dataclass default instance
        # would leak config mutations across engines
        self.scfg = ServeConfig(**serve_kwargs) if scfg is None else scfg
        if spec is not None:
            # copy-on-write: never mutate a caller-supplied (possibly
            # shared) ServeConfig instance
            self.scfg = dataclasses.replace(self.scfg, spec=spec)
        self.index = index
        self.embed_proj = embed_proj
        self._decode = jax.jit(self.model.decode)

    # ------------------------------------------------------------- helpers
    def _request_embedding(self, batch: Dict[str, Array]) -> Array:
        """Mean-pooled token embeddings -> retrieval space (B, d_index),
        jitted (module-level fns so the cache is shared across engines)."""
        if self.embed_proj is not None:
            return _pooled_projected_embedding(
                self.params["embed"], batch["tokens"], self.embed_proj
            )
        return _pooled_embedding(self.params["embed"], batch["tokens"])

    def _retrieval_plan(self):
        """The engine's retrieval settings lowered into the index's cached
        :class:`repro.plan.ExecutionPlan`.  ``ServeConfig`` is an internal
        lowering target: an explicit ``spec`` wins, otherwise
        ``retrieve_k``/``target_recall``/``routed`` derive one."""
        from repro.api import MODE_ONESHOT, MODE_STREAMING, SearchSpec

        scfg = self.scfg
        spec = scfg.spec
        if spec is None:
            spec = SearchSpec(
                k=min(scfg.retrieve_k, self.index.k),
                target_recall=scfg.target_recall,
                mode=MODE_STREAMING if scfg.routed else MODE_ONESHOT,
            )
        return self.index.plan(spec)

    # ------------------------------------------------------------- serve
    def serve(self, batch: Dict[str, Array]) -> ServeResult:
        scfg = self.scfg
        logits, cache = self.model.prefill(self.params, batch)
        b = batch["tokens"].shape[0]
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.family == "vlm":
            prompt_len += batch["patches"].shape[1]
        cache = grow_cache(
            self.model.cfg, cache, scfg.max_new_tokens + scfg.cache_slack
        )

        retrieved = None
        router_stats = None
        sess = tickets = None
        responses: List[object] = []
        if self.index is not None:
            q = self._request_embedding(batch)
            plan = self._retrieval_plan()
            if plan.mode == "oneshot":
                # fused adaptive_search; dispatched asynchronously, so the
                # device overlaps it with the decode steps below
                retrieved = plan.search(np.asarray(q))
            else:
                # submit the whole batch to a *private* scheduler session
                # over the plan (compile caches shared through the plan's
                # router) and flush: the per-tier searches are in flight on
                # device while the decode loop below runs — poll() harvests
                # whatever finished between decode steps without blocking
                # either side.  A private session keeps this batch out of
                # the plan's shared lifecycle scheduler that streaming
                # callers hold (an unfiltered poll() there would steal our
                # responses, and our flush would force-drain their parked
                # queues).
                # submit_with_backoff: a plan whose scheduler bounds
                # admission (max_inflight) would otherwise refuse part of
                # the batch — the engine's policy is capped exponential
                # backoff, harvesting early completions to free capacity
                sess = plan.new_scheduler()
                qn = np.asarray(q)
                tickets = [
                    submit_with_backoff(
                        sess,
                        SearchRequest(query=qn[i], k=plan.k),
                        harvest=responses.extend,
                    )
                    for i in range(b)
                ]
                sess.flush()

        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        pos = jnp.full((b,), prompt_len, jnp.int32)
        out_tokens: List[np.ndarray] = []
        want = None if tickets is None else [t.uid for t in tickets]
        for _ in range(scfg.max_new_tokens):
            out_tokens.append(np.asarray(tok))
            logits_t, cache = self._decode(self.params, tok[:, None], cache, pos)
            tok = jnp.argmax(logits_t[:, -1], axis=-1).astype(jnp.int32)
            pos = pos + 1
            if sess is not None and len(responses) < b:
                responses.extend(sess.poll(uids=want))

        if sess is not None:
            if len(responses) < b:
                responses.extend(sess.poll(block=True, uids=want))
            by_uid = {r.ticket.uid: r for r in responses}
            ordered = [by_uid[t.uid] for t in tickets]
            retrieved = ServeRetrieval(
                ids=np.stack([r.ids for r in ordered]),
                dists=np.stack([r.dists for r in ordered]),
                ef_used=np.asarray([r.ef_used for r in ordered], np.int32),
            )
            router_stats = sess.router_stats().as_dict()
            router_stats["requests"] = [r.stats.as_dict() for r in ordered]
            if sess.auditor is not None:
                # Drain the session auditor's backlog before it is dropped
                # with the private session, so the recall EWMAs / alerts
                # reported here cover every sampled request of this batch.
                sess.auditor.flush()
                router_stats["audit"] = sess.auditor.as_dict()

        return ServeResult(
            tokens=np.stack(out_tokens, axis=1),
            retrieved_ids=None if retrieved is None else np.asarray(retrieved.ids),
            retrieved_dists=None if retrieved is None else np.asarray(retrieved.dists),
            ef_used=None if retrieved is None else np.asarray(retrieved.ef_used),
            prefill_logits=np.asarray(logits),
            router_stats=router_stats,
        )
