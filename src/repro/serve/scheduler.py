"""Continuous-batching scheduler over the ef-tier router (request lifecycle).

:class:`AdaServeScheduler` turns the one-shot synchronous
``QueryRouter.route`` barrier into a request lifecycle:

1. **submit()** — a :class:`repro.serve.api.SearchRequest` enters the
   admission queue and gets a :class:`SearchTicket` back; nothing runs yet.
2. **step()** — one scheduler tick.  Whatever has arrived since the last
   tick runs **one shared estimation pass** (phase A + ESTIMATE-EF, padded
   to a pow2 shape; padding rows converge immediately, see
   ``estimate_pass(num_real=...)``), and each estimated request drops into
   its ef-tier queue *carrying its phase-A* :class:`SearchState` — the
   resumable unit the phase-split search provides.  Then every tier bucket
   that has reached its pow2 **fill**, or whose **oldest request's deadline**
   is due, drains as one batch-hoisted ``resume_at_ef`` dispatch.  There is
   *no all-tier barrier*: an easy (small-ef) tier drains the moment it
   fills while a hard tier keeps accumulating, and dispatches are
   asynchronous (JAX async dispatch) so tiers overlap on device.
3. **poll()** — completed :class:`SearchResponse` objects (non-blocking by
   default: only dispatches whose device buffers are ready materialize).
4. **drain()** — force-flush everything and block for all responses.

Equivalence: tier searches resume the carried phase-A state, and both
phases are per-query independent, so for any interleaving of
``submit``/``step``/``poll`` and any drain trigger the scheduler returns
results bit-identical to a synchronous submit-all/drain-all barrier under a
lossless config (the arrival-order invariance property test in
``tests/test_scheduler.py``).  ``ExecutionPlan.search`` in a lifecycle mode
is exactly that barrier over a one-shot instance of this class.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.distances import key_sign
from repro.index.search import resize_state, resume_at_ef
from repro.obs import (
    MetricsRegistry, RecallAuditor, SpanTracer, device_annotation, oracle_topk,
)
from repro.pytrees import register_static_config
from .api import (
    STATUS_DEGRADED, STATUS_OK, STATUS_PARTIAL, STATUS_REJECTED,
    STATUS_TIMED_OUT, DispatchFailedError, InvalidQueryError, OverloadedError,
    RequestStats, SearchRequest, SearchResponse, SearchTicket, StalePlanError,
    TenantSLO,
)
from .bucketing import assign_tiers, pad_shape
from .stats import SchedulerStats, TierCostModel, TierStats
from .tiers import TierSpec

TRIGGER_FILL = "fill"
TRIGGER_DEADLINE = "deadline"
TRIGGER_FLUSH = "flush"
TRIGGER_IDLE = "idle"
TRIGGER_PARTIAL = "partial"

OVERLOAD_RAISE = "raise"    # submit() raises OverloadedError at capacity
OVERLOAD_TICKET = "ticket"  # submit() returns a ticket whose response is
#   already REJECTED (poll it like any other) — never raises


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Drain policy knobs (host-side; no effect on compiled shapes beyond
    the pow2 padding every dispatch already uses)."""

    fill: int = 8           # tier bucket drains once it holds >= fill requests
    #   (power of two: a full bucket then dispatches pad-free)
    min_shape: int = 0      # smallest padded dispatch shape; 0 -> inherit the
    #   router's RouterConfig.min_shape
    flush_margin_s: float = 0.0  # drain a tier this early before its oldest
    #   deadline (headroom for the dispatch itself)
    est_wait_s: float = 0.0  # admission batching window: hold arrivals up to
    #   this long (unless ``fill`` arrivals or a deadline force it) so one
    #   estimation pass amortizes over more requests; 0 = estimate every tick
    work_conserving: bool = True  # never hold work while the device is idle:
    #   when no dispatch is in flight, arrivals estimate immediately and the
    #   first nonempty tier drains immediately (batching windows only apply
    #   under load, where they amortize; under light load the scheduler then
    #   matches a greedy synchronous server instead of idling toward fill).
    #   Tiers are scanned smallest-ef first, so idle drains favor easy work.
    max_inflight: int = 0   # admission bound: live requests (admitted +
    #   queued + dispatched, excluding finished-but-unpolled) a submit may
    #   not exceed; 0 = unbounded (the pre-admission-control behavior)
    max_tier_queue: int = 0  # per-tier queue bound applied when estimated
    #   requests file into their rung; overflow is shed REJECTED. 0 = off
    overload: str = OVERLOAD_RAISE  # what a shed submit gets: "raise" ->
    #   OverloadedError; "ticket" -> a normal ticket whose response is
    #   REJECTED (lock-step replay loops keep their 1:1 submit/poll pairing)
    degrade: bool = False   # arm the deadline-aware degradation ladder:
    #   demote at-risk requests down the ef tiers (DEGRADED), answer blown
    #   deadlines from their phase-A state (PARTIAL).  Off by default —
    #   degradation trades the bit-exact barrier equivalence for latency,
    #   so it must be an explicit opt-in (plan_spec arms it for deadline_ms
    #   specs, where the caller already declared latency to matter)
    cost_alpha: float = 0.25  # EWMA smoothing of the per-tier cost model
    trace: bool = False     # arm per-request span tracing (repro.obs.trace):
    #   submit -> estimate -> queue -> dispatch -> materialize -> terminal
    #   spans on the injected clock, exportable as Chrome trace JSON.  Off by
    #   default — the disabled path costs one None check per emission site
    trace_capacity: int = 4096  # span ring-buffer bound (oldest evicted)
    audit_fraction: float = 0.0  # online recall audit (repro.obs.audit):
    #   deterministically sample this fraction of completed requests and
    #   re-run them through the oracle ef_cap reference on idle ticks,
    #   tracking per-tier achieved-recall EWMAs vs target.  0 = off
    audit_margin: float = 0.02  # RecallAlert when a tier's achieved-recall
    #   EWMA drops below its target EWMA minus this margin
    tenants: Tuple[Tuple[str, TenantSLO], ...] = ()  # per-tenant namespaces:
    #   ((name, TenantSLO), ...).  A request carrying a configured tenant
    #   resolves unset target_recall/deadline_s from its SLO (request values
    #   win, scheduler defaults are the last fallback) and is bounded by the
    #   SLO's max_inflight admission quota, so one saturating tenant cannot
    #   occupy the whole ladder.  Tenants also bound the metrics label set:
    #   configured names pass through, anything else labels as "other",
    #   no tenant labels as "default".  A dict {name: TenantSLO} is
    #   accepted and canonicalized (sorted) for hash stability

    def __post_init__(self):
        if self.fill < 1 or (self.fill & (self.fill - 1)) != 0:
            raise ValueError(f"fill={self.fill} must be a power of two >= 1")
        if self.flush_margin_s < 0:
            raise ValueError("flush_margin_s must be >= 0")
        if self.est_wait_s < 0:
            raise ValueError("est_wait_s must be >= 0")
        if self.max_inflight < 0 or self.max_tier_queue < 0:
            raise ValueError("max_inflight/max_tier_queue must be >= 0")
        if self.overload not in (OVERLOAD_RAISE, OVERLOAD_TICKET):
            raise ValueError(
                f"overload={self.overload!r} not in ('raise', 'ticket')"
            )
        if not 0.0 < self.cost_alpha <= 1.0:
            raise ValueError("cost_alpha must be in (0, 1]")
        if self.trace_capacity < 1:
            raise ValueError("trace_capacity must be >= 1")
        if not 0.0 <= self.audit_fraction <= 1.0:
            raise ValueError("audit_fraction must be in [0, 1]")
        if self.audit_margin < 0:
            raise ValueError("audit_margin must be >= 0")
        t = self.tenants
        t = tuple(sorted(t.items())) if isinstance(t, dict) else tuple(
            (str(name), slo) for name, slo in t
        )
        for name, slo in t:
            if not name:
                raise ValueError("tenant names must be non-empty")
            if not isinstance(slo, TenantSLO):
                raise ValueError(
                    f"tenants[{name!r}] must be a TenantSLO, "
                    f"got {type(slo).__name__}"
                )
        if len({name for name, _ in t}) != len(t):
            raise ValueError("duplicate tenant names in SchedulerConfig.tenants")
        object.__setattr__(self, "tenants", t)


# Static pytree: zero leaves, jit-keyed by dataclass equality (same policy
# -> same compile-cache entry), never traced.
register_static_config(SchedulerConfig)


class _EstPass:
    """One estimation dispatch: the carried batched phase-A state plus the
    padded raw query panel it was computed from.  Tier drains gather rows out
    of (possibly several) of these; the object stays alive until every
    request it admitted has been dispatched."""

    __slots__ = ("states", "queries")

    def __init__(self, states, queries: np.ndarray):
        self.states = states
        self.queries = queries


class _Pending:
    """A request in flight: admission -> (estimated) tier queue -> dispatch.

    ``graph`` pins the epoch's :class:`DeviceGraph` the request was
    *estimated* against: phase-A states only resume correctly on the arrays
    they were computed from, and under churn the request's recall audit must
    compare against the same snapshot it was served from.  The mutation
    fence guarantees estimation and dispatch share one epoch."""

    __slots__ = (
        "ticket", "query", "target", "k", "tenant", "stats",
        "est_pass", "row", "ef", "qspan", "dspan", "graph",
    )

    def __init__(self, ticket: SearchTicket, query: np.ndarray,
                 target: float, k: int, tenant: str = ""):
        self.ticket = ticket
        self.query = query
        self.target = target
        self.k = k
        self.tenant = tenant
        self.stats = RequestStats(submit_t=ticket.submit_t, tenant=tenant)
        self.est_pass: Optional[_EstPass] = None
        self.row = -1
        self.ef = -1
        self.qspan = None   # open "queue" trace span (tracer armed only)
        self.dspan = None   # open "dispatch" trace span
        self.graph = None   # epoch-pinned DeviceGraph (set at estimation)


class _Dispatch:
    """One tier drain: device results shared by its requests, materialized
    (blocked + pulled to host) lazily at poll time so dispatches overlap.

    Carries its device inputs and the *remaining* backend-attempt ladder
    until materialization succeeds: JAX dispatch is asynchronous, so a
    runtime kernel failure may only surface at ``block_until_ready`` — the
    scheduler's :meth:`AdaServeScheduler._materialize` then re-dispatches
    the same inputs synchronously down the ladder.
    """

    __slots__ = (
        "tier", "tier_idx", "entries", "shape", "res_dev", "res_np", "t0",
        "wall_s", "inputs", "attempts", "used_ai", "backend", "didx", "graph",
    )

    def __init__(self, tier: TierSpec, tier_idx: int, entries: List[_Pending],
                 shape: int, res_dev, t0: float, inputs, attempts, used_ai: int,
                 didx: int, graph=None):
        self.tier = tier
        self.tier_idx = tier_idx
        self.entries = entries
        self.shape = shape
        self.res_dev = res_dev
        self.res_np = None
        self.t0 = t0
        self.wall_s = 0.0
        self.inputs = inputs          # (q_dev, states, ef_dev) until done
        self.attempts = attempts      # full (cfg, backend_label) ladder
        self.used_ai = used_ai        # index of the attempt in flight
        self.backend = attempts[used_ai][1]
        self.didx = didx              # chaos dispatch index (-1 = no chaos)
        self.graph = graph            # epoch-pinned DeviceGraph: retry rungs
        #   at materialize time must resume on the *same* arrays the phase-A
        #   states were computed from, even if the index mutated in between

    def ready(self) -> bool:
        if self.res_np is not None:
            return True
        try:
            return all(
                leaf.is_ready()
                for leaf in jax.tree_util.tree_leaves(self.res_dev)
            )
        except AttributeError:
            # jax without Array.is_ready: report not-ready so non-blocking
            # polls stay non-blocking; results are harvested by the blocking
            # polls every consumer ends with (drain / replay tail / engine)
            return False

    def finish(self, stats: SchedulerStats,
               clock: Callable[[], float] = time.monotonic) -> None:
        """Block, pull to host, record the drain's TierStats, release the
        carried inputs.  Raises whatever the device execution raised.
        ``clock`` must be the scheduler's injected clock (``t0`` was stamped
        on it), so walls, deadlines and trace spans share one timeline."""
        if self.res_np is not None:
            return
        jax.block_until_ready(self.res_dev)
        self.wall_s = clock() - self.t0
        self.res_np = jax.tree_util.tree_map(np.asarray, self.res_dev)
        self.res_dev = None
        self.inputs = None
        n = len(self.entries)
        stats.tiers.append(
            TierStats(
                ef=self.tier.ef,
                beam=self.tier.beam,
                count=n,
                padded_to=self.shape,
                ndist_total=int(self.res_np.ndist[:n].sum()),
                wall_s=self.wall_s,
            )
        )


class AdaServeScheduler:
    """Continuous-batching executor over one :class:`QueryRouter`.

    Owns the admission queue, the per-tier request queues, and the set of
    in-flight dispatches.  Index mutations are survivable: the scheduler
    pins each request's epoch (the :class:`DeviceGraph` it was estimated
    against) and exposes a **mutation seam** — :meth:`apply_mutation` /
    :meth:`absorb_mutation` — that fences at a safe point between tier
    drains, force-dispatches everything still queued against the
    pre-mutation epoch, then rebinds to the post-mutation router.  Pending
    tickets complete normally against the snapshot they were dispatched on
    (JAX arrays are immutable; pinning is just holding references), and new
    work binds the new epoch.  ``AdaEfIndex.insert``/``delete`` route
    through this seam automatically for index-registered schedulers.

    ``clock`` is injectable (tests drive deadlines with a fake clock); it
    only gates *deadline draining*, degradation and telemetry timestamps,
    never results.

    ``version_probe`` (when given, e.g. by ``AdaEfIndex.scheduler()`` /
    ``ExecutionPlan.new_scheduler()``) returns the owning index's graph
    version; ``router_probe`` (same callers) returns a router rebuilt
    against the index's *current* epoch, letting :meth:`absorb_mutation`
    rebind without the caller threading the new router through.  A
    scheduler constructed with a ``version_probe`` but **no** registration
    (no ``router_probe``, built directly rather than via the index/plan) is
    *orphaned*: it cannot rebind, so ``submit``/``step`` — and any ``poll``
    that would otherwise lose live work — raise :class:`StalePlanError`
    once the index mutates under it.

    ``chaos`` is an optional :class:`repro.serve.chaos.FaultInjector`; an
    absent (or empty-plan) injector leaves behavior bit-identical.
    """

    def __init__(
        self,
        router,
        cfg: Optional[SchedulerConfig] = None,
        *,
        default_target_recall: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        version_probe: Optional[Callable[[], int]] = None,
        router_probe: Optional[Callable[[], object]] = None,
        chaos=None,
        cost_model: Optional[TierCostModel] = None,
        metrics: Optional[MetricsRegistry] = None,
        tracer: Optional[SpanTracer] = None,
        auditor: Optional[RecallAuditor] = None,
    ):
        self.router = router
        self.cfg = cfg or SchedulerConfig()
        self.min_shape = self.cfg.min_shape or router.router_cfg.min_shape
        self.default_target_recall = default_target_recall
        self._chaos = chaos
        self.clock = chaos.wrap_clock(clock) if chaos is not None else clock
        self._version_probe = version_probe
        self._version0 = None if version_probe is None else version_probe()
        self._router_probe = router_probe
        self._stepping = False   # reentrancy guard: a mutation landing
        #   mid-step (e.g. chaos mutate_fn inside a dispatch) defers its
        #   absorb to the end of the tick instead of fencing recursively
        self._deferred_absorb = False
        self._absorbing = False  # suspends the staleness gate during the
        #   fence tick, which intentionally runs on the pre-mutation epoch
        self.cost_model = (
            cost_model
            if cost_model is not None
            else TierCostModel(alpha=self.cfg.cost_alpha)
        )
        # Observability (repro.obs).  A caller-supplied registry (e.g. the
        # owning plan's, or the process-global one) aggregates across
        # schedulers; otherwise each scheduler gets its own.  Tracer and
        # auditor stay None unless armed — every hot-path emission site is
        # behind a single `is not None` check, so the disabled scheduler
        # does no extra device syncs (the acceptance bar for this layer).
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if tracer is not None:
            self.tracer: Optional[SpanTracer] = tracer
        elif self.cfg.trace:
            self.tracer = SpanTracer(
                clock=self.clock, capacity=self.cfg.trace_capacity
            )
        else:
            self.tracer = None
        if auditor is not None:
            self.auditor: Optional[RecallAuditor] = auditor
        elif self.cfg.audit_fraction > 0.0:
            self.auditor = RecallAuditor(
                self._audit_reference,
                fraction=self.cfg.audit_fraction,
                margin=self.cfg.audit_margin,
                clock=self.clock,
                on_alert=self._on_recall_alert,
            )
        else:
            self.auditor = None
        self.stats = SchedulerStats().bind(self.metrics)
        self._export_resident_bytes()
        self._uids = itertools.count()
        self._tenant_slos = dict(self.cfg.tenants)
        self._tenant_live: dict = {}  # tenant -> admitted-and-live count
        #   (incremented only on actual admission, decremented in _terminal
        #   — submit-time overload rejections never touch it)
        self._admission: List[_Pending] = []
        self._queues: List[List[_Pending]] = [[] for _ in router.tiers]
        self._inflight: List[Tuple[_Dispatch, int, _Pending]] = []
        self._done: List[SearchResponse] = []  # terminal w/o dispatch
        #   (REJECTED tickets, PARTIAL answers) awaiting poll

    # -------------------------------------------------------- observability
    def _export_resident_bytes(self) -> None:
        """Per-panel device memory gauges for the graph this scheduler
        serves: fp32 vector table, quantized estimation panel (0 when no
        panel is attached), adjacency.  Refreshed on every rebind so the
        ``--metrics`` surface tracks the live epoch."""
        from repro.quant import graph_resident_bytes

        for panel, nbytes in graph_resident_bytes(self.router.graph).items():
            self.metrics.gauge("resident_bytes", panel=panel).set(nbytes)

    def _audit_reference(self, queries: np.ndarray) -> np.ndarray:
        """The auditor's ground truth: full-``ef_cap`` oracle-backend search
        over this scheduler's graph (the rung the fallback ladder and the
        bit-exactness property tests already trust)."""
        return oracle_topk(self.router.graph, queries, self.router.base_cfg)

    def _on_recall_alert(self, alert) -> None:
        self.stats.inc("recall_alerts")

    def _tenant_label(self, tenant: str) -> str:
        """Bounded-cardinality metrics label: configured tenants pass
        through, anything unconfigured pools under "other", no tenant is
        "default" — an adversarial tenant string cannot mint unbounded
        metric series."""
        if not tenant:
            return "default"
        return tenant if tenant in self._tenant_slos else "other"

    def _terminal(self, p: _Pending, status: str,
                  ids: Optional[np.ndarray] = None) -> None:
        """Terminal bookkeeping shared by every exit path: close open trace
        spans, emit the terminal event, observe the latency histograms, and
        — when the request produced an answer (``ids``) — offer it to the
        recall auditor's deterministic sample queue."""
        tr = self.tracer
        if tr is not None:
            tr.end(p.qspan)
            tr.end(p.dspan, status=status)
            tr.event("terminal", p.ticket.uid, status=status)
        st = p.stats
        m = self.metrics
        m.histogram(
            "request_e2e_s", status=status, tenant=self._tenant_label(p.tenant)
        ).observe(st.e2e_s)
        if st.dispatch_t:
            m.histogram("request_queue_wait_s").observe(st.queue_wait_s)
            m.histogram("request_service_s").observe(st.service_s)
        live = self._tenant_live.get(p.tenant)
        if live is not None:  # every admitted request exits through here
            if live <= 1:
                self._tenant_live.pop(p.tenant, None)
            else:
                self._tenant_live[p.tenant] = live - 1
        aud = self.auditor
        if ids is not None and aud is not None and aud.admit(p.ticket.uid):
            # p.stats.tier_ef is 0 for PARTIAL answers (no tier search ran),
            # which the auditor buckets as the non-alerting pseudo-tier.
            # The oracle reference is pinned to the request's epoch: a
            # pre-mutation response audited after an epoch swap must be
            # compared against the snapshot it was actually served from.
            graph, cfg = p.graph, self.router.base_cfg
            ref = (
                None if graph is None
                else (lambda q, g=graph, c=cfg: oracle_topk(g, q, c))
            )
            aud.enqueue(
                p.ticket.uid, p.query, ids,
                k=p.k, tier_ef=st.tier_ef, target=p.target, status=status,
                reference=ref, epoch=st.epoch,
            )

    # ------------------------------------------------------------ freshness
    def _live(self) -> int:
        """Requests that still need device work (admission bound + what a
        stale graph would orphan); excludes finished-but-unpolled."""
        return (
            len(self._admission)
            + sum(len(q) for q in self._queues)
            + len(self._inflight)
        )

    def _check_fresh(self) -> None:
        if self._version_probe is None or self._absorbing:
            return
        v = self._version_probe()
        if v != self._version0:
            raise StalePlanError(
                f"stale scheduler: index graph version bumped "
                f"{self._version0} -> {v} (insert/delete under an orphaned "
                f"scheduler — one the index has no mutation seam to); "
                f"{self._live()} pending request(s) cannot be recovered. "
                "Either drain() before mutating, route the mutation through "
                "apply_mutation(), or build the scheduler via "
                "index.scheduler() / plan.new_scheduler() so mutations are "
                "absorbed automatically"
            )

    def _epoch(self) -> int:
        """The epoch (index graph version) new requests bind; -1 when the
        scheduler is unversioned (no ``version_probe``)."""
        return -1 if self._version0 is None else int(self._version0)

    # -------------------------------------------------------- mutation seam
    def apply_mutation(self, fn: Callable[[], object]):
        """Run an index mutation under this scheduler's fence and absorb
        the resulting epoch swap; returns ``fn``'s result.

        This is the manual seam for schedulers the index does not know
        about: ``sched.apply_mutation(lambda: idx.insert(rows))`` keeps the
        scheduler serviceable where a bare ``idx.insert(rows)`` would leave
        it orphaned-stale.  Index-registered schedulers (``idx.scheduler()``
        / ``plan.new_scheduler()``) are absorbed by the index itself, and a
        second absorb here is a cheap no-op (the version already matches).
        """
        out = fn()
        self.absorb_mutation()
        return out

    def absorb_mutation(self, router=None) -> int:
        """Absorb an index mutation that already happened: fence (force-
        dispatch everything still queued against the pre-mutation epoch the
        old router pins), then rebind to ``router`` (or the ``router_probe``
        result) for new work.  Returns the number of requests the fence
        force-dispatched.  Safe mid-step: a reentrant call (mutation fired
        inside a dispatch) defers to the end of the current tick."""
        if self._stepping:
            self._deferred_absorb = True
            self._deferred_router = router
            return 0
        if (
            router is None
            and self._version_probe is not None
            and self._version_probe() == self._version0
        ):
            return 0  # nothing changed (or already absorbed by the index)
        return self._absorb_now(router)

    def _absorb_now(self, router) -> int:
        tr = self.tracer
        pinned = len(self._inflight)
        fenced = len(self._admission) + sum(len(q) for q in self._queues)
        span = (
            None if tr is None
            else tr.begin("mutation", None, fenced=fenced, pinned=pinned)
        )
        old_v = self._epoch()
        if fenced:
            # the fence tick intentionally runs on the pre-mutation epoch
            # (the old router's arrays are still pinned by self.router), so
            # suspend the staleness gate and keep the old epoch stamp for
            # everything it estimates/dispatches
            self._absorbing = True
            try:
                self.step(force=True)
            finally:
                self._absorbing = False
        if self._version_probe is not None:
            self._version0 = self._version_probe()
        if router is None and self._router_probe is not None:
            router = self._router_probe()
        if router is not None and router is not self.router:
            if len(router.tiers) != len(self.router.tiers):
                # post-fence the tier queues are empty; resize to the new
                # ladder (an insert can change n and therefore the tiering)
                self._queues = [[] for _ in router.tiers]
            self.router = router
            self.min_shape = self.cfg.min_shape or router.router_cfg.min_shape
            self._export_resident_bytes()
        self.stats.inc("mutations")
        if fenced:
            self.stats.inc("fenced_requests", fenced)
        if tr is not None:
            tr.end(span, epoch=self._epoch(), prev_epoch=old_v)
        return fenced

    # --------------------------------------------------------------- submit
    def _validate_query(self, query) -> np.ndarray:
        arr = np.asarray(query)
        if arr.dtype.kind not in "fiu":
            raise InvalidQueryError(
                f"query dtype {arr.dtype} is not numeric (expected float32)"
            )
        q = arr.astype(np.float32)
        if q.ndim == 2 and q.shape[0] == 1:
            q = q[0]
        if q.ndim != 1:
            raise InvalidQueryError(
                f"expected a single (d,) query, got {tuple(arr.shape)}"
            )
        dim = int(self.router.graph.vectors.shape[1])
        if q.shape[0] != dim:
            raise InvalidQueryError(
                f"query dimensionality {q.shape[0]} != index dim {dim}"
            )
        if not np.isfinite(q).all():
            raise InvalidQueryError("query contains NaN/Inf values")
        return q

    def _rejected_response(
        self, ticket: SearchTicket, k: int, reason: str, now: float,
        tenant: str = "",
    ) -> SearchResponse:
        rstats = RequestStats(submit_t=ticket.submit_t, tenant=tenant)
        rstats.status = STATUS_REJECTED
        rstats.reject_reason = reason
        rstats.done_t = now
        self.stats.inc("rejected")
        if self.tracer is not None:
            self.tracer.event("screen", ticket.uid, reason=reason)
            self.tracer.event("terminal", ticket.uid, status=STATUS_REJECTED)
        self.metrics.histogram(
            "request_e2e_s", status=STATUS_REJECTED,
            tenant=self._tenant_label(tenant),
        ).observe(rstats.e2e_s)
        return SearchResponse(
            ticket=ticket,
            ids=np.full(k, -1, np.int32),
            dists=np.full(k, np.inf, np.float32),
            ndist=0,
            iters=0,
            ef_used=0,
            stats=rstats,
            status=STATUS_REJECTED,
        )

    def _shed(self, p: _Pending, now: float, reason: str) -> None:
        """Reject an already-admitted request (NaN screen / tier-queue
        bound): terminal REJECTED response into the done queue."""
        p.est_pass = None
        p.stats.status = STATUS_REJECTED
        p.stats.reject_reason = reason
        p.stats.done_t = now
        self.stats.inc("rejected")
        if self.tracer is not None:
            self.tracer.event("screen", p.ticket.uid, reason=reason)
        self._terminal(p, STATUS_REJECTED)
        self._done.append(
            SearchResponse(
                ticket=p.ticket,
                ids=np.full(p.k, -1, np.int32),
                dists=np.full(p.k, np.inf, np.float32),
                ndist=p.stats.est_ndist,
                iters=0,
                ef_used=0,
                stats=p.stats,
                status=STATUS_REJECTED,
            )
        )

    def submit(self, request: SearchRequest) -> SearchTicket:
        """Admit one request; returns its ticket.  Nothing is dispatched
        until the next :meth:`step` (call it as often as you like — an empty
        tick is cheap).

        Raises :class:`InvalidQueryError` for unusable query vectors and —
        at the ``max_inflight`` admission bound (global, or the request's
        tenant quota) under ``overload="raise"`` — :class:`OverloadedError`;
        under ``overload="ticket"`` an over-bound submit instead returns a
        ticket whose response is already REJECTED.

        A request carrying a ``tenant`` resolves unset ``target_recall``/
        ``deadline_s`` from the tenant's :class:`TenantSLO` (request values
        win, scheduler defaults are the final fallback) and counts against
        the tenant's ``max_inflight`` admission quota.
        """
        self._check_fresh()
        q = self._validate_query(request.query)
        k = self.router.base_cfg.k if request.k is None else int(request.k)
        if not 1 <= k <= self.router.base_cfg.k:
            raise ValueError(
                f"k={k} not in [1, index k={self.router.base_cfg.k}]"
            )
        tenant = request.tenant or ""
        slo = self._tenant_slos.get(tenant)
        target = request.target_recall
        if target is None and slo is not None:
            target = slo.target_recall
        if target is None:
            target = self.default_target_recall
        if target is None:
            raise ValueError(
                "request has no target_recall and the scheduler has no default"
            )
        deadline_s = request.deadline_s
        if deadline_s is None and slo is not None:
            deadline_s = slo.deadline_s
        self.metrics.counter(
            "requests", tenant=self._tenant_label(tenant)
        ).inc()
        shed_reason = None
        if self.cfg.max_inflight and self._live() >= self.cfg.max_inflight:
            shed_reason = (
                f"admission refused: {self._live()} live requests >= "
                f"max_inflight={self.cfg.max_inflight} — poll to free "
                "capacity or retry with backoff (submit_with_backoff)"
            )
        elif (
            slo is not None
            and slo.max_inflight
            and self._tenant_live.get(tenant, 0) >= slo.max_inflight
        ):
            shed_reason = (
                f"tenant {tenant!r} quota: "
                f"{self._tenant_live.get(tenant, 0)} live requests >= "
                f"tenant max_inflight={slo.max_inflight} — other tenants "
                "keep their admission headroom"
            )
        if shed_reason is not None:
            if self.cfg.overload == OVERLOAD_RAISE:
                self.stats.inc("rejected")
                raise OverloadedError(shed_reason)
            now = self.clock()
            ticket = SearchTicket(uid=next(self._uids), submit_t=now)
            self.stats.inc("submitted")
            if self.tracer is not None:
                self.tracer.event("submit", ticket.uid, k=k, tenant=tenant)
            self._done.append(
                self._rejected_response(
                    ticket, k, "overloaded", now, tenant=tenant
                )
            )
            return ticket
        now = self.clock()
        ticket = SearchTicket(
            uid=next(self._uids),
            submit_t=now,
            deadline_t=(None if deadline_s is None else now + deadline_s),
        )
        if self._chaos is not None:
            q = self._chaos.corrupt(ticket.uid, q)
        self._admission.append(
            _Pending(ticket, q, float(target), k, tenant=tenant)
        )
        self._tenant_live[tenant] = self._tenant_live.get(tenant, 0) + 1
        self.stats.inc("submitted")
        if self.tracer is not None:
            self.tracer.event(
                "submit", ticket.uid,
                k=k, target=float(target), deadline_s=deadline_s,
                tenant=tenant,
            )
        return ticket

    # ----------------------------------------------------------------- tick
    def step(self, now: Optional[float] = None, *, force: bool = False) -> int:
        """One scheduler tick: estimate whatever arrived, degrade/shed
        deadline-risky work (when armed), then drain every tier bucket that
        is due (fill reached / oldest deadline due / ``force``).  Returns
        the number of requests dispatched this tick.  Dispatches are
        asynchronous — harvest results with :meth:`poll`."""
        self._check_fresh()
        now = self.clock() if now is None else now
        self._stepping = True
        try:
            if self._admission and (force or self._est_due(now)):
                self._estimate_admitted(now)
            if self.cfg.degrade:
                self._degrade_at_risk(now)
            dispatched = 0
            for t, queue in enumerate(self._queues):
                if not queue:
                    continue
                trigger = self._due(t, queue, now, force)
                if trigger is not None:
                    dispatched += self._dispatch_tier(t, now, trigger)
            if (
                self.auditor is not None
                and self.auditor.pending
                and dispatched == 0
                and not self._admission
                and not self._busy()
            ):
                # Work-conserving idle tick: nothing dispatched, nothing
                # waiting, no device work in flight — spend it on one recall
                # audit instead of returning idle.  Audits never compete
                # with live drains.
                self.auditor.step(budget=1)
        finally:
            self._stepping = False
        if self._deferred_absorb:
            # A mutation landed mid-tick (e.g. a chaos mutate_fn inside a
            # dispatch attempt): every dispatch this tick already ran on the
            # pre-mutation epoch it pinned, so absorbing now — after the
            # tick — is equivalent to fencing before the mutation.
            self._deferred_absorb = False
            self._absorb_now(self.__dict__.pop("_deferred_router", None))
        return dispatched

    def flush(self) -> int:
        """Force-drain every queue (estimation included); non-blocking."""
        return self.step(force=True)

    def _busy(self) -> bool:
        """Any dispatch still executing (not yet materializable)?"""
        return any(not item[0].ready() for item in self._inflight)

    def _est_due(self, now: float) -> bool:
        """Should the admission queue run its estimation pass this tick?
        Immediately unless an ``est_wait_s`` batching window is configured;
        an idle device (work-conserving mode), ``fill`` arrivals or a
        deadline inside the window override the wait."""
        if self.cfg.est_wait_s <= 0:
            return True
        if self.cfg.work_conserving and not self._busy():
            return True
        if len(self._admission) >= self.cfg.fill:
            return True
        oldest = min(p.ticket.submit_t for p in self._admission)
        if now - oldest >= self.cfg.est_wait_s:
            return True
        deadlines = [
            p.ticket.deadline_t
            for p in self._admission
            if p.ticket.deadline_t is not None
        ]
        return bool(deadlines) and (
            min(deadlines) - self.cfg.flush_margin_s <= now + self.cfg.est_wait_s
        )

    def _due(self, t: int, queue: List[_Pending], now: float,
             force: bool) -> Optional[str]:
        if force:
            return TRIGGER_FLUSH
        if len(queue) >= self.cfg.fill:
            return TRIGGER_FILL
        deadlines = [
            p.ticket.deadline_t for p in queue if p.ticket.deadline_t is not None
        ]
        # With the degradation ladder armed, look ahead by the tier's
        # predicted drain cost: a bucket whose oldest deadline falls inside
        # the window [now, now + predicted] must dispatch *now* to have any
        # chance of making it (waiting can only convert OK into TIMED_OUT).
        horizon = now + (
            self.cost_model.predict(t) if self.cfg.degrade else 0.0
        )
        if deadlines and min(deadlines) - self.cfg.flush_margin_s <= horizon:
            return TRIGGER_DEADLINE
        if self.cfg.work_conserving and not self._busy():
            # nothing is running: holding this bucket buys no amortization.
            # Tiers are scanned smallest-ef first, so the cheap bucket goes
            # now and the device is busy again by the next tier's check.
            return TRIGGER_IDLE
        return None

    # ---------------------------------------------------------- degradation
    def _degrade_at_risk(self, now: float) -> None:
        """Walk queued requests down the ef-tier ladder when the cost model
        predicts their deadline cannot survive their current rung, and
        answer already-blown deadlines from their phase-A state as PARTIAL.

        Tiers are scanned top-down, so a request appended to rung ``t-1``
        is re-examined there in the same sweep and may walk several rungs
        at once.  Rung 0 has nowhere lower to go — its at-risk requests are
        left for the deadline trigger (the lookahead in :meth:`_due`
        dispatches them as early as possible).  A cold cost model predicts
        0.0, so nothing degrades before at least one drain was observed.
        """
        for t in range(len(self._queues) - 1, -1, -1):
            queue = self._queues[t]
            if not queue:
                continue
            keep: List[_Pending] = []
            for p in queue:
                deadline = p.ticket.deadline_t
                if deadline is None:
                    keep.append(p)
                    continue
                remaining = deadline - now
                if remaining <= 0:
                    self._answer_partial(p, now)
                    continue
                predicted = self.cost_model.predict(t)
                if (
                    t > 0
                    and predicted > 0.0
                    and predicted > remaining - self.cfg.flush_margin_s
                ):
                    p.ef = min(p.ef, self.router.tiers[t - 1].ef)
                    p.stats.demotions += 1
                    self.stats.inc("demotions")
                    if self.tracer is not None:
                        self.tracer.event(
                            "demote", p.ticket.uid,
                            from_ef=self.router.tiers[t].ef,
                            to_ef=self.router.tiers[t - 1].ef,
                            predicted_s=predicted,
                            remaining_s=remaining,
                        )
                    self._queues[t - 1].append(p)
                    continue
                keep.append(p)
            self._queues[t] = keep

    def _answer_partial(self, p: _Pending, now: float) -> None:
        """Deadline already blown: answer best-effort from the carried
        phase-A result heap instead of spending a (pointless) tier search.

        Under a **post-filter** plan the phase-A heap is unfiltered by
        design (the predicate is enforced by the tier search's heap
        epilogue, which never ran here), so the partial answer filters the
        full heap row host-side before slicing top-k — a partial response
        may be short of k, never wrong."""
        states = p.est_pass.states
        rk = np.asarray(states.rk[p.row])
        ri = np.asarray(states.ri[p.row])
        p.est_pass = None
        graph = p.graph if p.graph is not None else self.router.graph
        fmask = getattr(graph, "fmask", None)
        if self.router.base_cfg.filter_mode == "post" and fmask is not None:
            fm = np.asarray(fmask)
            ok = (ri >= 0) & fm[np.maximum(ri, 0)]
            rk = np.where(ok, rk, np.inf)
            ri = np.where(ok, ri, -1)
            order = np.argsort(rk, kind="stable")
            rk, ri = rk[order], ri[order]
        rk = rk[: p.k]
        ri = ri[: p.k]
        finite = np.isfinite(rk)
        sign = key_sign(self.router.base_cfg.metric)
        ids = np.where(finite, ri, -1).astype(np.int32)
        dists = np.where(finite, rk * sign, np.inf).astype(np.float32)
        p.stats.status = STATUS_PARTIAL
        p.stats.trigger = TRIGGER_PARTIAL
        p.stats.dispatch_t = now
        p.stats.done_t = now
        p.stats.ndist = p.stats.est_ndist
        self.stats.inc("partials")
        self._terminal(p, STATUS_PARTIAL, ids=ids)
        self._done.append(
            SearchResponse(
                ticket=p.ticket,
                ids=ids,
                dists=dists,
                ndist=p.stats.est_ndist,
                iters=0,
                ef_used=0,
                stats=p.stats,
                status=STATUS_PARTIAL,
            )
        )

    # ----------------------------------------------------------- estimation
    def _estimate_admitted(self, now: float) -> None:
        entries, self._admission = self._admission, []
        # Screen non-finite rows (corruption past the submit-time front
        # door, e.g. injected by the chaos harness): shed exactly the
        # offenders as REJECTED before they can poison the shared pass —
        # cohabiting requests estimate and serve normally.
        finite: List[_Pending] = []
        for p in entries:
            if np.isfinite(p.query).all():
                finite.append(p)
            else:
                self._shed(p, now, "non-finite query values")
        entries = finite
        if not entries:
            return
        b = len(entries)
        shape = pad_shape(b, self.min_shape)
        q = np.stack([p.query for p in entries])
        q_pad = np.concatenate([q, np.repeat(q[:1], shape - b, axis=0)])
        targets = np.asarray([p.target for p in entries], np.float32)
        t_pad = np.concatenate([targets, np.repeat(targets[:1], shape - b)])
        tr = self.tracer
        espan = (
            None if tr is None
            else tr.begin("estimate", None, batch=b, shape=shape)
        )
        t0 = self.clock()
        ef_np, states = self.router.estimate(
            q_pad, t_pad[:, None], num_real=b
        )
        jax.block_until_ready(states)
        wall = self.clock() - t0
        if tr is not None:
            tr.end(espan, wall_s=wall)
        self.metrics.histogram("est_pass_wall_s").observe(wall)
        est_ndist = np.asarray(states.ndist)
        est_ndist_q = np.asarray(states.ndist_q)
        est_pass = _EstPass(states=states, queries=q_pad)
        tiers = assign_tiers(ef_np[:b], self.router._tier_efs)
        epoch = self._epoch()
        for i, p in enumerate(entries):
            p.est_pass = est_pass
            p.row = i
            p.ef = int(ef_np[i])
            p.graph = self.router.graph   # pin the epoch the phase-A state
            #   was computed on; dispatch and audit must resume/compare here
            p.stats.epoch = epoch
            p.stats.est_t = now
            p.stats.est_batch = b
            p.stats.est_ndist = int(est_ndist[i])
            p.stats.ndist_q = int(est_ndist_q[i])
            p.stats.ef_est = p.ef
            ti = int(tiers[i])
            if tr is not None:
                tr.event("estimate", p.ticket.uid, ef_est=p.ef)
            queue = self._queues[ti]
            if self.cfg.max_tier_queue and len(queue) >= self.cfg.max_tier_queue:
                self._shed(
                    p, now,
                    f"tier queue full (ef={self.router.tiers[ti].ef},"
                    f" bound={self.cfg.max_tier_queue})",
                )
                continue
            if tr is not None:
                p.qspan = tr.begin(
                    "queue", p.ticket.uid, tier_ef=self.router.tiers[ti].ef
                )
            queue.append(p)
        st = self.stats
        st.inc("est_passes")
        st.inc("est_shape_total", shape)
        st.inc("est_ndist_total", int(est_ndist[:b].sum()))
        st.inc("est_pad_ndist", int(est_ndist[b:].sum()))
        st.inc("est_wall_s", wall)

    # -------------------------------------------------------------- dispatch
    def _attempt_ladder(self, tier: TierSpec) -> List[Tuple[object, str]]:
        """The (cfg, backend_label) attempts a tier drain may consume:
        primary, primary again (one retry — transient faults), then the
        planner's backend ladder below the primary.  ``ops`` kernels already
        self-select interpret off-TPU, so the one rung below a kernel config
        is the pure-jnp oracle (``use_distance_kernel=False``)."""
        ladder: List[Tuple[object, str]] = [(tier.cfg, ""), (tier.cfg, "")]
        if tier.cfg.use_distance_kernel:
            ladder.append(
                (
                    dataclasses.replace(tier.cfg, use_distance_kernel=False),
                    "oracle",
                )
            )
        return ladder

    def _count_attempt(self, attempts, ai: int) -> None:
        """Attempt ``ai > 0`` is being consumed: same cfg as the previous
        attempt -> retry, different cfg -> backend fallback."""
        if attempts[ai][0] == attempts[ai - 1][0]:
            self.stats.inc("kernel_retries")
        else:
            self.stats.inc("kernel_fallbacks")

    def _materialize(self, d: _Dispatch) -> None:
        """Block on a dispatch's device results, walking the remaining
        backend ladder synchronously if execution failed (async dispatch
        surfaces runtime kernel failures only at ``block_until_ready``).
        Feeds the tier cost model on success."""
        if d.res_np is not None:  # a sibling slot already materialized it
            return
        tr = self.tracer
        mspan = (
            None if tr is None
            else tr.begin("materialize", None, tier_ef=d.tier.ef)
        )
        last_err: Optional[Exception] = None
        while True:
            if d.res_dev is not None:
                try:
                    d.finish(self.stats, self.clock)
                    break
                except Exception as err:  # runtime failure: ladder below
                    last_err = err
                    d.res_dev = None
            ai = d.used_ai + 1
            if ai >= len(d.attempts):
                raise DispatchFailedError(
                    f"tier ef={d.tier.ef} dispatch failed on every backend "
                    f"rung ({[lb or 'primary' for _, lb in d.attempts]})"
                ) from last_err
            self._count_attempt(d.attempts, ai)
            d.used_ai = ai
            d.backend = d.attempts[ai][1]
            try:
                if self._chaos is not None:
                    self._chaos.before_attempt(d.didx, ai)
                q_dev, states, ef_dev = d.inputs
                graph = d.graph if d.graph is not None else self.router.graph
                with (
                    device_annotation(f"ada_resume_ef{d.tier.ef}_retry")
                    if tr is not None else contextlib.nullcontext()
                ):
                    d.res_dev = resume_at_ef(
                        graph, q_dev, states, ef_dev, d.attempts[ai][0],
                    )
            except Exception as err:
                last_err = err
        if tr is not None:
            tr.end(
                mspan, wall_s=d.wall_s,
                backend=d.backend or "primary", attempts=d.used_ai + 1,
            )
        self.metrics.histogram(
            "tier_drain_wall_s", ef=d.tier.ef
        ).observe(d.wall_s)
        self.cost_model.observe(d.tier_idx, d.wall_s)
        if d.used_ai > 0:
            for p in d.entries:
                p.stats.dispatch_retries = d.used_ai
                p.stats.fallback_backend = d.backend

    def _dispatch_tier(self, t: int, now: float, trigger: str) -> int:
        entries, self._queues[t] = self._queues[t], []
        tier = self.router.tiers[t]
        # Resume on the epoch the bucket's phase-A states were computed on.
        # The mutation fence drains every queue before the router rebinds,
        # so a bucket never mixes epochs: all entries pin the same graph.
        graph = (
            entries[0].graph
            if entries[0].graph is not None
            else self.router.graph
        )
        b = len(entries)
        shape = pad_shape(b, self.min_shape)
        # Gather each request's carried phase-A state row.  A bucket may span
        # several estimation passes; every device op here runs at the
        # *padded dispatch shape* (one full-shape take per pass, then a
        # masked where-merge across passes), so the eager-op compile cache is
        # keyed only by the small pow2 shape set — never by how many requests
        # happened to share a pass.  Padding slots replicate the first entry
        # (the cheapest legal resume: ef = k), exactly like the synchronous
        # route() barrier did.
        passes: List[_EstPass] = []
        owner = np.zeros(shape, np.int64)
        rows = np.zeros(shape, np.int64)
        for slot, p in enumerate(entries):
            for pi, est_pass in enumerate(passes):
                if est_pass is p.est_pass:
                    break
            else:
                passes.append(p.est_pass)
                pi = len(passes) - 1
            owner[slot] = pi
            rows[slot] = p.row
        owner[b:] = owner[0]
        rows[b:] = rows[0]

        states = q_b = None
        for pi, est_pass in enumerate(passes):
            mine = owner == pi
            take = jnp.asarray(np.where(mine, rows, 0))
            part = jax.tree_util.tree_map(
                lambda a, t_=take: a[t_], est_pass.states
            )
            q_part = est_pass.queries[np.where(mine, rows, 0)]
            if states is None:
                states, q_b = part, q_part
            else:
                m_dev = jnp.asarray(mine)
                states = jax.tree_util.tree_map(
                    lambda pa, aa: jnp.where(
                        m_dev.reshape((shape,) + (1,) * (pa.ndim - 1)), pa, aa
                    ),
                    part,
                    states,
                )
                q_b = np.where(mine[:, None], q_part, q_b)
        ef_b = np.asarray(
            [p.ef for p in entries]
            + [self.router.base_cfg.k] * (shape - b),
            np.int32,
        )
        for p in entries:
            # the carried phase-A rows are gathered; dropping the reference
            # lets each estimation pass free its device buffers as soon as
            # the last request it admitted has dispatched
            p.est_pass = None
        q_dev = jnp.asarray(q_b)
        states = resize_state(states, tier.ef)
        ef_dev = jnp.asarray(ef_b)
        attempts = self._attempt_ladder(tier)
        didx = -1 if self._chaos is None else self._chaos.next_dispatch()
        tr = self.tracer
        dspan = (
            None if tr is None
            else tr.begin(
                "dispatch", None,
                tier_ef=tier.ef, batch=b, shape=shape, trigger=trigger,
            )
        )
        t0 = self.clock()
        res_dev = None
        last_err: Optional[Exception] = None
        ai = 0
        while ai < len(attempts):
            if ai > 0:
                self._count_attempt(attempts, ai)
            try:
                if self._chaos is not None:
                    self._chaos.before_attempt(didx, ai)
                with (
                    device_annotation(f"ada_resume_ef{tier.ef}")
                    if tr is not None else contextlib.nullcontext()
                ):
                    res_dev = resume_at_ef(
                        graph, q_dev, states, ef_dev, attempts[ai][0],
                    )
                break
            except Exception as err:  # dispatch-time failure: walk the ladder
                last_err = err
                ai += 1
        if res_dev is None:
            raise DispatchFailedError(
                f"tier ef={tier.ef} dispatch failed on every backend rung "
                f"({[label or 'primary' for _, label in attempts]})"
            ) from last_err
        if tr is not None:
            tr.end(dspan, attempts=ai + 1)
        dispatch = _Dispatch(
            tier, t, entries, shape, res_dev, t0,
            (q_dev, states, ef_dev), attempts, ai, didx, graph=graph,
        )
        for slot, p in enumerate(entries):
            p.stats.dispatch_t = now
            p.stats.tier_ef = tier.ef
            p.stats.tier_beam = tier.beam
            p.stats.dispatch_batch = b
            p.stats.padded_to = shape
            p.stats.trigger = trigger
            if tr is not None:
                tr.end(p.qspan, tier_ef=tier.ef)
                p.qspan = None
                p.dspan = tr.begin(
                    "dispatch", p.ticket.uid,
                    tier_ef=tier.ef, trigger=trigger, ef=p.ef,
                )
            self._inflight.append((dispatch, slot, p))
        self.stats.inc({
            TRIGGER_FILL: "fill_drains",
            TRIGGER_DEADLINE: "deadline_drains",
            TRIGGER_FLUSH: "flush_drains",
            TRIGGER_IDLE: "idle_drains",
        }[trigger])
        return b

    # ------------------------------------------------------------------ poll
    def poll(
        self,
        *,
        block: bool = False,
        uids: Optional[Sequence[int]] = None,
    ) -> List[SearchResponse]:
        """Harvest completed responses.  Non-blocking by default: only
        dispatches whose device buffers are ready materialize (plus any
        dispatch-free terminal responses — REJECTED tickets, PARTIAL
        answers — which are always ready).  ``uids`` restricts harvesting to
        those tickets (others stay queued — e.g. an engine polling its own
        requests on a shared scheduler).  Raises :class:`StalePlanError` if
        the index mutated under an *orphaned* scheduler (no mutation seam)
        while live work was still queued/in flight; already-terminal
        responses of a stale scheduler remain harvestable, and absorbed
        (index-registered) schedulers never raise here.
        """
        if self._live() > 0:
            self._check_fresh()
        want = None if uids is None else set(uids)
        out: List[SearchResponse] = []
        if self._done:
            still: List[SearchResponse] = []
            for r in self._done:
                if want is None or r.ticket.uid in want:
                    out.append(r)
                else:
                    still.append(r)
            self._done = still
        keep: List[Tuple[_Dispatch, int, _Pending]] = []
        for item in self._inflight:
            dispatch, slot, p = item
            if want is not None and p.ticket.uid not in want:
                keep.append(item)
                continue
            if not (block or dispatch.ready()):
                keep.append(item)
                continue
            self._materialize(dispatch)
            out.append(self._response(dispatch, slot, p))
        self._inflight = keep
        if out:
            self.stats.inc("completed", len(out))
        return out

    def drain(self) -> List[SearchResponse]:
        """Flush everything and block for every outstanding response; any
        recall audits still pending run to completion before returning."""
        self.flush()
        out = self.poll(block=True)
        if self.auditor is not None:
            self.auditor.flush()
        return out

    def _response(self, dispatch: _Dispatch, slot: int,
                  p: _Pending) -> SearchResponse:
        res = dispatch.res_np
        p.stats.done_t = self.clock()
        p.stats.ndist = int(res.ndist[slot])
        if res.ndist_q is not None:
            p.stats.ndist_q = int(res.ndist_q[slot])
        p.stats.ef_achieved = int(res.ef_used[slot])
        deadline = p.ticket.deadline_t
        if deadline is not None and p.stats.done_t > deadline:
            status = STATUS_TIMED_OUT
            self.stats.inc("timed_out")
        elif p.stats.demotions > 0:
            status = STATUS_DEGRADED
            self.stats.inc("degraded")
        else:
            status = STATUS_OK
        p.stats.status = status
        ids = res.ids[slot, : p.k].copy()
        self._terminal(p, status, ids=ids)
        return SearchResponse(
            ticket=p.ticket,
            ids=ids,
            dists=res.dists[slot, : p.k].copy(),
            ndist=int(res.ndist[slot]),
            iters=int(res.iters[slot]),
            ef_used=int(res.ef_used[slot]),
            stats=p.stats,
            status=status,
            ndist_q=p.stats.ndist_q,
        )

    # ------------------------------------------------------------ inspection
    @property
    def pending(self) -> int:
        """Requests submitted but not yet returned through :meth:`poll`
        (terminal-but-unpolled responses included)."""
        return self._live() + len(self._done)

    def queue_depths(self) -> List[int]:
        """Current per-tier queue lengths (admission not included)."""
        return [len(q) for q in self._queues]

    def router_stats(self, since: Optional[SchedulerStats] = None):
        """Render (a slice of) the scheduler counters as a batch-compatible
        :class:`RouterStats` — ``since`` is a prior ``stats.snapshot()``."""
        from .stats import RouterStats

        st = self.stats.delta(since)
        return RouterStats(
            batch=st.submitted,
            est_shape=st.est_shape_total,
            est_cap=self.router.est_cfg.ef_cap,
            est_ndist_total=st.est_ndist_total,
            est_wall_s=st.est_wall_s,
            est_matched=self.router.est_matched,
            est_pad_ndist=st.est_pad_ndist,
            tiers=list(st.tiers),
        )


def submit_with_backoff(
    sched: AdaServeScheduler,
    request: SearchRequest,
    *,
    attempts: int = 6,
    base_s: float = 0.002,
    max_s: float = 0.1,
    harvest: Optional[Callable[[List[SearchResponse]], None]] = None,
) -> SearchTicket:
    """Submit with capped exponential backoff against admission control.

    On :class:`OverloadedError` the caller's best move is not to sleep but
    to *make room*: tick the scheduler (dispatching whatever is due — the
    last attempts force-flush) and block-poll for completed responses,
    handing them to ``harvest`` so they are not dropped.  Only when that
    freed nothing does it sleep ``base_s * 2**attempt`` (capped at
    ``max_s``) and try again; the final failure re-raises.  This is the
    :class:`repro.serve.engine.Engine` retry policy and usable standalone.
    """
    for attempt in range(attempts):
        try:
            return sched.submit(request)
        except OverloadedError:
            if attempt == attempts - 1:
                raise
            if attempt >= 2:
                sched.flush()
            else:
                sched.step()
            got = sched.poll(block=True)
            if harvest is not None and got:
                harvest(got)
            if not got:
                time.sleep(min(base_s * (2 ** attempt), max_s))
    raise AssertionError("unreachable")


def replay_trace(
    sched: AdaServeScheduler,
    requests: Sequence[SearchRequest],
    arrivals: Sequence[float],
    *,
    sleep_s: float = 1e-3,
) -> Tuple[List[SearchResponse], np.ndarray]:
    """Real-time replay of an arrival trace through a scheduler.

    Submits ``requests[i]`` once ``arrivals[i]`` seconds (ascending, relative
    to the replay start) have elapsed, ticking and polling the scheduler in
    between; sleeps briefly whenever a tick produced nothing so the host does
    not busy-spin, and finishes with a flush + blocking poll.  Only this
    trace's tickets are harvested (uid-filtered), so a shared scheduler's
    other traffic is left alone.  Returns ``(responses, latencies)`` aligned
    with the submit order, latency = arrival -> response materialization.
    This is the one canonical submit/step/poll loop — the streaming drivers
    and the scheduler benchmark all replay through it.  Replay timing runs
    on the scheduler's injected clock, so replay latencies, deadline
    decisions and trace spans share one timeline.
    """
    clock = getattr(sched, "clock", None) or time.monotonic
    n = len(requests)
    arrive = {}
    order: List[int] = []
    got = {}
    lat = {}
    t0 = clock()

    def harvest(block: bool = False) -> int:
        pend = [u for u in order if u not in got]
        if not pend:
            return 0
        res = sched.poll(block=block, uids=pend)
        for r in res:
            got[r.ticket.uid] = r
            lat[r.ticket.uid] = clock() - t0 - arrive[r.ticket.uid]
        return len(res)

    i = 0
    while i < n:
        now = clock() - t0
        while i < n and arrivals[i] <= now:
            tk = sched.submit(requests[i])
            arrive[tk.uid] = arrivals[i]
            order.append(tk.uid)
            i += 1
        progressed = harvest()
        sched.step()
        progressed += harvest()
        if i < n and not progressed:
            gap = arrivals[i] - (clock() - t0)
            if gap > 0:
                time.sleep(min(gap, sleep_s))
    sched.flush()
    harvest(block=True)
    return [got[u] for u in order], np.asarray([lat[u] for u in order])
