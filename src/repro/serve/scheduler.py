"""Continuous-batching scheduler over the ef-tier router (request lifecycle).

:class:`AdaServeScheduler` turns the one-shot synchronous
``QueryRouter.route`` barrier into a request lifecycle:

1. **submit()** — a :class:`repro.serve.api.SearchRequest` enters the
   admission queue and gets a :class:`SearchTicket` back; nothing runs yet.
2. **step()** — one scheduler tick.  Whatever has arrived since the last
   tick runs **one shared estimation pass** (phase A + ESTIMATE-EF, padded
   to a pow2 shape; padding rows converge immediately, see
   ``estimate_pass(num_real=...)``), and each estimated request drops into
   its ef-tier queue *carrying its phase-A* :class:`SearchState` — the
   resumable unit the phase-split search provides.  Then every tier bucket
   that has reached its pow2 **fill**, or whose **oldest request's deadline**
   is due, drains as one batch-hoisted ``resume_at_ef`` dispatch.  There is
   *no all-tier barrier*: an easy (small-ef) tier drains the moment it
   fills while a hard tier keeps accumulating, and dispatches are
   asynchronous (JAX async dispatch) so tiers overlap on device.
3. **poll()** — completed :class:`SearchResponse` objects (non-blocking by
   default: only dispatches whose device buffers are ready materialize).
4. **drain()** — force-flush everything and block for all responses.

Equivalence: tier searches resume the carried phase-A state, and both
phases are per-query independent, so for any interleaving of
``submit``/``step``/``poll`` and any drain trigger the scheduler returns
results bit-identical to a synchronous submit-all/drain-all barrier under a
lossless config (the arrival-order invariance property test in
``tests/test_scheduler.py``).  ``ExecutionPlan.search`` in a lifecycle mode
is exactly that barrier over a one-shot instance of this class.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.search import resize_state, resume_at_ef
from repro.pytrees import register_static_config
from .api import RequestStats, SearchRequest, SearchResponse, SearchTicket
from .bucketing import assign_tiers, pad_shape
from .stats import SchedulerStats, TierStats
from .tiers import TierSpec

TRIGGER_FILL = "fill"
TRIGGER_DEADLINE = "deadline"
TRIGGER_FLUSH = "flush"
TRIGGER_IDLE = "idle"


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Drain policy knobs (host-side; no effect on compiled shapes beyond
    the pow2 padding every dispatch already uses)."""

    fill: int = 8           # tier bucket drains once it holds >= fill requests
    #   (power of two: a full bucket then dispatches pad-free)
    min_shape: int = 0      # smallest padded dispatch shape; 0 -> inherit the
    #   router's RouterConfig.min_shape
    flush_margin_s: float = 0.0  # drain a tier this early before its oldest
    #   deadline (headroom for the dispatch itself)
    est_wait_s: float = 0.0  # admission batching window: hold arrivals up to
    #   this long (unless ``fill`` arrivals or a deadline force it) so one
    #   estimation pass amortizes over more requests; 0 = estimate every tick
    work_conserving: bool = True  # never hold work while the device is idle:
    #   when no dispatch is in flight, arrivals estimate immediately and the
    #   first nonempty tier drains immediately (batching windows only apply
    #   under load, where they amortize; under light load the scheduler then
    #   matches a greedy synchronous server instead of idling toward fill).
    #   Tiers are scanned smallest-ef first, so idle drains favor easy work.

    def __post_init__(self):
        if self.fill < 1 or (self.fill & (self.fill - 1)) != 0:
            raise ValueError(f"fill={self.fill} must be a power of two >= 1")
        if self.flush_margin_s < 0:
            raise ValueError("flush_margin_s must be >= 0")
        if self.est_wait_s < 0:
            raise ValueError("est_wait_s must be >= 0")


# Static pytree: zero leaves, jit-keyed by dataclass equality (same policy
# -> same compile-cache entry), never traced.
register_static_config(SchedulerConfig)


class _EstPass:
    """One estimation dispatch: the carried batched phase-A state plus the
    padded raw query panel it was computed from.  Tier drains gather rows out
    of (possibly several) of these; the object stays alive until every
    request it admitted has been dispatched."""

    __slots__ = ("states", "queries")

    def __init__(self, states, queries: np.ndarray):
        self.states = states
        self.queries = queries


class _Pending:
    """A request in flight: admission -> (estimated) tier queue -> dispatch."""

    __slots__ = (
        "ticket", "query", "target", "k", "stats",
        "est_pass", "row", "ef",
    )

    def __init__(self, ticket: SearchTicket, query: np.ndarray,
                 target: float, k: int):
        self.ticket = ticket
        self.query = query
        self.target = target
        self.k = k
        self.stats = RequestStats(submit_t=ticket.submit_t)
        self.est_pass: Optional[_EstPass] = None
        self.row = -1
        self.ef = -1


class _Dispatch:
    """One tier drain: device results shared by its requests, materialized
    (blocked + pulled to host) lazily at poll time so dispatches overlap."""

    __slots__ = ("tier", "entries", "shape", "res_dev", "res_np", "t0", "wall_s")

    def __init__(self, tier: TierSpec, entries: List[_Pending], shape: int,
                 res_dev, t0: float):
        self.tier = tier
        self.entries = entries
        self.shape = shape
        self.res_dev = res_dev
        self.res_np = None
        self.t0 = t0
        self.wall_s = 0.0

    def ready(self) -> bool:
        if self.res_np is not None:
            return True
        try:
            return all(
                leaf.is_ready()
                for leaf in jax.tree_util.tree_leaves(self.res_dev)
            )
        except AttributeError:
            # jax without Array.is_ready: report not-ready so non-blocking
            # polls stay non-blocking; results are harvested by the blocking
            # polls every consumer ends with (drain / replay tail / engine)
            return False

    def materialize(self, stats: SchedulerStats) -> None:
        if self.res_np is not None:
            return
        jax.block_until_ready(self.res_dev)
        self.wall_s = time.perf_counter() - self.t0
        self.res_np = jax.tree_util.tree_map(np.asarray, self.res_dev)
        self.res_dev = None
        n = len(self.entries)
        stats.tiers.append(
            TierStats(
                ef=self.tier.ef,
                beam=self.tier.beam,
                count=n,
                padded_to=self.shape,
                ndist_total=int(self.res_np.ndist[:n].sum()),
                wall_s=self.wall_s,
            )
        )


class AdaServeScheduler:
    """Continuous-batching executor over one :class:`QueryRouter`.

    Owns the admission queue, the per-tier request queues, and the set of
    in-flight dispatches.  Rebuild (or let ``AdaEfIndex.scheduler()``
    rebuild) after index updates — it holds the router's graph/table
    references, and pending requests do not survive an index mutation.

    ``clock`` is injectable (tests drive deadlines with a fake clock); it
    only gates *deadline draining* and telemetry timestamps, never results.
    """

    def __init__(
        self,
        router,
        cfg: Optional[SchedulerConfig] = None,
        *,
        default_target_recall: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.router = router
        self.cfg = cfg or SchedulerConfig()
        self.min_shape = self.cfg.min_shape or router.router_cfg.min_shape
        self.default_target_recall = default_target_recall
        self.clock = clock
        self.stats = SchedulerStats()
        self._uids = itertools.count()
        self._admission: List[_Pending] = []
        self._queues: List[List[_Pending]] = [[] for _ in router.tiers]
        self._inflight: List[Tuple[_Dispatch, int, _Pending]] = []

    # --------------------------------------------------------------- submit
    def submit(self, request: SearchRequest) -> SearchTicket:
        """Admit one request; returns its ticket.  Nothing is dispatched
        until the next :meth:`step` (call it as often as you like — an empty
        tick is cheap)."""
        q = np.asarray(request.query, np.float32)
        if q.ndim == 2 and q.shape[0] == 1:
            q = q[0]
        if q.ndim != 1:
            raise ValueError(f"expected a single (d,) query, got {q.shape}")
        k = self.router.base_cfg.k if request.k is None else int(request.k)
        if not 1 <= k <= self.router.base_cfg.k:
            raise ValueError(
                f"k={k} not in [1, index k={self.router.base_cfg.k}]"
            )
        target = (
            self.default_target_recall
            if request.target_recall is None
            else request.target_recall
        )
        if target is None:
            raise ValueError(
                "request has no target_recall and the scheduler has no default"
            )
        now = self.clock()
        ticket = SearchTicket(
            uid=next(self._uids),
            submit_t=now,
            deadline_t=(
                None if request.deadline_s is None else now + request.deadline_s
            ),
        )
        self._admission.append(_Pending(ticket, q, float(target), k))
        self.stats.submitted += 1
        return ticket

    # ----------------------------------------------------------------- tick
    def step(self, now: Optional[float] = None, *, force: bool = False) -> int:
        """One scheduler tick: estimate whatever arrived, then drain every
        tier bucket that is due (fill reached / oldest deadline due /
        ``force``).  Returns the number of requests dispatched this tick.
        Dispatches are asynchronous — harvest results with :meth:`poll`."""
        now = self.clock() if now is None else now
        if self._admission and (force or self._est_due(now)):
            self._estimate_admitted(now)
        dispatched = 0
        for t, queue in enumerate(self._queues):
            if not queue:
                continue
            trigger = self._due(queue, now, force)
            if trigger is not None:
                dispatched += self._dispatch_tier(t, now, trigger)
        return dispatched

    def flush(self) -> int:
        """Force-drain every queue (estimation included); non-blocking."""
        return self.step(force=True)

    def _busy(self) -> bool:
        """Any dispatch still executing (not yet materializable)?"""
        return any(not item[0].ready() for item in self._inflight)

    def _est_due(self, now: float) -> bool:
        """Should the admission queue run its estimation pass this tick?
        Immediately unless an ``est_wait_s`` batching window is configured;
        an idle device (work-conserving mode), ``fill`` arrivals or a
        deadline inside the window override the wait."""
        if self.cfg.est_wait_s <= 0:
            return True
        if self.cfg.work_conserving and not self._busy():
            return True
        if len(self._admission) >= self.cfg.fill:
            return True
        oldest = min(p.ticket.submit_t for p in self._admission)
        if now - oldest >= self.cfg.est_wait_s:
            return True
        deadlines = [
            p.ticket.deadline_t
            for p in self._admission
            if p.ticket.deadline_t is not None
        ]
        return bool(deadlines) and (
            min(deadlines) - self.cfg.flush_margin_s <= now + self.cfg.est_wait_s
        )

    def _due(self, queue: List[_Pending], now: float,
             force: bool) -> Optional[str]:
        if force:
            return TRIGGER_FLUSH
        if len(queue) >= self.cfg.fill:
            return TRIGGER_FILL
        deadlines = [
            p.ticket.deadline_t for p in queue if p.ticket.deadline_t is not None
        ]
        if deadlines and min(deadlines) - self.cfg.flush_margin_s <= now:
            return TRIGGER_DEADLINE
        if self.cfg.work_conserving and not self._busy():
            # nothing is running: holding this bucket buys no amortization.
            # Tiers are scanned smallest-ef first, so the cheap bucket goes
            # now and the device is busy again by the next tier's check.
            return TRIGGER_IDLE
        return None

    # ----------------------------------------------------------- estimation
    def _estimate_admitted(self, now: float) -> None:
        entries, self._admission = self._admission, []
        b = len(entries)
        shape = pad_shape(b, self.min_shape)
        q = np.stack([p.query for p in entries])
        q_pad = np.concatenate([q, np.repeat(q[:1], shape - b, axis=0)])
        targets = np.asarray([p.target for p in entries], np.float32)
        t_pad = np.concatenate([targets, np.repeat(targets[:1], shape - b)])
        t0 = time.perf_counter()
        ef_np, states = self.router.estimate(
            q_pad, t_pad[:, None], num_real=b
        )
        jax.block_until_ready(states)
        wall = time.perf_counter() - t0
        est_ndist = np.asarray(states.ndist)
        est_pass = _EstPass(states=states, queries=q_pad)
        tiers = assign_tiers(ef_np[:b], self.router._tier_efs)
        for i, p in enumerate(entries):
            p.est_pass = est_pass
            p.row = i
            p.ef = int(ef_np[i])
            p.stats.est_t = now
            p.stats.est_batch = b
            p.stats.est_ndist = int(est_ndist[i])
            p.stats.ef_est = p.ef
            self._queues[int(tiers[i])].append(p)
        st = self.stats
        st.est_passes += 1
        st.est_shape_total += shape
        st.est_ndist_total += int(est_ndist[:b].sum())
        st.est_pad_ndist += int(est_ndist[b:].sum())
        st.est_wall_s += wall

    # -------------------------------------------------------------- dispatch
    def _dispatch_tier(self, t: int, now: float, trigger: str) -> int:
        entries, self._queues[t] = self._queues[t], []
        tier = self.router.tiers[t]
        b = len(entries)
        shape = pad_shape(b, self.min_shape)
        # Gather each request's carried phase-A state row.  A bucket may span
        # several estimation passes; every device op here runs at the
        # *padded dispatch shape* (one full-shape take per pass, then a
        # masked where-merge across passes), so the eager-op compile cache is
        # keyed only by the small pow2 shape set — never by how many requests
        # happened to share a pass.  Padding slots replicate the first entry
        # (the cheapest legal resume: ef = k), exactly like the synchronous
        # route() barrier did.
        passes: List[_EstPass] = []
        owner = np.zeros(shape, np.int64)
        rows = np.zeros(shape, np.int64)
        for slot, p in enumerate(entries):
            for pi, est_pass in enumerate(passes):
                if est_pass is p.est_pass:
                    break
            else:
                passes.append(p.est_pass)
                pi = len(passes) - 1
            owner[slot] = pi
            rows[slot] = p.row
        owner[b:] = owner[0]
        rows[b:] = rows[0]

        states = q_b = None
        for pi, est_pass in enumerate(passes):
            mine = owner == pi
            take = jnp.asarray(np.where(mine, rows, 0))
            part = jax.tree_util.tree_map(
                lambda a, t_=take: a[t_], est_pass.states
            )
            q_part = est_pass.queries[np.where(mine, rows, 0)]
            if states is None:
                states, q_b = part, q_part
            else:
                m_dev = jnp.asarray(mine)
                states = jax.tree_util.tree_map(
                    lambda pa, aa: jnp.where(
                        m_dev.reshape((shape,) + (1,) * (pa.ndim - 1)), pa, aa
                    ),
                    part,
                    states,
                )
                q_b = np.where(mine[:, None], q_part, q_b)
        ef_b = np.asarray(
            [p.ef for p in entries]
            + [self.router.base_cfg.k] * (shape - b),
            np.int32,
        )
        for p in entries:
            # the carried phase-A rows are gathered; dropping the reference
            # lets each estimation pass free its device buffers as soon as
            # the last request it admitted has dispatched
            p.est_pass = None
        t0 = time.perf_counter()
        res_dev = resume_at_ef(
            self.router.graph,
            jnp.asarray(q_b),
            resize_state(states, tier.ef),
            jnp.asarray(ef_b),
            tier.cfg,
        )
        dispatch = _Dispatch(tier, entries, shape, res_dev, t0)
        for slot, p in enumerate(entries):
            p.stats.dispatch_t = now
            p.stats.tier_ef = tier.ef
            p.stats.tier_beam = tier.beam
            p.stats.dispatch_batch = b
            p.stats.padded_to = shape
            p.stats.trigger = trigger
            self._inflight.append((dispatch, slot, p))
        counter = {
            TRIGGER_FILL: "fill_drains",
            TRIGGER_DEADLINE: "deadline_drains",
            TRIGGER_FLUSH: "flush_drains",
            TRIGGER_IDLE: "idle_drains",
        }[trigger]
        setattr(self.stats, counter, getattr(self.stats, counter) + 1)
        return b

    # ------------------------------------------------------------------ poll
    def poll(
        self,
        *,
        block: bool = False,
        uids: Optional[Sequence[int]] = None,
    ) -> List[SearchResponse]:
        """Harvest completed responses.  Non-blocking by default: only
        dispatches whose device buffers are ready materialize.  ``uids``
        restricts harvesting to those tickets (others stay queued — e.g. an
        engine polling its own requests on a shared scheduler)."""
        want = None if uids is None else set(uids)
        out: List[SearchResponse] = []
        keep: List[Tuple[_Dispatch, int, _Pending]] = []
        for item in self._inflight:
            dispatch, slot, p = item
            if want is not None and p.ticket.uid not in want:
                keep.append(item)
                continue
            if not (block or dispatch.ready()):
                keep.append(item)
                continue
            dispatch.materialize(self.stats)
            out.append(self._response(dispatch, slot, p))
        self._inflight = keep
        self.stats.completed += len(out)
        return out

    def drain(self) -> List[SearchResponse]:
        """Flush everything and block for every outstanding response."""
        self.flush()
        return self.poll(block=True)

    def _response(self, dispatch: _Dispatch, slot: int,
                  p: _Pending) -> SearchResponse:
        res = dispatch.res_np
        p.stats.done_t = self.clock()
        p.stats.ndist = int(res.ndist[slot])
        return SearchResponse(
            ticket=p.ticket,
            ids=res.ids[slot, : p.k].copy(),
            dists=res.dists[slot, : p.k].copy(),
            ndist=int(res.ndist[slot]),
            iters=int(res.iters[slot]),
            ef_used=int(res.ef_used[slot]),
            stats=p.stats,
        )

    # ------------------------------------------------------------ inspection
    @property
    def pending(self) -> int:
        """Requests submitted but not yet returned through :meth:`poll`."""
        return (
            len(self._admission)
            + sum(len(q) for q in self._queues)
            + len(self._inflight)
        )

    def queue_depths(self) -> List[int]:
        """Current per-tier queue lengths (admission not included)."""
        return [len(q) for q in self._queues]

    def router_stats(self, since: Optional[SchedulerStats] = None):
        """Render (a slice of) the scheduler counters as a batch-compatible
        :class:`RouterStats` — ``since`` is a prior ``stats.snapshot()``."""
        from .stats import RouterStats

        st = self.stats.delta(since)
        return RouterStats(
            batch=st.submitted,
            est_shape=st.est_shape_total,
            est_cap=self.router.est_cfg.ef_cap,
            est_ndist_total=st.est_ndist_total,
            est_wall_s=st.est_wall_s,
            est_matched=self.router.est_matched,
            est_pad_ndist=st.est_pad_ndist,
            tiers=list(st.tiers),
        )


def replay_trace(
    sched: AdaServeScheduler,
    requests: Sequence[SearchRequest],
    arrivals: Sequence[float],
    *,
    sleep_s: float = 1e-3,
) -> Tuple[List[SearchResponse], np.ndarray]:
    """Real-time replay of an arrival trace through a scheduler.

    Submits ``requests[i]`` once ``arrivals[i]`` seconds (ascending, relative
    to the replay start) have elapsed, ticking and polling the scheduler in
    between; sleeps briefly whenever a tick produced nothing so the host does
    not busy-spin, and finishes with a flush + blocking poll.  Only this
    trace's tickets are harvested (uid-filtered), so a shared scheduler's
    other traffic is left alone.  Returns ``(responses, latencies)`` aligned
    with the submit order, latency = arrival -> response materialization.
    This is the one canonical submit/step/poll loop — the streaming drivers
    and the scheduler benchmark all replay through it.
    """
    n = len(requests)
    arrive = {}
    order: List[int] = []
    got = {}
    lat = {}
    t0 = time.perf_counter()

    def harvest(block: bool = False) -> int:
        pend = [u for u in order if u not in got]
        if not pend:
            return 0
        res = sched.poll(block=block, uids=pend)
        for r in res:
            got[r.ticket.uid] = r
            lat[r.ticket.uid] = (
                time.perf_counter() - t0 - arrive[r.ticket.uid]
            )
        return len(res)

    i = 0
    while i < n:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            tk = sched.submit(requests[i])
            arrive[tk.uid] = arrivals[i]
            order.append(tk.uid)
            i += 1
        progressed = harvest()
        sched.step()
        progressed += harvest()
        if i < n and not progressed:
            gap = arrivals[i] - (time.perf_counter() - t0)
            if gap > 0:
                time.sleep(min(gap, sleep_s))
    sched.flush()
    harvest(block=True)
    return [got[u] for u in order], np.asarray([lat[u] for u in order])
