"""Router telemetry: per-tier and per-batch serving counters.

A :class:`RouterStats` is produced per routed batch — cheap host-side
counters (no device sync beyond the results the router already pulls), meant
to be aggregated by whatever metrics layer sits above the engine.  ``ndist``
totals are cumulative across both phases (estimation + tier search), so they
are directly comparable against the monolithic ``adaptive_search`` cost.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass
class TierStats:
    ef: int                # tier capacity
    beam: int              # tier beam width
    count: int             # real queries routed to this tier
    padded_to: int         # fixed batch shape the bucket was padded to
    ndist_total: int       # sum of per-query ndist (est + search), real rows
    wall_s: float          # dispatch -> block_until_ready on the bucket
                           # outputs (execution, not just dispatch); tiers
                           # overlap on device, so walls do not sum to total

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RouterStats:
    batch: int                    # real queries in the request batch
    est_shape: int                # padded shape of the estimation pass
    est_cap: int                  # estimation-pass state capacity
    est_ndist_total: int          # estimation-pass ndist over real queries
    est_wall_s: float             # estimation pass wall-clock (blocked)
    est_matched: bool = False     # efs looked up in an estimation-matched table
    tiers: List[TierStats] = dataclasses.field(default_factory=list)
    total_wall_s: float = 0.0     # end-to-end route() wall-clock

    @property
    def ndist_total(self) -> int:
        """Cumulative distance computations for the batch (est + tiers)."""
        return sum(t.ndist_total for t in self.tiers)

    @property
    def padded_total(self) -> int:
        return self.est_shape + sum(t.padded_to for t in self.tiers)

    @property
    def padding_waste(self) -> float:
        """Fraction of dispatched rows that were padding, in [0, 1)."""
        real = self.batch + sum(t.count for t in self.tiers)
        return 1.0 - real / max(self.padded_total, 1)

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["tiers"] = [t.as_dict() for t in self.tiers]
        d["ndist_total"] = self.ndist_total
        d["padding_waste"] = self.padding_waste
        return d
