"""Serving telemetry: per-tier, per-batch and per-scheduler counters.

A :class:`RouterStats` is produced per routed batch — cheap host-side
counters (no device sync beyond the results the router already pulls), meant
to be aggregated by whatever metrics layer sits above the engine.  ``ndist``
totals are cumulative across both phases (estimation + tier search), so they
are directly comparable against the monolithic ``adaptive_search`` cost.

A :class:`SchedulerStats` accumulates the same counters over the lifetime of
an :class:`repro.serve.scheduler.AdaServeScheduler` (many estimation passes,
many independent tier drains); ``snapshot()``/``delta()`` carve out the slice
belonging to one serving call, and the scheduler can render any slice as a
batch-compatible :class:`RouterStats` for existing consumers.

That "metrics layer above the engine" is :mod:`repro.obs.metrics`:
:meth:`SchedulerStats.bind` mirrors every counter bump into a
:class:`repro.obs.metrics.MetricsRegistry` (which adds what snapshots
cannot — cross-scheduler aggregation, latency *distributions* with
p50/p95/p99, Prometheus text export), while ``as_dict()`` consumers keep
working unchanged.  Per-request timelines live in
:mod:`repro.obs.trace`; achieved-recall auditing in
:mod:`repro.obs.audit`.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class TierCostModel:
    """EWMA of per-tier drain wall-clock, the scheduler's deadline oracle.

    ``observe()`` feeds each drain's measured wall; ``predict()`` answers
    "if this request dispatches on tier ``t`` now, how long until its
    response materializes?".  A tier that has never drained borrows the
    costliest *lower* rung seen so far (a lower bound — higher ef never
    drains faster), and a fully cold model predicts 0.0, so degradation
    never fires before at least one drain has been measured: the ladder
    sheds work based on evidence, not priors.
    """

    alpha: float = 0.25                 # EWMA smoothing (1.0 = last sample)
    costs: Dict[int, float] = dataclasses.field(default_factory=dict)

    def observe(self, tier: int, wall_s: float) -> None:
        prev = self.costs.get(tier)
        if prev is None:
            self.costs[tier] = float(wall_s)
        else:
            self.costs[tier] = prev + self.alpha * (float(wall_s) - prev)

    def predict(self, tier: int) -> float:
        if tier in self.costs:
            return self.costs[tier]
        lower = [w for t, w in self.costs.items() if t < tier]
        return max(lower) if lower else 0.0

    def as_dict(self) -> Dict:
        return {str(t): w for t, w in sorted(self.costs.items())}


@dataclasses.dataclass
class TierStats:
    ef: int                # tier capacity
    beam: int              # tier beam width
    count: int             # real queries routed to this tier
    padded_to: int         # fixed batch shape the bucket was padded to
    ndist_total: int       # sum of per-query ndist (est + search), real rows
    wall_s: float          # dispatch -> first *observed* completion of the
                           # bucket outputs: a blocked pull for synchronous
                           # drains (route()), so execution wall there; under
                           # lazy polling (engine decode overlap, streaming)
                           # an upper bound that includes host idle time
                           # until the poll.  Tiers overlap on device, so
                           # walls do not sum to the batch wall-clock.

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RouterStats:
    batch: int                    # real queries in the request batch
    est_shape: int                # padded shape of the estimation pass
    est_cap: int                  # estimation-pass state capacity
    est_ndist_total: int          # estimation-pass ndist over real queries
    est_wall_s: float             # estimation pass wall-clock (blocked)
    est_matched: bool = False     # efs looked up in an estimation-matched table
    est_pad_ndist: int = 0        # estimation-pass ndist spent on padding rows
    #   (pad rows skip phase A, so this is ~1 per pad row — the counter exists
    #   to make the padding cost visible, not to hide it)
    tiers: List[TierStats] = dataclasses.field(default_factory=list)
    total_wall_s: float = 0.0     # end-to-end route() wall-clock

    @property
    def ndist_total(self) -> int:
        """Cumulative distance computations for the batch (est + tiers)."""
        return sum(t.ndist_total for t in self.tiers)

    @property
    def padded_total(self) -> int:
        return self.est_shape + sum(t.padded_to for t in self.tiers)

    @property
    def padding_waste(self) -> float:
        """Fraction of dispatched rows that were padding, in [0, 1)."""
        real = self.batch + sum(t.count for t in self.tiers)
        return 1.0 - real / max(self.padded_total, 1)

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["tiers"] = [t.as_dict() for t in self.tiers]
        d["ndist_total"] = self.ndist_total
        d["padding_waste"] = self.padding_waste
        return d


@dataclasses.dataclass
class SchedulerStats:
    """Lifetime counters of one :class:`AdaServeScheduler`.

    ``tiers`` holds one :class:`TierStats` per *drain dispatch* (a tier may
    appear many times — each independent drain is one record), in dispatch
    order.  Drain-trigger counters split out why buckets drained: ``fill``
    (reached the pow2 fill), ``deadline`` (oldest request's deadline due),
    ``flush`` (explicit/forced drain), ``idle`` (work-conserving: the device
    had nothing in flight).  The per-dispatch records accumulate for the
    scheduler's lifetime; long-lived owners should slice their own traffic
    with ``snapshot()``/``delta()`` (cheap — no record copying) and may
    ``stats.tiers.clear()`` after exporting if the history grows large.
    """

    submitted: int = 0            # tickets issued
    completed: int = 0            # responses returned through poll()
    est_passes: int = 0           # estimation dispatches run
    est_shape_total: int = 0      # sum of padded estimation shapes
    est_ndist_total: int = 0      # phase-A ndist over real rows
    est_pad_ndist: int = 0        # phase-A ndist spent on padding rows
    est_wall_s: float = 0.0       # summed estimation walls (blocked)
    fill_drains: int = 0
    deadline_drains: int = 0
    flush_drains: int = 0
    idle_drains: int = 0          # work-conserving drains (device was idle)
    rejected: int = 0             # admission control / invalid-query sheds
    demotions: int = 0            # tier-ladder downgrades (rungs walked)
    degraded: int = 0             # responses answered below estimated tier
    partials: int = 0             # blown deadlines answered from phase A
    timed_out: int = 0            # full responses that missed their deadline
    kernel_retries: int = 0       # dispatch retried on the same backend
    kernel_fallbacks: int = 0     # dispatch fell down the backend ladder
    recall_alerts: int = 0        # RecallAuditor contract breaches surfaced
    mutations: int = 0            # index mutations absorbed (epoch swaps):
    #   fence -> pin in-flight state on the pre-mutation epoch -> rebind
    fenced_requests: int = 0      # pending requests force-dispatched against
    #   their pre-mutation epoch by a mutation fence (they complete normally)
    tiers: List[TierStats] = dataclasses.field(default_factory=list)
    tier_mark: int = 0            # len(tiers) at snapshot time (delta cursor)

    def bind(self, registry, prefix: str = "scheduler_") -> "SchedulerStats":
        """Mirror subsequent :meth:`inc` bumps into a
        :class:`repro.obs.metrics.MetricsRegistry` as ``prefix + name``
        counters.  Stored as a plain instance attribute (not a dataclass
        field), so ``as_dict()``/``snapshot()``/``delta()`` and every
        existing consumer are unaffected."""
        self._registry = registry
        self._prefix = prefix
        return self

    def inc(self, name: str, n: float = 1) -> None:
        """Bump counter field ``name`` by ``n``, mirroring into the bound
        registry (if any).  The scheduler routes every increment through
        here so snapshot consumers and the metrics layer cannot drift."""
        setattr(self, name, getattr(self, name) + n)
        reg = getattr(self, "_registry", None)
        if reg is not None:
            reg.counter(self._prefix + name).inc(n)

    def snapshot(self) -> "SchedulerStats":
        """A cheap counter copy marking 'now' — pass it to :meth:`delta`
        later.  The per-dispatch records are not copied (only their current
        count), so snapshotting is O(1) however long the scheduler lived."""
        mark = copy.copy(self)
        mark.tiers = []
        mark.tier_mark = len(self.tiers)
        return mark

    def delta(self, since: Optional["SchedulerStats"]) -> "SchedulerStats":
        """Counters accumulated after ``since`` (a prior :meth:`snapshot`)."""
        if since is None:
            return self
        diff = {
            f.name: getattr(self, f.name) - getattr(since, f.name)
            for f in dataclasses.fields(self)
            if f.name not in ("tiers", "tier_mark")
        }
        return SchedulerStats(tiers=self.tiers[since.tier_mark:], **diff)

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.pop("tier_mark", None)  # internal delta cursor, not telemetry
        d["tiers"] = [t.as_dict() for t in self.tiers]
        return d
