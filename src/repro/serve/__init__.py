"""Serving substrate: request-lifecycle retrieval scheduling, KV-cache
management, and the batched RAG engine.

The serving surface is a **request lifecycle**, not a batch call:

1. ``submit()`` — a :class:`SearchRequest` (query, per-request declarative
   ``target_recall``, optional ``k`` and ``deadline_s``) enters the
   :class:`AdaServeScheduler`'s admission queue; a :class:`SearchTicket`
   comes back.
2. ``step()`` — arriving requests share one small-capacity estimation pass
   (phase A + ESTIMATE-EF; padding rows converge immediately) and drop into
   per-ef-tier queues carrying their resumable phase-A ``SearchState``; any
   tier bucket that reaches its pow2 fill — or whose oldest request's
   deadline is due — drains as one batch-hoisted ``resume_at_ef`` dispatch.
   No all-tier barrier: easy tiers drain while hard tiers accumulate.
3. ``poll()`` / ``drain()`` — completed :class:`SearchResponse` objects with
   per-request :class:`RequestStats` telemetry.

:class:`QueryRouter` owns the routing *policy* (estimation budget, tier
ladder, margins); :class:`AdaServeScheduler` owns execution.  Both are
internal lowering targets of the declarative facade — callers build a
:class:`repro.api.SearchSpec` and hold the ``index.plan(spec)``
:class:`repro.plan.ExecutionPlan`, whose ``submit()``/``poll()`` delegate
here.  :class:`Engine` submits its batch's retrieval before the decode loop
and polls between decode steps, overlapping retrieval with generation;
streaming drivers (``launch/serve.py --stream``, ``examples/rag_serve.py
--stream``) hold a plan directly.

Observability rides on the same lifecycle (see :mod:`repro.obs`): every
scheduler mirrors its counters into a ``MetricsRegistry``; setting
``SchedulerConfig.trace`` arms per-request span tracing (Chrome trace-event
export), ``SchedulerConfig.audit_fraction`` arms the online recall auditor;
``plan.explain(analyze=True)`` runs both on a probe batch and merges the
live measurements into the static explain tree.
"""
from .api import (  # noqa: F401
    STATUS_DEGRADED,
    STATUS_OK,
    STATUS_PARTIAL,
    STATUS_REJECTED,
    STATUS_TIMED_OUT,
    TERMINAL_STATUSES,
    DispatchFailedError,
    InvalidQueryError,
    OverloadedError,
    RequestStats,
    SearchRequest,
    SearchResponse,
    SearchTicket,
    ServeError,
    StalePlanError,
    TenantSLO,
)
from .chaos import FaultInjector, FaultPlan, InjectedFault  # noqa: F401
from .engine import Engine, ServeConfig, ServeResult  # noqa: F401
from .kvcache import grow_cache  # noqa: F401
from .router import QueryRouter, RouterConfig  # noqa: F401
from .scheduler import (  # noqa: F401
    AdaServeScheduler,
    SchedulerConfig,
    submit_with_backoff,
)
from .stats import RouterStats, SchedulerStats, TierCostModel, TierStats  # noqa: F401
from .tiers import TierSpec, tier_ladder  # noqa: F401
