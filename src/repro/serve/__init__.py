"""Serving substrate: KV-cache management, batched RAG engine, and the
Ada-ef query router.

Request flow for a serving batch:

1. ``Engine.serve`` prefills the prompt batch through the LM,
2. each request is embedded into the retrieval space (jitted mean-pool +
   projection),
3. retrieval dispatches through one of two paths:
   - **monolithic** — one fused ``adaptive_search`` over the whole batch, or
   - **routed** (``ServeConfig.routed``) — the :class:`QueryRouter` runs a
     cheap small-capacity estimation pass (phase A + ESTIMATE-EF), buckets
     queries into an ef-tier ladder (per-tier state capacity + auto-tuned
     beam), resumes each padded bucket on its tier's pre-compiled search,
     and scatters results back into request order, emitting
     :class:`RouterStats` telemetry,
4. greedy ``decode`` continues generation with the retrieved ids surfaced to
   the caller.

The engine stays synchronous/batched; the router is the seam where async
continuous batching will hang off (tier queues drained independently).
"""
from .engine import Engine, ServeConfig, ServeResult  # noqa: F401
from .kvcache import grow_cache  # noqa: F401
from .router import QueryRouter, RouterConfig  # noqa: F401
from .stats import RouterStats, TierStats  # noqa: F401
from .tiers import TierSpec, tier_ladder  # noqa: F401
