"""Serving substrate: KV-cache management + batched RAG engine."""
from .engine import Engine, ServeConfig, ServeResult  # noqa: F401
from .kvcache import grow_cache  # noqa: F401
