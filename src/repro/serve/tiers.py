"""Ef-tier ladder for the routed serving path.

A tier is one pre-compilable search variant: C/W state sized to the tier's
``ef_cap`` and a beam width auto-tuned to it (small ef -> narrow beam, large
ef -> wide beam; see :func:`repro.index.search.auto_beam`, applied to the
rung's ef, i.e. the bucket's *worst-case* estimate — the default ef=64 rung
runs beam 2).  A query whose estimated ef is 32 then runs through 64-slot
merges instead of dragging the full-capacity arrays of the monolithic
search, while a query estimated at 400 gets wide MXU-friendly frontier
contractions.

The ladder is static per router — tier configs are hashable
:class:`SearchConfig` instances, so XLA compiles each (tier, bucket-shape)
pair exactly once and reuses it across requests.  The continuous-batching
scheduler keeps one request queue per rung and drains each independently
(fill/deadline/idle), so a rung is also the unit of batching: its ef bound
caps the per-dispatch cost a queued request can be made to wait behind.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.index.search import SearchConfig, auto_beam

DEFAULT_TIER_EFS = (64, 128, 256)

BEAM_AUTO = "auto"    # per-tier auto_beam(ef)
BEAM_FIXED = "fixed"  # inherit the base config's beam on every tier


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One rung of the ladder: queries with ``ef <= ef`` run under ``cfg``."""

    ef: int             # tier capacity == upper bound on routed per-query ef
    beam: int           # auto-tuned expansion width for this rung
    cfg: SearchConfig   # compiled-search variant (ef_cap == ef)


def tier_ladder(
    base: SearchConfig,
    tier_efs: Sequence[int] = (),
    beam_mode: str = BEAM_AUTO,
    max_beam: int = 8,
) -> Tuple[TierSpec, ...]:
    """Build the ladder from a base (full-capacity) search config.

    ``tier_efs`` are the intermediate rungs (defaults to
    ``DEFAULT_TIER_EFS``); values outside ``[k, ef_cap)`` are dropped and the
    base ``ef_cap`` is always appended as the final catch-all rung, so every
    estimated ef has a tier.  Each tier pins ``max_iters`` to the *base*
    budget: a tier search must never terminate earlier than the monolithic
    search would purely because its capacity-derived iteration default is
    smaller.  Every rung inherits the base config's ``batch_hoisted`` loop
    mode (``RouterConfig.batch_hoisted`` bakes its override into the base
    before the ladder is built) — a resumed tier bucket is exactly the shape
    the batch-hoisted loop is built for: one padded batch of same-capacity
    states driven to joint termination.
    """
    if beam_mode not in (BEAM_AUTO, BEAM_FIXED):
        raise ValueError(f"beam_mode={beam_mode!r} not in ('auto', 'fixed')")
    efs = sorted({int(e) for e in (tier_efs or DEFAULT_TIER_EFS)
                  if base.k <= int(e) < base.ef_cap} | {base.ef_cap})
    tiers = []
    for ef in efs:
        beam = auto_beam(ef, max_beam) if beam_mode == BEAM_AUTO else base.beam
        beam = max(1, min(beam, ef))
        cfg = dataclasses.replace(
            base, ef_cap=ef, beam=beam, max_iters=base.iters(), patience=0
        )
        tiers.append(TierSpec(ef=ef, beam=beam, cfg=cfg))
    return tuple(tiers)
