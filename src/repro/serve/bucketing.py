"""Bucketing primitives for the ef-tier dispatch layer.

Host-side (numpy) helpers: assign queries to ef tiers, pad each bucket to one
of a small set of fixed batch shapes (powers of two, floored at
``min_shape``) so the per-tier jitted searches hit a bounded compile cache,
and scatter per-bucket results back into request order.  The
continuous-batching scheduler (:mod:`repro.serve.scheduler`) keys every
estimation pass and tier drain on :func:`pad_shape` and files estimated
requests with :func:`assign_tiers`; :func:`pad_indices` /
:func:`scatter_results` are batch-shaped utilities kept for callers that
assemble their own buckets (and for the order-restoration property tests).

Everything here is pure index arithmetic — property-testable without a graph
or a device.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import numpy as np


def pad_shape(n: int, min_shape: int = 8) -> int:
    """Smallest power-of-two batch shape >= max(n, min_shape)."""
    if n <= 0:
        raise ValueError(f"pad_shape needs n >= 1, got {n}")
    return 1 << (max(int(n), int(min_shape)) - 1).bit_length()


def assign_tiers(ef: np.ndarray, tier_efs: Sequence[int]) -> np.ndarray:
    """Per-query tier index: the first (smallest) tier with capacity >= ef.

    ``tier_efs`` must be ascending and its last entry must cover every ef
    (the ladder always ends at the base ``ef_cap``, and estimates are clipped
    there).
    """
    ladder = np.asarray(tier_efs, np.int64)
    ef = np.asarray(ef, np.int64)
    if ef.size and ef.max() > ladder[-1]:
        raise ValueError(
            f"ef {int(ef.max())} exceeds the top tier {int(ladder[-1])}"
        )
    return np.searchsorted(ladder, ef, side="left")


def bucket_indices(assign: np.ndarray, num_tiers: int) -> List[np.ndarray]:
    """Request positions per tier, in original order within each bucket."""
    return [np.nonzero(assign == t)[0] for t in range(num_tiers)]


def pad_indices(idx: np.ndarray, shape: int) -> np.ndarray:
    """Pad a bucket's index list to ``shape`` by repeating its first entry.

    Pad rows rerun an already-routed query (results are sliced off before the
    scatter), so no out-of-distribution inputs reach the compiled search.
    """
    if len(idx) == 0 or shape < len(idx):
        raise ValueError(f"cannot pad {len(idx)} indices to shape {shape}")
    return np.concatenate([idx, np.full(shape - len(idx), idx[0], idx.dtype)])


def scatter_results(
    buckets: Sequence[Tuple[np.ndarray, object]], batch: int
):
    """Restore request order: place each bucket's rows at its positions.

    ``buckets`` is ``[(idx, result_pytree), ...]`` where each result pytree
    (e.g. a :class:`SearchResult`) has leading dim >= len(idx) (padding rows
    beyond ``len(idx)`` are dropped).  Buckets must jointly cover every
    position ``0..batch-1`` exactly once.  Returns one pytree of numpy arrays
    with leading dim ``batch``.
    """
    buckets = [(np.asarray(idx), res) for idx, res in buckets if len(idx) > 0]
    if not buckets:
        raise ValueError("scatter_results needs at least one non-empty bucket")
    cover = np.concatenate([idx for idx, _ in buckets])
    if len(cover) != batch or len(np.unique(cover)) != batch:
        raise ValueError(
            f"buckets cover {len(np.unique(cover))}/{batch} positions"
        )

    def _scatter(*parts):
        parts = [np.asarray(p) for p in parts]
        out = np.zeros((batch,) + parts[0].shape[1:], parts[0].dtype)
        for (idx, _), part in zip(buckets, parts):
            out[idx] = part[: len(idx)]
        return out

    return jax.tree_util.tree_map(_scatter, *[res for _, res in buckets])
