"""Fault injection for the serving stack (the chaos harness).

A :class:`FaultPlan` is a declarative list of failure points; a
:class:`FaultInjector` is its runtime, threaded through
:class:`repro.serve.scheduler.AdaServeScheduler` (``chaos=`` keyword).  The
scheduler calls the injector at the same three seams a real failure would
enter through, so tests exercise the *production* recovery paths — the
retry/fallback ladder, NaN screening, and the mutation seam (an
index-registered scheduler absorbs a mid-flight mutation; an orphaned one
raises :class:`StalePlanError`) — not test-only shims:

- ``wrap_clock`` — skews the scheduler's clock (deadline logic under a
  misbehaving time source).
- ``corrupt`` — overwrites chosen queries with NaN *after* submit-time
  validation, modeling corruption that bypasses the front door (the
  estimation-pass NaN screen must catch it without poisoning cohabitants).
- ``before_dispatch`` — runs at the top of every tier-drain attempt: can add
  artificial latency, mutate the index mid-flight, or raise
  :class:`InjectedFault` to trip the kernel retry/backend-fallback ladder.

Faults are addressed by **dispatch index** (0-based count of tier drains,
in dispatch order) and **attempt** (0 = first try, 1 = retry, 2+ =
fallback rungs), so a plan like ``fail_dispatches=(0,), fail_attempts=2``
means "the first tier drain fails twice, succeeding only after the
scheduler has fallen down one backend rung".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """The failure a :class:`FaultPlan` raises inside a dispatch attempt."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative failure points, all off by default (an empty plan is a
    no-op injector — the chaos-threaded scheduler is then bit-identical to
    an unthreaded one)."""

    fail_dispatches: Tuple[int, ...] = ()  # dispatch indices that raise
    fail_attempts: int = 1        # how many attempts of each such dispatch
    #   fail before one succeeds (1 = first try only -> retry recovers;
    #   2 = retry also fails -> backend fallback must recover)
    dispatch_latency_s: float = 0.0  # host sleep injected per dispatch
    clock_skew_s: float = 0.0     # constant added to the scheduler clock
    nan_uids: Tuple[int, ...] = ()  # ticket uids whose queries are NaN'd
    #   post-validation (estimation-pass screen must reject exactly these)
    mutate_at_dispatch: Optional[int] = None  # run the injector's
    #   ``mutate_fn`` right before this dispatch (mid-flight index mutation:
    #   absorbed via the mutation seam when the scheduler is index-
    #   registered — the tick completes on the pinned pre-mutation epoch,
    #   then rebinds; an orphaned scheduler raises StalePlanError on the
    #   next version check instead)


class FaultInjector:
    """Runtime for one :class:`FaultPlan`.

    ``mutate_fn`` is the side effect for ``mutate_at_dispatch`` (typically
    ``lambda: index.insert(...)``).  The injector counts dispatches itself —
    a retried/fallen-back dispatch keeps one index, attempts count within
    it.
    """

    def __init__(self, plan: FaultPlan,
                 mutate_fn: Optional[Callable[[], None]] = None):
        self.plan = plan
        self.mutate_fn = mutate_fn
        self.dispatches = 0          # tier drains seen (public telemetry)
        self.faults_raised = 0

    def wrap_clock(self, clock: Callable[[], float]) -> Callable[[], float]:
        if not self.plan.clock_skew_s:
            return clock
        skew = self.plan.clock_skew_s
        return lambda: clock() + skew

    def corrupt(self, uid: int, query: np.ndarray) -> np.ndarray:
        if uid not in self.plan.nan_uids:
            return query
        bad = query.copy()
        bad[: max(1, bad.shape[0] // 4)] = np.nan
        return bad

    def next_dispatch(self) -> int:
        """Claim the next dispatch index (called once per tier drain)."""
        idx = self.dispatches
        self.dispatches += 1
        return idx

    def before_attempt(self, dispatch_idx: int, attempt: int) -> None:
        """Called at the top of every attempt of a tier drain; raises
        :class:`InjectedFault` when the plan says this attempt fails."""
        if self.plan.dispatch_latency_s and attempt == 0:
            time.sleep(self.plan.dispatch_latency_s)
        if (
            self.plan.mutate_at_dispatch == dispatch_idx
            and attempt == 0
            and self.mutate_fn is not None
        ):
            self.mutate_fn()
        if (
            dispatch_idx in self.plan.fail_dispatches
            and attempt < self.plan.fail_attempts
        ):
            self.faults_raised += 1
            raise InjectedFault(
                f"injected fault: dispatch {dispatch_idx} attempt {attempt}"
            )
