"""KV-cache utilities: capacity growth after prefill, sharding specs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.encdec import EncDecCache
from repro.models.transformer import XLSTMCache, Zamba2Cache

Array = jax.Array


def _pad_seq(a: Array, extra: int, axis: int = 2) -> Array:
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, extra)
    return jnp.pad(a, pad)


def grow_cache(cfg: ArchConfig, cache, extra: int):
    """Extend the attention-cache sequence capacity by ``extra`` slots.

    Prefill returns caches sized exactly to the prompt; decode scatters at
    positions >= prompt_len, so the engine grows capacity once up front.
    State-space caches (mamba/xlstm) are O(1) and pass through.
    """
    if cfg.family in ("dense", "moe", "vlm"):
        return {"k": _pad_seq(cache["k"], extra), "v": _pad_seq(cache["v"], extra)}
    if cfg.family == "audio":
        return EncDecCache(
            self_k=_pad_seq(cache.self_k, extra),
            self_v=_pad_seq(cache.self_v, extra),
            cross_k=cache.cross_k,
            cross_v=cache.cross_v,
        )
    if cfg.family == "hybrid":
        return Zamba2Cache(
            mamba=cache.mamba,
            shared_k=_pad_seq(cache.shared_k, extra),
            shared_v=_pad_seq(cache.shared_v, extra),
        )
    if cfg.family == "ssm":
        return cache
    raise ValueError(cfg.family)
