"""Ada-ef query router: estimate-then-route batch scheduling (serving path).

The monolithic :func:`repro.index.search.adaptive_search` runs every query of
a batch in one vmapped ``lax.while_loop`` with full ``ef_cap``-sized state —
the batch finishes at the pace of its slowest query, and a query needing
ef=32 drags full-capacity sorted-array merges through every iteration.  The
router exploits the paper's core signal (per-query ef varies wildly across a
workload) at dispatch time instead of throwing it away:

1. **Estimation pass** — phase A only (distance collection + ESTIMATE-EF)
   for the whole incoming batch at a *small* fixed state capacity
   (:func:`repro.index.search.estimation_config`).  With the default
   (lossless) capacity this reproduces Algorithm 2's estimates bit-for-bit;
   a caller-capped budget (``RouterConfig.est_cap``) prices estimation below
   that at a small, measurable estimate bias.
2. **Ef-tier ladder** — one pre-compiled search variant per rung
   (:mod:`repro.serve.tiers`), each sized to its tier's ``ef_cap`` with a
   per-tier auto-tuned beam.
3. **Bucketed dispatch** — queries partition by estimated ef, each bucket
   pads to a power-of-two batch shape (compile-cache friendly,
   :mod:`repro.serve.bucketing`), resumes its phase-A state on the tier's
   small arrays, and results scatter back into request order.
4. **Telemetry** — a :class:`repro.serve.stats.RouterStats` per batch.

Because tier searches *resume* the estimation-pass state (rather than
restarting from the entry point), a routed batch performs the same cumulative
work as Algorithm 2 — with lossless estimation and ``beam_mode="fixed"`` the
routed results match the monolithic ``adaptive_search`` per query on a
tombstone-free graph (see the deletion caveat on
:func:`repro.index.search.resize_state`), while every merge runs at tier
capacity and easy buckets stop iterating as soon as their own slowest member
finishes.

Since the request-lifecycle redesign the router owns only the *policy*
(estimation budget, tier ladder, margins); execution lives in
:class:`repro.serve.scheduler.AdaServeScheduler`, which admits requests
continuously and drains tier buckets independently.  Both are internal
lowering targets of the declarative facade: callers hold a
:class:`repro.plan.ExecutionPlan` (``index.plan(spec)``) whose batch
``search()`` and ``submit()``/``poll()`` lifecycle replace the old
synchronous ``route()`` barrier.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import DatasetStats, EfTable
from repro.pytrees import register_static_config
from repro.index.search import (
    AdaEfConfig,
    DeviceGraph,
    SearchConfig,
    estimate_pass,
    estimation_config,
)
from .tiers import BEAM_AUTO, TierSpec, tier_ladder


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Routing policy knobs (all static: part of the compile-cache key)."""

    tier_efs: Tuple[int, ...] = ()   # intermediate rungs; () -> DEFAULT_TIER_EFS
    beam_mode: str = BEAM_AUTO       # "auto" (per-tier auto_beam) | "fixed"
    est_cap: int = 0                 # estimation state capacity; 0 -> lossless
    est_lmax: int = 0                # collection budget |D|; 0 -> full (lossless)
    ef_margin: float = 1.0           # scale estimates up (guard for lossy est)
    min_shape: int = 8               # smallest padded bucket shape
    batch_hoisted: Optional[bool] = None  # force the batch-hoisted loop on
    #   every dispatched search (estimation pass + all tier resumes);
    #   None inherits the base SearchConfig's flag
    est_matched_table: bool = True   # lossy estimation looks efs up in a
    #   table built from proxies scored at the same truncated budget
    #   (requires the owner to supply a builder — AdaEfIndex.router does).
    #   Removes the truncation bias, which *raises* routed work toward the
    #   monolithic level in exchange for recall at the unbiased estimates:
    #   set False to keep the old biased-low estimates (fewer ndist, lower
    #   tail latency, recall slightly under the monolithic path).


# Static pytree: zero leaves, jit-keyed by dataclass equality (same policy
# -> same compile-cache entry), never traced.
register_static_config(RouterConfig)


class QueryRouter:
    """Estimate-then-route executor over one :class:`DeviceGraph`.

    Stateless across batches apart from jit caches — safe to share across
    threads that serve disjoint batches.  Rebuild (or let
    ``AdaEfIndex.router()`` rebuild) after index updates: the router holds
    graph/stats/table references.
    """

    def __init__(
        self,
        graph: DeviceGraph,
        stats: DatasetStats,
        table: EfTable,
        search_cfg: SearchConfig,
        ada_cfg: AdaEfConfig = AdaEfConfig(),
        router_cfg: Optional[RouterConfig] = None,
        est_table_builder=None,
    ):
        self.graph = graph
        self.stats = stats
        self.table = table
        self.ada_cfg = ada_cfg
        self.router_cfg = router_cfg or RouterConfig()
        if self.router_cfg.batch_hoisted is not None:
            search_cfg = dataclasses.replace(
                search_cfg, batch_hoisted=self.router_cfg.batch_hoisted
            )
        self.base_cfg = search_cfg
        m0 = graph.base_adj.shape[1]
        # est_lmax caps the phase-A collection goal |D| (the dominant cost of
        # estimation): the collected prefix skews toward closer distances, so
        # scores bias "easy" — compensated by an estimation-matched table
        # (below) and/or ef_margin > 1.
        self.est_ada = ada_cfg
        if self.router_cfg.est_lmax > 0:
            self.est_ada = dataclasses.replace(
                ada_cfg, lmax=min(self.router_cfg.est_lmax, ada_cfg.buf(m0))
            )
        self.est_cfg = estimation_config(
            search_cfg, m0, self.est_ada, self.router_cfg.est_cap
        )
        # Effective lossiness, not nominal: an est_lmax at or above the full
        # collection budget, or an est_cap at or above the lossless capacity,
        # leaves phase A bit-exact and needs no compensation.  Kept on the
        # instance — plan.explain() reports this decision rather than
        # re-deriving it.
        self.est_lossy = est_lossy = self.est_ada.buf(m0) < ada_cfg.buf(m0) or (
            self.est_cfg.ef_cap
            < estimation_config(search_cfg, m0, self.est_ada, 0).ef_cap
        )
        # Estimation-matched ef table (ROADMAP): a lossy estimation budget
        # truncates the collected distance list, so scores are computed in
        # different units than the full-budget table was built from.  When the
        # owner supplies a builder (``AdaEfIndex.router`` passes
        # ``estimation_table``), re-score the proxies at exactly this router's
        # estimation budget and look efs up in *that* table; with lossless
        # estimation the full-budget table is already exact, so fall back.
        self.est_matched = (
            est_lossy
            and est_table_builder is not None
            and self.router_cfg.est_matched_table
        )
        # built lazily: constructing a router (e.g. for plan.explain()) must
        # stay cheap — the matched-table proxy re-scoring only runs once an
        # estimation pass actually needs the table
        self._est_table_builder = est_table_builder
        self._est_table: Optional[EfTable] = None
        self.tiers: Tuple[TierSpec, ...] = tier_ladder(
            self.base_cfg, self.router_cfg.tier_efs, self.router_cfg.beam_mode
        )
        self._tier_efs = tuple(t.ef for t in self.tiers)

    @property
    def est_table(self) -> EfTable:
        """The table estimates are looked up in: the owner's full-budget
        table, or (lossy budgets with a builder) the estimation-matched one,
        built on first use."""
        if self._est_table is None:
            self._est_table = (
                self._est_table_builder(self.est_cfg, self.est_ada)
                if self.est_matched
                else self.table
            )
        return self._est_table

    # ------------------------------------------------------------- phases
    def estimate(
        self,
        queries: np.ndarray,
        target_recall,
        num_real: Optional[int] = None,
    ):
        """Estimation pass for a padded batch.  Returns ``(ef_est, states)``
        with ``ef_est`` a host int array over the *padded* batch.

        ``target_recall`` is a scalar or a per-query ``(B, 1)`` array (the
        scheduler mixes declarative targets in one pass).  ``num_real`` marks
        rows at or beyond it as batch padding: they skip phase A at ~one
        distance computation each instead of running a full collection."""
        ef_est, states = estimate_pass(
            self.graph,
            jnp.asarray(queries),
            self.stats,
            self.est_table,
            jnp.asarray(target_recall, jnp.float32),
            self.est_cfg,
            self.est_ada,
            ef_cap_out=self.base_cfg.ef_cap,
            num_real=None if num_real is None else jnp.asarray(num_real, jnp.int32),
        )
        ef_np = np.asarray(ef_est)
        if self.router_cfg.ef_margin != 1.0:
            ef_np = np.clip(
                np.ceil(ef_np * self.router_cfg.ef_margin).astype(ef_np.dtype),
                self.base_cfg.k,
                self.base_cfg.ef_cap,
            )
        return ef_np, states

    # ------------------------------------------------------------- dispatch
    def scheduler(self, scheduler_cfg=None, **kwargs):
        """A fresh :class:`AdaServeScheduler` over this router (the
        continuous-batching serving surface; prefer the cached
        ``AdaEfIndex.scheduler()`` which survives router rebuilds)."""
        from .scheduler import AdaServeScheduler

        return AdaServeScheduler(self, scheduler_cfg, **kwargs)
