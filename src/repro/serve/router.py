"""Ada-ef query router: estimate-then-route batch scheduling (serving path).

The monolithic :func:`repro.index.search.adaptive_search` runs every query of
a batch in one vmapped ``lax.while_loop`` with full ``ef_cap``-sized state —
the batch finishes at the pace of its slowest query, and a query needing
ef=32 drags full-capacity sorted-array merges through every iteration.  The
router exploits the paper's core signal (per-query ef varies wildly across a
workload) at dispatch time instead of throwing it away:

1. **Estimation pass** — phase A only (distance collection + ESTIMATE-EF)
   for the whole incoming batch at a *small* fixed state capacity
   (:func:`repro.index.search.estimation_config`).  With the default
   (lossless) capacity this reproduces Algorithm 2's estimates bit-for-bit;
   a caller-capped budget (``RouterConfig.est_cap``) prices estimation below
   that at a small, measurable estimate bias.
2. **Ef-tier ladder** — one pre-compiled search variant per rung
   (:mod:`repro.serve.tiers`), each sized to its tier's ``ef_cap`` with a
   per-tier auto-tuned beam.
3. **Bucketed dispatch** — queries partition by estimated ef, each bucket
   pads to a power-of-two batch shape (compile-cache friendly,
   :mod:`repro.serve.bucketing`), resumes its phase-A state on the tier's
   small arrays, and results scatter back into request order.
4. **Telemetry** — a :class:`repro.serve.stats.RouterStats` per batch.

Because tier searches *resume* the estimation-pass state (rather than
restarting from the entry point), a routed batch performs the same cumulative
work as Algorithm 2 — with lossless estimation and ``beam_mode="fixed"`` the
routed results match the monolithic ``adaptive_search`` per query on a
tombstone-free graph (see the deletion caveat on
:func:`repro.index.search.resize_state`), while every merge runs at tier
capacity and easy buckets stop iterating as soon as their own slowest member
finishes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DatasetStats, EfTable
from repro.index.search import (
    AdaEfConfig,
    DeviceGraph,
    SearchConfig,
    SearchResult,
    SearchState,
    estimate_pass,
    estimation_config,
    resume_at_ef,
    resize_state,
)
from .bucketing import (
    assign_tiers,
    bucket_indices,
    pad_indices,
    pad_shape,
    scatter_results,
)
from .stats import RouterStats, TierStats
from .tiers import BEAM_AUTO, TierSpec, tier_ladder

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Routing policy knobs (all static: part of the compile-cache key)."""

    tier_efs: Tuple[int, ...] = ()   # intermediate rungs; () -> DEFAULT_TIER_EFS
    beam_mode: str = BEAM_AUTO       # "auto" (per-tier auto_beam) | "fixed"
    est_cap: int = 0                 # estimation state capacity; 0 -> lossless
    est_lmax: int = 0                # collection budget |D|; 0 -> full (lossless)
    ef_margin: float = 1.0           # scale estimates up (guard for lossy est)
    min_shape: int = 8               # smallest padded bucket shape
    batch_hoisted: Optional[bool] = None  # force the batch-hoisted loop on
    #   every dispatched search (estimation pass + all tier resumes);
    #   None inherits the base SearchConfig's flag
    est_matched_table: bool = True   # lossy estimation looks efs up in a
    #   table built from proxies scored at the same truncated budget
    #   (requires the owner to supply a builder — AdaEfIndex.router does).
    #   Removes the truncation bias, which *raises* routed work toward the
    #   monolithic level in exchange for recall at the unbiased estimates:
    #   set False to keep the old biased-low estimates (fewer ndist, lower
    #   tail latency, recall slightly under the monolithic path).


class QueryRouter:
    """Estimate-then-route executor over one :class:`DeviceGraph`.

    Stateless across batches apart from jit caches — safe to share across
    threads that serve disjoint batches.  Rebuild (or let
    ``AdaEfIndex.router()`` rebuild) after index updates: the router holds
    graph/stats/table references.
    """

    def __init__(
        self,
        graph: DeviceGraph,
        stats: DatasetStats,
        table: EfTable,
        search_cfg: SearchConfig,
        ada_cfg: AdaEfConfig = AdaEfConfig(),
        router_cfg: Optional[RouterConfig] = None,
        est_table_builder=None,
    ):
        self.graph = graph
        self.stats = stats
        self.table = table
        self.ada_cfg = ada_cfg
        self.router_cfg = router_cfg or RouterConfig()
        if self.router_cfg.batch_hoisted is not None:
            search_cfg = dataclasses.replace(
                search_cfg, batch_hoisted=self.router_cfg.batch_hoisted
            )
        self.base_cfg = search_cfg
        m0 = graph.base_adj.shape[1]
        # est_lmax caps the phase-A collection goal |D| (the dominant cost of
        # estimation): the collected prefix skews toward closer distances, so
        # scores bias "easy" — compensated by an estimation-matched table
        # (below) and/or ef_margin > 1.
        self.est_ada = ada_cfg
        if self.router_cfg.est_lmax > 0:
            self.est_ada = dataclasses.replace(
                ada_cfg, lmax=min(self.router_cfg.est_lmax, ada_cfg.buf(m0))
            )
        self.est_cfg = estimation_config(
            search_cfg, m0, self.est_ada, self.router_cfg.est_cap
        )
        # Effective lossiness, not nominal: an est_lmax at or above the full
        # collection budget, or an est_cap at or above the lossless capacity,
        # leaves phase A bit-exact and needs no compensation.
        est_lossy = self.est_ada.buf(m0) < ada_cfg.buf(m0) or (
            self.est_cfg.ef_cap
            < estimation_config(search_cfg, m0, self.est_ada, 0).ef_cap
        )
        # Estimation-matched ef table (ROADMAP): a lossy estimation budget
        # truncates the collected distance list, so scores are computed in
        # different units than the full-budget table was built from.  When the
        # owner supplies a builder (``AdaEfIndex.router`` passes
        # ``estimation_table``), re-score the proxies at exactly this router's
        # estimation budget and look efs up in *that* table; with lossless
        # estimation the full-budget table is already exact, so fall back.
        self.est_matched = (
            est_lossy
            and est_table_builder is not None
            and self.router_cfg.est_matched_table
        )
        self.est_table = (
            est_table_builder(self.est_cfg, self.est_ada)
            if self.est_matched
            else table
        )
        self.tiers: Tuple[TierSpec, ...] = tier_ladder(
            self.base_cfg, self.router_cfg.tier_efs, self.router_cfg.beam_mode
        )
        self._tier_efs = tuple(t.ef for t in self.tiers)

    # ------------------------------------------------------------- phases
    def estimate(self, queries: np.ndarray, target_recall: float):
        """Estimation pass for a padded batch.  Returns ``(ef_est, states)``
        with ``ef_est`` a host int array over the *padded* batch."""
        ef_est, states = estimate_pass(
            self.graph,
            jnp.asarray(queries),
            self.stats,
            self.est_table,
            jnp.asarray(target_recall, jnp.float32),
            self.est_cfg,
            self.est_ada,
            ef_cap_out=self.base_cfg.ef_cap,
        )
        ef_np = np.asarray(ef_est)
        if self.router_cfg.ef_margin != 1.0:
            ef_np = np.clip(
                np.ceil(ef_np * self.router_cfg.ef_margin).astype(ef_np.dtype),
                self.base_cfg.k,
                self.base_cfg.ef_cap,
            )
        return ef_np, states

    def _resume_bucket(
        self,
        tier: TierSpec,
        queries: Array,
        states: SearchState,
        idx_pad: np.ndarray,
        ef_np: np.ndarray,
        num_real: int,
    ) -> SearchResult:
        """Gather one padded bucket out of the estimation state and resume it
        on the tier's arrays.  Padding rows rerun the bucket's first query at
        ef=k (the cheapest legal resume) and are sliced off by the caller."""
        take = jnp.asarray(idx_pad)
        q_b = queries[take]
        s_b = resize_state(
            jax.tree_util.tree_map(lambda a: a[take], states), tier.ef
        )
        ef_b = ef_np[idx_pad].astype(np.int32)
        ef_b[num_real:] = self.base_cfg.k
        return resume_at_ef(self.graph, q_b, s_b, jnp.asarray(ef_b), tier.cfg)

    # ------------------------------------------------------------- dispatch
    def route(
        self, queries: np.ndarray, target_recall: float
    ) -> Tuple[SearchResult, RouterStats]:
        """Route one request batch; returns results in request order plus the
        batch's telemetry.  ``SearchResult`` fields are host numpy arrays."""
        queries = np.asarray(queries, np.float32)
        if queries.ndim != 2 or len(queries) == 0:
            raise ValueError(f"expected (B, d) queries, got {queries.shape}")
        batch = len(queries)
        t_start = time.perf_counter()

        # ---- estimation pass over the (padded) full batch -----------------
        est_shape = pad_shape(batch, self.router_cfg.min_shape)
        q_pad = np.concatenate(
            [queries, np.repeat(queries[:1], est_shape - batch, axis=0)]
        )
        t0 = time.perf_counter()
        ef_np, states = self.estimate(q_pad, target_recall)
        # stamp only after the whole estimation state materialized, so the
        # wall covers execution (not just dispatch + the ef pull)
        jax.block_until_ready(states)
        est_wall = time.perf_counter() - t0
        est_ndist = np.asarray(states.ndist)

        # ---- bucket by tier, resume each bucket at its own capacity -------
        # Dispatch every bucket before pulling any result: JAX async dispatch
        # lets the device pipeline independent tier computations while the
        # host does the next bucket's gather/pad bookkeeping.
        assign = assign_tiers(ef_np[:batch], self._tier_efs)
        buckets = bucket_indices(assign, len(self.tiers))
        q_dev = jnp.asarray(q_pad)
        dispatched = []
        for tier, idx in zip(self.tiers, buckets):
            if len(idx) == 0:
                continue
            shape = pad_shape(len(idx), self.router_cfg.min_shape)
            idx_pad = pad_indices(idx, shape)
            t0 = time.perf_counter()
            res_dev = self._resume_bucket(
                tier, q_dev, states, idx_pad, ef_np, len(idx)
            )
            dispatched.append((tier, idx, shape, res_dev, t0))

        parts = []
        tier_stats = []
        for tier, idx, shape, res_dev, t0 in dispatched:
            # block on the device outputs *before* stamping: the wall then
            # measures dispatch -> execution complete rather than whenever the
            # host got around to pulling the arrays.  Tiers still overlap on
            # device, so these walls do not sum to the batch wall-clock.
            jax.block_until_ready(res_dev)
            wall = time.perf_counter() - t0
            res = jax.tree_util.tree_map(np.asarray, res_dev)
            parts.append((idx, res))
            tier_stats.append(
                TierStats(
                    ef=tier.ef,
                    beam=tier.beam,
                    count=len(idx),
                    padded_to=shape,
                    ndist_total=int(res.ndist[: len(idx)].sum()),
                    wall_s=wall,
                )
            )

        out = scatter_results(parts, batch)
        stats = RouterStats(
            batch=batch,
            est_shape=est_shape,
            est_cap=self.est_cfg.ef_cap,
            est_ndist_total=int(est_ndist[:batch].sum()),
            est_wall_s=est_wall,
            est_matched=self.est_matched,
            tiers=tier_stats,
            total_wall_s=time.perf_counter() - t_start,
        )
        return out, stats
