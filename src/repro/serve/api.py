"""Request-lifecycle serving API: the types a request moves through.

The serving surface is no longer a one-shot batch call: a caller **submits**
a :class:`SearchRequest` (query + declarative per-request target recall, an
optional result size override, an optional deadline), gets back an opaque
:class:`SearchTicket`, and later **polls** for the matching
:class:`SearchResponse` (top-k result + per-request :class:`RequestStats`
telemetry).  The lifecycle itself — admission, shared estimation pass,
ef-tier queueing, batched drain — lives in
:class:`repro.serve.scheduler.AdaServeScheduler`; this module is the pure
data contract and imports nothing from the rest of ``serve``.

Lifecycle of one request::

    ticket = scheduler.submit(SearchRequest(query=q, target_recall=0.95))
    scheduler.step()            # estimation + any due tier drains
    for resp in scheduler.poll():
        resp.ids, resp.stats    # SearchResponse once its tier drained

Every response carries a **terminal status** — the serving contract under
overload is "always answer, and say what kind of answer this is":

- ``ok`` — full search, deadline (if any) met.
- ``degraded`` — served, but demoted down the ef-tier ladder to protect its
  deadline (achieved ef < estimated ef; the declarative-recall analogue of
  load shedding).
- ``partial`` — deadline already blown before the tier search ran; answered
  best-effort from the carried phase-A ``SearchState``.
- ``rejected`` — admission control shed it (queue bounds / invalid query);
  no search ran.
- ``timed_out`` — full search completed, but past the deadline (an explicit
  miss, never a silent one).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# ---------------------------------------------------------------- statuses
STATUS_OK = "ok"
STATUS_DEGRADED = "degraded"
STATUS_PARTIAL = "partial"
STATUS_REJECTED = "rejected"
STATUS_TIMED_OUT = "timed_out"
TERMINAL_STATUSES = (
    STATUS_OK, STATUS_DEGRADED, STATUS_PARTIAL, STATUS_REJECTED,
    STATUS_TIMED_OUT,
)


# ------------------------------------------------------------------ errors
class ServeError(RuntimeError):
    """Base of the serving stack's typed failures."""


class OverloadedError(ServeError):
    """Admission control refused the request (``SchedulerConfig.
    max_inflight`` reached).  Retry with backoff (see
    :func:`repro.serve.scheduler.submit_with_backoff`), poll to free
    capacity, or configure ``overload="ticket"`` to receive REJECTED
    responses instead of exceptions."""


class InvalidQueryError(ServeError, ValueError):
    """The query vector is unusable: NaN/Inf values, a non-numeric dtype,
    or the wrong dimensionality.  Raised at ``submit()``/``plan.search()``
    *before* the query can enter (and poison) a shared estimation pass."""


class StalePlanError(ServeError):
    """The index was mutated (``insert``/``delete`` bumped the graph
    version) under a held plan or scheduler that cannot — or must not —
    absorb the change.

    Since the epoch-versioned mutation path this is the *opt-in strict*
    behavior, not the default: index-registered consumers (plans from
    ``index.plan()``, schedulers from ``index.scheduler()`` /
    ``plan.new_scheduler()``) are fenced and rebound through the mutation
    seam — pending tickets complete against the pre-mutation epoch and new
    work binds the new one.  This error still fires for (a) plans lowered
    from a ``SearchSpec(on_mutation="strict")``, which refuse revalidation
    by contract, and (b) *orphaned* schedulers constructed directly around
    a ``version_probe`` (no index registration), which have no mutation
    seam to absorb through — drain those before mutating, then rebuild."""


class DispatchFailedError(ServeError):
    """A tier dispatch failed on every rung of the backend fallback ladder
    (kernel -> interpret -> oracle); carries the last underlying error as
    ``__cause__``."""


@dataclasses.dataclass(frozen=True)
class TenantSLO:
    """Per-tenant serving contract (``SchedulerConfig.tenants``).

    ``target_recall``/``deadline_s`` fill a request's unset fields when it
    carries this tenant (request-level values still win); ``max_inflight``
    caps the tenant's concurrently admitted requests (0 = unlimited) so one
    saturating tenant cannot starve the ladder for the others — a breach is
    handled exactly like global admission control (``SchedulerConfig.
    overload``: raise :class:`OverloadedError` or answer REJECTED).
    """

    target_recall: Optional[float] = None
    deadline_s: Optional[float] = None
    max_inflight: int = 0

    def __post_init__(self):
        if self.target_recall is not None and not 0.0 < self.target_recall <= 1.0:
            raise ValueError(
                f"target_recall={self.target_recall} not in (0, 1]"
            )
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s={self.deadline_s} must be > 0")
        if self.max_inflight < 0:
            raise ValueError(f"max_inflight={self.max_inflight} must be >= 0")


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """One retrieval request.

    ``target_recall``/``k`` default to the owning scheduler's (index's)
    settings; ``k`` may only *shrink* the result (the tier searches run at
    the index's configured k, the response is sliced).  ``deadline_s`` is a
    latency budget in seconds **relative to submit time**: the request's tier
    bucket is drained no later than the deadline even if the bucket has not
    reached its fill, trading batch efficiency for tail latency.
    ``tenant`` names the request's namespace: the scheduler resolves unset
    ``target_recall``/``deadline_s`` from the tenant's :class:`TenantSLO`
    (before falling back to scheduler defaults), enforces its admission
    quota, and labels metrics/spans with it.
    """

    query: np.ndarray                     # (d,) float32 retrieval embedding
    target_recall: Optional[float] = None # None -> scheduler default
    k: Optional[int] = None               # None -> index k (must be <= it)
    deadline_s: Optional[float] = None    # None -> drain on fill/flush only
    tenant: Optional[str] = None          # None -> the default namespace


@dataclasses.dataclass(frozen=True)
class SearchTicket:
    """Opaque handle returned by ``submit()``; matches a later response.

    ``uid`` is unique and monotone per scheduler.  ``deadline_t`` is the
    absolute deadline on the scheduler's clock (``submit_t + deadline_s``),
    ``None`` when the request carries no deadline.
    """

    uid: int
    submit_t: float
    deadline_t: Optional[float] = None


@dataclasses.dataclass
class RequestStats:
    """Per-request telemetry stamped along the lifecycle.

    Timestamps are on the scheduler's clock (``time.monotonic`` unless a
    test injects its own).  ``ndist`` is cumulative across both phases
    (estimation + tier search) — directly comparable to the monolithic
    ``adaptive_search`` cost, like ``RouterStats``.
    """

    submit_t: float                # ticket issue time
    est_t: float = 0.0             # estimation pass that admitted this request
    dispatch_t: float = 0.0        # tier drain that included this request
    done_t: float = 0.0            # response materialization time
    est_batch: int = 0             # real rows sharing the estimation pass
    est_ndist: int = 0             # phase-A distance computations
    ef_est: int = 0                # estimated (margin-adjusted) ef
    tier_ef: int = 0               # capacity of the tier that served it
    tier_beam: int = 0             # beam width of that tier
    dispatch_batch: int = 0        # real rows sharing the drain dispatch
    padded_to: int = 0             # pow2 shape the drain was padded to
    ndist: int = 0                 # cumulative est + search cost
    ndist_q: int = 0               # quantized-tier distances within ndist
    #   (0 for fp32 plans; the fp32 re-rank and descent are in ndist only)
    trigger: str = ""              # what drained the bucket:
    #   fill | deadline | flush | idle (work-conserving drain) | partial
    status: str = ""               # terminal status (mirrors SearchResponse)
    demotions: int = 0             # ladder rungs walked down (deadline at risk)
    ef_achieved: int = 0           # ef the search actually ran at
    #   (< ef_est when degraded; 0 for partial/rejected — no tier search ran)
    dispatch_retries: int = 0      # extra dispatch attempts consumed
    fallback_backend: str = ""     # non-empty when the backend ladder was
    #   walked at runtime (e.g. "oracle")
    reject_reason: str = ""        # why admission/screening shed the request
    epoch: int = -1                # index epoch (graph version) the request
    #   was estimated/served against; under churn a response stamped with a
    #   pre-mutation epoch was answered from that snapshot (-1 = unversioned
    #   scheduler, or rejected before binding an epoch)
    tenant: str = ""               # namespace the request was served under
    #   ("" = the default namespace).  The raw string; the scheduler's
    #   metric labels are separately bounded (configured tenants + "other")

    # Derived intervals.  Lifecycle stamps default to 0.0 ("never
    # happened"): a rejected request never estimates or dispatches, a
    # partial answer never dispatches a tier drain.  Each interval guards
    # on both of its stamps and answers 0.0 when either is missing, so
    # degraded/partial/rejected telemetry never reports negative walls.

    @property
    def e2e_s(self) -> float:
        """submit -> response materialization (0.0 while in flight)."""
        if not self.done_t:
            return 0.0
        return self.done_t - self.submit_t

    @property
    def latency_s(self) -> float:
        """Alias of :attr:`e2e_s` (pre-existing name, kept for consumers)."""
        return self.e2e_s

    @property
    def queue_wait_s(self) -> float:
        """Time spent parked in the tier queue (estimated -> dispatched)."""
        if not self.est_t or not self.dispatch_t:
            return 0.0
        return self.dispatch_t - self.est_t

    @property
    def service_s(self) -> float:
        """Tier drain dispatch -> response materialization."""
        if not self.dispatch_t or not self.done_t:
            return 0.0
        return self.done_t - self.dispatch_t

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["latency_s"] = self.latency_s
        d["queue_wait_s"] = self.queue_wait_s
        d["service_s"] = self.service_s
        d["e2e_s"] = self.e2e_s
        return d


@dataclasses.dataclass
class SearchResponse:
    """Completed request: result rows + the request's lifecycle telemetry.

    ``status`` is always one of :data:`TERMINAL_STATUSES` — a response never
    leaves the scheduler without declaring what kind of answer it is.
    """

    ticket: SearchTicket
    ids: np.ndarray                # (k,) int32, -1 padded
    dists: np.ndarray              # (k,) float32 metric-oriented values
    ndist: int                     # cumulative est + search cost
    iters: int
    ef_used: int                   # effective ef the tier search ran at
    stats: RequestStats
    status: str = STATUS_OK
    ndist_q: int = 0               # quantized-tier distances within ndist
