"""Serving driver: batched generation + Ada-ef retrieval (RAG loop).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 4 \
        --prompt-len 32 --new-tokens 16 --corpus 2000

``--stream`` switches the retrieval stage to the request-lifecycle serving
API: requests arrive on a Poisson process, enter a streaming-mode
``ExecutionPlan`` (``submit``/``step``/``poll``; the planner derives the
drain policy from the spec's deadline), and per-request latency is reported
instead of one batch wall.

Observability flags (stream mode): ``--metrics`` dumps the scheduler's
metrics registry (Prometheus text format) at exit, ``--trace-out PATH``
arms per-request span tracing and writes Chrome trace-event JSON (open in
Perfetto), ``--audit FRACTION`` samples completed requests through the
online recall auditor and prints the per-tier achieved-recall EWMAs +
alert summary at exit.  See :mod:`repro.obs`.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import Counter

import jax
import numpy as np

from repro.api import SearchSpec
from repro.index.pipeline import build_ada_index
from repro.configs import ARCHS
from repro.models import build_model
from repro.serve import Engine, SearchRequest
from repro.serve.scheduler import replay_trace


def stream_retrieval(engine, index, batch, *, target_recall, arrival_rate,
                     deadline_ms, seed, metrics=False, trace_out=None,
                     audit=0.0):
    """Poisson-arrival replay of the batch's retrieval stage through a
    streaming-mode plan; returns the responses in arrival order.

    ``metrics``/``trace_out``/``audit`` arm the :mod:`repro.obs` layer on a
    private scheduler (the plan itself is not re-lowered): registry dump,
    Chrome trace export, and online recall audit respectively.
    """
    plan = index.plan(SearchSpec(
        target_recall=target_recall, deadline_ms=deadline_ms, mode="streaming"
    ))
    print(plan.explain(fmt="text"))
    scfg = dataclasses.replace(
        plan.scheduler_cfg,
        trace=bool(trace_out) or plan.scheduler_cfg.trace,
        audit_fraction=max(audit, plan.scheduler_cfg.audit_fraction),
    )
    sched = plan.new_scheduler(scfg)
    emb = np.asarray(engine._request_embedding(batch))
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, len(emb)))
    requests = [SearchRequest(query=e, deadline_s=plan.deadline_s)
                for e in emb]
    responses, lats = replay_trace(sched, requests, arrivals)
    st = sched.stats
    print(
        f"streamed {len(responses)} requests: latency p50={np.percentile(lats, 50) * 1e3:.1f}ms "
        f"p99={np.percentile(lats, 99) * 1e3:.1f}ms (first run includes jit compiles)"
    )
    print(
        f"scheduler: est_passes={st.est_passes} drains fill/deadline/flush/idle="
        f"{st.fill_drains}/{st.deadline_drains}/{st.flush_drains}/{st.idle_drains} "
        f"est_pad_ndist={st.est_pad_ndist}"
    )
    by_status = Counter(r.status for r in responses)
    print("statuses: " + ", ".join(
        f"{s}={n}" for s, n in sorted(by_status.items())))
    if sched.auditor is not None:
        sched.auditor.flush()
        aud = sched.auditor.as_dict()
        tiers = " ".join(
            f"ef{ef}:recall={t['recall_ewma']:.3f}(n={t['samples']})"
            for ef, t in aud["tiers"].items()
        )
        print(f"recall audit: sampled={aud['sampled']} "
              f"audited={aud['audited']} {tiers}")
        if aud["alerts"]:
            print(f"RECALL ALERTS ({len(aud['alerts'])}):")
            for a in aud["alerts"]:
                print(f"  tier ef={a['tier_ef']}: ewma={a['ewma']:.4f} < "
                      f"target={a['target']:.4f} - margin={a['margin']}")
        else:
            print("recall audit: no alerts (all tiers within margin)")
    if trace_out and sched.tracer is not None:
        sched.tracer.export(trace_out)
        print(f"trace: {len(sched.tracer.spans())} spans -> {trace_out} "
              "(open in Perfetto / chrome://tracing)")
    if metrics:
        print("--- metrics registry ---")
        print(sched.metrics.render_prometheus(), end="")
    return responses


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--corpus", type=int, default=0, help="vector corpus size (0 = no RAG)")
    ap.add_argument("--target-recall", type=float, default=0.95)
    ap.add_argument("--routed", action="store_true",
                    help="submit retrieval through the continuous-batching "
                         "ef-tier scheduler (overlaps the decode loop)")
    ap.add_argument("--stream", action="store_true",
                    help="streaming-arrival mode: Poisson arrivals through "
                         "the scheduler lifecycle (submit/step/poll), "
                         "per-request latency report; requires --corpus")
    ap.add_argument("--arrival-rate", type=float, default=64.0,
                    help="streaming arrivals per second")
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="per-request latency budget in stream mode (0 = none)")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the scheduler's metrics registry "
                         "(Prometheus text) at exit (stream mode)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="arm span tracing and write Chrome trace-event "
                         "JSON to PATH at exit (stream mode)")
    ap.add_argument("--audit", type=float, default=0.0, metavar="FRACTION",
                    help="online recall audit: fraction of completed "
                         "requests re-checked against the oracle "
                         "(stream mode)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(args.seed))

    index = None
    proj = None
    if args.corpus > 0:
        rng = np.random.default_rng(args.seed)
        centers = rng.normal(0, 1, (32, cfg.d_model))
        corpus = centers[rng.integers(0, 32, args.corpus)] + 0.3 * rng.normal(
            0, 1, (args.corpus, cfg.d_model)
        )
        t0 = time.perf_counter()
        index = build_ada_index(
            corpus.astype(np.float32),
            k=10,
            target_recall=args.target_recall,
            m=8,
            ef_construction=60,
            ef_cap=200,
            num_samples=64,
        )
        print(f"corpus index built in {time.perf_counter() - t0:.1f}s")

    engine = Engine(
        model,
        params,
        index=index,
        embed_proj=proj,
        max_new_tokens=args.new_tokens,
        target_recall=args.target_recall,
        routed=args.routed,
    )
    rng = np.random.default_rng(args.seed + 1)
    batch = {
        "tokens": jax.numpy.asarray(
            rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len)), jax.numpy.int32
        )
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.numpy.asarray(
            rng.normal(0, 1, (args.requests, cfg.num_frontend_tokens, cfg.frontend_dim)),
            jax.numpy.float32,
        )
    if cfg.family == "audio":
        batch["frames"] = jax.numpy.asarray(
            rng.normal(0, 1, (args.requests, args.prompt_len, cfg.frontend_dim)),
            jax.numpy.float32,
        )
    if args.stream:
        if index is None:
            raise SystemExit("--stream needs a retrieval corpus (--corpus N)")
        responses = stream_retrieval(
            engine, index, batch,
            target_recall=args.target_recall,
            arrival_rate=args.arrival_rate, deadline_ms=args.deadline_ms,
            seed=args.seed + 2,
            metrics=args.metrics, trace_out=args.trace_out, audit=args.audit,
        )
        print("retrieved ids (first request):", responses[0].ids)
        print("(run without --stream for the batched decode loop)")
        return
    t0 = time.perf_counter()
    res = engine.serve(batch)
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests x {args.new_tokens} tokens in {dt:.1f}s")
    print("generated token ids:\n", res.tokens)
    if res.retrieved_ids is not None:
        print("retrieved ids (first request):", res.retrieved_ids[0])
        print("adaptive ef used:", res.ef_used)
    if res.router_stats is not None:
        rs = res.router_stats
        tiers = " ".join(
            f"ef{t['ef']}(beam={t['beam']}):{t['count']}/{t['padded_to']}"
            for t in rs["tiers"]
        )
        print(
            f"router: est_cap={rs['est_cap']} est_ndist={rs['est_ndist_total']} "
            f"ndist={rs['ndist_total']} padding_waste={rs['padding_waste']:.2f} "
            f"tiers[{tiers}]"
        )


if __name__ == "__main__":
    main()
