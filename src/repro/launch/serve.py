"""Serving driver: batched generation + Ada-ef retrieval (RAG loop).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 4 \
        --prompt-len 32 --new-tokens 16 --corpus 2000
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.index.pipeline import build_ada_index
from repro.models import build_model
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--corpus", type=int, default=0, help="vector corpus size (0 = no RAG)")
    ap.add_argument("--target-recall", type=float, default=0.95)
    ap.add_argument("--routed", action="store_true",
                    help="dispatch retrieval through the ef-bucketed router")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(args.seed))

    index = None
    proj = None
    if args.corpus > 0:
        rng = np.random.default_rng(args.seed)
        centers = rng.normal(0, 1, (32, cfg.d_model))
        corpus = centers[rng.integers(0, 32, args.corpus)] + 0.3 * rng.normal(
            0, 1, (args.corpus, cfg.d_model)
        )
        t0 = time.perf_counter()
        index = build_ada_index(
            corpus.astype(np.float32),
            k=10,
            target_recall=args.target_recall,
            m=8,
            ef_construction=60,
            ef_cap=200,
            num_samples=64,
        )
        print(f"corpus index built in {time.perf_counter() - t0:.1f}s")

    engine = Engine(
        model,
        params,
        ServeConfig(
            max_new_tokens=args.new_tokens,
            target_recall=args.target_recall,
            routed=args.routed,
        ),
        index=index,
        embed_proj=proj,
    )
    rng = np.random.default_rng(args.seed + 1)
    batch = {
        "tokens": jax.numpy.asarray(
            rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len)), jax.numpy.int32
        )
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.numpy.asarray(
            rng.normal(0, 1, (args.requests, cfg.num_frontend_tokens, cfg.frontend_dim)),
            jax.numpy.float32,
        )
    if cfg.family == "audio":
        batch["frames"] = jax.numpy.asarray(
            rng.normal(0, 1, (args.requests, args.prompt_len, cfg.frontend_dim)),
            jax.numpy.float32,
        )
    t0 = time.perf_counter()
    res = engine.serve(batch)
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests x {args.new_tokens} tokens in {dt:.1f}s")
    print("generated token ids:\n", res.tokens)
    if res.retrieved_ids is not None:
        print("retrieved ids (first request):", res.retrieved_ids[0])
        print("adaptive ef used:", res.ef_used)
    if res.router_stats is not None:
        rs = res.router_stats
        tiers = " ".join(
            f"ef{t['ef']}(beam={t['beam']}):{t['count']}/{t['padded_to']}"
            for t in rs["tiers"]
        )
        print(
            f"router: est_cap={rs['est_cap']} est_ndist={rs['est_ndist_total']} "
            f"ndist={rs['ndist_total']} padding_waste={rs['padding_waste']:.2f} "
            f"tiers[{tiers}]"
        )


if __name__ == "__main__":
    main()
