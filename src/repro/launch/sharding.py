"""Per-architecture sharding rules: parameters, optimizer state, activations,
batches, KV caches.

Strategy (DESIGN.md §5): TP over "model" (heads / d_ff / experts / vocab),
DP over ("pod","data"), FSDP-style parameter sharding of the non-TP dim over
"data" for large archs.  KV caches shard heads over "model" when divisible,
else the sequence dim; batch over DP when divisible.

All rules return ``PartitionSpec``s on *trailing* dimensions, padded with
``None`` on the left, so the same rule covers plain and layer-stacked leaves.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from .mesh import dp_axes, dp_size, model_size

MODEL = "model"


def _dp(mesh: Mesh):
    ax = dp_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# (path regex, trailing-dims spec builder). FSDP token resolved at build time.
FSDP = "__fsdp__"

_PARAM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"\bembed$", ("model", FSDP)),              # (V, D)
    (r"\bhead$", (FSDP, "model")),               # (D, V)
    (r"frontend_proj.*\bw1$", (None, "model")),
    (r"frontend_proj.*\bw2$", ("model", None)),
    (r"\bwq$|\bwk$|\bwv$", (FSDP, "model", None)),   # (D, H, hd): shard heads
    (r"\bwqkv$|\bwz$|\bwx$|\bwif$", (FSDP, "model")),
    (r"\bwo$", ("model", None, FSDP)),                # (H, hd, D)
    (r"\bbq$|\bbk$|\bbv$", ("model", None)),
    (r"moe.*\bw_gate$|moe.*\bw_up$", ("model", FSDP, None)),   # (E, D, F)
    (r"moe.*\bw_down$", ("model", None, FSDP)),                # (E, F, D)
    (r"\brouter$", (FSDP, "model")),                           # (D, E)
    (r"\bw_gate$|\bw_up$", (FSDP, "model")),     # dense swiglu (D, F)
    (r"\bw_down$", ("model", FSDP)),             # (F, D)
    (r"\bin_proj$", (FSDP, "model")),            # mamba/zamba (D, X)
    (r"\bout_proj$", ("model", FSDP)),           # (X, D)
    (r"\bconv_w$", (None, "model")),             # (W, C)
    (r"\bconv_b$", ("model",)),
    (r"\bdt_bias$|\ba_log$|\bd_skip$", (None,)),
    (r"\br$", (None, None, None)),               # slstm recurrence, replicated
    (r"\bshared_gate$", (None, None)),
)


def param_spec(path: str, ndim: int, mesh: Mesh, *, fsdp: bool) -> P:
    fsdp_ax = "data" if (fsdp and "data" in mesh.axis_names) else None
    for pattern, trailing in _PARAM_RULES:
        if re.search(pattern, path):
            spec = [None] * ndim
            t = [fsdp_ax if x == FSDP else x for x in trailing]
            k = min(len(t), ndim)
            spec[ndim - k :] = t[len(t) - k :]
            # drop axes that don't exist on this mesh
            spec = [s if (s is None or s in mesh.axis_names) else None for s in spec]
            return P(*spec)
    return P()  # norms, scalars: replicated


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [".".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path) for path, _ in flat]
    return flat, treedef, paths


def params_shardings(abstract_params, cfg: ArchConfig, mesh: Mesh, *, fsdp: Optional[bool] = None):
    """NamedSharding pytree for params (and reusable for AdamW m/v)."""
    if fsdp is None:
        fsdp = cfg.d_model * cfg.num_layers >= 2048 * 24  # on for >~1B models
    flat, treedef, paths = _tree_paths(abstract_params)

    def shardable(spec: P, shape) -> P:
        # verify divisibility; drop axes that don't divide
        out = []
        for dim, s in zip(shape, spec + (None,) * (len(shape) - len(spec))):
            if s is None:
                out.append(None)
                continue
            axes = s if isinstance(s, tuple) else (s,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            out.append(s if dim % n == 0 else None)
        return P(*out)

    leaves = []
    for (path, leaf), pstr in zip(flat, paths):
        spec = param_spec(pstr, leaf.ndim, mesh, fsdp=fsdp)
        spec = shardable(spec, leaf.shape)
        leaves.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def opt_state_shardings(abstract_opt_state, params_shard, mesh: Mesh):
    """AdamW state: step replicated; m/v shard like params."""
    from repro.train.optimizer import AdamWState

    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=params_shard,
        v=jax.tree_util.tree_map(lambda s: s, params_shard),
    )


# --------------------------------------------------------------------------
# batch / cache rules
# --------------------------------------------------------------------------


def batch_shardings(abstract_batch, mesh: Mesh):
    dp = _dp(mesh)
    nd = dp_size(mesh)

    def spec(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % nd == 0 and leaf.shape[0] > 1:
            return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(spec, abstract_batch)


def _kv_cache_spec(shape, cfg: ArchConfig, mesh: Mesh) -> P:
    """(L, B, S, Hk, hd) or (nseg, B, S, Hk, hd)."""
    dp = _dp(mesh)
    nd = dp_size(mesh)
    nm = model_size(mesh)
    _, b, s, hk, _ = shape
    batch_ax = dp if (b % nd == 0 and b >= nd) else None
    if hk % nm == 0:
        return P(None, batch_ax, None, MODEL, None)
    if s % nm == 0:
        if batch_ax is None and s % (nd * nm) == 0:
            # B=1 long-context: shard seq over every axis we have
            return P(None, None, (*dp_axes(mesh), MODEL), None, None)
        return P(None, batch_ax, MODEL, None, None)
    return P(None, batch_ax, None, None, None)


def cache_shardings(abstract_cache, cfg: ArchConfig, mesh: Mesh):
    dp = _dp(mesh)
    nd = dp_size(mesh)

    def spec(leaf):
        if leaf.ndim == 5:  # stacked KV cache
            return NamedSharding(mesh, _kv_cache_spec(leaf.shape, cfg, mesh))
        # state caches (mamba ssm/conv, xlstm): shard batch when divisible
        for i, d in enumerate(leaf.shape):
            if i >= 1 and d % nd == 0 and d >= nd and i <= 2:
                return NamedSharding(
                    mesh, P(*([None] * i), dp, *([None] * (leaf.ndim - i - 1)))
                )
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(spec, abstract_cache)


# --------------------------------------------------------------------------
# activation rules (logical names used by repro.partitioning.constrain)
# --------------------------------------------------------------------------


def activation_rules(cfg: ArchConfig, mesh: Mesh, shape: Optional[ShapeConfig] = None):
    dp = _dp(mesh)
    nm = model_size(mesh)
    batchable = shape is None or (
        shape.global_batch % dp_size(mesh) == 0 and shape.global_batch > 1
    )
    b_ax = dp if batchable else None
    # q heads always shard on "model": GSPMD pads non-divisible head
    # counts (e.g. 14 on 16) — a few idle shards beat replicating the
    # O(S^2) score computation across the whole model axis.
    h_ax = MODEL
    kv_ax = MODEL if cfg.num_kv_heads % nm == 0 else None
    return {
        "act_btd": P(b_ax, None, None),
        "logits": P(b_ax, None, MODEL),
        "moe_ecd": P(MODEL, b_ax, None),
        "moe_ecf": P(MODEL, b_ax, None),
        "act_q_bshd": P(b_ax, None, h_ax, None),
        "act_kv_bshd": P(b_ax, None, kv_ax, None),
    }
