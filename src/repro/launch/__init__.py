"""Launch layer: meshes, sharding rules, dry-run, train/serve drivers, elastic."""
from .mesh import dp_axes, dp_size, make_debug_mesh, make_production_mesh, model_size  # noqa: F401
from .sharding import (  # noqa: F401
    activation_rules,
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    params_shardings,
)
