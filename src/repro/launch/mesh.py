"""Production mesh construction.

Single pod: (16, 16) over ("data", "model") — 256 chips (one v5e pod).
Multi-pod: (2, 16, 16) over ("pod", "data", "model") — 512 chips.

Defined as a FUNCTION so importing this module never touches jax device
state; only ``dryrun.py`` (which sets XLA_FLAGS first) materializes the
512-device host platform.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ("pod", "data") on multi-pod, ("data",) single."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def make_debug_mesh(n_data: int = 1, n_model: int = 1) -> Mesh:
    """Tiny mesh over however many real devices exist (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
