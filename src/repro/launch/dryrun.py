import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# isort: split
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell against
the production mesh, print memory/cost analysis, extract collective traffic.

This is the proof that the distribution config is coherent without hardware:
``jax.jit(step).lower(**input_specs).compile()`` must succeed for the 16x16
single-pod mesh AND the (2,16,16) multi-pod mesh for every applicable cell.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --arch all --shape all \
        --out results/dryrun.json

The XLA_FLAGS line above MUST stay the first statement (jax locks the device
count on first init); nothing above imports jax.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, ArchConfig, ShapeConfig, cell_applicable
from repro.models import build_model
from repro.partitioning import axis_rules
from repro.train import OptimizerConfig, TrainConfig, init_optimizer, make_train_step
from repro.utils.hlo import analyze_hlo, count_ops
from .mesh import make_production_mesh
from .sharding import (
    activation_rules,
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    params_shardings,
)


def count_params(abstract_params) -> Dict[str, int]:
    total = 0
    embed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(abstract_params)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        pstr = ".".join(str(getattr(p, "key", p)) for p in path)
        if "embed" in pstr or "head" in pstr:
            embed += n
    return {"total": total, "non_embedding": total - embed}


def active_param_fraction(cfg: ArchConfig) -> float:
    """Fraction of backbone params active per token (MoE top-k / E)."""
    if not cfg.is_moe:
        return 1.0
    # expert params dominate; approximate active share analytically
    expert = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts
    active_expert = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts_per_tok
    attn = 2 * cfg.d_model * (cfg.num_heads + cfg.num_kv_heads) * cfg.hd
    shared = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_shared_experts
    dense_part = attn + shared
    return (active_expert + dense_part) / max(expert + dense_part, 1)


def _mem_dict(compiled) -> Dict[str, int]:
    m = compiled.memory_analysis()
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    return {k: int(getattr(m, k, 0)) for k in keys}


def run_cell(
    arch: ArchConfig,
    shape: ShapeConfig,
    mesh,
    mesh_name: str,
    *,
    impl: str = "jnp_flash",
    fsdp: Optional[bool] = None,
    microbatches: int = 1,
    parse_collectives: bool = True,
) -> Dict[str, Any]:
    """Lower + compile one cell; return the roofline record."""
    rec: Dict[str, Any] = {
        "arch": arch.name,
        "shape": shape.name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "status": "ok",
    }
    model = build_model(arch, impl=impl)
    abstract_params = model.abstract_params()
    rec["params"] = count_params(abstract_params)
    rec["active_fraction"] = active_param_fraction(arch)
    if shape.kind != "train" and fsdp is None:
        # inference sharding policy: FSDP is a training-memory optimization;
        # at serve time it re-gathers every layer's weights per token step
        # (59.6 GB/step on qwen3-moe decode_32k — §Perf cell 3, iter 1).
        fsdp = False
    p_shard = params_shardings(abstract_params, arch, mesh, fsdp=fsdp)
    rules = activation_rules(arch, mesh, shape)
    specs = model.input_specs(shape)

    t0 = time.perf_counter()
    with axis_rules(mesh, rules):
        if shape.kind == "train":
            tcfg = TrainConfig(microbatches=microbatches, opt=OptimizerConfig())
            step = make_train_step(model, tcfg)
            abstract_opt = jax.eval_shape(init_optimizer, abstract_params)
            o_shard = opt_state_shardings(abstract_opt, p_shard, mesh)
            b_shard = batch_shardings(specs, mesh)
            from jax.sharding import NamedSharding, PartitionSpec as P

            rep = NamedSharding(mesh, P())
            metric_shard = {
                k: rep for k in ("loss", "ce", "aux", "lr", "grad_norm")
            }
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, metric_shard),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(abstract_params, abstract_opt, specs)
        elif shape.kind == "prefill":
            b_shard = batch_shardings(specs, mesh)
            jitted = jax.jit(
                lambda params, batch: model.prefill(params, batch),
                in_shardings=(p_shard, b_shard),
            )
            lowered = jitted.lower(abstract_params, specs)
        else:  # decode
            cache_spec = specs["cache"]
            c_shard = cache_shardings(cache_spec, arch, mesh)
            tok_shard = batch_shardings(
                {"tokens": specs["tokens"], "pos": specs["pos"]}, mesh
            )
            from jax.sharding import NamedSharding, PartitionSpec as P

            jitted = jax.jit(
                lambda params, tokens, cache, pos: model.decode(params, tokens, cache, pos),
                in_shardings=(p_shard, tok_shard["tokens"], c_shard, tok_shard["pos"]),
                out_shardings=(NamedSharding(mesh, P()), c_shard),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                abstract_params, specs["tokens"], cache_spec, specs["pos"]
            )
        rec["lower_s"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t0

    cost = compiled.cost_analysis() or {}
    rec["flops"] = float(cost.get("flops", 0.0))
    rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    rec["memory"] = _mem_dict(compiled)
    print(compiled.memory_analysis())
    if parse_collectives:
        t0 = time.perf_counter()
        txt = compiled.as_text()
        cost = analyze_hlo(txt)
        rec["collectives"] = {k: int(v) for k, v in cost.collectives().items()}
        rec["collectives"]["total"] = int(cost.collective_total)
        rec["weighted_flops"] = float(cost.flops)          # execution-weighted
        rec["weighted_bytes"] = float(cost.bytes)
        rec["hlo_chars"] = len(txt)
        rec["parse_s"] = time.perf_counter() - t0
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--impl", default="jnp_flash")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--no-collectives", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS.values()) if args.arch == "all" else [ARCHS[args.arch]]
    shapes = list(SHAPES) if args.shape == "all" else [
        s for s in SHAPES if s.name == args.shape
    ]
    meshes = {
        "single": [("single", False)],
        "multi": [("multi", True)],
        "both": [("single", False), ("multi", True)],
    }[args.mesh]
    fsdp = {"auto": None, "on": True, "off": False}[args.fsdp]

    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r.get("status") == "ok"}

    for mesh_name, multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape in shapes:
                ok, why = cell_applicable(arch, shape)
                key = (arch.name, shape.name, mesh_name)
                if key in done:
                    continue
                if not ok:
                    results.append(
                        {
                            "arch": arch.name,
                            "shape": shape.name,
                            "mesh": mesh_name,
                            "status": "skipped",
                            "reason": why,
                        }
                    )
                    continue
                print(f"=== {arch.name} x {shape.name} x {mesh_name} ===", flush=True)
                try:
                    rec = run_cell(
                        arch,
                        shape,
                        mesh,
                        mesh_name,
                        impl=args.impl,
                        fsdp=fsdp,
                        microbatches=args.microbatches,
                        parse_collectives=not args.no_collectives,
                    )
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {
                        "arch": arch.name,
                        "shape": shape.name,
                        "mesh": mesh_name,
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                    print(rec["error"], flush=True)
                results.append(rec)
                jax.clear_caches()  # bound host memory across many big compiles
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if rec.get("status") == "ok":
                    print(
                        f"  flops={rec['flops']:.3e} coll={rec.get('collectives', {}).get('total', 0):.3e}B "
                        f"lower={rec['lower_s']:.0f}s compile={rec['compile_s']:.0f}s",
                        flush=True,
                    )

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_err = sum(1 for r in results if r["status"] == "error")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    print(f"done: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors -> {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
