"""Elastic scaling + failure handling.

Mechanisms (exercised by tests/test_elastic.py):

- **Checkpoint re-shard**: checkpoints are mesh-agnostic (host-gathered
  arrays + manifest); :func:`reshard_restore` restores onto a *different*
  mesh by passing the new mesh's sharding tree to ``restore_checkpoint`` —
  scale 512 -> 256 chips (pod loss) or up without conversion tools.
- **Mesh shrink**: :func:`surviving_mesh` builds the largest valid
  (data, model) mesh from a surviving device count, keeping the model axis
  (TP degree must match the checkpoint's weight layout constraints only in
  that divisibility is preserved — weights are re-sharded on restore).
- **Data rebalance**: the synthetic pipeline is a pure function of
  (seed, step), so after a shrink the batch simply re-shards across the new
  data axis — no shard manifests to rebuild.  For real corpora the same
  contract holds if the loader is keyed by (step, global_rank_count).
- **Straggler mitigation**: with synchronous SPMD the unit of recovery is the
  step; the driver (launch/train.py) checkpoints asynchronously and handles
  SIGTERM, so a straggling/preempted host costs at most ``ckpt_every`` steps.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.train.checkpoint import restore_checkpoint
from .sharding import opt_state_shardings, params_shardings


def surviving_mesh(n_devices: int, *, model_axis: int = 16) -> Mesh:
    """Largest (data, model) mesh from ``n_devices`` keeping the TP degree."""
    devs = jax.devices()[:n_devices]
    model = min(model_axis, len(devs))
    data = len(devs) // model
    if data < 1:
        raise ValueError(f"not enough devices ({n_devices}) for model axis {model_axis}")
    import numpy as np

    arr = np.asarray(devs[: data * model]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def reshard_restore(
    ckpt_dir: str,
    step: Optional[int],
    model,
    new_mesh: Mesh,
    *,
    fsdp: Optional[bool] = None,
) -> Tuple[object, object]:
    """Restore (params, opt_state) from a checkpoint onto ``new_mesh``."""
    from repro.train.optimizer import init_optimizer

    abstract_params = model.abstract_params()
    abstract_opt = jax.eval_shape(init_optimizer, abstract_params)
    p_shard = params_shardings(abstract_params, model.cfg, new_mesh, fsdp=fsdp)
    o_shard = opt_state_shardings(abstract_opt, p_shard, new_mesh)
    restored = restore_checkpoint(
        ckpt_dir,
        step,
        {"params": abstract_params, "opt": abstract_opt},
        shardings={"params": p_shard, "opt": o_shard},
    )
    return restored["params"], restored["opt"]
