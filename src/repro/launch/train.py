"""End-to-end training driver.

Fault-tolerant loop: deterministic data from (seed, step), checkpoint every N
steps (atomic + async), resume from LATEST on restart, optional elastic
re-shard when the mesh changed between runs.  On CPU it trains reduced
configs for real (examples/train_lm.py drives a ~100M model); under the
production mesh the same code path trains the full configs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.train import (
    DataConfig,
    OptimizerConfig,
    TrainConfig,
    init_optimizer,
    latest_step,
    make_batch,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
    wait_async,
)

_PREEMPTED = False


def _on_sigterm(signum, frame):  # graceful preemption: checkpoint then exit
    global _PREEMPTED
    _PREEMPTED = True


def train_loop(
    arch_name: str,
    *,
    reduced: bool = True,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    ckpt_dir: str = "",
    ckpt_every: int = 50,
    microbatches: int = 1,
    lr: float = 3e-4,
    log_every: int = 10,
    impl: str = "jnp_flash",
    seed: int = 0,
):
    cfg = ARCHS[arch_name]
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, impl=impl)
    shape = ShapeConfig("cli", seq, batch, "train")
    tcfg = TrainConfig(
        microbatches=microbatches,
        opt=OptimizerConfig(lr=lr, warmup_steps=max(steps // 20, 5), total_steps=steps),
    )
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

    start = 0
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_optimizer(params)
    if ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            restored = restore_checkpoint(ckpt_dir, last, {"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            start = last
            print(f"resumed from step {start}")

    signal.signal(signal.SIGTERM, _on_sigterm)
    losses = []
    last_saved = start
    t0 = time.perf_counter()
    for step in range(start, steps):
        b = make_batch(cfg, shape, step, DataConfig(seed=seed))
        params, opt, metrics = step_fn(params, opt, b)
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            dt = time.perf_counter() - t0
            print(f"step {step:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, {"params": params, "opt": opt}, blocking=False)
            last_saved = step + 1
        if _PREEMPTED:
            print("preempted: writing final checkpoint")
            break
    if ckpt_dir:
        wait_async()  # never race the async writer on the same step dir
        final = min(step + 1, steps)
        if final != last_saved:
            save_checkpoint(ckpt_dir, final, {"params": params, "opt": opt})
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--impl", default="jnp_flash")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _, _, losses = train_loop(
        args.arch,
        reduced=args.reduced,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        microbatches=args.microbatches,
        lr=args.lr,
        impl=args.impl,
        seed=args.seed,
    )
    first, last = losses[0][1], losses[-1][1]
    print(f"loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
