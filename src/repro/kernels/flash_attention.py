"""Flash-attention Pallas kernels (serving/training substrate hot spot).

Two kernels, both GQA-aware:

- :func:`flash_attention` — blocked causal attention for prefill/training
  forward.  Grid ``(B, H, Sq/bq, Skv/bk)`` with the KV axis innermost; online
  softmax state (m, l, acc) lives in VMEM scratch and the output tile is
  written once on the last KV step.  Never materializes the (Sq, Skv) score
  matrix — the working set is O(bq*bk + bq*D).
- :func:`decode_attention` — single-token decode against a (possibly ring)
  KV cache with a runtime valid length.  Grid ``(B, S/bs)``; rows are the
  (H, D) query panel so the MXU stays busy at batch-of-heads granularity.

Numerics: scores are computed in fp32 with a -1e30 additive mask (avoids
-inf NaN propagation); outputs cast back to the query dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

NEG = -1e30


# --------------------------------------------------------------------------
# prefill / training forward
# --------------------------------------------------------------------------


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, bq, bk, sq, skv, scale, causal):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                    # (bq, bk)
    if causal:
        off = skv - sq
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + off
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == pl.num_programs(3) - 1)
    def _fini():
        o_ref[0, 0] = (acc / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> Array:
    """q (B, H, Sq, D); k/v (B, Hk, Skv, D) -> (B, H, Sq, D)."""
    b, h, sq, dh = q.shape
    hk, skv = k.shape[1], k.shape[2]
    rep = h // hk
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, "pad seq lens to block multiples"
    scale = 1.0 / (dh ** 0.5)

    grid = (b, h, sq // bq, skv // bk)
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, sq=sq, skv=skv, scale=scale, causal=causal
    )
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda ib, ih, iq, ik, rep=rep: (ib, ih // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda ib, ih, iq, ik, rep=rep: (ib, ih // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# --------------------------------------------------------------------------
# decode (one new token, long KV cache)
# --------------------------------------------------------------------------


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, bs, hk, rep, scale):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale             # (H, D)
    k = k_ref[0].astype(jnp.float32)                     # (bs, Hk, D)
    v = v_ref[0].astype(jnp.float32)
    h, dh = q.shape
    qr = q.reshape(hk, rep, dh)
    kt = jnp.transpose(k, (1, 2, 0))                     # (Hk, D, bs)
    s = jax.lax.dot_general(
        qr, kt, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )                                                    # (Hk, rep, bs)
    s = s.reshape(h, bs)
    kv_len = len_ref[0, 0]
    pos = ik * bs + jax.lax.broadcasted_iota(jnp.int32, (h, bs), 1)
    s = jnp.where(pos < kv_len, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # (H, bs)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    vt = jnp.transpose(v, (1, 0, 2))                     # (Hk, bs, D)
    pr = p.reshape(hk, rep, bs)
    av = jax.lax.dot_general(
        pr, vt, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )                                                    # (Hk, rep, D)
    acc = acc_scr[...] * alpha + av.reshape(h, dh)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == pl.num_programs(1) - 1)
    def _fini():
        o_ref[0] = (acc / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention(
    q: Array,
    k: Array,
    v: Array,
    kv_len: Array,
    *,
    bs: int = 512,
    interpret: bool = False,
) -> Array:
    """q (B, H, D); k/v (B, S, Hk, D); kv_len (B,) -> (B, H, D)."""
    b, h, dh = q.shape
    s, hk = k.shape[1], k.shape[2]
    rep = h // hk
    bs = min(bs, s)
    assert s % bs == 0, "pad cache length to block multiple"
    scale = 1.0 / (dh ** 0.5)
    lens = kv_len.astype(jnp.int32).reshape(b, 1)
    from jax.experimental.pallas import tpu as pltpu

    kern = functools.partial(_decode_kernel, bs=bs, hk=hk, rep=rep, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(b, s // bs),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ik: (ib, 0)),
            pl.BlockSpec((1, h, dh), lambda ib, ik: (ib, 0, 0)),
            pl.BlockSpec((1, bs, hk, dh), lambda ib, ik: (ib, ik, 0, 0)),
            pl.BlockSpec((1, bs, hk, dh), lambda ib, ik: (ib, ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda ib, ik: (ib, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q, k, v)
