"""Quadratic-form Pallas kernel:  y_b = q_b Sigma q_b^T  (paper §5.4 online).

The per-query FDL variance is a d x d quadratic form; for OpenAI-ada2 scale
(d = 1536) Sigma is 9.4 MiB fp32, too large to keep resident next to the
activations — we stream it through VMEM in (bd, bd) panels and accumulate the
(B,) result in the output block across the reduction grid.

Grid: (d/bd, d/bd) with both axes reductions; the output BlockSpec maps every
step to the same (B, 1) block (revisited accumulation — the standard Pallas
reduction idiom).  Per step:  acc += rowsum( (Q_i @ S_ij) * Q_j ).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BD = 256


def _qform_kernel(qi_ref, sij_ref, qj_ref, out_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qi = qi_ref[...].astype(jnp.float32)        # (B, bd)
    s = sij_ref[...].astype(jnp.float32)        # (bd, bd)
    qj = qj_ref[...].astype(jnp.float32)        # (B, bd)
    t = jax.lax.dot_general(
        qi, s, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # (B, bd)
    out_ref[...] += jnp.sum(t * qj, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def quadratic_form(
    q: Array, sigma: Array, *, bd: int = DEFAULT_BD, interpret: bool = False
) -> Array:
    """q (B, d), sigma (d, d) -> (B,) fp32."""
    b, d = q.shape
    bd = min(bd, max(128, d))
    dp = (d + bd - 1) // bd * bd
    bp = max((b + 7) // 8 * 8, 8)
    qp = jnp.pad(q.astype(jnp.float32), ((0, bp - b), (0, dp - d)))
    sp = jnp.pad(sigma.astype(jnp.float32), ((0, dp - d), (0, dp - d)))
    nb = dp // bd

    out = pl.pallas_call(
        _qform_kernel,
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((bp, bd), lambda i, j: (0, i)),
            pl.BlockSpec((bd, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bp, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        interpret=interpret,
    )(qp, sp, qp)
    return out[:b, 0]
