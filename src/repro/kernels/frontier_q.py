"""Int8 fused frontier-distance Pallas kernel (quantized estimation tier).

Quantized sibling of :func:`repro.kernels.frontier.frontier_batch_distance`:
the batch-hoisted search loop's compacted ``(R,)`` frontier is scored against
the whole query block as **one int8 x int8 MXU matmul with fp32 accumulation**
instead of an fp32 contraction — 4x less VMEM/HBM distance bandwidth, which
is the entire point of the quantized estimation pass.

The quantization scheme (see :mod:`repro.quant.calibrate`) factors every
inner product as

    q · x̂[i]  =  corr_b  +  row_scale[i] * q_scale_b * (q_codes_b · codes[i])

so the kernel only needs the integer contraction plus a per-row scale; the
cheap per-*query* epilogue (``q_scale``/``corr`` gather, metric orientation,
``ids < 0`` masking — predicate-masked ids arrive already rewritten to
``-1`` by ``ops._apply_valid``, so filtered search is free here) runs as
O(R) jnp in the wrapper, keeping the kernel minimal and making the jnp oracle (:func:`repro.kernels.ref.
frontier_batch_q_ref`) bit-comparable: both paths sum exact small integers
in fp32, so kernel and oracle agree to the last ulp for any ``d`` where
``d * 127^2 < 2^24``.

Tiling mirrors the fp32 kernel — 1-D grid over ``R / rt`` row tiles, ids /
owners / row scales / output lane-packed ``(rt/128, 128)``, the query code
block resident across tiles, and an SMEM ``nvalid`` scalar that lets tiles
wholly past the compacted valid prefix skip the matmul.  The one int8-
specific change: the resident query block pads its sublane dim to 32 (the
int8 MXU minimum tile is (32, 128), vs 8 sublanes for fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tiling import round_up

Array = jax.Array

DEFAULT_RT = 256  # cross-query rows per tile (lane multiple)
_LANE = 128
_INT8_SUBLANE = 32  # minimum sublane multiple for int8 MXU operands


def _frontier_batch_q_kernel(
    nvalid_ref, own_ref, rs_ref, qc_ref, panel_ref, out_ref, *, rt: int
):
    i = pl.program_id(0)

    @pl.when(i * rt < nvalid_ref[0])
    def _score():
        own = own_ref[...]                          # (rt/128, 128) int32
        rs = rs_ref[...]                            # (rt/128, 128) f32
        qc = qc_ref[...]                            # (bp, dp) int8
        panel = panel_ref[...]                      # (rt, dp) int8
        raw = jax.lax.dot_general(
            panel,
            qc,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                           # (rt, bp) exact int sums
        bp = qc.shape[0]
        s3 = raw.reshape(own.shape[0], own.shape[1], bp)  # free sublane split
        sel = own[:, :, None] == jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, bp), 2
        )
        vals = jnp.sum(jnp.where(sel, s3, 0.0), axis=-1)  # owner column pick
        out_ref[...] = vals * rs

    @pl.when(i * rt >= nvalid_ref[0])
    def _skip():
        # whole tile past the compacted valid prefix: every row is masked by
        # the wrapper (ids < 0), so any finite fill value works
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("metric", "rt", "interpret"))
def frontier_batch_distance_q(
    ids: Array,
    owners: Array,
    nvalid: Array,
    q_codes: Array,
    q_scale: Array,
    corr: Array,
    codes: Array,
    row_scale: Array,
    *,
    metric: str = "cos_dist",
    rt: int = DEFAULT_RT,
    interpret: bool = False,
) -> Array:
    """Cross-query quantized frontier scoring over a compacted flat panel.

    ``ids`` (R,) int32 compacted candidate ids (valid prefix, ``-1`` tail),
    ``owners`` (R,) int32 owning-query index per row, ``nvalid`` () int32
    valid-prefix length, ``q_codes`` (B, d) int8 quantized queries with
    per-query ``q_scale`` (B,) and zero-point correction ``corr`` (B,)
    (see :func:`repro.quant.calibrate.quantize_queries`), ``codes`` (n, d)
    int8 panel with per-row ``row_scale`` (n,).  Returns (R,) keys
    (smaller = better, masked -> +inf).
    """
    r = ids.shape[0]
    b, d = q_codes.shape
    rt = max(_LANE, min(round_up(rt, _LANE), round_up(r, _LANE)))
    rp = round_up(r, rt)
    bp, dp = round_up(b, _INT8_SUBLANE), round_up(d, _LANE)

    ids_p = jnp.pad(ids.astype(jnp.int32), (0, rp - r), constant_values=-1)
    own_p = jnp.pad(owners.astype(jnp.int32), (0, rp - r))
    safe = jnp.maximum(ids_p, 0)
    qc_p = jnp.pad(q_codes.astype(jnp.int8), ((0, bp - b), (0, dp - d)))
    panel = jnp.pad(codes[safe].astype(jnp.int8), ((0, 0), (0, dp - d)))
    rs_p = row_scale[safe].astype(jnp.float32)                       # (rp,)
    rtt = rt // _LANE

    svals = pl.pallas_call(
        functools.partial(_frontier_batch_q_kernel, rt=rt),
        grid=(rp // rt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),         # nvalid (1,)
            pl.BlockSpec((rtt, _LANE), lambda i: (i, 0)),  # owners
            pl.BlockSpec((rtt, _LANE), lambda i: (i, 0)),  # row scales
            pl.BlockSpec((bp, dp), lambda i: (0, 0)),      # resident q codes
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),      # code panel
        ],
        out_specs=pl.BlockSpec((rtt, _LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp // _LANE, _LANE), jnp.float32),
        interpret=interpret,
    )(
        jnp.asarray(nvalid, jnp.int32).reshape(1),
        own_p.reshape(rp // _LANE, _LANE),
        rs_p.reshape(rp // _LANE, _LANE),
        qc_p,
        panel,
    )
    svals = svals.reshape(rp)[:r]                        # row_scale * rawdot
    ow = jnp.clip(owners, 0, b - 1)
    sims = svals * q_scale[ow] + corr[ow]
    keys = (1.0 - sims) if metric == "cos_dist" else -sims
    return jnp.where(ids >= 0, keys, jnp.inf)
