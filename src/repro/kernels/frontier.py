"""Fused frontier-distance Pallas kernels (beam-batched HNSW expansion).

The base-layer search must score gathered adjacency rows — candidate ids with
``-1`` marking padded / visited-masked slots — fusing the contraction with the
metric epilogue and the id mask:

    keys[slot] = +inf                      if ids[slot] < 0
               = 1 - <q_owner, v_id>       cosine distance
               = -<q_owner, v_id>          similarity metrics (key orientation)

so the search loop consumes *keys* (smaller = better) directly and never
materializes unmasked distances.  Predicate masking (filtered search) rides
this same convention: ``ops._apply_valid`` rewrites mask-failing ids to
``-1`` *before* the kernel (and before compaction, in the batch path), so a
filtered query costs zero extra MXU work and no kernel-internal change
(the "epilogue-level" mask contract).  Candidate rows are gathered outside the
kernel (XLA gather, amortized over the whole frontier); in-kernel HBM->VMEM
DMA by id is the ROADMAP follow-up.  Two kernels share the epilogue:

**Per-query** (:func:`frontier_distance`): a ``(B, F)`` id panel, one grid
program per ``(bb, bf)`` tile contracting a ``(bb, bf, d)`` row panel against
its ``(bb, d)`` query panel as a batched MXU matvec.  This is the shape the
per-query ``vmap`` search loop traces (``bb == 1`` there), so at serving
batch sizes the MXU sees B tiny matvecs.

**Cross-query** (:func:`frontier_batch_distance`): the batch-hoisted loop
flattens the whole batch's frontier to ``(R,)`` compacted rows (valid rows
first — see ``ops.compact_frontier``) with an ``owners`` array naming each
row's query, and contracts the row panel against the *entire* query block as
one ``(R, d) x (d, B)`` MXU matmul — queries are the contraction minor.  The
epilogue selects each row's owner column with an in-register one-hot reduce,
applies the metric, and masks ``ids < 0`` to ``+inf``.  A scalar ``nvalid``
(SMEM) lets grid programs wholly past the compacted valid prefix skip the
matmul and emit ``+inf`` directly, so converged queries stop costing MXU
cycles even though the panel shape is static.

Cross-query tiling and VMEM budget: the grid is 1-D over ``R / rt`` row
tiles (``rt`` a lane multiple, default 256); ``d`` is kept whole per panel
(padded to 128 lanes) and the query block is resident across tiles.  Ids,
owners, and the output keys travel in ``(rt / 128, 128)`` lane-packed
layout; the score tile reshapes ``(rt, Bp) -> (rt/128, 128, Bp)`` (a free
sublane split) for the owner one-hot reduce.  Per program at the default
``rt = 256``, ``d = 512``, ``B = 128``: row panel 512 KiB + query block
256 KiB + score tile 128 KiB + ids/owners/out ~6 KiB ≈ 0.9 MiB of the
~16 MiB VMEM; even ``d = 4096`` with ``B = 512`` stays under 13 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tiling import round_up

Array = jax.Array

DEFAULT_BB = 8    # query rows per tile (fp32 sublane multiple)
DEFAULT_BF = 128  # frontier slots per tile (lane multiple)
DEFAULT_RT = 256  # cross-query rows per tile (lane multiple)
_LANE = 128


def _frontier_kernel(ids_ref, q_ref, panel_ref, out_ref, *, subtract_from_one: bool):
    ids = ids_ref[...]                            # (bb, bf) int32
    q = q_ref[...].astype(jnp.float32)            # (bb, d)
    panel = panel_ref[...].astype(jnp.float32)    # (bb, bf, d)
    sims = jax.lax.dot_general(
        panel,
        q,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                             # (bb, bf)
    keys = (1.0 - sims) if subtract_from_one else -sims
    out_ref[...] = jnp.where(ids >= 0, keys, jnp.inf)


@functools.partial(jax.jit, static_argnames=("metric", "bb", "bf", "interpret"))
def frontier_distance(
    ids: Array,
    q: Array,
    vectors: Array,
    *,
    metric: str = "cos_dist",
    bb: int = DEFAULT_BB,
    bf: int = DEFAULT_BF,
    interpret: bool = False,
) -> Array:
    """(B, F) ids + (B, d) queries + (n, d) table -> (B, F) masked keys.

    Inputs are prepared (normalized for cosine metrics).  Padded / masked ids
    (``< 0``) emit ``+inf`` keys so downstream merges drop them naturally.
    """
    b, f = ids.shape
    d = q.shape[-1]

    # let the query tile shrink to the actual batch: under the search loop's
    # per-query vmap this traces with b=1, and padding 1 -> 8 would gather and
    # contract 8x the rows per iteration for nothing
    bb = min(bb, b)
    # frontier tile: at most the (lane-padded) frontier, kept a 128-multiple
    bf = round_up(min(bf, round_up(f, _LANE)), _LANE)

    bp, fp, dp = round_up(b, bb), round_up(f, bf), round_up(d, _LANE)
    ids_p = jnp.pad(ids.astype(jnp.int32), ((0, bp - b), (0, fp - f)), constant_values=-1)
    q_p = jnp.pad(q.astype(jnp.float32), ((0, bp - b), (0, dp - d)))
    panel = vectors[jnp.maximum(ids_p, 0)].astype(jnp.float32)      # (bp, fp, d)
    panel = jnp.pad(panel, ((0, 0), (0, 0), (0, dp - d)))

    out = pl.pallas_call(
        functools.partial(
            _frontier_kernel, subtract_from_one=(metric == "cos_dist")
        ),
        grid=(bp // bb, fp // bf),
        in_specs=[
            pl.BlockSpec((bb, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bb, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, bf, dp), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, fp), jnp.float32),
        interpret=interpret,
    )(ids_p, q_p, panel)
    return out[:b, :f]


def _frontier_batch_kernel(
    nvalid_ref, ids_ref, own_ref, q_ref, panel_ref, out_ref,
    *, subtract_from_one: bool, rt: int
):
    i = pl.program_id(0)
    nvalid = nvalid_ref[0]

    @pl.when(i * rt < nvalid)
    def _score():
        ids = ids_ref[...]                              # (rt/128, 128) int32
        own = own_ref[...]                              # (rt/128, 128) int32
        q = q_ref[...].astype(jnp.float32)              # (bp, dp)
        panel = panel_ref[...].astype(jnp.float32)      # (rt, dp)
        sims = jax.lax.dot_general(
            panel,
            q,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                               # (rt, bp)
        bp = q.shape[0]
        s3 = sims.reshape(ids.shape[0], ids.shape[1], bp)   # free sublane split
        sel = own[:, :, None] == jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, bp), 2
        )
        vals = jnp.sum(jnp.where(sel, s3, 0.0), axis=-1)    # owner column pick
        keys = (1.0 - vals) if subtract_from_one else -vals
        out_ref[...] = jnp.where(ids >= 0, keys, jnp.inf)

    @pl.when(i * rt >= nvalid)
    def _skip():
        # whole tile past the compacted valid prefix: no gather rows to score
        out_ref[...] = jnp.full(out_ref.shape, jnp.inf, out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("metric", "rt", "interpret"))
def frontier_batch_distance(
    ids: Array,
    owners: Array,
    nvalid: Array,
    q: Array,
    vectors: Array,
    *,
    metric: str = "cos_dist",
    rt: int = DEFAULT_RT,
    interpret: bool = False,
) -> Array:
    """Cross-query fused frontier scoring over a compacted flat row panel.

    ``ids`` (R,) int32 compacted candidate ids (valid prefix, ``-1`` tail),
    ``owners`` (R,) int32 owning-query index per row (in ``[0, B)``),
    ``nvalid`` () int32 length of the valid prefix (tiles beyond it are
    skipped), ``q`` (B, d) prepared queries, ``vectors`` (n, d) prepared
    table.  Returns (R,) keys (smaller = better, masked -> +inf).
    """
    r = ids.shape[0]
    b, d = q.shape
    rt = max(_LANE, min(round_up(rt, _LANE), round_up(r, _LANE)))
    rp, bp, dp = round_up(r, rt), round_up(b, 8), round_up(d, _LANE)

    ids_p = jnp.pad(ids.astype(jnp.int32), (0, rp - r), constant_values=-1)
    own_p = jnp.pad(owners.astype(jnp.int32), (0, rp - r))
    q_p = jnp.pad(q.astype(jnp.float32), ((0, bp - b), (0, dp - d)))
    panel = vectors[jnp.maximum(ids_p, 0)].astype(jnp.float32)       # (rp, d)
    panel = jnp.pad(panel, ((0, 0), (0, dp - d)))
    rtt = rt // _LANE

    out = pl.pallas_call(
        functools.partial(
            _frontier_batch_kernel,
            subtract_from_one=(metric == "cos_dist"),
            rt=rt,
        ),
        grid=(rp // rt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),         # nvalid (1,)
            pl.BlockSpec((rtt, _LANE), lambda i: (i, 0)),  # ids
            pl.BlockSpec((rtt, _LANE), lambda i: (i, 0)),  # owners
            pl.BlockSpec((bp, dp), lambda i: (0, 0)),      # resident q block
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),      # row panel
        ],
        out_specs=pl.BlockSpec((rtt, _LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp // _LANE, _LANE), jnp.float32),
        interpret=interpret,
    )(
        jnp.asarray(nvalid, jnp.int32).reshape(1),
        ids_p.reshape(rp // _LANE, _LANE),
        own_p.reshape(rp // _LANE, _LANE),
        q_p,
        panel,
    )
    return out.reshape(rp)[:r]
