"""Fused frontier-distance Pallas kernel (beam-batched HNSW expansion).

The beamed base-layer search pops ``beam`` candidates per iteration and must
score their gathered adjacency rows — a ``(B, F)`` panel of candidate ids per
query batch (``F = beam * M0``, ``-1`` = padded / visited-masked).  This kernel
fuses the per-query frontier contraction with the metric epilogue and the
id mask:

    keys[b, f] = +inf                      if ids[b, f] < 0
               = 1 - <q_b, v_ids[b,f]>     cosine distance
               = -<q_b, v_ids[b,f]>        similarity metrics (key orientation)

so the search loop consumes *keys* (smaller = better) directly and never
materializes unmasked distances.  The candidate rows are gathered outside the
kernel (XLA gather, amortized over the whole frontier); each grid program then
contracts a ``(bb, bf, d)`` row panel against its ``(bb, d)`` query panel as a
batched MXU matvec with the epilogue fused.

Tiling: grid over (B / bb, F / bf); d is kept whole per panel (padded to a
lane multiple).  A 8 x 128 x 512 fp32 row panel is 2 MiB — row panel + query
panel + output tile fit comfortably in VMEM.  Cross-query batching of the
frontier contraction (one (F, d) x (d, B) matmul) is a ROADMAP follow-up.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BB = 8    # query rows per tile (fp32 sublane multiple)
DEFAULT_BF = 128  # frontier slots per tile (lane multiple)


def _frontier_kernel(ids_ref, q_ref, panel_ref, out_ref, *, subtract_from_one: bool):
    ids = ids_ref[...]                            # (bb, bf) int32
    q = q_ref[...].astype(jnp.float32)            # (bb, d)
    panel = panel_ref[...].astype(jnp.float32)    # (bb, bf, d)
    sims = jax.lax.dot_general(
        panel,
        q,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                             # (bb, bf)
    keys = (1.0 - sims) if subtract_from_one else -sims
    out_ref[...] = jnp.where(ids >= 0, keys, jnp.inf)


@functools.partial(jax.jit, static_argnames=("metric", "bb", "bf", "interpret"))
def frontier_distance(
    ids: Array,
    q: Array,
    vectors: Array,
    *,
    metric: str = "cos_dist",
    bb: int = DEFAULT_BB,
    bf: int = DEFAULT_BF,
    interpret: bool = False,
) -> Array:
    """(B, F) ids + (B, d) queries + (n, d) table -> (B, F) masked keys.

    Inputs are prepared (normalized for cosine metrics).  Padded / masked ids
    (``< 0``) emit ``+inf`` keys so downstream merges drop them naturally.
    """
    b, f = ids.shape
    d = q.shape[-1]

    def rup(x, m):
        return (x + m - 1) // m * m

    # let the query tile shrink to the actual batch: under the search loop's
    # per-query vmap this traces with b=1, and padding 1 -> 8 would gather and
    # contract 8x the rows per iteration for nothing
    bb = min(bb, b)
    # frontier tile: at most the (lane-padded) frontier, kept a 128-multiple
    bf = rup(min(bf, rup(f, 128)), 128)

    bp, fp, dp = rup(b, bb), rup(f, bf), rup(d, 128)
    ids_p = jnp.pad(ids.astype(jnp.int32), ((0, bp - b), (0, fp - f)), constant_values=-1)
    q_p = jnp.pad(q.astype(jnp.float32), ((0, bp - b), (0, dp - d)))
    panel = vectors[jnp.maximum(ids_p, 0)].astype(jnp.float32)      # (bp, fp, d)
    panel = jnp.pad(panel, ((0, 0), (0, 0), (0, dp - d)))

    out = pl.pallas_call(
        functools.partial(
            _frontier_kernel, subtract_from_one=(metric == "cos_dist")
        ),
        grid=(bp // bb, fp // bf),
        in_specs=[
            pl.BlockSpec((bb, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bb, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, bf, dp), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, fp), jnp.float32),
        interpret=interpret,
    )(ids_p, q_p, panel)
    return out[:b, :f]
