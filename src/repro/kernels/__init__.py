"""Pallas TPU kernels for the perf-critical compute layers.

Paper hot spots: fused pairwise distance, the qSigmaq^T quadratic form, fused
quantile-bin scoring.  Serving substrate: flash attention (prefill) + blocked
decode attention.  Validated in interpret mode against ``ref.py`` oracles.
"""
from . import ops, ref  # noqa: F401
from .distance import pairwise_distance  # noqa: F401
from .frontier import frontier_distance  # noqa: F401
from .qform import quadratic_form  # noqa: F401
from .binscore import binscore  # noqa: F401
from .flash_attention import decode_attention, flash_attention  # noqa: F401
