"""Fused quantile-bin scoring Pallas kernel (paper Eqs. 5-6).

One pass over the collected distance list computes the per-bin counts *and*
the weighted score — no (B, L, m) intermediate like the jnp reference builds.
The m thresholds/weights per query are tiny and live alongside the (bb, L)
distance panel in VMEM.

Grid: (B / bb,).  Inside: counts_i = sum_l valid_l * [theta_{i-1} < d_l <= theta_i]
computed as a difference of cumulative comparisons; score = counts @ w / |D|.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BB = 128


def _binscore_kernel(d_ref, t_ref, w_ref, v_ref, out_ref):
    d = d_ref[...].astype(jnp.float32)          # (bb, L)
    t = t_ref[...].astype(jnp.float32)          # (bb, m)
    w = w_ref[...].astype(jnp.float32)          # (1, m)
    valid = v_ref[...].astype(jnp.float32)      # (bb, L)
    # cumulative membership per bin edge: (bb, L, m) would blow VMEM for large
    # L*m; instead loop over the (small, static) m with a running "previous
    # cumulative count" so the working set stays (bb, L).
    m = t.shape[1]
    denom = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1.0)  # (bb, 1)
    score = jnp.zeros_like(denom)
    prev = jnp.zeros_like(denom)
    for i in range(m):
        cum_i = jnp.sum(
            jnp.where(d <= t[:, i : i + 1], valid, 0.0), axis=1, keepdims=True
        )
        count_i = cum_i - prev
        score += count_i * w[0, i]
        prev = cum_i
    out_ref[...] = score / denom


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def binscore(
    distances: Array,
    thresholds: Array,
    weights: Array,
    valid: Array,
    *,
    bb: int = DEFAULT_BB,
    interpret: bool = False,
) -> Array:
    """distances (B, L), thresholds (B, m), weights (m,), valid (B, L) -> (B,)."""
    b, l = distances.shape
    m = thresholds.shape[1]
    bb = min(bb, max(8, b))
    bp = (b + bb - 1) // bb * bb
    lp = (l + 127) // 128 * 128
    d = jnp.pad(distances.astype(jnp.float32), ((0, bp - b), (0, lp - l)),
                constant_values=jnp.inf)
    t = jnp.pad(thresholds.astype(jnp.float32), ((0, bp - b), (0, 0)))
    v = jnp.pad(valid.astype(jnp.float32), ((0, bp - b), (0, lp - l)))
    w = weights.astype(jnp.float32)[None, :]

    out = pl.pallas_call(
        _binscore_kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, lp), lambda i: (i, 0)),
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((bb, lp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        interpret=interpret,
    )(d, t, w, v)
    return out[:b, 0]
