"""Fused pairwise-distance Pallas kernel (the paper's hottest op).

HNSW search cost is dominated by query-to-candidate distance evaluation; on
TPU we compute a whole tile of them as one MXU contraction with the cosine
``1 - x`` epilogue fused, instead of HNSWlib's one-AVX-dot-per-pair.

Tiling: grid over (B / bb, n / bn); each program loads a ``(bb, d)`` query
panel and a ``(bn, d)`` database panel into VMEM and emits a ``(bb, bn)``
distance tile.  d is kept whole per panel (embedding dims ≤ ~4k: a
128 x 4096 fp32 panel is 2 MiB — two panels + the output tile fit comfortably
in the ~16 MiB of VMEM); wrappers pad B/n/d to hardware-aligned multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import round_up

Array = jax.Array

DEFAULT_BB = 128  # query-tile rows (MXU-aligned)
DEFAULT_BN = 256  # database-tile rows


def _distance_kernel(q_ref, v_ref, out_ref, *, subtract_from_one: bool):
    q = q_ref[...].astype(jnp.float32)          # (bb, d)
    v = v_ref[...].astype(jnp.float32)          # (bn, d)
    sims = jax.lax.dot_general(
        q,
        v,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # (bb, bn)
    out_ref[...] = (1.0 - sims) if subtract_from_one else sims


@functools.partial(
    jax.jit, static_argnames=("metric", "bb", "bn", "interpret")
)
def pairwise_distance(
    q: Array,
    v: Array,
    *,
    metric: str = "cos_dist",
    bb: int = DEFAULT_BB,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> Array:
    """(B, d) x (n, d) -> (B, n) fused distance tiles. Inputs prepared."""
    b, d = q.shape
    n = v.shape[0]
    bb = min(bb, max(8, b))
    bn = min(bn, max(128, n))

    bp, np_, dp = round_up(b, bb), round_up(n, bn), round_up(d, 128)
    qp = jnp.pad(q, ((0, bp - b), (0, dp - d)))
    vp = jnp.pad(v, ((0, np_ - n), (0, dp - d)))

    out = pl.pallas_call(
        functools.partial(_distance_kernel, subtract_from_one=(metric == "cos_dist")),
        grid=(bp // bb, np_ // bn),
        in_specs=[
            pl.BlockSpec((bb, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        interpret=interpret,
    )(qp, vp)
    return out[:b, :n]
