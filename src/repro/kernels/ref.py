"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the semantic ground truth the kernels are tested against
(interpret mode on CPU, shape/dtype sweeps in tests/test_kernels_*.py).

All frontier oracles share the ``ids < 0 -> +inf`` masking convention, so
predicate masks (filtered search) need no oracle change: ``ops.
_apply_valid`` rewrites mask-failing ids to ``-1`` before scoring, and the
existing guard emits +inf for them — oracle and kernel stay bit-identical
under any validity mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def distance_ref(q: Array, v: Array, *, metric: str = "cos_dist") -> Array:
    """Pairwise distances: q (B, d) x v (n, d) -> (B, n).

    Inputs are *prepared* (normalized for cosine metrics).
    """
    sims = jnp.dot(q.astype(jnp.float32), v.astype(jnp.float32).T)
    if metric == "cos_dist":
        return 1.0 - sims
    return sims


def frontier_ref(ids: Array, q: Array, vectors: Array, *, metric: str = "cos_dist") -> Array:
    """Masked frontier keys: ids (B, F) int32 (-1 = masked), q (B, d),
    vectors (n, d) -> (B, F) float32 *keys* (smaller = better).

    cos_dist: key = 1 - <q, v>; similarity metrics: key = -<q, v>;
    masked slots emit +inf.  Inputs are prepared (normalized for cosine).
    """
    safe = jnp.maximum(ids, 0)
    rows = vectors[safe].astype(jnp.float32)               # (B, F, d)
    sims = jnp.einsum("bfd,bd->bf", rows, q.astype(jnp.float32))
    keys = (1.0 - sims) if metric == "cos_dist" else -sims
    return jnp.where(ids >= 0, keys, jnp.inf)


def frontier_batch_ref(
    ids: Array, owners: Array, q: Array, vectors: Array, *, metric: str = "cos_dist"
) -> Array:
    """Cross-query masked frontier keys over a flat row panel.

    ids (R,) int32 candidate ids (-1 = masked), owners (R,) int32 owning-query
    index in ``[0, B)``, q (B, d), vectors (n, d) -> (R,) float32 keys
    (smaller = better, masked -> +inf).  Semantics of the cross-query Pallas
    kernel: each row is scored against its owner's query only; row order is
    arbitrary (the compaction in ``ops.frontier_keys_batch`` is a pure
    permutation).  Inputs are prepared (normalized for cosine).
    """
    safe = jnp.maximum(ids, 0)
    rows = vectors[safe].astype(jnp.float32)                        # (R, d)
    qo = q[jnp.clip(owners, 0, q.shape[0] - 1)].astype(jnp.float32)  # (R, d)
    sims = jnp.einsum("rd,rd->r", rows, qo)
    keys = (1.0 - sims) if metric == "cos_dist" else -sims
    return jnp.where(ids >= 0, keys, jnp.inf)


def frontier_batch_q_ref(
    ids: Array,
    owners: Array,
    q_codes: Array,
    q_scale: Array,
    corr: Array,
    codes: Array,
    row_scale: Array,
    *,
    metric: str = "cos_dist",
) -> Array:
    """Quantized cross-query frontier keys over a flat row panel.

    Semantic ground truth of :func:`repro.kernels.frontier_q.
    frontier_batch_distance_q`, sharing its factored inner product

        sim = corr_b + row_scale[i] * q_scale_b * (q_codes_b · codes_i)

    so kernel and oracle sum the same exact small integers in fp32 (bit-
    comparable while ``d * 127^2 < 2^24``).  ``codes`` may be int8 or fp8
    (the fp8 path always scores here — the Pallas kernel is int8-only).
    """
    safe = jnp.maximum(ids, 0)
    ow = jnp.clip(owners, 0, q_codes.shape[0] - 1)
    rows = codes[safe].astype(jnp.float32)                          # (R, d)
    qo = q_codes[ow].astype(jnp.float32)                            # (R, d)
    raw = jnp.einsum("rd,rd->r", rows, qo)
    sims = raw * row_scale[safe] * q_scale[ow] + corr[ow]
    keys = (1.0 - sims) if metric == "cos_dist" else -sims
    return jnp.where(ids >= 0, keys, jnp.inf)


def qform_ref(q: Array, sigma: Array) -> Array:
    """Quadratic form q Sigma q^T, batched: q (B, d), sigma (d, d) -> (B,)."""
    q = q.astype(jnp.float32)
    return jnp.einsum("bi,ij,bj->b", q, sigma.astype(jnp.float32), q)


def binscore_ref(
    distances: Array,
    thresholds: Array,
    weights: Array,
    valid: Array,
) -> Array:
    """Fused quantile-bin weighted score (paper Eqs. 5-6).

    distances  (B, L) collected values (distance orientation: smaller=closer)
    thresholds (B, m) ascending bin upper edges
    weights    (m,)
    valid      (B, L) float/bool mask
    Returns (B,) scores  s = sum_i w_i c_i / |D|.
    """
    d = distances[:, :, None]
    t = thresholds[:, None, :]
    cum = (d <= t).astype(jnp.float32)
    per_bin = jnp.diff(cum, axis=-1, prepend=jnp.zeros_like(cum[..., :1]))
    per_bin = per_bin * valid.astype(jnp.float32)[:, :, None]
    counts = jnp.sum(per_bin, axis=1)  # (B, m)
    denom = jnp.maximum(jnp.sum(valid.astype(jnp.float32), axis=1), 1.0)
    return jnp.sum(counts * weights[None, :], axis=-1) / denom


def mha_ref(
    q: Array, k: Array, v: Array, *, causal: bool = True, q_offset: int | None = None
) -> Array:
    """Multi-head attention oracle with GQA.

    q (B, H, Sq, D); k/v (B, Hk, Skv, D); H % Hk == 0.
    ``q_offset``: absolute position of q row 0 (defaults to Skv - Sq, i.e. the
    query block is the suffix — the decode/prefill convention).
    """
    b, h, sq, dh = q.shape
    hk = k.shape[1]
    rep = h // hk
    kf = jnp.repeat(k, rep, axis=1)
    vf = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    if causal:
        skv = k.shape[2]
        off = skv - sq if q_offset is None else q_offset
        qpos = jnp.arange(sq)[:, None] + off
        kpos = jnp.arange(skv)[None, :]
        mask = qpos >= kpos
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: Array, k: Array, v: Array, kv_len: Array) -> Array:
    """Single-token decode attention oracle.

    q (B, H, D); k/v (B, S, Hk, D) rings with valid prefix ``kv_len`` (B,).
    Returns (B, H, D).
    """
    b, h, dh = q.shape
    hk = k.shape[2]
    rep = h // hk
    kf = jnp.repeat(k, rep, axis=2)  # (B, S, H, D)
    vf = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kf.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    s = k.shape[1]
    mask = jnp.arange(s)[None, None, :] < kv_len[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, vf.astype(jnp.float32))
    return out.astype(q.dtype)
