"""Leaf helpers shared by the Pallas kernel modules and their dispatchers.

Kept import-free of the rest of the package: ``ops`` imports every kernel
module and re-exports these, so anything both sides need must live below
them in the import graph.
"""
from __future__ import annotations


def round_up(x: int, m: int) -> int:
    """Round ``x`` up to the next multiple of ``m`` (kernel tile padding)."""
    return (x + m - 1) // m * m
