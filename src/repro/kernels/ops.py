"""Jit'd public wrappers around the Pallas kernels (the ``ops.py`` contract).

Every op dispatches between the Pallas kernel (TPU target; ``interpret=True``
on CPU for validation) and the pure-jnp reference, controlled per call.  The
framework's higher layers import from here, never from the kernels directly.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .binscore import binscore as _binscore_kernel
from .distance import pairwise_distance as _distance_kernel
from .flash_attention import decode_attention as _decode_kernel
from .flash_attention import flash_attention as _flash_kernel
from .frontier import frontier_batch_distance as _frontier_batch_kernel
from .frontier import frontier_distance as _frontier_kernel
from .frontier_q import frontier_batch_distance_q as _frontier_batch_q_kernel
from .qform import quadratic_form as _qform_kernel
from .tiling import round_up  # noqa: F401  (re-export: the shared helper)

Array = jax.Array

_ON_TPU = jax.default_backend() == "tpu"


def pairwise_distance(q, v, *, metric: str = "cos_dist", use_kernel: bool = False,
                      interpret: Optional[bool] = None) -> Array:
    if use_kernel:
        return _distance_kernel(
            q, v, metric=metric, interpret=(not _ON_TPU) if interpret is None else interpret
        )
    return ref.distance_ref(q, v, metric=metric)


def _apply_valid(ids: Array, valid: Optional[Array]) -> Array:
    """Fold a per-node validity bitmask into the id mask convention.

    ``valid`` (n,) bool indexed by node id; rows failing it are rewritten to
    ``-1`` so every downstream kernel/oracle emits +inf for them through the
    *existing* padded-id machinery — predicate masking costs zero extra MXU
    work and no kernel-internal change (the ISSUE-10 "epilogue-level"
    contract, shared by the Pallas kernels and the jnp refs alike).
    """
    if valid is None:
        return ids
    return jnp.where(valid[jnp.maximum(ids, 0)], ids, -1)


def frontier_keys(ids, q, vectors, *, metric: str = "cos_dist",
                  use_kernel: bool = False,
                  interpret: Optional[bool] = None,
                  valid: Optional[Array] = None) -> Array:
    """Masked frontier keys for beamed HNSW expansion.

    ``ids`` (B, F) or (F,) gathered candidate ids (-1 = padded/masked),
    ``q`` (B, d) or (d,) prepared queries, ``vectors`` (n, d) prepared table.
    ``valid`` is an optional (n,) per-node validity bitmask (predicate /
    alive composition): ids failing it score +inf, exactly like padded ids.
    Returns keys shaped like ``ids`` (smaller = better, masked -> +inf).
    """
    ids = _apply_valid(ids, valid)
    squeeze = ids.ndim == 1
    ids2 = ids[None] if squeeze else ids
    q2 = q[None] if squeeze else q
    if use_kernel:
        out = _frontier_kernel(
            ids2, q2, vectors, metric=metric,
            interpret=(not _ON_TPU) if interpret is None else interpret,
        )
    else:
        out = ref.frontier_ref(ids2, q2, vectors, metric=metric)
    return out[0] if squeeze else out


def compact_frontier(ids: Array):
    """Stable-partition a flat frontier so valid ids form a contiguous prefix.

    ``ids`` (R,) int32 with ``-1`` = padded / visited / done-query slots.
    Returns ``(compact_ids, owners, dest, nvalid)`` where ``dest`` (R,) maps
    each original slot to its compacted position (``compact[dest[i]] ==
    ids[i]``; un-compact any per-row output with ``out_compact[dest]``),
    ``owners`` carries the original slot index of each compacted row, and
    ``nvalid`` () int32 counts the valid prefix.  Pure cumsum + scatter —
    O(R), no sort — so finished queries' all ``-1`` rows cost one pass and
    land at the tail where the cross-query kernel skips whole tiles.
    """
    valid = ids >= 0
    nvalid = jnp.sum(valid.astype(jnp.int32))
    up = jnp.cumsum(valid.astype(jnp.int32)) - 1
    down = nvalid + jnp.cumsum((~valid).astype(jnp.int32)) - 1
    dest = jnp.where(valid, up, down)
    slot = jnp.arange(ids.shape[0], dtype=jnp.int32)
    compact_ids = jnp.zeros_like(ids).at[dest].set(ids)
    owners = jnp.zeros_like(slot).at[dest].set(slot)
    return compact_ids, owners, dest, nvalid


def frontier_keys_batch(ids, q, vectors, *, metric: str = "cos_dist",
                        use_kernel: bool = False,
                        interpret: Optional[bool] = None,
                        qpanel=None,
                        valid: Optional[Array] = None) -> Array:
    """Cross-query masked frontier keys for the batch-hoisted search loop.

    ``ids`` (B, F) gathered candidate ids (-1 = padded / visited / done
    query), ``q`` (B, d) prepared queries, ``vectors`` (n, d) prepared table.
    ``valid`` is an optional (n,) per-node validity bitmask: failing ids are
    folded into the ``-1`` convention *before* compaction, so masked rows
    sink to the tail with the done-query rows and the kernel skips their
    tiles outright.  Returns (B, F) keys (smaller = better, masked -> +inf).

    Unlike :func:`frontier_keys` (one ``(F, d)`` contraction per query), the
    whole batch is flattened to ``(B*F,)`` rows, compacted so valid rows form
    a prefix (see :func:`compact_frontier` — finished queries' ``-1`` rows
    sink to the tail and contribute no fresh gather rows, their panel slots
    re-read row 0), and scored as **one** ``(B*F, d) x (d, B)`` MXU matmul
    with the per-row owner select fused into the kernel epilogue.

    ``qpanel`` routes scoring through the quantized estimation tier: a
    ``(codes, row_scale, dim_scale, zero)`` tuple (the
    :class:`repro.quant.QuantizedPanel` fields) scored by the int8 Pallas
    kernel when ``use_kernel`` and the codes are int8, else by the quantized
    jnp oracle — the same pallas→interpret→oracle ladder as the fp32 path,
    and both rungs share the query-quantization math so a mid-flight
    fallback stays bit-comparable.
    """
    b, f = ids.shape
    ids = _apply_valid(ids, valid)
    flat = ids.reshape(-1).astype(jnp.int32)
    compact_ids, owner_slots, dest, nvalid = compact_frontier(flat)
    owners = owner_slots // f  # owning query of each compacted row
    if qpanel is not None:
        from repro.quant.calibrate import QuantizedPanel, quantize_queries

        panel = QuantizedPanel(*qpanel)
        q_codes, q_scale, corr = quantize_queries(panel, q)
        if use_kernel and panel.codes.dtype == jnp.int8:
            keys_c = _frontier_batch_q_kernel(
                compact_ids, owners, nvalid, q_codes, q_scale, corr,
                panel.codes, panel.row_scale, metric=metric,
                interpret=(not _ON_TPU) if interpret is None else interpret,
            )
        else:
            keys_c = ref.frontier_batch_q_ref(
                compact_ids, owners, q_codes, q_scale, corr,
                panel.codes, panel.row_scale, metric=metric,
            )
        return keys_c[dest].reshape(b, f)
    if use_kernel:
        keys_c = _frontier_batch_kernel(
            compact_ids, owners, nvalid, q, vectors, metric=metric,
            interpret=(not _ON_TPU) if interpret is None else interpret,
        )
    else:
        keys_c = ref.frontier_batch_ref(
            compact_ids, owners, q, vectors, metric=metric
        )
    return keys_c[dest].reshape(b, f)


def quadratic_form(q, sigma, *, use_kernel: bool = False,
                   interpret: Optional[bool] = None) -> Array:
    if use_kernel:
        return _qform_kernel(
            q, sigma, interpret=(not _ON_TPU) if interpret is None else interpret
        )
    return ref.qform_ref(q, sigma)


def binscore_raw(distances, thresholds, weights, valid, *, use_kernel: bool = True,
                 interpret: Optional[bool] = None) -> Array:
    if use_kernel:
        return _binscore_kernel(
            distances, thresholds, weights, valid,
            interpret=(not _ON_TPU) if interpret is None else interpret,
        )
    return ref.binscore_ref(distances, thresholds, weights, valid)


def score(params, distances, *, valid=None, m: int = 10, delta: float = 1e-3,
          metric: str = "cos_dist", decay: str = "exp",
          interpret: Optional[bool] = None) -> Array:
    """Kernel-backed version of `repro.core.scoring.score_query` (same semantics)."""
    from repro.core.scoring import bin_thresholds, bin_weights

    thresholds = bin_thresholds(params, m=m, delta=delta, metric=metric)
    weights = bin_weights(m, decay)
    if valid is None:
        valid = jnp.ones(distances.shape, jnp.float32)
    sign = 1.0 if metric == "cos_dist" else -1.0
    # kernel works in distance orientation (ascending thresholds)
    d = distances * sign
    t = thresholds * sign
    if sign < 0:
        t = t[..., :]  # similarity thresholds negated are ascending already
    return binscore_raw(
        d, t, weights, valid,
        interpret=(not _ON_TPU) if interpret is None else interpret,
    )


def flash_attention(q, k, v, *, causal: bool = True, use_kernel: bool = False,
                    bq: int = 256, bk: int = 256,
                    interpret: Optional[bool] = None) -> Array:
    if use_kernel:
        return _flash_kernel(
            q, k, v, causal=causal, bq=bq, bk=bk,
            interpret=(not _ON_TPU) if interpret is None else interpret,
        )
    return ref.mha_ref(q, k, v, causal=causal)


def decode_attention(q, k, v, kv_len, *, use_kernel: bool = False, bs: int = 512,
                     interpret: Optional[bool] = None) -> Array:
    if use_kernel:
        return _decode_kernel(
            q, k, v, kv_len, bs=bs,
            interpret=(not _ON_TPU) if interpret is None else interpret,
        )
    return ref.decode_attention_ref(q, k, v, kv_len)
