"""Static-pytree registration for config dataclasses (leaf module).

Lives below ``repro.api`` / ``repro.serve`` / ``repro.index`` so every
config module can share one implementation without an import cycle (the
same layering trick as ``kernels/tiling.round_up``).
"""
from __future__ import annotations

import jax


def register_static_config(cls):
    """Register a frozen, hashable dataclass as a zero-leaf pytree.

    The instance becomes its own treedef aux data: it can be passed through
    ``jit``/``vmap`` boundaries as a normal argument, participates in
    compile-cache keys via its dataclass ``__eq__``/``__hash__``, and never
    shows up as an array leaf.  Returns ``cls`` so it stacks as a decorator.
    """
    jax.tree_util.register_pytree_node(
        cls, lambda c: ((), c), lambda aux, _children: aux
    )
    return cls
