"""Serve-side observability: metrics, span tracing, online recall audit.

Three pieces, all host-side and opt-in so the scheduler hot path stays
free of device syncs (see each module's docstring):

- :mod:`repro.obs.metrics` — process-wide :class:`MetricsRegistry`
  (counters / gauges / mergeable p50-p95-p99 histograms, dict +
  Prometheus text export).
- :mod:`repro.obs.trace` — per-request :class:`SpanTracer` on the
  scheduler's injectable clock, Chrome trace-event JSON export.
- :mod:`repro.obs.audit` — :class:`RecallAuditor`: deterministic sampling
  of completed requests, re-run through the oracle ``ef_cap`` reference on
  idle ticks, per-tier achieved-recall EWMAs + :class:`RecallAlert`.

Entry points: ``SchedulerConfig(trace=..., audit_fraction=...)``,
``plan.explain(analyze=True)``, and ``launch/serve.py --metrics
--trace-out``.
"""
from .audit import RecallAlert, RecallAuditor, oracle_topk, sample_uid
from .metrics import (
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    global_registry,
)
from .trace import Span, SpanTracer, device_annotation

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "global_registry",
    "Span",
    "SpanTracer",
    "device_annotation",
    "RecallAlert",
    "RecallAuditor",
    "oracle_topk",
    "sample_uid",
]
