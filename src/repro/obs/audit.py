"""Online recall auditor — the paper's declarative-recall contract, measured.

The Ada-ef stack *promises* a target recall per query (paper Alg. 2 +
ESTIMATE-EF), but a promise without measurement is a config knob, not a
contract.  :class:`RecallAuditor` closes the loop in the style DARTH
(PAPERS.md) frames declarative recall — as a *monitored runtime property*:

1. **Sample** a deterministic fraction of completed requests (hash of the
   ticket uid, so replays audit the same requests and two auditors agree).
2. **Re-run** each sampled query through the full-``ef_cap`` oracle ladder
   — the same reference the bit-exactness tests trust — *off the hot path*:
   the scheduler calls :meth:`RecallAuditor.step` only on work-conserving
   idle ticks, so audits never compete with live tier drains.
3. **Track** per-tier achieved-recall EWMAs against the per-request
   ``target_recall`` EWMA; when a tier's achieved recall drops below
   target − margin (after a minimum sample count), surface a
   :class:`RecallAlert` in stats — an edge-triggered "this tier is breaking
   the recall contract" signal.

Partial answers (deadline blown while queued, served from the phase-A
heap) are audited under the pseudo-tier ``ef=0`` so their — expectedly
lower — recall never drags a real tier's EWMA below its alert line.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

# Knuth multiplicative hash over the ticket uid: uniform in [0, 1) for
# sequential uids, deterministic across processes and replays.
_HASH_MULT = 0x9E3779B1
_HASH_MOD = 1 << 32


def sample_uid(uid: int, fraction: float) -> bool:
    """Deterministic sampling decision for a ticket uid."""
    if fraction <= 0.0:
        return False
    if fraction >= 1.0:
        return True
    return ((uid * _HASH_MULT) % _HASH_MOD) / _HASH_MOD < fraction


def oracle_topk(graph, queries: np.ndarray, cfg, ef: Optional[int] = None,
                valid=None):
    """Ground-truth-by-construction reference: full-``ef_cap`` search on
    the oracle (pure-jnp) backend — the same rung the backend fallback
    ladder and the bit-exactness property tests bottom out on.

    ``valid`` is an optional per-node validity bitmask (a compiled
    FilterSpec): it composes into ``graph.alive`` so the oracle's results
    honor the predicate — filtered queries must never be graded against
    unfiltered ground truth.  When ``graph`` already carries a predicate
    mask (``fmask``), it is folded in the same way automatically, so
    auditor closures built over a filtered plan's graph need no extra
    plumbing.  Returns host ``(B, k)`` int ids.  Callers batch tiny (the
    auditor audits one request per idle tick), so the compile for the
    ``(1, d)`` shape happens once and is reused for every subsequent audit.
    """
    import jax.numpy as jnp
    from repro.index.search import search

    alive = graph.alive
    if valid is not None:
        alive = alive & jnp.asarray(valid, bool)
    if graph.fmask is not None:
        alive = alive & graph.fmask
    if alive is not graph.alive:
        # tombstone semantics: masked-out rows stay traversable but never
        # surface — exactly the filtered ground truth contract.  The mask
        # moves into `alive` (and fmask clears) so the oracle result is
        # independent of cfg.filter_mode.
        graph = graph._replace(alive=alive, fmask=None)
    ocfg = dataclasses.replace(
        cfg,
        use_distance_kernel=False,
        ef_cap=int(ef or cfg.ef_cap),
        patience=0,
        precision="fp32",  # quantized plans audit against the fp32 oracle:
        #   the reference must not share the quantization error under test
        filter_mode="off",  # the mask (if any) is already folded into alive
    )
    q = np.atleast_2d(np.asarray(queries))
    ef_arr = jnp.full((q.shape[0],), ocfg.ef_cap, jnp.int32)
    res = search(graph, jnp.asarray(q), ef_arr, ocfg)
    return np.asarray(res.ids)


@dataclasses.dataclass(frozen=True)
class RecallAlert:
    """Edge-triggered contract violation: a tier's achieved-recall EWMA
    crossed below its target EWMA minus ``margin``."""

    tier_ef: int
    ewma: float
    target: float
    margin: float
    samples: int
    t: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def __str__(self):
        return (
            f"RecallAlert(tier ef={self.tier_ef}: achieved EWMA "
            f"{self.ewma:.4f} < target {self.target:.4f} - "
            f"margin {self.margin:.3f} after {self.samples} samples)"
        )


class _TierEwma:
    __slots__ = ("recall", "target", "n", "alerting")

    def __init__(self):
        self.recall = 0.0
        self.target = 0.0
        self.n = 0
        self.alerting = False


class RecallAuditor:
    """Samples completed requests and audits achieved recall online.

    Parameters
    ----------
    reference:
        ``(query (1, d) or (d,)) -> (1, K) host ids`` — the oracle answer
        to compare against (the scheduler wires :func:`oracle_topk` over
        its router's graph/config).
    fraction:
        Deterministic sample fraction in [0, 1]
        (``SchedulerConfig.audit_fraction``).
    margin:
        Alert when a tier's recall EWMA < target EWMA − margin.
    alpha:
        EWMA smoothing weight for new samples.
    min_samples:
        Per-tier sample count before alerts may fire (cold EWMAs lie).
    max_pending:
        Bound on the not-yet-audited queue; overflow evicts the oldest
        sample and counts it in ``overflowed``.
    """

    def __init__(
        self,
        reference: Callable[[np.ndarray], np.ndarray],
        *,
        fraction: float,
        margin: float = 0.02,
        alpha: float = 0.2,
        min_samples: int = 5,
        max_pending: int = 256,
        clock=time.monotonic,
        on_alert: Optional[Callable[[RecallAlert], None]] = None,
    ):
        if not 0.0 <= fraction <= 1.0:
            raise ValueError(f"fraction={fraction} not in [0, 1]")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha={alpha} not in (0, 1]")
        self.reference = reference
        self.fraction = fraction
        self.margin = margin
        self.alpha = alpha
        self.min_samples = min_samples
        self.clock = clock
        self.on_alert = on_alert
        self._pending: deque = deque(maxlen=max_pending)
        self._tiers: Dict[int, _TierEwma] = {}
        self.samples: List[Dict] = []
        self.alerts: List[RecallAlert] = []
        self.sampled = 0
        self.audited = 0
        self.overflowed = 0

    # -- hot path (scheduler response emission) --------------------------

    def admit(self, uid: int) -> bool:
        """Deterministic per-uid sampling decision (pure, host-side)."""
        return sample_uid(uid, self.fraction)

    def enqueue(
        self,
        uid: int,
        query: np.ndarray,
        ids: np.ndarray,
        *,
        k: int,
        tier_ef: int,
        target: float,
        status: str,
        reference: Optional[Callable[[np.ndarray], np.ndarray]] = None,
        epoch: int = -1,
    ) -> None:
        """Record a completed request for later auditing.  Host-side
        only: the served ids are already on host by response time, so
        this adds no device sync to the response path.

        ``reference`` optionally pins a per-sample oracle (falling back to
        the auditor-wide one): under index churn a request is served
        against the epoch it was dispatched on, so its recall must be
        audited against *that* epoch's graph — the scheduler passes a
        closure over the request's pinned snapshot, and pre-mutation
        responses audited after the swap still compare apples to apples."""
        if len(self._pending) == self._pending.maxlen:
            self.overflowed += 1
        self._pending.append(
            (uid, np.asarray(query), np.asarray(ids), k, tier_ef,
             float(target), status, reference, int(epoch))
        )
        self.sampled += 1

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- off the hot path (idle ticks / flush) ---------------------------

    def step(self, budget: int = 1) -> int:
        """Audit up to ``budget`` pending samples; returns audits done.
        Called by the scheduler only on work-conserving idle ticks."""
        done = 0
        while self._pending and done < budget:
            self._audit_one(*self._pending.popleft())
            done += 1
        return done

    def flush(self) -> int:
        """Audit everything still pending (drain / shutdown path)."""
        return self.step(budget=len(self._pending))

    def _audit_one(self, uid, query, ids, k, tier_ef, target, status,
                   reference=None, epoch=-1):
        ref = reference if reference is not None else self.reference
        ref_ids = np.asarray(ref(query[None, :]))[0]
        served = np.asarray(ids[:k]).ravel()
        truth = set(int(i) for i in ref_ids[:k] if i >= 0)
        hit = sum(1 for i in served if int(i) in truth)
        recall = hit / max(k, 1)
        self.audited += 1
        self.samples.append(
            {
                "uid": int(uid),
                "tier_ef": int(tier_ef),
                "recall": float(recall),
                "target": float(target),
                "status": status,
                "epoch": int(epoch),
            }
        )
        tier = self._tiers.setdefault(int(tier_ef), _TierEwma())
        if tier.n == 0:
            tier.recall = recall
            tier.target = target
        else:
            a = self.alpha
            tier.recall = (1 - a) * tier.recall + a * recall
            tier.target = (1 - a) * tier.target + a * target
        tier.n += 1
        self._maybe_alert(int(tier_ef), tier)

    def _maybe_alert(self, tier_ef: int, tier: _TierEwma) -> None:
        # The ef=0 pseudo-tier holds partial (phase-A heap) answers whose
        # recall is expected to trail target — never alert on it.
        breach = (
            tier_ef > 0
            and tier.n >= self.min_samples
            and tier.recall < tier.target - self.margin
        )
        if breach and not tier.alerting:
            tier.alerting = True
            alert = RecallAlert(
                tier_ef=tier_ef,
                ewma=float(tier.recall),
                target=float(tier.target),
                margin=self.margin,
                samples=tier.n,
                t=self.clock(),
            )
            self.alerts.append(alert)
            if self.on_alert is not None:
                self.on_alert(alert)
        elif not breach and tier.alerting:
            tier.alerting = False  # re-arm: recovery resets the edge

    # -- export ----------------------------------------------------------

    def tier_ewmas(self) -> Dict[int, Dict]:
        return {
            ef: {
                "recall_ewma": t.recall,
                "target_ewma": t.target,
                "samples": t.n,
                "alerting": t.alerting,
            }
            for ef, t in sorted(self._tiers.items())
        }

    def as_dict(self) -> Dict:
        """JSON-able summary (stringified tier keys for round-trips)."""
        return {
            "fraction": self.fraction,
            "margin": self.margin,
            "sampled": self.sampled,
            "audited": self.audited,
            "pending": self.pending,
            "overflowed": self.overflowed,
            "tiers": {str(ef): d for ef, d in self.tier_ewmas().items()},
            "alerts": [a.as_dict() for a in self.alerts],
        }
