"""Process-wide metrics: counters, gauges, fixed-bucket latency histograms.

The serving stack's telemetry dataclasses (:class:`repro.serve.stats.
SchedulerStats` / ``RouterStats``) are *snapshots* — great for one batch or
one scheduler lifetime, but nothing aggregated them across schedulers,
engines and benchmark runs, and nothing measured a latency *distribution*
(only sums).  A :class:`MetricsRegistry` is the aggregation point:

- :class:`Counter` — monotone accumulator (``inc``), int or float.
- :class:`Gauge` — last-write-wins instantaneous value (``set``).
- :class:`Histogram` — fixed-bucket distribution with p50/p95/p99 quantile
  *estimates* (linear interpolation inside the owning bucket — resolution is
  the bucket width, which is the standard Prometheus trade).  Histograms
  with equal bucket layouts **merge**, so per-seed / per-shard histograms
  pool into one distribution (``bench_scheduler`` pools arrival seeds this
  way).

Everything is plain host-side Python — recording a metric never touches a
JAX array, so the scheduler hot path stays free of device syncs.  Export as
a nested dict (``as_dict``, JSON-able) or Prometheus text-exposition lines
(``render_prometheus``).

Names take optional ``**labels``; the same name with different label sets
is a metric *family* (one ``HELP``/``TYPE`` block, many series), exactly
like Prometheus.  ``AdaServeScheduler`` binds its ``SchedulerStats`` to a
registry (:meth:`repro.serve.stats.SchedulerStats.bind`), so every counter
the scheduler bumps is mirrored here without a second bookkeeping path.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

# Log-spaced seconds buckets covering sub-ms kernel drains to multi-second
# stalls; the +inf overflow bucket is implicit (the last counts slot).
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

LabelItems = Tuple[Tuple[str, str], ...]


def _label_items(labels: Dict[str, object]) -> LabelItems:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(items: LabelItems) -> str:
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


@dataclasses.dataclass
class Counter:
    """Monotone accumulator.  ``inc`` accepts ints and floats (walls)."""

    value: float = 0.0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment {n} must be >= 0")
        self.value += n

    def as_dict(self) -> float:
        return self.value


@dataclasses.dataclass
class Gauge:
    """Last-write-wins instantaneous value (queue depth, inflight count)."""

    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def as_dict(self) -> float:
        return self.value


class Histogram:
    """Fixed-bucket histogram with quantile estimates.

    ``buckets`` are ascending upper bounds; observations above the last
    bound land in the implicit +inf overflow slot.  ``quantile`` walks the
    cumulative counts and interpolates linearly inside the owning bucket
    (the overflow bucket answers with the max observed value), so estimates
    are exact at bucket edges and bounded by bucket width in between —
    mergeable across processes/seeds, unlike a reservoir.
    """

    __slots__ = ("buckets", "counts", "count", "sum", "min", "max")

    def __init__(self, buckets: Iterable[float] = LATENCY_BUCKETS_S):
        self.buckets: Tuple[float, ...] = tuple(float(b) for b in buckets)
        if not self.buckets or list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError("histogram buckets must be ascending and unique")
        self.counts: List[int] = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for i, ub in enumerate(self.buckets):  # noqa: B007 - tiny fixed scan
            if v <= ub:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (q in [0, 1]); NaN when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q={q} not in [0, 1]")
        if self.count == 0:
            return math.nan
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= target:
                if i == len(self.buckets):  # overflow: max observed
                    return self.max
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                hi = self.buckets[i]
                lo = max(lo, self.min) if self.min < hi else lo
                hi = min(hi, self.max)
                frac = (target - seen) / c
                return lo + frac * max(hi - lo, 0.0)
            seen += c
        return self.max  # pragma: no cover - unreachable (count > 0)

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p95(self) -> float:
        return self.quantile(0.95)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into this histogram (equal bucket layouts only)."""
        if self.buckets != other.buckets:
            raise ValueError("cannot merge histograms with different buckets")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def as_dict(self) -> Dict:
        empty = self.count == 0
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": None if empty else self.mean,
            "p50": None if empty else self.p50,
            "p95": None if empty else self.p95,
            "p99": None if empty else self.p99,
            "min": None if empty else self.min,
            "max": None if empty else self.max,
            "buckets": {
                ("+Inf" if i == len(self.buckets) else repr(self.buckets[i])): c
                for i, c in enumerate(self.counts)
                if c
            },
        }


class MetricsRegistry:
    """Named metric families, keyed ``(name, sorted label items)``.

    ``counter``/``gauge``/``histogram`` are get-or-create (the Prometheus
    client idiom): callers write ``registry.counter("sheds", reason=r).inc()``
    at the event site and never hold metric objects across config changes.
    Thread-safe creation; individual updates are plain attribute writes
    (GIL-atomic, and the serving stack is single-threaded per scheduler).
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, LabelItems], object] = {}
        self._kinds: Dict[str, str] = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str, labels: Dict, factory):
        key = (name, _label_items(labels))
        got = self._metrics.get(key)
        if got is None:
            with self._lock:
                got = self._metrics.get(key)
                if got is None:
                    prev = self._kinds.setdefault(name, kind)
                    if prev != kind:
                        raise ValueError(
                            f"metric {name!r} already registered as {prev}"
                        )
                    got = self._metrics[key] = factory()
        elif self._kinds.get(name) != kind:
            raise ValueError(
                f"metric {name!r} already registered as {self._kinds[name]}"
            )
        return got

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(
        self, name: str, buckets: Optional[Iterable[float]] = None, **labels
    ) -> Histogram:
        return self._get(
            "histogram", name, labels,
            lambda: Histogram(buckets or LATENCY_BUCKETS_S),
        )

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another registry in: counters add, gauges take the other's
        value, histograms merge."""
        for (name, items), metric in other._metrics.items():
            labels = dict(items)
            if isinstance(metric, Counter):
                self.counter(name, **labels).inc(metric.value)
            elif isinstance(metric, Gauge):
                self.gauge(name, **labels).set(metric.value)
            else:
                self.histogram(name, buckets=metric.buckets, **labels).merge(
                    metric
                )
        return self

    def as_dict(self) -> Dict:
        """``{name: {label-string: metric dict/value}}`` — JSON-able."""
        out: Dict[str, Dict] = {}
        for (name, items), metric in sorted(
            self._metrics.items(), key=lambda kv: kv[0]
        ):
            out.setdefault(name, {})[_label_str(items) or "_"] = (
                metric.as_dict()
            )
        return out

    def render_prometheus(self) -> str:
        """Text exposition format (the ``/metrics`` endpoint payload)."""
        lines: List[str] = []
        seen_type = set()
        for (name, items), metric in sorted(
            self._metrics.items(), key=lambda kv: kv[0]
        ):
            kind = self._kinds[name]
            if name not in seen_type:
                lines.append(f"# TYPE {name} {kind}")
                seen_type.add(name)
            ls = _label_str(items)
            if isinstance(metric, (Counter, Gauge)):
                lines.append(f"{name}{ls} {metric.value:g}")
                continue
            cum = 0
            for i, c in enumerate(metric.counts):
                cum += c
                le = (
                    "+Inf" if i == len(metric.buckets)
                    else f"{metric.buckets[i]:g}"
                )
                extra = (("le", le),) + tuple(items)
                lines.append(
                    f"{name}_bucket{_label_str(_label_items(dict(extra)))} "
                    f"{cum}"
                )
            lines.append(f"{name}_sum{ls} {metric.sum:g}")
            lines.append(f"{name}_count{ls} {metric.count}")
        return "\n".join(lines) + ("\n" if lines else "")


_GLOBAL: Optional[MetricsRegistry] = None
_GLOBAL_LOCK = threading.Lock()


def global_registry() -> MetricsRegistry:
    """The process-wide registry (drivers pass it to every scheduler they
    build so ``--metrics`` dumps one merged view)."""
    global _GLOBAL
    if _GLOBAL is None:
        with _GLOBAL_LOCK:
            if _GLOBAL is None:
                _GLOBAL = MetricsRegistry()
    return _GLOBAL
