"""Per-request span tracing on the scheduler's injectable clock.

A request moving through :class:`repro.serve.scheduler.AdaServeScheduler`
passes distinct stations — ``submit → screen → estimate → queue(tier) →
[demote*] → dispatch → materialize → terminal(status)`` — and latency
pathologies live *between* them (queue wait vs estimation vs tier drain vs
device materialization).  :class:`SpanTracer` records that timeline as
spans and instant events in a bounded ring buffer, stamped by the same
injectable clock the scheduler uses for deadlines, so fake-clock tests and
chaos harnesses see spans on the exact timeline they control.

Export is Chrome trace-event JSON (``tracer.export(path)``): load the file
in Perfetto / ``chrome://tracing`` and each request renders as its own
track (``tid`` = ticket uid) with the queue/dispatch spans laid end to end.
Batch-level scheduler work (estimation passes, tier drains) lands on track
0.  :func:`device_annotation` optionally brackets kernel dispatches with a
``jax.profiler.TraceAnnotation`` so device profiles line up with host
spans; it degrades to a null context when the profiler is unavailable.

Tracing is opt-in (``SchedulerConfig.trace``); every emission site in the
scheduler is guarded by a single ``is None`` check, so the disabled path
costs one attribute load and the hot path stays sync-free.
"""
from __future__ import annotations

import contextlib
import json
from collections import deque
from typing import Dict, List, Optional

import time

#: Span/event names emitted by the scheduler, in lifecycle order.
LIFECYCLE = (
    "submit", "screen", "estimate", "queue", "demote",
    "dispatch", "materialize", "terminal",
)


class Span:
    """One named interval (or instant, when ``t1 == t0``) on the trace.

    ``uid`` ties the span to a request ticket; batch-level spans (shared
    estimation pass, tier drain) carry ``uid=None`` and render on track 0.
    ``args`` hold annotations (ef_est, tier_ef, trigger, backend, ...).
    """

    __slots__ = ("name", "uid", "t0", "t1", "args")

    def __init__(self, name: str, uid: Optional[int], t0: float, **args):
        self.name = name
        self.uid = uid
        self.t0 = t0
        self.t1: Optional[float] = None
        self.args: Dict = args

    @property
    def done(self) -> bool:
        return self.t1 is not None

    @property
    def duration_s(self) -> float:
        return (self.t1 - self.t0) if self.done else 0.0

    def __repr__(self):  # pragma: no cover - debugging aid
        tail = f" dur={self.duration_s:.6f}s" if self.done else " (open)"
        return f"Span({self.name!r}, uid={self.uid}{tail})"


class SpanTracer:
    """Bounded ring buffer of :class:`Span` on an injectable clock.

    ``begin``/``end`` bracket intervals; ``event`` records instants.  The
    ring (``capacity`` spans, :class:`collections.deque` with ``maxlen``)
    bounds memory under sustained traffic — ``dropped`` counts evictions so
    an exporter can tell a truncated trace from a complete one.
    """

    def __init__(self, clock=time.monotonic, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        self.clock = clock
        self.capacity = capacity
        self._spans: deque = deque(maxlen=capacity)
        self.dropped = 0

    def _push(self, span: Span) -> Span:
        if len(self._spans) == self.capacity:
            self.dropped += 1
        self._spans.append(span)
        return span

    def begin(self, name: str, uid: Optional[int] = None, **args) -> Span:
        return self._push(Span(name, uid, self.clock(), **args))

    def end(self, span: Optional[Span], **args) -> Optional[Span]:
        """Close a span (idempotent, None-tolerant so call sites stay flat)."""
        if span is not None and span.t1 is None:
            span.t1 = self.clock()
            if args:
                span.args.update(args)
        return span

    def event(self, name: str, uid: Optional[int] = None, **args) -> Span:
        span = self._push(Span(name, uid, self.clock(), **args))
        span.t1 = span.t0
        return span

    @contextlib.contextmanager
    def span(self, name: str, uid: Optional[int] = None, **args):
        s = self.begin(name, uid, **args)
        try:
            yield s
        finally:
            self.end(s)

    # -- queries ---------------------------------------------------------

    def spans(self, uid: Optional[int] = None) -> List[Span]:
        """All buffered spans, or just one request's (in emission order)."""
        if uid is None:
            return list(self._spans)
        return [s for s in self._spans if s.uid == uid]

    def request_terminal(self, uid: int) -> Optional[str]:
        """Terminal status recorded for ``uid`` (None while in flight)."""
        for s in reversed(self._spans):
            if s.uid == uid and s.name == "terminal":
                return s.args.get("status")
        return None

    def request_complete(self, uid: int) -> str:
        """Validate ``uid``'s span tree: spans exist, all closed, exactly
        one ``terminal`` event.  Returns the terminal status; raises
        ``ValueError`` describing the defect otherwise (the ``obs_gate``
        smoke asserts through this)."""
        got = self.spans(uid)
        if not got:
            raise ValueError(f"uid {uid}: no spans recorded")
        open_spans = [s.name for s in got if not s.done]
        if open_spans:
            raise ValueError(f"uid {uid}: unclosed spans {open_spans}")
        terminals = [s for s in got if s.name == "terminal"]
        if len(terminals) != 1:
            raise ValueError(
                f"uid {uid}: expected exactly one terminal event, "
                f"got {len(terminals)}"
            )
        status = terminals[0].args.get("status")
        if not status:
            raise ValueError(f"uid {uid}: terminal event missing status")
        return status

    # -- export ----------------------------------------------------------

    def to_chrome(self) -> Dict:
        """Chrome trace-event JSON object (``{"traceEvents": [...]}``).

        Closed spans become complete ("X") events, instants become
        instant ("i") events; times are µs relative to the earliest
        buffered span so fake-clock (epoch 0) and monotonic traces both
        render near the origin.  Open spans are exported as instants
        flagged ``"open": true`` rather than dropped.
        """
        spans = list(self._spans)
        origin = min((s.t0 for s in spans), default=0.0)
        events = []
        for s in spans:
            ts = (s.t0 - origin) * 1e6
            tid = 0 if s.uid is None else int(s.uid)
            base = {
                "name": s.name,
                "pid": 0,
                "tid": tid,
                "ts": ts,
                "args": dict(s.args),
            }
            if s.done and s.t1 > s.t0:
                base["ph"] = "X"
                base["dur"] = (s.t1 - s.t0) * 1e6
            else:
                base["ph"] = "i"
                base["s"] = "t"
                if not s.done:
                    base["args"]["open"] = True
            events.append(base)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped": self.dropped},
        }

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path`` (Perfetto-viewable)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


def device_annotation(name: str):
    """Context manager bracketing a kernel dispatch with a
    ``jax.profiler.TraceAnnotation`` so device profiles (``jax.profiler.
    trace``) line up with host-side spans; null context when the profiler
    is unavailable (interpret-only builds, stripped wheels)."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:  # pragma: no cover - depends on jax build
        return contextlib.nullcontext()
