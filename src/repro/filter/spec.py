"""Declarative predicate specs for filtered search.

A :class:`FilterSpec` names *what* must be true of a result row — tenant
ownership, categorical attribute membership, numeric/date ranges, an id
range — without saying *how* the engine enforces it.  The planner
(:func:`repro.plan.plan_spec`) compiles the spec against the index's
:class:`repro.filter.store.AttributeStore` into a per-node validity bitmask
(``DeviceGraph.fmask``) and picks the lowering from the estimated
selectivity: **pre-filter** (the mask joins the W admission logic, tombstone
semantics) when few rows pass, **post-filter with overquery** (unmasked
traversal, inflated ef, heap epilogue) when most rows pass.

Specs are immutable, hashable, and dict-round-trippable so they can ride
:class:`repro.api.SearchSpec` through the static-pytree plan cache.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

AttrValues = Tuple[Tuple[str, Tuple[str, ...]], ...]
NumRanges = Tuple[Tuple[str, float, float], ...]


def _canon_attrs(attrs) -> AttrValues:
    """Canonicalize ``{name: value-or-values}`` / tuple forms into a sorted
    nested tuple (hash- and equality-stable regardless of insertion order)."""
    if not attrs:
        return ()
    items = attrs.items() if isinstance(attrs, dict) else attrs
    out = []
    for name, vals in items:
        if isinstance(vals, (str, bytes)):
            vals = (vals,)
        vv = tuple(sorted(str(v) for v in vals))
        if not vv:
            raise ValueError(f"attr {name!r}: empty allowed-value set")
        out.append((str(name), vv))
    return tuple(sorted(out))


def _canon_ranges(ranges) -> NumRanges:
    """Canonicalize ``{name: (lo, hi)}`` / tuple forms; bounds are inclusive
    (``lo <= value <= hi`` — date predicates express "between day A and B")."""
    if not ranges:
        return ()
    items = ranges.items() if isinstance(ranges, dict) else ()
    if not isinstance(ranges, dict):
        items = [(r[0], (r[1], r[2])) for r in ranges]
    out = []
    for name, (lo, hi) in items:
        lo, hi = float(lo), float(hi)
        if hi < lo:
            raise ValueError(f"range {name!r}: hi={hi} < lo={lo}")
        out.append((str(name), lo, hi))
    return tuple(sorted(out))


@dataclasses.dataclass(frozen=True)
class FilterSpec:
    """Predicate over index rows; all clauses AND together.

    - ``tenant``: row must belong to this tenant namespace (the scheduler
      also uses it to resolve per-tenant SLOs/quotas).
    - ``attrs``: categorical membership, ``{"category": ("news", "blog")}``.
    - ``ranges``: inclusive numeric ranges, ``{"date": (19000, 19365)}`` —
      date predicates are numeric attributes (e.g. epoch days).
    - ``id_range``: half-open row-id interval ``[lo, hi)`` — needs no
      attribute store (ids are positional).
    """

    tenant: Optional[str] = None
    attrs: AttrValues = ()
    ranges: NumRanges = ()
    id_range: Optional[Tuple[int, int]] = None

    def __post_init__(self):
        object.__setattr__(self, "attrs", _canon_attrs(self.attrs))
        object.__setattr__(self, "ranges", _canon_ranges(self.ranges))
        if self.id_range is not None:
            lo, hi = self.id_range
            lo, hi = int(lo), int(hi)
            if lo < 0 or hi < lo:
                raise ValueError(f"id_range [{lo}, {hi}) is invalid")
            object.__setattr__(self, "id_range", (lo, hi))
        if self.tenant is not None and not str(self.tenant):
            raise ValueError("tenant must be a non-empty string or None")

    @property
    def trivial(self) -> bool:
        """True when no clause constrains anything (no mask needed)."""
        return (
            self.tenant is None
            and not self.attrs
            and not self.ranges
            and self.id_range is None
        )

    def needs_store(self) -> bool:
        """True when evaluation requires an attribute store (anything beyond
        the positional ``id_range`` clause)."""
        return self.tenant is not None or bool(self.attrs) or bool(self.ranges)

    def as_dict(self) -> Dict:
        return {
            "tenant": self.tenant,
            "attrs": {name: list(vals) for name, vals in self.attrs},
            "ranges": {name: [lo, hi] for name, lo, hi in self.ranges},
            "id_range": None if self.id_range is None else list(self.id_range),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "FilterSpec":
        return cls(
            tenant=d.get("tenant"),
            attrs=d.get("attrs") or (),
            ranges=d.get("ranges") or (),
            id_range=(
                None if d.get("id_range") is None else tuple(d["id_range"])
            ),
        )
