"""Filtered & multi-tenant search: predicate specs, attribute store, masks.

The vertical slice (ISSUE 10): :class:`FilterSpec` declares the predicate,
:class:`AttributeStore` compiles it to a per-node validity bitmask and
estimates its selectivity from attribute histograms, and the planner lowers
``SearchSpec.filter`` to either **pre-filter** (mask rides the tombstone
admission seam, ``SearchConfig.filter_mode="pre"``) or **post-filter with
overquery** (``"post"``: unmasked traversal at inflated ef + heap
epilogue).  ``attach_mask`` pins the compiled mask onto an immutable
:class:`repro.index.DeviceGraph` copy, so epoch snapshots and unfiltered
plans never see it.
"""
from .spec import FilterSpec  # noqa: F401
from .store import AttributeStore, FilterCompileError  # noqa: F401


def attach_mask(graph, mask):
    """Return a ``DeviceGraph`` copy carrying ``mask`` as its predicate
    validity bitmask (``fmask``).  The input graph is untouched — filtered
    plans hold their own masked copy, sharing every other array."""
    import jax.numpy as jnp

    mask = jnp.asarray(mask, bool)
    if mask.shape != graph.alive.shape:
        raise ValueError(
            f"mask shape {mask.shape} != graph rows {graph.alive.shape}"
        )
    return graph._replace(fmask=mask)
