"""Per-row attribute store: mask compilation + histogram selectivity.

Host-side numpy columns keyed by attribute name, aligned with the index's
row ids (row ``i`` of every column describes graph node ``i``).  Two jobs:

- :meth:`AttributeStore.compile_mask` — evaluate a :class:`FilterSpec`
  exactly, producing the ``(n,)`` bool validity mask the search loop
  composes with ``g.alive`` (this is the *correctness* path; it runs once
  per plan, not per query).
- :meth:`AttributeStore.estimate_selectivity` — answer "what fraction of
  rows would pass?" from **pre-built histograms** without touching the
  columns (the *planning* path: equi-depth value counts for categorical
  columns, fixed-bin histograms for numeric ones, clause independence
  assumed).  The planner picks pre-filter vs post-filter-with-overquery
  from this estimate and records it in ``plan.explain()["filter"]``.

Mutation contract mirrors the vector panels: :meth:`append` extends every
column for inserted rows (missing attributes get never-matching fills), and
deletes need no call at all — tombstoned rows keep their attributes and the
``alive`` mask already excludes them from results.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

import numpy as np

from .spec import FilterSpec

_NUM_BINS = 64
_CAT_TOP = 256  # histogram tracks the top-K values exactly; the tail pools


class FilterCompileError(ValueError):
    """A FilterSpec references an attribute the store does not have."""


class AttributeStore:
    """Columnar per-row attributes (tenant + categorical + numeric)."""

    def __init__(
        self,
        n: int,
        *,
        tenant: Optional[Sequence[str]] = None,
        categorical: Optional[Dict[str, Sequence[str]]] = None,
        numeric: Optional[Dict[str, Sequence[float]]] = None,
    ):
        self.n = int(n)
        self._cats: Dict[str, np.ndarray] = {}
        self._nums: Dict[str, np.ndarray] = {}
        if tenant is not None:
            self._cats["tenant"] = self._cat_col(tenant)
        for name, col in (categorical or {}).items():
            self._cats[str(name)] = self._cat_col(col)
        for name, col in (numeric or {}).items():
            arr = np.asarray(col, np.float64)
            if arr.shape != (self.n,):
                raise ValueError(
                    f"numeric column {name!r}: shape {arr.shape} != ({self.n},)"
                )
            self._nums[str(name)] = arr
        self._hist_cache: Dict[str, object] = {}

    def _cat_col(self, col: Sequence[str]) -> np.ndarray:
        arr = np.asarray([str(v) for v in col], object)
        if arr.shape != (self.n,):
            raise ValueError(f"categorical column shape {arr.shape} != ({self.n},)")
        return arr

    # ---- introspection ----------------------------------------------------

    @property
    def columns(self) -> Dict[str, str]:
        """``{name: kind}`` over every stored column."""
        out = {name: "categorical" for name in self._cats}
        out.update({name: "numeric" for name in self._nums})
        return out

    def tenants(self) -> Iterable[str]:
        col = self._cats.get("tenant")
        return () if col is None else sorted(set(col.tolist()))

    # ---- mutation (insert appends; delete is a no-op — tombstones keep
    # their attributes and `alive` already hides them) ----------------------

    def append(
        self,
        m: int,
        *,
        tenant: Optional[Sequence[str]] = None,
        categorical: Optional[Dict[str, Sequence[str]]] = None,
        numeric: Optional[Dict[str, Sequence[float]]] = None,
    ) -> None:
        """Extend every column by ``m`` inserted rows.  Columns the caller
        does not provide are filled with never-matching values ("" for
        categorical, NaN for numeric) so unattributed rows fail every
        predicate instead of silently passing one."""
        m = int(m)
        if m < 0:
            raise ValueError(f"append({m}) rows")
        new_cats = dict(categorical or {})
        if tenant is not None:
            new_cats["tenant"] = tenant
        for name, col in self._cats.items():
            add = new_cats.pop(name, None)
            if add is None:
                add = np.asarray([""] * m, object)
            else:
                add = np.asarray([str(v) for v in add], object)
            if add.shape != (m,):
                raise ValueError(f"append column {name!r}: {add.shape} != ({m},)")
            self._cats[name] = np.concatenate([col, add])
        for name, col in self._nums.items():
            add = (numeric or {}).get(name)
            arr = (
                np.full((m,), np.nan)
                if add is None
                else np.asarray(add, np.float64)
            )
            if arr.shape != (m,):
                raise ValueError(f"append column {name!r}: {arr.shape} != ({m},)")
            self._nums[name] = np.concatenate([col, arr])
        unknown = set(new_cats) | (
            set(numeric or {}) - set(self._nums)
        )
        if unknown:
            raise ValueError(f"append: unknown columns {sorted(unknown)}")
        self.n += m
        self._hist_cache.clear()

    # ---- exact mask -------------------------------------------------------

    def compile_mask(self, spec: FilterSpec, n: Optional[int] = None) -> np.ndarray:
        """Evaluate ``spec`` exactly over every row -> ``(n,) bool``."""
        n = self.n if n is None else int(n)
        if n != self.n:
            raise ValueError(f"store has {self.n} rows, index has {n}")
        mask = np.ones(self.n, bool)
        clauses = list(spec.attrs)
        if spec.tenant is not None:
            clauses.append(("tenant", (spec.tenant,)))
        for name, allowed in clauses:
            col = self._cats.get(name)
            if col is None:
                raise FilterCompileError(
                    f"categorical attribute {name!r} not in store "
                    f"(have {sorted(self.columns)})"
                )
            mask &= np.isin(col, np.asarray(allowed, object))
        for name, lo, hi in spec.ranges:
            col = self._nums.get(name)
            if col is None:
                raise FilterCompileError(
                    f"numeric attribute {name!r} not in store "
                    f"(have {sorted(self.columns)})"
                )
            mask &= (col >= lo) & (col <= hi)  # NaN fills fail both
        if spec.id_range is not None:
            lo, hi = spec.id_range
            ids = np.arange(self.n)
            mask &= (ids >= lo) & (ids < hi)
        return mask

    # ---- histogram selectivity -------------------------------------------

    def _cat_hist(self, name: str):
        got = self._hist_cache.get(("cat", name))
        if got is None:
            vals, counts = np.unique(self._cats[name], return_counts=True)
            order = np.argsort(counts)[::-1]
            vals, counts = vals[order], counts[order]
            top = dict(zip(vals[:_CAT_TOP].tolist(), counts[:_CAT_TOP].tolist()))
            tail = int(counts[_CAT_TOP:].sum())
            tail_kinds = max(len(vals) - _CAT_TOP, 1)
            got = (top, tail, tail_kinds)
            self._hist_cache[("cat", name)] = got
        return got

    def _num_hist(self, name: str):
        got = self._hist_cache.get(("num", name))
        if got is None:
            col = self._nums[name]
            finite = col[np.isfinite(col)]
            if finite.size == 0:
                got = (np.zeros(_NUM_BINS), np.linspace(0, 1, _NUM_BINS + 1))
            else:
                got = np.histogram(finite, bins=_NUM_BINS)
            self._hist_cache[("num", name)] = got
        return got

    def estimate_selectivity(self, spec: FilterSpec) -> float:
        """Estimated pass fraction in [0, 1] under clause independence."""
        if self.n == 0:
            return 0.0
        sel = 1.0
        clauses = list(spec.attrs)
        if spec.tenant is not None:
            clauses.append(("tenant", (spec.tenant,)))
        for name, allowed in clauses:
            if name not in self._cats:
                raise FilterCompileError(f"attribute {name!r} not in store")
            top, tail, tail_kinds = self._cat_hist(name)
            hits = 0.0
            for v in allowed:
                if v in top:
                    hits += top[v]
                else:  # unseen-or-tail value: assume a uniform tail share
                    hits += tail / tail_kinds
            sel *= min(hits / self.n, 1.0)
        for name, lo, hi in spec.ranges:
            if name not in self._nums:
                raise FilterCompileError(f"attribute {name!r} not in store")
            counts, edges = self._num_hist(name)
            total = counts.sum()
            if total == 0:
                return 0.0
            # fractional overlap of [lo, hi] with each bin
            bin_lo, bin_hi = edges[:-1], edges[1:]
            width = np.maximum(bin_hi - bin_lo, 1e-300)
            overlap = np.clip(
                (np.minimum(bin_hi, hi) - np.maximum(bin_lo, lo)) / width,
                0.0,
                1.0,
            )
            # point bins (lo == hi inside one bin) still contribute
            if hi == lo:
                overlap = np.where((bin_lo <= lo) & (lo <= bin_hi), 1.0, overlap)
            sel *= float((counts * overlap).sum() / total)
        if spec.id_range is not None:
            lo, hi = spec.id_range
            sel *= max(min(hi, self.n) - max(lo, 0), 0) / self.n
        return float(min(max(sel, 0.0), 1.0))
