"""Figure 3 + §5 validation: FDL Gaussianity and moment-estimate accuracy."""
import jax.numpy as jnp
import numpy as np
from scipy import stats as sps

from repro.core import compute_stats, estimate_fdl
from .common import DATASETS, emit


def run(quick=True, smoke=False):
    for name, gen in DATASETS.items():
        data, queries = gen()
        if smoke:
            data, queries = data[:1000], queries[:24]
        elif quick:
            data, queries = data[:5000], queries[:32]
        vn = data / np.linalg.norm(data, axis=1, keepdims=True)
        stats = compute_stats(jnp.asarray(data), mode="full", normalize=True)
        params = estimate_fdl(stats, jnp.asarray(queries))
        mus, sigmas, kss = [], [], []
        for i in range(min(16, len(queries))):
            qn = queries[i] / np.linalg.norm(queries[i])
            fdl = 1.0 - vn @ qn
            mus.append(abs(float(params.mu[i]) - fdl.mean()) / abs(fdl.mean()))
            sigmas.append(abs(float(params.sigma[i]) - fdl.std()) / fdl.std())
            z = (fdl - fdl.mean()) / fdl.std()
            kss.append(sps.kstest(z, "norm").statistic)
        emit(
            f"fdl.{name}",
            0.0,
            f"mu_relerr={np.mean(mus):.4f} sigma_relerr={np.mean(sigmas):.4f} ks={np.mean(kss):.4f}",
        )


if __name__ == "__main__":
    run()
