"""Poisson-arrival serving trace: continuous-batching scheduler vs barriers.

The serving claim behind the request-lifecycle redesign (ISSUE 4): a
synchronous batch call is a *barrier* — requests arriving while a batch is
in flight wait for the whole batch (including its hardest tier) to finish
before anything runs for them.  The :class:`AdaServeScheduler` admits
arrivals into the next estimation pass immediately and drains each ef tier
independently (pow2 fill or deadline), so an easy request never waits on a
hard tier it does not ride in.

The trace replays one Poisson arrival process over an easy/hard query mix
(same skewed mix as ``bench_router``) through three serving disciplines:

- ``scheduler``   — continuous batching: real-time submit/step/poll loop
                    with a per-request deadline budget,
- ``routed_sync`` — dynamic batching over the synchronous ``route()``
                    barrier: each call serves everything that arrived while
                    the previous call was blocking,
- ``mono``        — the same barrier over the monolithic fused
                    ``adaptive_search`` (batches pow2-padded so the compile
                    cache stays bounded, as a static-shape server would).

All three run a lossless fixed-beam config, so per-query results are
bit-identical (asserted) and the latency comparison is at *exactly* equal
recall.  Before the measured replays, a deterministic warmup compiles every
(tier, pow2-shape) variant any discipline can hit, so no XLA compile lands
inside a trace; the arrival horizon is *load-adaptive* (scaled to the
measured full-batch wall) so the system runs near saturation on any
machine.  Reported: p50/p99 request latency (arrival -> response
materialized), per-terminal-status latency quantiles (merged
:class:`repro.obs.metrics.Histogram` buckets, pooled across arrival seeds),
total distance computations, drain-trigger counts.  Results persist to
``BENCH_sched.json`` at the repo root (``.smoke.json`` in smoke runs).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import RouterConfig, SchedulerConfig, SearchSpec, SpecOverrides
from repro.index import (
    brute_force_topk_chunked,
    build_ada_index,
    prepare_queries,
    recall_at_k,
)
from repro.obs import Histogram
from repro.index.search import resize_state, resume_at_ef
from repro.serve import SearchRequest
from repro.serve.bucketing import pad_shape
from repro.serve.scheduler import replay_trace
from .bench_router import _skewed_queries
from .common import DATASETS, emit

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_sched.json"


def _poisson_arrivals(nq: int, horizon_s: float, seed: int) -> np.ndarray:
    """Arrival times of a Poisson process, normalized to span ``horizon_s``."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0, nq)
    t = np.cumsum(gaps)
    return (t * (horizon_s / t[-1])).astype(np.float64)


def _warm_shapes(idx, router, queries, target, nq):
    """Compile every variant a replay can hit, off the clock: estimation
    passes and per-tier resumes at each pow2 batch shape up to the full
    trace size, plus the monolithic search at the same shapes."""
    min_shape = router.router_cfg.min_shape
    top = pad_shape(nq, min_shape)
    shapes, s = [], min_shape
    while s <= top:
        shapes.append(s)
        s *= 2
    d = queries.shape[1]
    states_by_shape = {}
    for shape in shapes:
        qs = np.resize(queries, (shape, d))
        t_col = np.full((shape, 1), target, np.float32)
        _, states = router.estimate(qs, t_col, num_real=shape)
        jax.block_until_ready(states)
        states_by_shape[shape] = states
        for tier in router.tiers:
            res = resume_at_ef(
                router.graph,
                jnp.asarray(qs),
                resize_state(states, tier.ef),
                jnp.asarray(np.full(shape, router.base_cfg.k, np.int32)),
                tier.cfg,
            )
            jax.block_until_ready(res)
        jax.block_until_ready(idx.query(qs, target).ids)
    # the scheduler's dispatch gathers rows out of an estimation pass of one
    # pow2 shape into a drain of another: warm the (pass shape x drain shape)
    # gather/merge kernel cross product so none compiles mid-trace
    for states in states_by_shape.values():
        for dst in shapes:
            take = jnp.asarray(np.zeros(dst, np.int64))
            part = jax.tree_util.tree_map(lambda a, t_=take: a[t_], states)
            m = jnp.asarray(np.ones(dst, bool))
            merged = jax.tree_util.tree_map(
                lambda pa, aa: jnp.where(
                    m.reshape((dst,) + (1,) * (pa.ndim - 1)), pa, aa
                ),
                part,
                part,
            )
            jax.block_until_ready(merged)


def _replay_scheduler(plan, queries, arrivals, deadline_s):
    """Real-time replay through the continuous-batching lifecycle (the
    canonical ``replay_trace`` loop the streaming drivers also use) — a
    private scheduler session over the streaming plan, so pooled seeds do
    not share queues."""
    sched = plan.new_scheduler()
    requests = [
        SearchRequest(query=q, deadline_s=deadline_s) for q in queries
    ]
    t0 = time.perf_counter()
    responses, latency = replay_trace(sched, requests, arrivals)
    wall = time.perf_counter() - t0
    ids = np.stack([r.ids for r in responses])
    ndist = int(sum(r.ndist for r in responses))
    statuses = [r.status for r in responses]
    return ids, latency, ndist, wall, sched.stats, statuses


def _replay_barrier(batch_fn, queries, arrivals):
    """Dynamic batching over a blocking batch call: each call serves
    everything that arrived while the previous call was in flight."""
    nq = len(queries)
    lat = np.zeros(nq)
    parts = []
    ndist = 0
    i = 0
    t0 = time.perf_counter()
    while i < nq:
        now = time.perf_counter() - t0
        if arrivals[i] > now:
            time.sleep(arrivals[i] - now)
            now = arrivals[i]
        j = int(np.searchsorted(arrivals, now, side="right"))
        j = max(j, i + 1)
        ids_b, ndist_b = batch_fn(queries[i:j])
        done = time.perf_counter() - t0
        lat[i:j] = done - arrivals[i:j]
        parts.append(ids_b)
        ndist += ndist_b
        i = j
    wall = time.perf_counter() - t0
    return np.concatenate(parts), lat, ndist, wall


def _status_latency(hists):
    """Per-status latency quantiles out of merged :class:`repro.obs.metrics.
    Histogram` buckets — bucketed estimates (the trade for mergeability
    across arrival seeds), keyed by terminal status."""
    return {
        status: {
            "p50_ms": None if h.count == 0 else h.p50 * 1e3,
            "p95_ms": None if h.count == 0 else h.p95 * 1e3,
            "p99_ms": None if h.count == 0 else h.p99 * 1e3,
            "count": h.count,
        }
        for status, h in sorted(hists.items())
    }


def _observe_status_latency(hists, statuses, latencies):
    for status, lat in zip(statuses, latencies):
        hists.setdefault(status, Histogram()).observe(float(lat))


def _record(name, lat, ndist, wall, rec, extra=None):
    out = {
        "latency_p50_ms": float(np.percentile(lat, 50) * 1e3),
        "latency_p99_ms": float(np.percentile(lat, 99) * 1e3),
        "latency_mean_ms": float(lat.mean() * 1e3),
        "ndist_total": int(ndist),
        "trace_wall_s": float(wall),
        "recall_at_k": float(rec),
    }
    out.update(extra or {})
    emit(
        f"scheduler.{name}",
        out["latency_mean_ms"] * 1e3,
        f"p50={out['latency_p50_ms']:.1f}ms p99={out['latency_p99_ms']:.1f}ms "
        f"ndist={ndist} recall={rec:.4f}",
    )
    return out


def _overload_sweep(idx, queries, target, fill, w_full, nq):
    """Arrival rate >= 1.2x saturation (the whole trace arrives inside
    ~1/1.2 of the measured full-batch service wall) through a bounded,
    degrade-armed scheduler.  The overload contract is asserted, not just
    measured: every request resolves to a terminal status (zero silent
    deadline misses) and every OK response met its deadline; the
    shed/degrade/partial/timeout split is returned for BENCH_sched.json."""
    from repro.serve import STATUS_OK, TERMINAL_STATUSES

    saturation = 1.2
    # the horizon is *strictly* w_full/saturation (no floor) so the arrival
    # rate really is >= 1.2x the measured service rate on any machine; the
    # deadline is loose enough that early requests can still finish OK, so
    # the trace exercises the whole ladder rather than timing everything out
    deadline_s = max(w_full / 2.0, 0.02)
    horizon = max(w_full, 0.024) / saturation
    max_inflight = max(2 * fill, nq // 4)
    plan = idx.plan(SearchSpec(
        target_recall=target, mode="streaming",
        overrides=SpecOverrides(
            router=RouterConfig(beam_mode="fixed"),
            scheduler=SchedulerConfig(
                fill=fill,
                est_wait_s=deadline_s / 4.0,
                degrade=True,
                max_inflight=max_inflight,
                overload="ticket",
            ),
        ),
    ))
    sched = plan.new_scheduler()
    requests = [
        SearchRequest(query=q, deadline_s=deadline_s) for q in queries
    ]
    arrivals = _poisson_arrivals(nq, horizon, seed=17)
    responses, latency = replay_trace(sched, requests, arrivals)
    assert len(responses) == nq, "a request was dropped under overload"
    statuses = [r.status for r in responses]
    assert all(
        s in TERMINAL_STATUSES for s in statuses
    ), "non-terminal response under overload"
    for r in responses:
        if r.status == STATUS_OK and r.ticket.deadline_t is not None:
            assert r.stats.done_t <= r.ticket.deadline_t, (
                "silent deadline miss: OK response past its deadline"
            )
    counts = {s: statuses.count(s) for s in TERMINAL_STATUSES}
    served = [r for r in responses if r.status == STATUS_OK]
    hists = {}
    _observe_status_latency(hists, statuses, latency)
    out = {
        "saturation_factor": saturation,
        "horizon_s": float(horizon),
        "deadline_s": float(deadline_s),
        "max_inflight": int(max_inflight),
        "counts": counts,
        "demotions": int(sched.stats.demotions),
        "silent_deadline_misses": 0,  # asserted above
        "latency_p50_ms": float(np.percentile(latency, 50) * 1e3),
        "latency_p99_ms": float(np.percentile(latency, 99) * 1e3),
        "latency_by_status": _status_latency(hists),
        "ok_deadline_hit_rate": len(served) / nq,
    }
    for s in TERMINAL_STATUSES:
        out[f"{s}_rate"] = counts[s] / nq
    emit(
        "scheduler.overload", 0.0,
        f"{saturation}x saturation: ok={counts['ok']} "
        f"degraded={counts['degraded']} partial={counts['partial']} "
        f"rejected={counts['rejected']} timed_out={counts['timed_out']} "
        f"(all terminal, 0 silent misses)",
    )
    return out


def _recall_now(idx, queries, k):
    """Exact recall against ground truth over the *currently alive* rows —
    dead rows leave the reference set, inserted rows join it."""
    alive = np.flatnonzero(
        np.asarray(idx.host_index.alive[: idx.host_index.n])
    )
    qp = prepare_queries(jnp.asarray(queries), "cos_dist")
    _, gt_sub = brute_force_topk_chunked(qp, idx.raw_data[alive], k=k)
    gt = jnp.asarray(alive[np.asarray(gt_sub)])
    res = idx.query(queries)
    return float(np.asarray(recall_at_k(res.ids, gt)).mean())


def _churn_trace(idx, extra, queries, plan, horizon, smoke):
    """Sustained-churn acceptance trace (ISSUE 8): one Poisson arrival
    process of queries *interleaved with* insert/delete mutations, driven
    through a live streaming-plan scheduler session.  The robustness
    contract is asserted, not just measured: zero :class:`StalePlanError`
    escapes the mutation seam, every ticket reaches exactly one terminal
    status, and every response's epoch stamp lies inside the version span
    the trace actually published.  Folds in ``bench_updates``' stale-vs-
    incremental contrast (Tables 4-7): post-churn recall is evaluated once
    with the incrementally maintained stats/table and once with the
    pre-churn (stale) snapshots swapped back in."""
    from repro.serve import TERMINAL_STATUSES, StalePlanError

    nq, k = len(queries), idx.k
    n_events = 4 if smoke else 8
    ins_chunk = max(4, idx.host_index.n // 100)
    del_chunk = max(2, idx.host_index.n // 200)
    rng = np.random.default_rng(29)
    ev_times = np.sort(rng.uniform(0.1, 0.9, n_events)) * horizon
    arrivals = _poisson_arrivals(nq, horizon, seed=21)

    v0 = idx._graph_version
    stale_stats, stale_table = idx.stats, idx.table
    rec_pre = _recall_now(idx, queries, k)

    sched = plan.new_scheduler()
    order, arrive, got, lat = [], {}, {}, {}
    mut_walls = []
    rows_ins = rows_del = ins_ptr = 0

    def harvest(block=False):
        pend = [u for u in order if u not in got]
        if not pend:
            return 0
        res = sched.poll(block=block, uids=pend)
        for r in res:
            got[r.ticket.uid] = r
            lat[r.ticket.uid] = time.perf_counter() - t0 - arrive[r.ticket.uid]
        return len(res)

    qi = ei = 0
    t0 = time.perf_counter()
    try:
        while qi < nq or ei < n_events:
            now = time.perf_counter() - t0
            while qi < nq and arrivals[qi] <= now:
                tk = sched.submit(SearchRequest(query=queries[qi]))
                arrive[tk.uid] = arrivals[qi]
                order.append(tk.uid)
                qi += 1
            while ei < n_events and ev_times[ei] <= now:
                # the ef table refreshes only on the final event (periodic
                # recalibration); intermediate events keep the trace tight
                refresh = (ei == n_events - 1) and not smoke
                m0 = time.perf_counter()
                if ei % 2 == 0:
                    rows = extra[ins_ptr : ins_ptr + ins_chunk]
                    ins_ptr += len(rows)
                    idx.insert(rows, refresh_table=refresh)
                    rows_ins += len(rows)
                else:
                    alive = np.flatnonzero(
                        np.asarray(idx.host_index.alive[: idx.host_index.n])
                    )
                    dead = rng.choice(alive, size=del_chunk, replace=False)
                    idx.delete(dead, refresh_table=refresh)
                    rows_del += len(dead)
                mut_walls.append(time.perf_counter() - m0)
                ei += 1
            progressed = harvest()
            sched.step()
            progressed += harvest()
            if qi < nq and not progressed:
                gap = arrivals[qi] - (time.perf_counter() - t0)
                if gap > 0:
                    time.sleep(min(gap, 1e-3))
        sched.flush()
        harvest(block=True)
    except StalePlanError as e:
        raise AssertionError(
            f"StalePlanError escaped the mutation seam mid-trace: {e}"
        ) from e
    wall = time.perf_counter() - t0

    assert len(got) == nq, "a ticket was lost under churn"
    statuses = [got[u].status for u in order]
    assert all(s in TERMINAL_STATUSES for s in statuses)
    v1 = idx._graph_version
    assert v1 == v0 + n_events, "a mutation did not publish an epoch"
    epochs = [got[u].stats.epoch for u in order]
    assert all(v0 <= e <= v1 for e in epochs), "epoch stamp outside trace"
    assert sched.stats.mutations == n_events, "a mutation was not absorbed"

    rec_incr = _recall_now(idx, queries, k)
    incr_stats, incr_table = idx.stats, idx.table
    idx.stats, idx.table = stale_stats, stale_table
    rec_stale = _recall_now(idx, queries, k)
    idx.stats, idx.table = incr_stats, incr_table

    lat_arr = np.asarray([lat[u] for u in order])
    counts = {s: statuses.count(s) for s in TERMINAL_STATUSES}
    out = {
        "events": {
            "total": n_events,
            "rows_inserted": int(rows_ins),
            "rows_deleted": int(rows_del),
            "wall_s_mean": float(np.mean(mut_walls)),
            "wall_s_max": float(np.max(mut_walls)),
        },
        "latency_p50_ms": float(np.percentile(lat_arr, 50) * 1e3),
        "latency_p99_ms": float(np.percentile(lat_arr, 99) * 1e3),
        "counts": counts,
        "stale_plan_errors": 0,  # asserted: none escaped the seam
        "lost_tickets": 0,       # asserted: every ticket turned terminal
        "mutations_absorbed": int(sched.stats.mutations),
        "fenced_requests": int(sched.stats.fenced_requests),
        "epoch_span": [int(v0), int(v1)],
        "recall_pre_churn": rec_pre,
        "recall_post_incremental": rec_incr,
        "recall_post_stale": rec_stale,
        "trace_wall_s": float(wall),
    }
    emit(
        "scheduler.churn", 0.0,
        f"{n_events} mutations absorbed mid-trace: p99="
        f"{out['latency_p99_ms']:.1f}ms fenced={out['fenced_requests']} "
        f"recall incr={rec_incr:.4f} stale={rec_stale:.4f} "
        f"(0 stale-plan errors, 0 lost tickets)",
    )
    return out


def run(k=10, target=0.95, quick=True, smoke=False):
    # the non-smoke workload must match bench_router's full scale: only at
    # n ~ 6000 does the estimation table produce the heavy ef tail (a few %
    # of queries at the top tier) whose convoys the scheduler exists to break
    n, nq = (1000, 48) if smoke else (6000, 256)
    fill = 8
    full, _ = DATASETS["zipf_cluster"]()
    data = full[:n]
    queries, easy_mask = _skewed_queries(data, nq, easy_frac=0.75, seed=7)
    qp = prepare_queries(jnp.asarray(queries), "cos_dist")
    _, gt = brute_force_topk_chunked(qp, data, k=k)
    gt = jnp.asarray(gt)

    idx = build_ada_index(
        data, k=k, target_recall=target, m=8,
        ef_construction=60 if smoke else 100,
        ef_cap=160 if smoke else 400,
        num_samples=32 if smoke else 128,
    )
    # lossless fixed-beam config: all three disciplines are bit-identical per
    # query, so latencies compare at exactly equal recall
    fixed = SpecOverrides(router=RouterConfig(beam_mode="fixed"))
    routed_plan = idx.plan(SearchSpec(
        target_recall=target, mode="routed", overrides=fixed
    ))
    router = routed_plan.router

    _warm_shapes(idx, router, queries, target, nq)
    # load-adaptive horizon: arrivals span ~0.9x the warm full-batch routed
    # wall, so the trace runs near saturation (barriers convoy, the scheduler
    # has standing tier queues) on any machine
    t0 = time.perf_counter()
    routed_plan.search(queries)
    w_full = time.perf_counter() - t0
    horizon = max(0.9 * w_full, 0.25)
    # per-request latency budget: a small multiple of the per-dispatch service
    # time, so partial buckets drain quickly instead of idling toward fill
    deadline_s = max(w_full / 12.0, 0.004)
    # the streaming discipline under test: same routing policy, lifecycle
    # execution with a deadline-derived drain policy
    stream_plan = idx.plan(SearchSpec(
        target_recall=target, mode="streaming",
        overrides=SpecOverrides(
            router=RouterConfig(beam_mode="fixed"),
            scheduler=SchedulerConfig(fill=fill, est_wait_s=deadline_s / 2.0),
        ),
    ))

    def routed_batch(qs):
        res, st = routed_plan.search(qs, with_stats=True)
        return res.ids, st.ndist_total

    def mono_batch(qs):
        b = len(qs)
        shape = pad_shape(b, router.router_cfg.min_shape)
        q_pad = np.concatenate([qs, np.repeat(qs[:1], shape - b, axis=0)])
        res = idx.query(q_pad, target)
        ids = np.asarray(res.ids)
        return ids[:b], int(np.asarray(res.ndist)[:b].sum())

    out = {
        "workload": {
            "n": n, "nq": nq, "k": k, "easy_frac": float(easy_mask.mean()),
            "horizon_s": horizon, "deadline_s": deadline_s, "fill": fill,
            "ef_cap": idx.search_cfg.ef_cap,
        }
    }

    # pool latencies over several arrival seeds: a single short trace is
    # noisy (one unlucky hard-drain placement moves p99 by tens of ms).
    # ndist and ids are deterministic per request (seed-independent), so the
    # per-trace value is asserted consistent and reported once; walls are
    # averaged so every reported field describes one trace's workload.
    seeds = (11, 12, 13)
    lat_s_all, lat_r_all, lat_m_all = [], [], []
    wall_s = wall_r = wall_m = 0.0
    nd_s = nd_r = nd_m = None
    drains = {"fill": 0, "deadline": 0, "flush": 0, "idle": 0}
    est_passes = est_pad = 0
    status_hists = {}
    for seed in seeds:
        arrivals = _poisson_arrivals(nq, horizon, seed=seed)
        ids_s, lat_s, nd_s_i, w_s, sstats, statuses = _replay_scheduler(
            stream_plan, queries, arrivals, deadline_s
        )
        _observe_status_latency(status_hists, statuses, lat_s)
        ids_r, lat_r, nd_r_i, w_r = _replay_barrier(routed_batch, queries, arrivals)
        ids_m, lat_m, nd_m_i, w_m = _replay_barrier(mono_batch, queries, arrivals)
        # equal-recall guarantee: lossless config -> bit-identical ids
        assert np.array_equal(ids_s, ids_m), "scheduler diverged from monolithic"
        assert np.array_equal(ids_r, ids_m), "routed barrier diverged from mono"
        assert nd_s is None or (nd_s, nd_r, nd_m) == (nd_s_i, nd_r_i, nd_m_i)
        nd_s, nd_r, nd_m = nd_s_i, nd_r_i, nd_m_i
        lat_s_all.append(lat_s)
        lat_r_all.append(lat_r)
        lat_m_all.append(lat_m)
        wall_s += w_s / len(seeds)
        wall_r += w_r / len(seeds)
        wall_m += w_m / len(seeds)
        drains["fill"] += sstats.fill_drains
        drains["deadline"] += sstats.deadline_drains
        drains["flush"] += sstats.flush_drains
        drains["idle"] += sstats.idle_drains
        est_passes += sstats.est_passes
        est_pad += sstats.est_pad_ndist
    lat_s, lat_r, lat_m = map(np.concatenate, (lat_s_all, lat_r_all, lat_m_all))

    def rec(ids):
        return float(np.asarray(recall_at_k(jnp.asarray(ids), gt)).mean())

    out["scheduler"] = _record(
        "continuous", lat_s, nd_s, wall_s, rec(ids_s),
        {
            "fill_drains": drains["fill"],
            "deadline_drains": drains["deadline"],
            "flush_drains": drains["flush"],
            "idle_drains": drains["idle"],
            "est_passes": est_passes,
            "est_pad_ndist": est_pad,
            "latency_by_status": _status_latency(status_hists),
        },
    )
    out["routed_sync"] = _record("routed_sync", lat_r, nd_r, wall_r, rec(ids_r))
    out["mono"] = _record("mono_sync", lat_m, nd_m, wall_m, rec(ids_m))

    p99_gain = out["routed_sync"]["latency_p99_ms"] / max(
        out["scheduler"]["latency_p99_ms"], 1e-9
    )
    p50_gain = out["routed_sync"]["latency_p50_ms"] / max(
        out["scheduler"]["latency_p50_ms"], 1e-9
    )
    out["comparison"] = {
        "p99_speedup_vs_routed_sync": p99_gain,
        "p50_speedup_vs_routed_sync": p50_gain,
        "p99_speedup_vs_mono": out["mono"]["latency_p99_ms"] / max(
            out["scheduler"]["latency_p99_ms"], 1e-9
        ),
        "equal_recall": True,  # asserted bit-identical above
    }
    emit(
        "scheduler.vs_barriers", 0.0,
        f"p99_speedup={p99_gain:.2f}x p50_speedup={p50_gain:.2f}x "
        f"(vs routed_sync, bit-identical results)",
    )

    # overload discipline: same queries, arrivals compressed past saturation,
    # through the bounded + degrade-armed lifecycle (ISSUE 6 acceptance)
    out["overload"] = _overload_sweep(idx, queries, target, fill, w_full, nq)

    # sustained churn: queries + inserts/deletes on one timeline through the
    # live streaming plan (ISSUE 8 acceptance — runs last: it mutates idx)
    out["churn"] = _churn_trace(
        idx, full[n:], queries, stream_plan, horizon, smoke
    )

    out["meta"] = {"quick": bool(quick), "smoke": bool(smoke), "target_recall": float(target)}
    path = BENCH_JSON.with_suffix(".smoke.json") if smoke else BENCH_JSON
    if not smoke and quick and path.exists():
        try:
            prev_full = json.loads(path.read_text()).get("meta", {}).get("quick") is False
        except (ValueError, OSError):
            prev_full = False
        if prev_full:
            path = BENCH_JSON.with_suffix(".quick.json")
    path.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    emit("scheduler.bench_json", 0.0, f"wrote {path.name}")


if __name__ == "__main__":
    run()
