"""Shared benchmark infrastructure: dataset generators matching the paper's
workload statistics (§7.1), timing, and CSV reporting.

The paper's six real datasets are not redistributable offline; generators
reproduce their *distributional character* at a documented scale factor:

- ``glove_like``    : anisotropic low-d word-style vectors with frequency-skew
                      hubs (norm + direction concentration).
- ``openai_like``   : high-d (1536) normalized embeddings clustered on a cone
                      (ada-002-style anisotropy).
- ``uniform_cluster`` / ``zipf_cluster``: the paper's own synthetic suites
                      (Gaussian clusters; equal vs Zipf(1) sizes), downscaled.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import numpy as np

SCALE_NOTE = "scaled: n~=1e4 vs paper 1e7 (factor ~1e3); trends, not absolutes"


def glove_like(n=8000, d=100, nq=256, seed=0):
    rng = np.random.default_rng(seed)
    nc = 64
    freq = 1.0 / np.arange(1, nc + 1) ** 1.1
    freq /= freq.sum()
    centers = rng.normal(0, 1, (nc, d))
    # frequency-correlated norms: frequent words have larger norms (hubness)
    norms = 1.0 + 3.0 * freq[:, None] / freq.max()
    assign = rng.choice(nc, size=n, p=freq)
    data = centers[assign] * norms[assign] + 0.45 * rng.normal(0, 1, (n, d))
    qa = rng.choice(nc, size=nq, p=freq)
    queries = centers[qa] * norms[qa] + 0.45 * rng.normal(0, 1, (nq, d))
    return data.astype(np.float32), queries.astype(np.float32)


def openai_like(n=6000, d=512, nq=192, seed=1):
    rng = np.random.default_rng(seed)
    nc = 48
    # anisotropic cone: shared dominant direction + cluster offsets
    dom = rng.normal(0, 1, (1, d))
    dom /= np.linalg.norm(dom)
    centers = 2.0 * dom + 0.7 * rng.normal(0, 1, (nc, d))
    assign = rng.integers(0, nc, n)
    data = centers[assign] + 0.25 * rng.normal(0, 1, (n, d))
    data /= np.linalg.norm(data, axis=1, keepdims=True)
    qa = rng.integers(0, nc, nq)
    queries = centers[qa] + 0.25 * rng.normal(0, 1, (nq, d))
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    return data.astype(np.float32), queries.astype(np.float32)


def _cluster(n, d, nq, seed, zipf: bool):
    rng = np.random.default_rng(seed)
    nc = 100
    w = (1.0 / np.arange(1, nc + 1)) if zipf else np.ones(nc)
    w = w / w.sum()
    centers = rng.normal(0, 1, (nc, d))
    assign = rng.choice(nc, size=n, p=w)
    data = centers[assign] + 0.3 * rng.normal(0, 1, (n, d))
    qa = rng.choice(nc, size=nq, p=w)
    queries = centers[qa] + 0.3 * rng.normal(0, 1, (nq, d))
    return data.astype(np.float32), queries.astype(np.float32)


def uniform_cluster(n=8000, d=100, nq=256, seed=2):
    return _cluster(n, d, nq, seed, zipf=False)


def zipf_cluster(n=8000, d=100, nq=256, seed=3):
    return _cluster(n, d, nq, seed, zipf=True)


DATASETS: Dict[str, Callable[[], Tuple[np.ndarray, np.ndarray]]] = {
    "glove_like": glove_like,
    "openai_like": openai_like,
    "uniform_cluster": uniform_cluster,
    "zipf_cluster": zipf_cluster,
}


def timed(fn, *args, repeats=1, **kwargs):
    fn(*args, **kwargs)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


_rows = []


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV contract: name,us_per_call,derived."""
    _rows.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def recall_stats(rec: np.ndarray) -> str:
    return (
        f"avg={rec.mean():.3f} p5={np.percentile(rec, 5):.3f} "
        f"p1={np.percentile(rec, 1):.3f}"
    )
