"""Tables 4-7: incremental insertion/deletion — stale vs incremental vs recomputed."""
import jax.numpy as jnp
import numpy as np

from repro.index import brute_force_topk_chunked, build_ada_index, prepare_queries, recall_at_k
from .common import DATASETS, emit, recall_stats


def _eval(idx, queries, data_now, k):
    qp = prepare_queries(jnp.asarray(queries), "cos_dist")
    _, gt = brute_force_topk_chunked(qp, data_now, k=k)
    res = idx.query(queries)
    rec = np.asarray(recall_at_k(res.ids, jnp.asarray(gt)))
    return rec, np.asarray(res.ndist).mean()


def run(dataset="zipf_cluster", k=10, quick=True, smoke=False):
    data, queries = DATASETS[dataset]()
    if smoke:
        data, queries = data[:1000], queries[:24]
    elif quick:
        data, queries = data[:6000], queries[:128]
    ns = 16 if smoke else 96
    cap = 120 if smoke else 400
    for frac in (0.1,) if smoke else (0.1, 0.5):
        n_upd = int(len(data) * frac / (1 + frac))
        base, extra = data[:-n_upd], data[-n_upd:]

        # ---- insertion ----
        idx = build_ada_index(base, k=k, target_recall=0.95, m=8,
                              ef_construction=80, ef_cap=cap, num_samples=ns)
        stale_stats = idx.stats  # snapshot for "stale" variant
        stale_table = idx.table
        # smoke: skip the ef-table refresh (each rebuild probes many subset
        # shapes -> XLA recompiles dominate the toy run); stats + incremental
        # GT plumbing is still exercised
        t = idx.insert(extra, refresh_table=not smoke)  # incremental (§6.3)
        emit(f"updates.insert.bs{int(frac*100)}.time", t["stats_s"] * 1e6,
             f"stats={t['stats_s']:.3f}s samp={t['sample_s']:.3f}s table={t['ef_table_s']:.3f}s "
             f"index={t['index_s']:.1f}s")
        rec, nd = _eval(idx, queries, data, k)
        emit(f"updates.insert.bs{int(frac*100)}.incr", 0.0, f"{recall_stats(rec)} ndist={nd:.0f}")
        # stale: old stats/table on the updated graph
        incr_stats, incr_table = idx.stats, idx.table
        idx.stats, idx.table = stale_stats, stale_table
        rec, nd = _eval(idx, queries, data, k)
        emit(f"updates.insert.bs{int(frac*100)}.stale", 0.0, f"{recall_stats(rec)} ndist={nd:.0f}")
        idx.stats, idx.table = incr_stats, incr_table

        # recomputed from scratch (skipped in smoke: full rebuild, no new code path)
        if not smoke:
            reco = build_ada_index(data, k=k, target_recall=0.95, m=8,
                                   ef_construction=80, ef_cap=cap, num_samples=ns)
            rec, nd = _eval(reco, queries, data, k)
            emit(f"updates.insert.bs{int(frac*100)}.reco", 0.0, f"{recall_stats(rec)} ndist={nd:.0f}")

        # ---- deletion ----
        idx2 = build_ada_index(data, k=k, target_recall=0.95, m=8,
                               ef_construction=80, ef_cap=cap, num_samples=ns)
        dead = np.arange(len(data) - n_upd, len(data))
        t = idx2.delete(dead, refresh_table=not smoke)
        emit(f"updates.delete.bs{int(frac*100)}.time", t["stats_s"] * 1e6,
             f"stats={t['stats_s']:.3f}s samp={t['sample_s']:.3f}s table={t['ef_table_s']:.3f}s")
        rec, nd = _eval(idx2, queries, base, k)
        emit(f"updates.delete.bs{int(frac*100)}.incr", 0.0, f"{recall_stats(rec)} ndist={nd:.0f}")


if __name__ == "__main__":
    run()
