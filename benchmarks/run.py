"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--only MODULE]
                                            [--beam B ...]

``--smoke`` runs every registered benchmark at toy sizes (each module's
``smoke=True`` branch slices its workload down and skips learned baselines)
so kernel-plumbing regressions surface in well under a minute; any exception
exits non-zero, making it usable as a CI gate.
"""
import argparse
import inspect
import sys
import time
import traceback


def planner_gate() -> None:
    """Smoke gate for the declarative facade: lower one spec per mode on a
    toy index, print each ``plan.explain()``, and assert the planner made
    the expected CPU decisions (loop strategy and kernel dispatch resolve to
    interpret/oracle off-TPU).  A planner regression fails the smoke run."""
    import numpy as np

    from repro.api import SearchSpec
    from repro.index import build_ada_index

    rng = np.random.default_rng(0)
    centers = rng.normal(0, 1, (8, 24))
    data = (centers[rng.integers(0, 8, 600)]
            + 0.3 * rng.normal(0, 1, (600, 24))).astype(np.float32)
    idx = build_ada_index(data, k=5, target_recall=0.9, m=6,
                          ef_construction=40, ef_cap=64, num_samples=16)
    on_tpu = __import__("jax").default_backend() == "tpu"

    specs = {
        "oneshot": SearchSpec(k=5, target_recall=0.9),
        "routed": SearchSpec(k=5, target_recall=0.9, mode="routed"),
        "streaming": SearchSpec(k=5, target_recall=0.9, mode="streaming",
                                deadline_ms=50),
        "interpret": SearchSpec(k=5, target_recall=0.9, backend="interpret"),
    }
    for name, spec in specs.items():
        plan = idx.plan(spec)
        print(f"--- planner_gate[{name}] " + "-" * 40, file=sys.stderr)
        print(plan.explain(fmt="text"), file=sys.stderr)
        d = plan.explain()
        assert SearchSpec.from_dict(d["spec"]) == spec, "explain round-trip"
        if not on_tpu:
            expect = "interpret" if name == "interpret" else "oracle"
            assert d["backend"]["resolved"] == expect, (
                f"{name}: backend {d['backend']['resolved']} != {expect}"
            )
        expect_loop = "vmap" if name in ("oneshot", "interpret") else "batch_hoisted"
        assert plan.loop == expect_loop, (
            f"{name}: loop {plan.loop} != {expect_loop}"
        )
        assert d["tiers"][-1]["ef"] == d["search"]["ef_cap"], "ladder catch-all"
    # equal specs must share one plan-cache entry (and its compiled executors)
    assert idx.plan(SearchSpec(k=5, target_recall=0.9)) is idx.plan(
        SearchSpec(k=5, target_recall=0.9)
    ), "plan cache missed on equal specs"
    print("planner_gate,0,ok")


def chaos_gate() -> None:
    """Smoke gate for the fault-injection harness: one trace on a toy index
    with kernel failures, NaN corruption, and injected latency all armed.
    Asserts the overload/robustness contract — every request resolves to a
    terminal status, exactly the corrupted rows are REJECTED, and the kernel
    fault is absorbed by the retry/fallback ladder (recorded in stats)."""
    import numpy as np

    from repro.index import build_ada_index
    from repro.plan import probe_interpret
    from repro.serve import (
        STATUS_REJECTED,
        TERMINAL_STATUSES,
        AdaServeScheduler,
        FaultInjector,
        FaultPlan,
        SearchRequest,
    )

    rng = np.random.default_rng(1)
    centers = rng.normal(0, 1, (8, 24))
    data = (centers[rng.integers(0, 8, 600)]
            + 0.3 * rng.normal(0, 1, (600, 24))).astype(np.float32)
    use_kernel = probe_interpret()
    idx = build_ada_index(data, k=5, target_recall=0.9, m=6,
                          ef_construction=40, ef_cap=64, num_samples=16,
                          use_distance_kernel=use_kernel)
    nan_uids = (2, 5)
    chaos = FaultInjector(FaultPlan(
        fail_dispatches=(0,), fail_attempts=1,
        dispatch_latency_s=0.002, nan_uids=nan_uids,
    ))
    sched = AdaServeScheduler(
        idx.router(), chaos=chaos,
        default_target_recall=idx.target_recall,
        version_probe=lambda: idx._graph_version,
    )
    queries = data[rng.integers(0, len(data), 8)]
    tickets = [sched.submit(SearchRequest(query=q)) for q in queries]
    responses = sched.drain()
    assert len(responses) == len(tickets), "request dropped under faults"
    by_uid = {r.ticket.uid: r for r in responses}
    statuses = [by_uid[t.uid].status for t in tickets]
    assert all(s in TERMINAL_STATUSES for s in statuses), statuses
    rejected = {t.uid for t in tickets
                if by_uid[t.uid].status == STATUS_REJECTED}
    assert rejected == set(nan_uids), (
        f"NaN isolation: rejected {rejected} != corrupted {set(nan_uids)}"
    )
    assert chaos.faults_raised >= 1, "injected kernel fault never fired"
    absorbed = sched.stats.kernel_retries + sched.stats.kernel_fallbacks
    assert absorbed >= 1, "kernel fault not recorded as retry/fallback"
    healthy = [by_uid[t.uid] for t in tickets if t.uid not in rejected]
    assert all((r.ids >= 0).any() for r in healthy), "healthy rows unserved"
    print(f"chaos_gate,0,ok statuses={statuses} retries="
          f"{sched.stats.kernel_retries} fallbacks={sched.stats.kernel_fallbacks}")


def obs_gate() -> None:
    """Smoke gate for the observability layer: a short streaming trace on a
    toy index with tracing and auditing both armed.  Asserts the span
    contract — every ticket owns exactly one complete span tree whose
    terminal status matches its response — that the recall auditor actually
    sampled work, and that the Chrome trace export round-trips through
    ``json.load``."""
    import json
    import os
    import tempfile

    import numpy as np

    from repro.index import build_ada_index
    from repro.serve import AdaServeScheduler, SchedulerConfig, SearchRequest

    rng = np.random.default_rng(2)
    centers = rng.normal(0, 1, (8, 24))
    data = (centers[rng.integers(0, 8, 600)]
            + 0.3 * rng.normal(0, 1, (600, 24))).astype(np.float32)
    idx = build_ada_index(data, k=5, target_recall=0.9, m=6,
                          ef_construction=40, ef_cap=64, num_samples=16)
    sched = AdaServeScheduler(
        idx.router(),
        SchedulerConfig(fill=4, trace=True, audit_fraction=1.0),
        default_target_recall=idx.target_recall,
        version_probe=lambda: idx._graph_version,
    )
    queries = data[rng.integers(0, len(data), 10)]
    tickets = [sched.submit(SearchRequest(query=q)) for q in queries]
    responses = sched.drain()
    assert len(responses) == len(tickets), "request dropped"
    by_uid = {r.ticket.uid: r for r in responses}
    for t in tickets:
        # raises on missing/unclosed spans or a missing/duplicate terminal
        status = sched.tracer.request_complete(t.uid)
        assert status == by_uid[t.uid].status, (
            f"uid {t.uid}: span terminal {status} != "
            f"response {by_uid[t.uid].status}"
        )
    assert sched.auditor.audited >= 1, "auditor never ran a reference check"
    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        sched.tracer.export(path)
        with open(path) as f:
            trace = json.load(f)
        assert trace["traceEvents"], "empty Chrome trace export"
    finally:
        os.unlink(path)
    aud = sched.auditor.as_dict()
    print(f"obs_gate,0,ok spans={len(sched.tracer.spans())} "
          f"audited={aud['audited']} alerts={len(aud['alerts'])} "
          f"trace_events={len(trace['traceEvents'])}")


def churn_gate() -> None:
    """Smoke gate for the mutation seam (ISSUE 8): on a toy index, mutate
    between submit and poll through a registered scheduler and a held plan.
    Asserts the churn contract — zero ``StalePlanError`` on the registered
    path, every ticket terminal, in-flight work stamped with the epoch it
    was admitted under, empty mutations version-preserving, and the strict
    opt-in (``on_mutation='strict'``) still refusing to survive."""
    import numpy as np

    from repro.api import SearchSpec
    from repro.index import IndexMutationError, build_ada_index
    from repro.serve import TERMINAL_STATUSES, SearchRequest, StalePlanError

    rng = np.random.default_rng(3)
    centers = rng.normal(0, 1, (8, 24))
    data = (centers[rng.integers(0, 8, 650)]
            + 0.3 * rng.normal(0, 1, (650, 24))).astype(np.float32)
    idx = build_ada_index(data[:600], k=5, target_recall=0.9, m=6,
                          ef_construction=40, ef_cap=64, num_samples=16)
    v0 = idx._graph_version
    # empty mutations are version-preserving no-ops
    assert idx.insert(np.zeros((0, 24), np.float32)).get("noop") is True
    assert idx.delete(np.asarray([], np.int64)).get("noop") is True
    assert idx._graph_version == v0, "empty mutation bumped the version"
    # mutate between submit and poll on the registered scheduler: absorbed
    sched = idx.scheduler()
    queries = data[rng.integers(0, 600, 6)]
    pre = [sched.submit(SearchRequest(query=q)) for q in queries[:3]]
    idx.insert(data[600:625])
    idx.delete(np.asarray([3, 11]))
    post = [sched.submit(SearchRequest(query=q)) for q in queries[3:]]
    responses = sched.drain()
    by_uid = {r.ticket.uid: r for r in responses}
    assert sorted(by_uid) == sorted(t.uid for t in pre + post), "ticket lost"
    assert all(r.status in TERMINAL_STATUSES for r in responses)
    assert all(by_uid[t.uid].stats.epoch == v0 for t in pre), (
        "fenced work must carry its admission epoch"
    )
    assert all(by_uid[t.uid].stats.epoch == v0 + 2 for t in post)
    assert sched.stats.mutations == 2, "a mutation was not absorbed"
    for t in post:  # nothing dispatched post-mutation surfaces a dead row
        assert not np.isin(np.asarray(by_uid[t.uid].ids), [3, 11]).any()
    # delete validation is typed and atomic
    for bad in ([10**6], [3]):  # out of range; already tombstoned
        try:
            idx.delete(np.asarray(bad))
            raise AssertionError(f"delete({bad}) did not raise")
        except IndexMutationError:
            pass
    # the strict opt-in still refuses to survive a mutation
    strict = idx.plan(SearchSpec(on_mutation="strict"))
    strict.search(queries[:2])
    idx.insert(data[625:630])
    try:
        strict.search(queries[:2])
        raise AssertionError("strict plan survived a mutation")
    except StalePlanError:
        pass
    assert not idx.plan(SearchSpec()).stale, "default plan not revalidated"
    print(f"churn_gate,0,ok epochs={v0}->{idx._graph_version} "
          f"fenced={sched.stats.fenced_requests} "
          f"retired={idx.epochs.retired_versions}")


def quant_gate() -> None:
    """Smoke gate for the quantized estimation tier (PR 9): lower fp32 and
    int8 plans over one toy index and assert the tier's contract — measured
    recall within 0.005 of fp32 (the fp32 re-rank recovers the traversal's
    quantization error), the estimation pass pays >= 3x fewer traversal
    bytes, and ``plan.explain()`` reports the resolved precision, panel
    dtype, and resident-byte split."""
    import numpy as np

    import jax.numpy as jnp

    from repro.api import SearchSpec
    from repro.index import (
        brute_force_topk_chunked,
        build_ada_index,
        prepare_queries,
        recall_at_k,
    )
    from repro.quant import bytes_per_distance

    rng = np.random.default_rng(4)
    centers = rng.normal(0, 1, (8, 24))
    data = (centers[rng.integers(0, 8, 600)]
            + 0.3 * rng.normal(0, 1, (600, 24))).astype(np.float32)
    idx = build_ada_index(data, k=5, target_recall=0.9, m=6,
                          ef_construction=40, ef_cap=64, num_samples=16)
    queries = data[rng.integers(0, 600, 32)] + 0.05 * rng.normal(
        0, 1, (32, 24)).astype(np.float32)
    _, gt = brute_force_topk_chunked(
        prepare_queries(jnp.asarray(queries), "cos_dist"), data, k=5
    )
    plan_f = idx.plan(SearchSpec(k=5, target_recall=0.9))
    plan_q = idx.plan(SearchSpec(k=5, target_recall=0.9, precision="int8"))
    res_f = plan_f.search(queries)
    res_q = plan_q.search(queries)
    rec_f = float(np.asarray(recall_at_k(jnp.asarray(res_f.ids),
                                         jnp.asarray(gt))).mean())
    rec_q = float(np.asarray(recall_at_k(jnp.asarray(res_q.ids),
                                         jnp.asarray(gt))).mean())
    assert rec_q >= rec_f - 0.005, (
        f"quantized recall {rec_q:.4f} vs fp32 {rec_f:.4f}: re-rank failed "
        "to recover the quantization error"
    )
    assert int(np.asarray(res_q.ndist_q).sum()) > 0, "int8 plan never quantized"
    assert int(np.asarray(res_f.ndist_q).sum()) == 0, "fp32 plan quantized"

    # explain() must attribute the decision
    d = plan_q.explain()["precision"]
    assert d["resolved"] == "int8" and d["panel_dtype"] == "int8", d
    assert d["rerank_depth"] > 0, "re-rank depth not reported"
    assert 0 < d["resident_bytes"]["quantized"] < d["resident_bytes"]["fp32"]

    # estimation pass: traversal bytes down >= 3x (int8 rows are 4x smaller;
    # the phase-A collection is fully quantized, so the ratio sits near 4)
    r_f = idx.plan(SearchSpec(k=5, target_recall=0.9, mode="routed")).router
    r_q = idx.plan(SearchSpec(k=5, target_recall=0.9, mode="routed",
                              precision="int8")).router
    _, st_f = r_f.estimate(queries, 0.9)
    _, st_q = r_q.estimate(queries, 0.9)
    dim = data.shape[1]
    nd_f = int(np.asarray(st_f.ndist).sum())
    nd_q = int(np.asarray(st_q.ndist).sum())
    ndq = int(np.asarray(st_q.ndist_q).sum())
    bytes_f = nd_f * bytes_per_distance(dim, "fp32")
    bytes_q = (ndq * bytes_per_distance(dim, "int8")
               + (nd_q - ndq) * bytes_per_distance(dim, "fp32"))
    ratio = bytes_f / max(bytes_q, 1)
    assert ratio >= 3.0, (
        f"estimation bytes only {ratio:.2f}x down "
        f"(fp32 {bytes_f} vs int8 {bytes_q}, ndist_q {ndq}/{nd_q})"
    )
    print(f"quant_gate,0,ok recall={rec_q:.4f} (fp32 {rec_f:.4f}) "
          f"est_bytes_saved={ratio:.1f}x ndist_q={ndq}/{nd_q}")


def filter_gate() -> None:
    """Smoke gate for filtered & multi-tenant search (ISSUE 10): a
    mixed-selectivity trace over one attributed toy index.  Asserts the
    filter contract — the planner attributes its pre/post lowering choice
    (with the selectivity estimate) in ``explain()["filter"]``, every served
    row passes the predicate, filtered recall lands within the gate of the
    target under both lowerings — and the tenancy contract: every ticket is
    terminal, a saturating tenant is capped at its own admission quota, and
    the quiet tenant's worst-case latency stays inside its SLO deadline."""
    import numpy as np

    import jax.numpy as jnp

    from repro.api import SearchSpec
    from repro.filter import FilterSpec
    from repro.index import build_ada_index
    from repro.obs.audit import oracle_topk
    from repro.serve import (
        STATUS_REJECTED,
        TERMINAL_STATUSES,
        AdaServeScheduler,
        SchedulerConfig,
        SearchRequest,
        TenantSLO,
    )

    rng = np.random.default_rng(5)
    centers = rng.normal(0, 1, (8, 24))
    assign = rng.integers(0, 8, 600)
    data = (centers[assign]
            + 0.3 * rng.normal(0, 1, (600, 24))).astype(np.float32)
    idx = build_ada_index(data, k=5, target_recall=0.9, m=6,
                          ef_construction=40, ef_cap=64, num_samples=16)
    idx.attach_attributes(
        tenant=["noisy" if a % 2 else "quiet" for a in assign],
        categorical={"cluster": [str(a) for a in assign]},
        numeric={"date": 19000.0 + rng.uniform(0, 365, 600)},
    )

    # -- mixed-selectivity trace: one selective (pre) and one broad (post)
    # predicate; queries target valid rows (a tenant querying its own data)
    gate = 0.05
    cases = {}
    for name, filt, mode in (
        ("selective", FilterSpec(attrs={"cluster": ("0",)}), "oneshot"),
        ("broad", FilterSpec(ranges={"date": (19000.0, 19330.0)}), "routed"),
    ):
        plan = idx.plan(SearchSpec(k=5, target_recall=0.9, filter=filt,
                                   mode=mode))
        d = plan.explain()["filter"]
        mask = idx.attributes.compile_mask(filt)
        rows = np.flatnonzero(mask)
        queries = (data[rng.choice(rows, 16)]
                   + 0.05 * rng.normal(0, 1, (16, 24))).astype(np.float32)
        gt = oracle_topk(idx.graph, queries, idx.search_cfg,
                         valid=jnp.asarray(mask))
        ids = np.asarray(plan.search(queries).ids)
        assert mask[ids[ids >= 0]].all(), f"{name}: served an invalid row"
        recalls = []
        for row, g in zip(ids, np.asarray(gt)):
            g = g[g >= 0]
            recalls.append(
                len(set(row.tolist()) & set(g.tolist())) / max(len(g), 1))
        recall = float(np.mean(recalls))
        assert recall >= idx.target_recall - gate, (
            f"{name}: filtered recall {recall:.3f} < "
            f"{idx.target_recall} - {gate} under {d['mode']}-filter"
        )
        cases[name] = (d["mode"], float(d["selectivity_estimate"]), recall)
    assert cases["selective"][0] == "pre", cases
    assert cases["broad"][0] == "post", cases

    # -- tenancy: a saturating tenant hits its own quota, not the others'
    quota, slo_deadline = 3, 5.0
    sched = AdaServeScheduler(
        idx.router(),
        SchedulerConfig(fill=4, overload="ticket", tenants={
            "noisy": TenantSLO(max_inflight=quota),
            "quiet": TenantSLO(deadline_s=slo_deadline, target_recall=0.9),
        }),
        default_target_recall=idx.target_recall,
        version_probe=lambda: idx._graph_version,
    )
    sched.submit(SearchRequest(query=data[0]))
    sched.drain()  # warm the dispatch path: compile walls stay out of SLOs
    noisy_q = data[rng.integers(0, 600, 24)]
    quiet_q = iter(data[rng.integers(0, 600, 6)])
    tickets = {"noisy": [], "quiet": []}
    for i, q in enumerate(noisy_q):
        tickets["noisy"].append(
            sched.submit(SearchRequest(query=q, tenant="noisy")))
        if i % 4 == 0:
            tickets["quiet"].append(
                sched.submit(SearchRequest(query=next(quiet_q),
                                           tenant="quiet")))
    responses = sched.drain()
    by_uid = {r.ticket.uid: r for r in responses}
    assert all(r.status in TERMINAL_STATUSES for r in responses)
    noisy = [by_uid[t.uid] for t in tickets["noisy"]]
    quiet = [by_uid[t.uid] for t in tickets["quiet"]]
    n_shed = sum(r.status == STATUS_REJECTED for r in noisy)
    assert n_shed == len(noisy) - quota, (
        f"quota: {n_shed} shed of {len(noisy)} (max_inflight={quota})"
    )
    assert all(r.status != STATUS_REJECTED for r in quiet), (
        "saturating tenant consumed the quiet tenant's admission headroom"
    )
    quiet_worst = max(r.stats.e2e_s for r in quiet)
    assert quiet_worst <= slo_deadline, (
        f"quiet tenant p99 {quiet_worst:.3f}s blew its "
        f"{slo_deadline}s SLO under a saturating neighbor"
    )
    reqs = sched.metrics.as_dict()["requests"]
    assert reqs['{tenant="noisy"}'] == len(noisy)
    assert reqs['{tenant="quiet"}'] == len(quiet)
    print(f"filter_gate,0,ok pre_sel={cases['selective'][1]:.3f} "
          f"post_sel={cases['broad'][1]:.3f} "
          f"recall_pre={cases['selective'][2]:.3f} "
          f"recall_post={cases['broad'][2]:.3f} "
          f"noisy_shed={n_shed}/{len(noisy)} quiet_worst={quiet_worst:.3f}s")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, <60 s total, non-zero exit on exception")
    ap.add_argument("--only", default="", help="run a single module")
    ap.add_argument("--beam", type=str, nargs="+", default=None,
                    help="beam widths for the online beam sweep "
                         "(ints and/or 'auto', e.g. --beam 1 auto 8)")
    args = ap.parse_args()
    quick = not args.full

    from . import (
        bench_fdl,
        bench_recall_dist,
        bench_online,
        bench_offline,
        bench_router,
        bench_scheduler,
        bench_sensitivity,
        bench_updates,
        bench_ablation,
        bench_kernels,
        bench_frontier,
        roofline,
    )

    modules = {
        "fdl": bench_fdl,
        "recall_dist": bench_recall_dist,
        "online": bench_online,
        "router": bench_router,
        "scheduler": bench_scheduler,
        "offline": bench_offline,
        "sensitivity": bench_sensitivity,
        "updates": bench_updates,
        "ablation": bench_ablation,
        "kernels": bench_kernels,
        "frontier": bench_frontier,
        "roofline": roofline,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    if args.smoke and not args.only:
        for gate in (planner_gate, chaos_gate, obs_gate, churn_gate,
                     quant_gate, filter_gate):
            t0 = time.perf_counter()
            try:
                gate()
            except Exception:
                failures += 1
                print(f"{gate.__name__},0,ERROR", file=sys.stderr)
                traceback.print_exc()
            print(
                f"_module.{gate.__name__}.wall,"
                f"{(time.perf_counter() - t0) * 1e6:.0f},",
                flush=True,
            )
    for name, mod in modules.items():
        params = inspect.signature(mod.run).parameters
        kwargs = {"quick": quick}
        if args.smoke and "smoke" in params:
            kwargs["smoke"] = True
        if args.beam is not None and "beams" in params:
            kwargs["beams"] = tuple(args.beam)
        t0 = time.perf_counter()
        try:
            mod.run(**kwargs)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
        print(f"_module.{name}.wall,{(time.perf_counter() - t0) * 1e6:.0f},", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
