"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--only MODULE]
                                            [--beam B ...]

``--smoke`` runs every registered benchmark at toy sizes (each module's
``smoke=True`` branch slices its workload down and skips learned baselines)
so kernel-plumbing regressions surface in well under a minute; any exception
exits non-zero, making it usable as a CI gate.
"""
import argparse
import inspect
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, <60 s total, non-zero exit on exception")
    ap.add_argument("--only", default="", help="run a single module")
    ap.add_argument("--beam", type=str, nargs="+", default=None,
                    help="beam widths for the online beam sweep "
                         "(ints and/or 'auto', e.g. --beam 1 auto 8)")
    args = ap.parse_args()
    quick = not args.full

    from . import (
        bench_fdl,
        bench_recall_dist,
        bench_online,
        bench_offline,
        bench_router,
        bench_scheduler,
        bench_sensitivity,
        bench_updates,
        bench_ablation,
        bench_kernels,
        bench_frontier,
        roofline,
    )

    modules = {
        "fdl": bench_fdl,
        "recall_dist": bench_recall_dist,
        "online": bench_online,
        "router": bench_router,
        "scheduler": bench_scheduler,
        "offline": bench_offline,
        "sensitivity": bench_sensitivity,
        "updates": bench_updates,
        "ablation": bench_ablation,
        "kernels": bench_kernels,
        "frontier": bench_frontier,
        "roofline": roofline,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules.items():
        params = inspect.signature(mod.run).parameters
        kwargs = {"quick": quick}
        if args.smoke and "smoke" in params:
            kwargs["smoke"] = True
        if args.beam is not None and "beams" in params:
            kwargs["beams"] = tuple(args.beam)
        t0 = time.perf_counter()
        try:
            mod.run(**kwargs)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
        print(f"_module.{name}.wall,{(time.perf_counter() - t0) * 1e6:.0f},", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
