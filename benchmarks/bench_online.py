"""Figure 4/5/6: online search — Ada-ef vs static HNSW vs PiP vs LAET/DARTH.

Reports, per dataset: avg/P5/P1 recall, wall time per query batch, and the
paper's hardware-neutral work metric (distance computations/query).  Also
emits the adaptive-ef distribution (Fig 5), per-query latency-proxy CDF
deciles (Fig 6), and a **beam-width sweep** of the beamed base-layer loop
(iterations / ndist / ef_used / recall per beam), persisted to
``BENCH_online.json`` at the repo root to seed the perf trajectory.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.index import (
    SearchConfig,
    auto_beam,
    brute_force_topk_chunked,
    build_ada_index,
    fit_darth,
    fit_laet,
    prepare_queries,
    recall_at_k,
    search,
)
from .common import DATASETS, emit, recall_stats

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_online.json"


def _beam_sweep(idx, queries, gt, *, name: str, ef: int, beams) -> list:
    """Static-ef search at each beam width; equal ef => matched recall.

    A beam of ``"auto"`` resolves through :func:`repro.index.search.auto_beam`
    from the sweep's ef (the same policy the router's tier ladder uses)."""
    records = []
    for requested in beams:
        beam = auto_beam(ef) if str(requested) == "auto" else int(requested)
        cfg = dataclasses.replace(idx.search_cfg, beam=beam)
        r = search(idx.graph, jnp.asarray(queries), ef, cfg)  # compile
        jnp.asarray(r.ids).block_until_ready()
        t0 = time.perf_counter()
        r = search(idx.graph, jnp.asarray(queries), ef, cfg)
        jnp.asarray(r.ids).block_until_ready()
        dt = time.perf_counter() - t0
        rec = np.asarray(recall_at_k(r.ids, gt))
        records.append(
            {
                "beam": int(beam),
                "requested": str(requested),
                "ef": int(ef),
                "recall_at_10": float(rec.mean()),
                "iters_mean": float(np.asarray(r.iters).mean()),
                "ndist_mean": float(np.asarray(r.ndist).mean()),
                "ef_used_mean": float(np.asarray(r.ef_used).mean()),
                "us_per_query": dt / len(queries) * 1e6,
            }
        )
        emit(
            f"online.{name}.beam{requested}.ef{ef}",
            dt / len(queries) * 1e6,
            f"recall={rec.mean():.4f} iters={records[-1]['iters_mean']:.1f} "
            f"ndist={records[-1]['ndist_mean']:.0f} "
            f"ef_used={records[-1]['ef_used_mean']:.0f}",
        )
    return records


def run(datasets=("glove_like", "zipf_cluster"), k=10, target=0.95, quick=True,
        smoke=False, beams=None):
    out = {"workload": {}, "beam_sweep": {}}
    if beams is None:
        # default sweep; smoke keeps just the endpoints (an explicit ``beams``
        # argument is always honored as-is)
        beams = (1, 8) if smoke else (1, 2, 4, 8)
    if smoke:
        datasets = datasets[:1]
    for name in datasets:
        data, queries = DATASETS[name]()
        if smoke:
            data, queries = data[:1000], queries[:24]
        elif quick:
            data, queries = data[:6000], queries[:192]
        qp = prepare_queries(jnp.asarray(queries), "cos_dist")
        _, gt = brute_force_topk_chunked(qp, data, k=k)
        gt = jnp.asarray(gt)

        idx = build_ada_index(
            data, k=k, target_recall=target, m=8,
            ef_construction=60 if smoke else 100,
            ef_cap=160 if smoke else 400,
            num_samples=32 if smoke else 128,
        )

        # --- beam-width sweep (beamed frontier expansion) --------------------
        sweep = _beam_sweep(idx, queries, gt, name=name,
                            ef=min(10 * k, idx.search_cfg.ef_cap), beams=beams)
        out["beam_sweep"][name] = sweep
        out["workload"][name] = {"n": int(len(data)), "nq": int(len(queries)), "k": int(k)}
        # select by beam value, not sweep position (--beam order is honored as-is)
        b1 = min(sweep, key=lambda r: r["beam"])
        bmax = max(sweep, key=lambda r: r["beam"])
        if bmax["beam"] > b1["beam"] and abs(bmax["recall_at_10"] - b1["recall_at_10"]) <= 0.005:
            speedup = b1["iters_mean"] / max(bmax["iters_mean"], 1e-9)
            emit(f"online.{name}.beam_iter_speedup", 0.0,
                 f"beam{bmax['beam']}_vs_beam{b1['beam']}={speedup:.2f}x at matched recall")

        # --- Ada-ef ---------------------------------------------------------
        res = idx.query(queries)  # includes compile
        t0 = time.perf_counter()
        res = idx.query(queries)
        dt = time.perf_counter() - t0
        rec = np.asarray(recall_at_k(res.ids, gt))
        nd = np.asarray(res.ndist)
        emit(
            f"online.{name}.ada_ef",
            dt / len(queries) * 1e6,
            f"{recall_stats(rec)} ndist={nd.mean():.0f}",
        )
        efs = np.asarray(res.ef_used)
        emit(
            f"online.{name}.ada_ef.ef_dist",
            0.0,
            "p0/25/50/75/95/100=" + "/".join(str(int(x)) for x in np.percentile(efs, [0, 25, 50, 75, 95, 100])),
        )
        emit(
            f"online.{name}.ada_ef.latency_cdf",
            0.0,
            "ndist_deciles=" + "/".join(str(int(x)) for x in np.percentile(nd, np.arange(10, 101, 10))),
        )

        # --- static HNSW sweep (HNSWlib/FAISS reference behavior) ------------
        for ef in (k, 10 * k) if smoke else (k, 2 * k, 4 * k, 10 * k):
            r = idx.query_static(queries, ef)
            t0 = time.perf_counter()
            r = idx.query_static(queries, ef)
            dt = time.perf_counter() - t0
            rr = np.asarray(recall_at_k(r.ids, gt))
            emit(
                f"online.{name}.static_ef{ef}",
                dt / len(queries) * 1e6,
                f"{recall_stats(rr)} ndist={np.asarray(r.ndist).mean():.0f}",
            )

        # --- PiP -------------------------------------------------------------
        cap = idx.search_cfg.ef_cap
        cfgp = dataclasses.replace(idx.search_cfg, patience=30)
        r = search(idx.graph, jnp.asarray(queries), cap, cfgp)
        t0 = time.perf_counter()
        r = search(idx.graph, jnp.asarray(queries), cap, cfgp)
        dt = time.perf_counter() - t0
        rr = np.asarray(recall_at_k(r.ids, gt))
        emit(
            f"online.{name}.pip",
            dt / len(queries) * 1e6,
            f"{recall_stats(rr)} ndist={np.asarray(r.ndist).mean():.0f}",
        )

        # --- learned baselines (LAET / DARTH style; skipped in smoke) --------
        if not smoke:
            laet = fit_laet(idx.graph, data, cfg=idx.search_cfg, target_recall=target,
                            num_learn=256 if quick else 1000)
            r = laet.query(queries, target)
            rr = np.asarray(recall_at_k(jnp.asarray(np.asarray(r.ids)), gt))
            emit(
                f"online.{name}.laet",
                0.0,
                f"{recall_stats(rr)} ndist={np.asarray(r.ndist).mean():.0f}",
            )
            darth = fit_darth(idx.graph, data, cfg=idx.search_cfg,
                              num_learn=256 if quick else 1000)
            r = darth.query(queries, target)
            rr = np.asarray(recall_at_k(jnp.asarray(np.asarray(r.ids)), gt))
            emit(
                f"online.{name}.darth",
                0.0,
                f"{recall_stats(rr)} ndist={np.asarray(r.ndist).mean():.0f}",
            )

    out["meta"] = {"quick": bool(quick), "smoke": bool(smoke), "target_recall": float(target)}
    # smoke runs exercise the plumbing but must not clobber the tracked numbers,
    # and a quick run must not overwrite paper-scale (--full) numbers either
    path = BENCH_JSON.with_suffix(".smoke.json") if smoke else BENCH_JSON
    if not smoke and quick and path.exists():
        try:
            prev_full = json.loads(path.read_text()).get("meta", {}).get("quick") is False
        except (ValueError, OSError):
            prev_full = False
        if prev_full:
            path = BENCH_JSON.with_suffix(".quick.json")
    path.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    emit("online.bench_json", 0.0, f"wrote {path.name}")


if __name__ == "__main__":
    run()
