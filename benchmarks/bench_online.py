"""Figure 4/5/6: online search — Ada-ef vs static HNSW vs PiP vs LAET/DARTH.

Reports, per dataset: avg/P5/P1 recall, wall time per query batch, and the
paper's hardware-neutral work metric (distance computations/query).  Also
emits the adaptive-ef distribution (Fig 5) and per-query latency-proxy CDF
deciles (Fig 6).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.index import (
    SearchConfig,
    brute_force_topk_chunked,
    build_ada_index,
    fit_darth,
    fit_laet,
    prepare_queries,
    recall_at_k,
    search,
)
from .common import DATASETS, emit, recall_stats


def run(datasets=("glove_like", "zipf_cluster"), k=10, target=0.95, quick=True):
    for name in datasets:
        data, queries = DATASETS[name]()
        if quick:
            data, queries = data[:6000], queries[:192]
        qp = prepare_queries(jnp.asarray(queries), "cos_dist")
        _, gt = brute_force_topk_chunked(qp, data, k=k)
        gt = jnp.asarray(gt)

        idx = build_ada_index(
            data, k=k, target_recall=target, m=8, ef_construction=100,
            ef_cap=400, num_samples=128,
        )

        # --- Ada-ef ---------------------------------------------------------
        res = idx.query(queries)  # includes compile
        t0 = time.perf_counter()
        res = idx.query(queries)
        dt = time.perf_counter() - t0
        rec = np.asarray(recall_at_k(res.ids, gt))
        nd = np.asarray(res.ndist)
        emit(
            f"online.{name}.ada_ef",
            dt / len(queries) * 1e6,
            f"{recall_stats(rec)} ndist={nd.mean():.0f}",
        )
        efs = np.asarray(res.ef_used)
        emit(
            f"online.{name}.ada_ef.ef_dist",
            0.0,
            "p0/25/50/75/95/100=" + "/".join(str(int(x)) for x in np.percentile(efs, [0, 25, 50, 75, 95, 100])),
        )
        emit(
            f"online.{name}.ada_ef.latency_cdf",
            0.0,
            "ndist_deciles=" + "/".join(str(int(x)) for x in np.percentile(nd, np.arange(10, 101, 10))),
        )

        # --- static HNSW sweep (HNSWlib/FAISS reference behavior) ------------
        for ef in (k, 2 * k, 4 * k, 10 * k):
            r = idx.query_static(queries, ef)
            t0 = time.perf_counter()
            r = idx.query_static(queries, ef)
            dt = time.perf_counter() - t0
            rr = np.asarray(recall_at_k(r.ids, gt))
            emit(
                f"online.{name}.static_ef{ef}",
                dt / len(queries) * 1e6,
                f"{recall_stats(rr)} ndist={np.asarray(r.ndist).mean():.0f}",
            )

        # --- PiP -------------------------------------------------------------
        cfgp = SearchConfig(k=k, ef_cap=400, patience=30)
        r = search(idx.graph, jnp.asarray(queries), 400, cfgp)
        t0 = time.perf_counter()
        r = search(idx.graph, jnp.asarray(queries), 400, cfgp)
        dt = time.perf_counter() - t0
        rr = np.asarray(recall_at_k(r.ids, gt))
        emit(
            f"online.{name}.pip",
            dt / len(queries) * 1e6,
            f"{recall_stats(rr)} ndist={np.asarray(r.ndist).mean():.0f}",
        )

        # --- learned baselines (LAET / DARTH style) --------------------------
        laet = fit_laet(idx.graph, data, cfg=idx.search_cfg, target_recall=target,
                        num_learn=256 if quick else 1000)
        r = laet.query(queries, target)
        rr = np.asarray(recall_at_k(jnp.asarray(np.asarray(r.ids)), gt))
        emit(
            f"online.{name}.laet",
            0.0,
            f"{recall_stats(rr)} ndist={np.asarray(r.ndist).mean():.0f}",
        )
        darth = fit_darth(idx.graph, data, cfg=idx.search_cfg,
                          num_learn=256 if quick else 1000)
        r = darth.query(queries, target)
        rr = np.asarray(recall_at_k(jnp.asarray(np.asarray(r.ids)), gt))
        emit(
            f"online.{name}.darth",
            0.0,
            f"{recall_stats(rr)} ndist={np.asarray(r.ndist).mean():.0f}",
        )


if __name__ == "__main__":
    run()
