"""Figure 7: sensitivity to k and target recall."""
import jax.numpy as jnp
import numpy as np

from repro.index import brute_force_topk_chunked, build_ada_index, prepare_queries, recall_at_k
from .common import DATASETS, emit, recall_stats


def run(dataset="zipf_cluster", quick=True, smoke=False):
    data, queries = DATASETS[dataset]()
    if smoke:
        data, queries = data[:1000], queries[:24]
    elif quick:
        data, queries = data[:5000], queries[:128]
    for k in (10,) if smoke else (10, 50):
        qp = prepare_queries(jnp.asarray(queries), "cos_dist")
        _, gt = brute_force_topk_chunked(qp, data, k=k)
        gt = jnp.asarray(gt)
        idx = build_ada_index(data, k=k, target_recall=0.95, m=8,
                              ef_construction=60 if smoke else 100,
                              ef_cap=120 if smoke else 500,
                              num_samples=16 if smoke else 96)
        for target in (0.95,) if smoke else (0.9, 0.95, 0.99):
            res = idx.query(queries, target_recall=target)
            rec = np.asarray(recall_at_k(res.ids, gt))
            emit(
                f"sensitivity.{dataset}.k{k}.target{target}",
                0.0,
                f"{recall_stats(rec)} ndist={np.asarray(res.ndist).mean():.0f}",
            )


if __name__ == "__main__":
    run()
