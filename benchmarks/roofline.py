"""§Roofline: derive the three roofline terms per (arch x shape x mesh) from
the dry-run's compiled artifacts (results/dryrun_*.json).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

All extracted quantities (flops / bytes / collective bytes) are PER-CHIP
(the compiled module is the per-device SPMD program), so:

    compute    = flops_per_chip / 197e12          [s]
    memory     = bytes_per_chip / 819e9           [s]
    collective = coll_bytes_per_chip / 50e9       [s]

MODEL_FLOPS uses 6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode) with
N = active non-embedding params (MoE: top-k fraction); the ratio
MODEL_FLOPS / HLO_FLOPS exposes remat/redundancy waste.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from .common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

CHIPS = {"single": 256, "multi": 512}

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}


def model_flops(rec: Dict) -> float:
    n_active = rec["params"]["non_embedding"] * rec.get("active_fraction", 1.0)
    tokens = SHAPE_TOKENS[rec["shape"]]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * n_active * tokens


def bottleneck_advice(rec: Dict, dom: str) -> str:
    if dom == "compute":
        if rec.get("useful_ratio", 1) < 0.5:
            return "reduce recompute (remat policy / flash-bwd reuse)"
        return "increase per-chip arithmetic intensity (larger microbatch)"
    if dom == "memory":
        if rec["kind"] == "decode":
            return "KV-cache streaming dominates; quantize cache or widen batch"
        return "fuse elementwise chains / cut fp32 intermediates to bf16"
    return "reshard to cut collective volume (FSDP gather batching, EP locality)"


def analyze(paths=("results/dryrun_single.json", "results/dryrun_multi.json"),
            out_md="results/roofline.md") -> List[Dict]:
    rows = []
    for path in paths:
        if not os.path.exists(path):
            continue
        for rec in json.load(open(path)):
            if rec.get("status") != "ok":
                continue
            flops = rec.get("weighted_flops") or rec.get("flops", 0.0)
            byts = rec.get("weighted_bytes") or rec.get("bytes_accessed", 0.0)
            coll = rec.get("collectives", {}).get("total", 0)
            t_c = flops / PEAK_FLOPS
            t_m = byts / HBM_BW
            t_n = coll / LINK_BW
            dom = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                      key=lambda kv: kv[1])[0]
            mf = model_flops(rec)
            chips = CHIPS[rec["mesh"]]
            ratio = (mf / chips) / max(flops, 1.0)
            step_time = max(t_c, t_m, t_n)
            mfu = (mf / chips / max(step_time, 1e-12)) / PEAK_FLOPS
            row = {
                "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                "kind": rec["kind"],
                "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
                "dominant": dom, "model_flops": mf, "useful_ratio": ratio,
                "roofline_mfu": mfu,
                "mem_gb": rec["memory"]["temp_size_in_bytes"] / 1e9
                + rec["memory"]["argument_size_in_bytes"] / 1e9,
            }
            row["advice"] = bottleneck_advice({**rec, **row}, dom)
            rows.append(row)

    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | dominant | "
        "useful ratio | roofline MFU | per-chip GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_mfu']:.3f} | {r['mem_gb']:.1f} |"
        )
    os.makedirs(os.path.dirname(out_md) or ".", exist_ok=True)
    with open(out_md, "w") as f:
        f.write("\n".join(lines) + "\n")
    return rows


def run(quick=True):
    rows = analyze()
    if not rows:
        emit("roofline", 0.0, "no dry-run results found (run repro.launch.dryrun first)")
        return
    for r in rows:
        if r["mesh"] == "single":
            emit(
                f"roofline.{r['arch']}.{r['shape']}",
                max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
                f"dom={r['dominant']} useful={r['useful_ratio']:.2f} mfu={r['roofline_mfu']:.3f}",
            )
    n_dom = {}
    for r in rows:
        n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    emit("roofline.summary", 0.0, f"dominant_terms={n_dom} table=results/roofline.md")


if __name__ == "__main__":
    run()
