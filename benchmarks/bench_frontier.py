"""Per-query ``vmap(while_loop)`` vs the batch-hoisted search loop.

The serving claim behind ISSUE 3: the per-query vmap base-layer loop makes
the MXU see B tiny frontier matvecs and (through JAX's while-loop batching
rule) copies every query's full state — including the ``(n+1,)`` visited
bitmap — through a ``select`` every iteration.  The batch-hoisted loop runs
the same algorithm as one batched ``lax.while_loop`` with masked writes and a
cross-query frontier contraction, so its advantage grows with the batch size
and the corpus size.  This bench sweeps B ∈ {8, 32, 128} on a fixed smoke
workload and persists the trajectory to ``BENCH_kernels.json``.

Substrate: an approximate kNN graph (anchor-bucketed 14-NN + 2 random
long-range edges per node, NSW-style) — a real HNSW build at this corpus
size would dominate the bench wall-clock, and the loop mechanics under test
are graph-agnostic.  Both paths return bit-identical results (asserted), so
recall@10 is equal by construction and reported once.

Also records interpret-mode parity of the cross-query fused kernel vs the
``ref.py`` oracle at a bench shape, so kernel numerics regressions surface in
the same tracked file.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SearchConfig
from repro.index import (
    brute_force_topk_chunked,
    prepare_database,
    prepare_queries,
    recall_at_k,
    search,
)
from repro.index.search import DeviceGraph
from repro.kernels import ops, ref
from repro.plan import resolve_backend
from .common import emit, zipf_cluster

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_kernels.json"


def _nsw_graph(
    data: np.ndarray,
    *,
    m_knn: int = 14,
    m_long: int = 2,
    num_anchors: int = 96,
    seed: int = 0,
):
    """Approximate-kNN graph + random long-range edges: a navigable,
    connected base layer built in ~2 s at n=30k (exact 30k x 30k brute-force
    kNN took ~20 s, which alone blew the smoke gate's budget; the incremental
    HNSW builder takes minutes).  Points are assigned to their nearest of
    ``num_anchors`` sampled anchors and kNN is computed within each anchor
    bucket — near-exact on clustered data — then ``m_long`` random edges per
    node restore global connectivity.  The upper layer reuses the first half
    of each adjacency row."""
    rng = np.random.default_rng(seed)
    n = len(data)
    vp = np.asarray(prepare_database(jnp.asarray(data), "cos_dist"))
    anchors = vp[rng.choice(n, num_anchors, replace=False)]
    asim = vp @ anchors.T
    # multi-probe: each point's kNN candidates come from the union of its
    # top-2 anchor cells, so neighbors split across a cell boundary (large
    # Zipf-head clusters span several cells) are still found
    top2 = np.argpartition(-asim, 1, axis=1)[:, :2]
    adj = np.empty((n, m_knn), np.int32)
    for a in range(num_anchors):
        rows = np.nonzero(top2[:, 0] == a)[0]
        if len(rows) == 0:
            continue
        pool = np.nonzero((top2 == a).any(axis=1))[0]
        sims = vp[rows] @ vp[pool].T
        sims[rows[:, None] == pool[None, :]] = -np.inf  # no self-edges
        take = min(m_knn, len(pool) - 1)
        if take > 0:
            nb = np.argpartition(-sims, take - 1, axis=1)[:, :take]
            adj[rows, :take] = pool[nb]
        # undersized pools: pad with random nodes (a bench substrate; the
        # random edges double as extra long-range links)
        if take < m_knn:
            adj[rows, take:] = rng.integers(0, n, (len(rows), m_knn - take))
    adj = np.concatenate(
        [adj, rng.integers(0, n, (n, m_long)).astype(np.int32)], axis=1
    )
    base_adj = jnp.asarray(adj)
    # entry: most central point under the metric (medoid-ish, one matvec)
    entry = int(np.argmax(vp @ vp.mean(axis=0)))
    return DeviceGraph(
        base_adj=base_adj,
        upper_adj=base_adj[None, :, : (m_knn + m_long) // 2],
        entry=jnp.asarray(entry, jnp.int32),
        vectors=jnp.asarray(vp),
        alive=jnp.ones((n,), bool),
    )


def _timed_search(g, queries, ef, cfg, repeats=5):
    res = search(g, queries, ef, cfg)  # compile
    jax.block_until_ready(res.ids)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = search(g, queries, ef, cfg)
        jax.block_until_ready(res.ids)
        # min over repeats: robust to host load spikes, which at these batch
        # shapes dwarf the run-to-run device variance
        best = min(best, time.perf_counter() - t0)
    return res, best


def _kernel_parity(seed: int = 0):
    """Interpret-mode max error of the cross-query kernel vs the jnp oracle."""
    rng = np.random.default_rng(seed)
    n, d, b, f = 2000, 64, 16, 64
    vec = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32))
    ids = rng.integers(0, n, (b, f)).astype(np.int32)
    ids[:, ::4] = -1
    ids[0] = -1  # a finished query's row
    ids = jnp.asarray(ids)
    want = ref.frontier_ref(ids, q, vec)
    got = ops.frontier_keys_batch(ids, q, vec, use_kernel=True, interpret=True)
    fin = jnp.isfinite(want)
    return float(jnp.max(jnp.abs(jnp.where(fin, got - want, 0.0))))


def run(k=10, ef=64, quick=True, smoke=False, batch_sizes=(8, 32, 128)):
    # one fixed workload: the tracked numbers ARE the smoke workload (the
    # loop-mechanics gap needs a serving-scale corpus, not a paper-scale one)
    n, d, nq = 30000, 48, 128
    data, queries = zipf_cluster(n=n, d=d, nq=nq)

    t0 = time.perf_counter()
    g = _nsw_graph(data)
    build_s = time.perf_counter() - t0
    emit("frontier.graph_build", build_s * 1e6, f"n={n} d={d} anchor_knn")

    qp = jnp.asarray(queries)
    _, gt = brute_force_topk_chunked(prepare_queries(qp, "cos_dist"), data, k=k)
    gt = jnp.asarray(gt)

    out = {
        "workload": {"n": n, "d": d, "k": k, "ef": ef, "graph": "anchor_knn14+rand2"},
        "loop": {},
    }
    for b in batch_sizes:
        qb = qp[:b]
        cfg_v = SearchConfig(k=k, ef_cap=ef)
        cfg_h = SearchConfig(k=k, ef_cap=ef, batch_hoisted=True)
        res_v, dt_v = _timed_search(g, qb, ef, cfg_v)
        res_h, dt_h = _timed_search(g, qb, ef, cfg_h)
        ids_equal = bool(
            (np.asarray(res_v.ids) == np.asarray(res_h.ids)).all()
        )
        # the smoke gate exits non-zero on exceptions: a loop-equivalence
        # regression must fail the run, not just flip a JSON field
        assert ids_equal, f"batch-hoisted != per-query ids at B={b}"
        rec = float(np.asarray(recall_at_k(res_v.ids, gt[:b])).mean())
        speedup = dt_v / max(dt_h, 1e-9)
        out["loop"][f"B{b}"] = {
            "per_query_ms": dt_v * 1e3,
            "batch_hoisted_ms": dt_h * 1e3,
            "speedup": speedup,
            "ids_equal": ids_equal,
            "recall_at_10": rec,
            "iters_mean": float(np.asarray(res_v.iters).mean()),
            "ndist_mean": float(np.asarray(res_v.ndist).mean()),
        }
        emit(
            f"frontier.loop.B{b}",
            dt_h / b * 1e6,
            f"per_query={dt_v * 1e3:.1f}ms hoisted={dt_h * 1e3:.1f}ms "
            f"speedup={speedup:.2f}x ids_equal={ids_equal} recall={rec:.3f}",
        )

    err = _kernel_parity()
    out["xq_kernel_interpret_maxerr"] = err
    emit("frontier.xq_kernel", 0.0, f"interpret_maxerr={err:.2e}")

    # what the planner's capability probe would dispatch on this host — the
    # loop/kernel numbers above are attributable to a concrete plan decision
    backend, use_kernel, note = resolve_backend("auto", False)
    out["planner_backend"] = {
        "resolved": backend, "use_kernel": use_kernel, "note": note,
    }
    emit("frontier.planner_backend", 0.0, f"{backend} ({note})")

    out["meta"] = {"quick": bool(quick), "smoke": bool(smoke)}
    # smoke exercises the plumbing but must not clobber tracked numbers (the
    # workload is identical, but smoke runs on loaded CI hosts whose timings
    # are not worth tracking); *.smoke.json is gitignored
    path = BENCH_JSON.with_suffix(".smoke.json") if smoke else BENCH_JSON
    path.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    emit("frontier.bench_json", 0.0, f"wrote {path.name}")
    return out


if __name__ == "__main__":
    run()
