"""Tables 2-3: offline computation time and memory, Ada-ef vs LAET/DARTH."""
import numpy as np

from repro.core import stats_nbytes
from repro.index import build_ada_index, build_index, fit_darth, fit_laet
from .common import DATASETS, emit, timed


def run(datasets=("glove_like", "zipf_cluster"), k=10, quick=True, smoke=False):
    if smoke:
        datasets = datasets[:1]
    for name in datasets:
        data, _ = DATASETS[name]()
        if smoke:
            data = data[:1000]
        elif quick:
            data = data[:5000]
        # HNSW construction reference
        import time

        t0 = time.perf_counter()
        host = build_index(data, m=8, ef_construction=100)
        t_index = time.perf_counter() - t0
        emit(f"offline.{name}.hnsw_build", t_index * 1e6, f"n={len(data)}")

        t0 = time.perf_counter()
        idx = build_ada_index(data, k=k, target_recall=0.95, m=8,
                              ef_construction=100,
                              ef_cap=160 if smoke else 400,
                              num_samples=32 if smoke else 128,
                              host_index=host)
        t_ada = idx.timings
        emit(
            f"offline.{name}.ada_ef",
            t_ada.total_s * 1e6,
            f"stats={t_ada.stats_s:.2f}s samp={t_ada.sample_s:.2f}s "
            f"table={t_ada.ef_table_s:.2f}s frac_of_index={t_ada.total_s / t_index:.3f}",
        )
        mem_ada = stats_nbytes(idx.stats) + idx.table.nbytes() + idx.sample_gt.nbytes
        emit(f"offline.{name}.ada_ef_mem", 0.0,
             f"bytes={mem_ada} index_bytes={host.freeze().nbytes()}")

        # learned baselines offline cost (skipped in smoke: MLP training only)
        if smoke:
            continue
        laet = fit_laet(idx.graph, data, cfg=idx.search_cfg, num_learn=256 if quick else 1000)
        t = laet.offline_seconds
        total = sum(t.values())
        emit(f"offline.{name}.laet", total * 1e6,
             f"lvec_gt={t['lvec_gt_s']:.2f}s tdata={t['tdata_s']:.2f}s train={t['train_s']:.2f}s "
             f"x{total / max(t_ada.total_s, 1e-9):.1f} vs ada")
        darth = fit_darth(idx.graph, data, cfg=idx.search_cfg, num_learn=256 if quick else 1000)
        t = darth.offline_seconds
        total = sum(t.values())
        emit(f"offline.{name}.darth", total * 1e6,
             f"lvec_gt={t['lvec_gt_s']:.2f}s tdata={t['tdata_s']:.2f}s train={t['train_s']:.2f}s "
             f"x{total / max(t_ada.total_s, 1e-9):.1f} vs ada")


if __name__ == "__main__":
    run()
