"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall-time on
CPU is NOT meaningful for TPU perf — this bench validates numerics at bench
shapes and reports the jnp-reference throughput as the CPU baseline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from .common import emit, timed

RNG = np.random.default_rng(0)


def run(quick=True, smoke=False):
    # distance: ef-search frontier shape
    q = jnp.asarray(RNG.normal(0, 1, (256, 512)).astype(np.float32))
    v = jnp.asarray(RNG.normal(0, 1, (4096, 512)).astype(np.float32))
    ref_fn = jax.jit(lambda a, b: ref.distance_ref(a, b))
    _, dt = timed(lambda: jax.block_until_ready(ref_fn(q, v)), repeats=5)
    got = ops.pairwise_distance(q, v, use_kernel=True, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_fn(q, v))))
    emit("kernels.distance.256x4096x512", dt * 1e6, f"interpret_maxerr={err:.2e}")

    # frontier: beam-batched expansion shape (beam=8 x M0=32 slots per query)
    b, f, d, n = (16, 64, 100, 2000) if smoke else (64, 256, 512, 20000)
    vec = jnp.asarray(RNG.normal(0, 1, (n, d)).astype(np.float32))
    fq = jnp.asarray(RNG.normal(0, 1, (b, d)).astype(np.float32))
    fids = RNG.integers(0, n, (b, f)).astype(np.int32)
    fids[:, ::4] = -1  # typical visited/padded masking density
    fids = jnp.asarray(fids)
    ref_fn = jax.jit(lambda i, qq, vv: ref.frontier_ref(i, qq, vv))
    _, dt = timed(lambda: jax.block_until_ready(ref_fn(fids, fq, vec)), repeats=5)
    got = ops.frontier_keys(fids, fq, vec, use_kernel=True, interpret=True)
    want = ref_fn(fids, fq, vec)
    fin = jnp.isfinite(want)
    err = float(jnp.max(jnp.abs(jnp.where(fin, got - want, 0.0))))
    emit(f"kernels.frontier.{b}x{f}x{d}", dt * 1e6, f"interpret_maxerr={err:.2e}")
    if smoke:
        return

    sigma = RNG.normal(0, 1, (1536, 1536)).astype(np.float32)
    sigma = sigma @ sigma.T / 1536
    qq = jnp.asarray(RNG.normal(0, 1, (64, 1536)).astype(np.float32))
    ref_fn = jax.jit(lambda a, s: ref.qform_ref(a, s))
    _, dt = timed(lambda: jax.block_until_ready(ref_fn(qq, jnp.asarray(sigma))), repeats=5)
    got = ops.quadratic_form(qq, jnp.asarray(sigma), use_kernel=True, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_fn(qq, jnp.asarray(sigma))) / jnp.abs(ref_fn(qq, jnp.asarray(sigma)))))
    emit("kernels.qform.64x1536", dt * 1e6, f"interpret_relerr={err:.2e}")

    d = jnp.asarray(np.sort(RNG.normal(1, 0.1, (128, 1088))).astype(np.float32))
    t = jnp.asarray(np.sort(RNG.normal(0.9, 0.05, (128, 10)), axis=1).astype(np.float32))
    w = jnp.asarray((100 * np.exp(-np.arange(10))).astype(np.float32))
    valid = jnp.ones((128, 1088), jnp.float32)
    ref_fn = jax.jit(lambda *a: ref.binscore_ref(*a))
    _, dt = timed(lambda: jax.block_until_ready(ref_fn(d, t, w, valid)), repeats=5)
    got = ops.binscore_raw(d, t, w, valid, use_kernel=True, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_fn(d, t, w, valid))))
    emit("kernels.binscore.128x1088x10", dt * 1e6, f"interpret_maxerr={err:.2e}")

    b, h, hk, s, dd = 1, 8, 2, 1024, 64
    qa = jnp.asarray(RNG.normal(0, 1, (b, h, s, dd)).astype(np.float32))
    ka = jnp.asarray(RNG.normal(0, 1, (b, hk, s, dd)).astype(np.float32))
    va = jnp.asarray(RNG.normal(0, 1, (b, hk, s, dd)).astype(np.float32))
    ref_fn = jax.jit(lambda *a: ref.mha_ref(*a, causal=True))
    _, dt = timed(lambda: jax.block_until_ready(ref_fn(qa, ka, va)), repeats=3)
    got = ops.flash_attention(qa, ka, va, causal=True, use_kernel=True, bq=256, bk=256, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref_fn(qa, ka, va))))
    emit("kernels.flash_attn.1x8x1024x64", dt * 1e6, f"interpret_maxerr={err:.2e}")


if __name__ == "__main__":
    run()
