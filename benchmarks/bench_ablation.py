"""Tables 8-10: ablations — |D| hops, sample count, decay function."""
import jax.numpy as jnp
import numpy as np

from repro.core import EstimatorConfig
from repro.index import (
    AdaEfConfig,
    brute_force_topk_chunked,
    build_ada_index,
    build_index,
    prepare_queries,
    recall_at_k,
)
from .common import DATASETS, emit, recall_stats


def run(dataset="zipf_cluster", k=10, quick=True, smoke=False):
    data, queries = DATASETS[dataset]()
    if smoke:
        data, queries = data[:1000], queries[:24]
    elif quick:
        data, queries = data[:5000], queries[:128]
    cap = 120 if smoke else 400
    ns = 16 if smoke else 96
    qp = prepare_queries(jnp.asarray(queries), "cos_dist")
    _, gt = brute_force_topk_chunked(qp, data, k=k)
    gt = jnp.asarray(gt)
    host = build_index(data, m=8, ef_construction=60 if smoke else 100)

    # Table 8: |D| hops
    for hops in (2,) if smoke else (1, 2, 3):
        idx = build_ada_index(data, k=k, target_recall=0.95, m=8, ef_cap=cap,
                              num_samples=ns, host_index=host,
                              ada_cfg=AdaEfConfig(hops=hops))
        res = idx.query(queries)
        rec = np.asarray(recall_at_k(res.ids, gt))
        emit(f"ablation.hops{hops}", idx.timings.ef_table_s * 1e6,
             f"{recall_stats(rec)} ndist={np.asarray(res.ndist).mean():.0f}")

    # Table 9: sample count
    for num in (24,) if smoke else (50, 200, 500):
        idx = build_ada_index(data, k=k, target_recall=0.95, m=8, ef_cap=cap,
                              num_samples=num, host_index=host)
        res = idx.query(queries)
        rec = np.asarray(recall_at_k(res.ids, gt))
        emit(f"ablation.samples{num}",
             (idx.timings.sample_s + idx.timings.ef_table_s) * 1e6,
             f"{recall_stats(rec)} ndist={np.asarray(res.ndist).mean():.0f}")

    # Table 10: decay function
    for decay in ("exp",) if smoke else ("none", "linear", "exp"):
        idx = build_ada_index(data, k=k, target_recall=0.95, m=8, ef_cap=cap,
                              num_samples=ns, host_index=host,
                              ada_cfg=AdaEfConfig(estimator=EstimatorConfig(decay=decay)))
        res = idx.query(queries)
        rec = np.asarray(recall_at_k(res.ids, gt))
        emit(f"ablation.decay_{decay}", 0.0,
             f"{recall_stats(rec)} ndist={np.asarray(res.ndist).mean():.0f}")


if __name__ == "__main__":
    run()
