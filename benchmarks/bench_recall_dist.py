"""Figure 1: recall distribution of static-ef HNSW search (motivating example).

Shows the paper's two observations: (i) different datasets need different ef
for the same recall; (ii) a large fraction of queries sit far above/below the
average (over/under-searching)."""
import jax.numpy as jnp
import numpy as np

from repro.index import brute_force_topk_chunked, build_ada_index, prepare_queries, recall_at_k
from .common import DATASETS, emit


def run(datasets=("glove_like", "openai_like"), k=10, quick=True, smoke=False):
    if smoke:
        datasets = datasets[:1]
    for name in datasets:
        data, queries = DATASETS[name]()
        if smoke:
            data, queries = data[:1000], queries[:24]
        elif quick:
            data, queries = data[:5000], queries[:192]
        qp = prepare_queries(jnp.asarray(queries), "cos_dist")
        _, gt = brute_force_topk_chunked(qp, data, k=k)
        gt = jnp.asarray(gt)
        idx = build_ada_index(data, k=k, target_recall=0.95, m=8,
                              ef_construction=60 if smoke else 100,
                              ef_cap=120 if smoke else 400,
                              num_samples=16 if smoke else 64)
        for ef in (k, 2 * k):
            res = idx.query_static(queries, ef)
            rec = np.asarray(recall_at_k(res.ids, gt))
            hist, _ = np.histogram(rec, bins=np.linspace(0, 1.0001, 11))
            emit(
                f"recall_dist.{name}.ef{ef}",
                0.0,
                f"avg={rec.mean():.3f} hist10={'/'.join(map(str, hist))}",
            )


if __name__ == "__main__":
    run()
