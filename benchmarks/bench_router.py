"""Routed vs monolithic serving dispatch on a skewed query mix.

The serving claim behind the router (ISSUE 2): per-query ef varies wildly, so
executing a batch as one fused ``adaptive_search`` makes every query pay for
the slowest one and drags full-capacity merges through easy queries.  This
benchmark builds a skewed mix (75% easy near-duplicate queries, 25% hard
far-field queries), then compares:

- ``mono``          — the fused Algorithm 2 batch (the PR-1 serving path),
- ``routed_exact``  — router with lossless estimation + fixed beam: results
                      are per-query identical to mono (sanity: id match frac),
- ``routed``        — router with a capped estimation budget (est_lmax):
                      equal measured recall at fewer distance computations
                      and a fraction of the wall-clock,
- ``routed_margin`` — same + ef_margin headroom: recall *above* mono for a
                      modest ndist premium,
- ``routed_beam1``  — the routed config with beam forced to 1 on every tier,
                      to show auto-tuned beams never lose recall.

Latency is reported as p50/p99 over per-query ndist (the hardware-neutral
latency proxy) plus measured batch wall-clock.  Results persist to
``BENCH_serve.json`` at the repo root (``.smoke.json`` in smoke runs).

Since PR 3 the lossy ``routed*`` configs look estimates up in the
estimation-matched ef table (``RouterConfig.est_matched_table``, on by
default through ``AdaEfIndex.router``).  That removes the truncation bias
that used to shrink estimates, so routed ndist rises back to the monolithic
level (``ndist_saved`` can go slightly negative and the hard-query ndist
tail widens) in exchange for recall matching mono without any ``ef_margin``:
the pre-PR-3 numbers traded recall (d_recall ~ -0.002) for that ndist
saving.  Set ``est_matched_table=False`` to benchmark the old trade.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import RouterConfig, SearchSpec, SpecOverrides
from repro.index import (
    brute_force_topk_chunked,
    build_ada_index,
    prepare_queries,
    recall_at_k,
)
from .common import DATASETS, emit

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_serve.json"


def _skewed_queries(data: np.ndarray, nq: int, easy_frac: float, seed: int):
    """Serving-shaped mix: mostly near-duplicate (easy) queries + a far-field
    hard tail.  Returns shuffled queries and the easy-query mask."""
    rng = np.random.default_rng(seed)
    d = data.shape[1]
    n_easy = int(easy_frac * nq)
    easy = data[rng.choice(len(data), n_easy)] + 0.02 * rng.normal(
        0, 1, (n_easy, d)
    ).astype(np.float32)
    hard = rng.normal(0, 1.1, (nq - n_easy, d)).astype(np.float32)
    q = np.concatenate([easy, hard]).astype(np.float32)
    mask = np.zeros(nq, bool)
    mask[:n_easy] = True
    perm = rng.permutation(nq)
    return q[perm], mask[perm]


def _timed_mono(plan, queries):
    res = plan.search(queries)
    jax.block_until_ready(res.ids)
    t0 = time.perf_counter()
    res = plan.search(queries)
    jax.block_until_ready(res.ids)
    return jax.tree_util.tree_map(np.asarray, res), time.perf_counter() - t0


def _timed_routed(plan, queries):
    plan.search(queries)  # compile every tier it will hit
    t0 = time.perf_counter()
    res, stats = plan.search(queries, with_stats=True)
    return res, stats, time.perf_counter() - t0


def _routed_plan(idx, target, rcfg=None):
    """Lower one routed spec; ``rcfg`` pins the router policy through the
    overrides escape hatch (the benchmark sweeps estimation budgets)."""
    overrides = SpecOverrides() if rcfg is None else SpecOverrides(router=rcfg)
    return idx.plan(
        SearchSpec(target_recall=target, mode="routed", overrides=overrides)
    )


def _record(name, res, gt, wall_s, nq, extra=None):
    nd = np.asarray(res.ndist)
    rec = {
        "recall_at_10": float(np.asarray(recall_at_k(jnp.asarray(res.ids), gt)).mean()),
        "ndist_total": int(nd.sum()),
        "ndist_p50": float(np.percentile(nd, 50)),
        "ndist_p99": float(np.percentile(nd, 99)),
        "wall_ms": wall_s * 1e3,
        "us_per_query": wall_s / nq * 1e6,
    }
    rec.update(extra or {})
    emit(
        f"router.{name}",
        rec["us_per_query"],
        f"recall={rec['recall_at_10']:.4f} ndist={rec['ndist_total']} "
        f"ndist_p50/p99={rec['ndist_p50']:.0f}/{rec['ndist_p99']:.0f}",
    )
    return rec


def run(k=10, target=0.95, quick=True, smoke=False):
    n, nq = (1000, 48) if smoke else (6000, 256)
    data, _ = DATASETS["zipf_cluster"]()
    data = data[:n]
    queries, easy_mask = _skewed_queries(data, nq, easy_frac=0.75, seed=7)
    qp = prepare_queries(jnp.asarray(queries), "cos_dist")
    _, gt = brute_force_topk_chunked(qp, data, k=k)
    gt = jnp.asarray(gt)

    idx = build_ada_index(
        data, k=k, target_recall=target, m=8,
        ef_construction=60 if smoke else 100,
        ef_cap=160 if smoke else 400,
        num_samples=32 if smoke else 128,
    )
    out = {
        "workload": {
            "n": n, "nq": nq, "k": k, "easy_frac": float(easy_mask.mean()),
            "ef_cap": idx.search_cfg.ef_cap,
        }
    }

    # ---- monolithic fused adaptive_search --------------------------------
    mono_plan = idx.plan(SearchSpec(target_recall=target))
    mono, mono_wall = _timed_mono(mono_plan, queries)
    out["mono"] = _record("mono", mono, gt, mono_wall, nq)

    # ---- routed, lossless estimation + fixed beam: per-query identical ----
    plan_ex = _routed_plan(idx, target, RouterConfig(beam_mode="fixed"))
    res_ex, st_ex, wall_ex = _timed_routed(plan_ex, queries)
    match = float((res_ex.ids == mono.ids).all(axis=1).mean())
    out["routed_exact"] = _record(
        "routed_exact", res_ex, gt, wall_ex, nq,
        {"id_match_frac": match, "stats": st_ex.as_dict()},
    )
    emit("router.routed_exact.id_match", 0.0, f"frac={match:.3f}")

    # ---- routed, capped estimation budget (the serving configuration) -----
    est_lmax = 32 if smoke else 64
    configs = {
        "routed": RouterConfig(est_lmax=est_lmax),
        "routed_margin": RouterConfig(est_lmax=est_lmax, ef_margin=1.25),
        "routed_beam1": RouterConfig(est_lmax=est_lmax, beam_mode="fixed"),
    }
    for name, rcfg in configs.items():
        plan = _routed_plan(idx, target, rcfg)
        res, st, wall = _timed_routed(plan, queries)
        tiers = [(t.ef, t.beam, t.count) for t in st.tiers]
        out[name] = _record(
            name, res, gt, wall, nq,
            {"stats": st.as_dict(), "tiers": tiers,
             "explain": plan.explain()["estimation"]},
        )
        emit(f"router.{name}.tiers", 0.0,
             " ".join(f"ef{e}b{b}:{c}" for e, b, c in tiers)
             + f" padding_waste={st.padding_waste:.2f}")

    # ---- the acceptance comparisons --------------------------------------
    d_nd = 1.0 - out["routed"]["ndist_total"] / max(out["mono"]["ndist_total"], 1)
    d_wall = out["mono"]["wall_ms"] / max(out["routed"]["wall_ms"], 1e-9)
    d_rec = out["routed"]["recall_at_10"] - out["mono"]["recall_at_10"]
    emit(
        "router.routed_vs_mono", 0.0,
        f"ndist_saved={d_nd:.3f} wall_speedup={d_wall:.2f}x d_recall={d_rec:+.4f}",
    )
    auto_vs_b1 = out["routed"]["recall_at_10"] - out["routed_beam1"]["recall_at_10"]
    emit("router.auto_beam_vs_beam1", 0.0, f"d_recall={auto_vs_b1:+.4f}")
    out["comparison"] = {
        "ndist_saved_frac": d_nd,
        "wall_speedup": d_wall,
        "d_recall_routed_vs_mono": d_rec,
        "d_recall_auto_vs_beam1": auto_vs_b1,
    }

    # ---- quantized estimation tier (int8 traversal + fp32 re-rank) --------
    from repro.quant import bytes_per_distance

    plan_q = idx.plan(
        SearchSpec(target_recall=target, mode="routed", precision="int8",
                   overrides=SpecOverrides(router=RouterConfig(est_lmax=est_lmax)))
    )
    res_q, st_q, wall_q = _timed_routed(plan_q, queries)
    nd_tot = int(np.asarray(res_q.ndist).sum())
    ndq_tot = int(np.asarray(res_q.ndist_q).sum())
    dim = data.shape[1]
    bytes_q = (ndq_tot * bytes_per_distance(dim, "int8")
               + (nd_tot - ndq_tot) * bytes_per_distance(dim, "fp32"))
    bytes_f = out["routed"]["ndist_total"] * bytes_per_distance(dim, "fp32")
    out["quant"] = _record(
        "quant_int8", res_q, gt, wall_q, nq,
        {
            "stats": st_q.as_dict(),
            "ndist_q_total": ndq_tot,
            "traversal_bytes": bytes_q,
            "fp32_routed_bytes": bytes_f,
            "bytes_saved_frac": 1.0 - bytes_q / max(bytes_f, 1),
            "d_recall_vs_routed": None,  # filled below
            "precision": plan_q.explain()["precision"],
        },
    )
    out["quant"]["d_recall_vs_routed"] = (
        out["quant"]["recall_at_10"] - out["routed"]["recall_at_10"]
    )
    emit(
        "router.quant_vs_routed", 0.0,
        f"d_recall={out['quant']['d_recall_vs_routed']:+.4f} "
        f"bytes_saved={out['quant']['bytes_saved_frac']:.3f} "
        f"ndist_q={ndq_tot}/{nd_tot}",
    )

    # ---- filtered search (predicate masks, selectivity-aware lowering) ----
    from repro.filter import FilterSpec
    from repro.obs.audit import oracle_topk

    frng = np.random.default_rng(13)
    idx.attach_attributes(
        tenant=frng.choice(["acme", "globex"], n, p=[0.25, 0.75]).tolist(),
        numeric={"date": 19000.0 + frng.uniform(0, 365, n)},
    )
    out["filtered"] = {}
    for name, filt in (
        ("tenant", FilterSpec(tenant="acme")),                      # -> pre
        ("date", FilterSpec(ranges={"date": (19000.0, 19300.0)})),  # -> post
    ):
        mask = idx.attributes.compile_mask(filt)
        rows = np.flatnonzero(mask)
        fq = (data[frng.choice(rows, nq)] + 0.02 * frng.normal(
            0, 1, (nq, data.shape[1]))).astype(np.float32)
        gt_f = jnp.asarray(oracle_topk(
            idx.graph, fq, idx.search_cfg, valid=jnp.asarray(mask)))
        plan = idx.plan(SearchSpec(target_recall=target, mode="routed",
                                   filter=filt))
        fd = plan.explain()["filter"]
        res, st, wall = _timed_routed(plan, fq)
        ids = np.asarray(res.ids)
        assert mask[ids[ids >= 0]].all(), f"filtered[{name}]: invalid row"
        out["filtered"][name] = _record(
            f"filtered_{name}", res, gt_f, wall, nq,
            {
                "stats": st.as_dict(),
                "mode": fd["mode"],
                "selectivity_true": float(mask.mean()),
                "selectivity_estimate": fd["selectivity_estimate"],
                "ef_inflation": fd["ef_inflation"],
            },
        )
        emit(
            f"router.filtered_{name}.plan", 0.0,
            f"mode={fd['mode']} sel~{fd['selectivity_estimate']:.3f} "
            f"(true {float(mask.mean()):.3f}) "
            f"ef_inflation={fd['ef_inflation']:.2f}",
        )
    assert out["filtered"]["tenant"]["mode"] == "pre"
    assert out["filtered"]["date"]["mode"] == "post"

    out["meta"] = {"quick": bool(quick), "smoke": bool(smoke), "target_recall": float(target)}
    # smoke exercises the plumbing but must not clobber tracked numbers, and a
    # quick run must not overwrite paper-scale (--full) numbers either
    path = BENCH_JSON.with_suffix(".smoke.json") if smoke else BENCH_JSON
    if not smoke and quick and path.exists():
        try:
            prev_full = json.loads(path.read_text()).get("meta", {}).get("quick") is False
        except (ValueError, OSError):
            prev_full = False
        if prev_full:
            path = BENCH_JSON.with_suffix(".quick.json")
    path.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    emit("router.bench_json", 0.0, f"wrote {path.name}")


if __name__ == "__main__":
    run()
